from .specs import AttnMode, ShardCtx, attn_mode_for, spec_for_param
