"""Sharding policy: logical axes → mesh axes, and the ShardCtx helper.

Mesh axes: ``("pod",) data, model`` — batch-like logical axes map to
``("pod","data")`` (or ``("data",)`` single-pod); weight/activation feature
axes map to ``"model"``.

Per-arch attention modes (DESIGN.md §4):
  HEADS — shard q-heads over model (requires num_heads % model_size == 0)
  QSEQ  — shard query seq over model, gather KV (small), for odd head counts
  KVSEQ — decode: shard the KV cache's sequence dim over model, sharded
          softmax (flash-decode-style combine is what XLA lowers this to)

All constraints are *advisory* (``with_sharding_constraint``); on a 1-device
CPU mesh (smoke tests) ``ShardCtx.null()`` turns them into no-ops.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardCtx", "AttnMode", "attn_mode_for", "param_spec_rules",
           "spec_for_param"]


@dataclasses.dataclass(frozen=True)
class AttnMode:
    HEADS = "heads"
    QSEQ = "qseq"
    KVSEQ = "kvseq"


@dataclasses.dataclass
class ShardCtx:
    """Carries the mesh + axis names through model code."""

    mesh: Optional[Mesh] = None
    dp_axes: Tuple[str, ...] = ("data",)        # ("pod","data") multi-pod
    model_axis: Optional[str] = "model"
    attn_mode: str = AttnMode.HEADS
    shard_batch: bool = True      # False for batch=1 decode (long_500k)
    # residual-stream sharding between blocks: "d" = hidden dim over model
    # (Megatron TP default); "seq" = sequence over model (Megatron-SP) —
    # pre-norms run fully sharded and the partitioner pairs the layer-exit
    # psum with the layer-entry gather as reduce-scatter + all-gather
    # (half the bytes of all-reduce). Train/prefill only (decode has S=1).
    residual: str = "d"

    @staticmethod
    def null() -> "ShardCtx":
        return ShardCtx(mesh=None, model_axis=None)

    @property
    def model_size(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def dp(self):
        """Partition entry for batch dims (None when not sharding batch)."""
        if self.mesh is None or not self.shard_batch:
            return None
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    @property
    def tp(self):
        return self.model_axis if self.mesh is not None else None

    def constrain(self, x, *spec):
        """``with_sharding_constraint`` if a mesh is active, else identity."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    def res(self, x):
        """Constrain a (B, S, D) residual-stream activation."""
        if self.mesh is None:
            return x
        if self.residual == "seq" and x.shape[1] % max(self.model_size, 1) \
                == 0 and x.shape[1] > 1:
            return self.constrain(x, self.dp, self.tp, None)
        return self.constrain(x, self.dp, None, self.tp)

    def sharding(self, *spec) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(*spec))


def attn_mode_for(num_heads: int, num_kv_heads: int, model_size: int,
                  kind: str, batch: int) -> str:
    """Pick the attention sharding mode for (arch, shape, mesh)."""
    if model_size == 1:
        return AttnMode.HEADS
    if kind == "decode":
        # decode: Q is one token; shard the big thing — the KV cache.
        # Heads-sharding the cache requires kv_heads % model == 0 (rare);
        # KVSEQ always works and is the flash-decode layout.
        if num_kv_heads % model_size == 0 and batch > 1:
            return AttnMode.HEADS
        return AttnMode.KVSEQ
    if num_heads % model_size == 0:
        return AttnMode.HEADS
    return AttnMode.QSEQ


# ---------------------------------------------------------------------------
# Parameter partition rules, keyed by parameter-name suffix. Shapes listed
# for reference; `model` shards the axis marked M.
# ---------------------------------------------------------------------------

_RULES = [
    # name-suffix,         spec builder (dp unused for params)
    ("embed",              lambda tp: P(tp, None)),        # (V, D): vocab
    ("pos_embed",          lambda tp: P(None, None)),
    ("unembed",            lambda tp: P(None, tp)),        # (D, V)
    ("wq",                 lambda tp: P(None, tp)),        # (D, H*dh)
    ("wk",                 lambda tp: P(None, tp)),
    ("wv",                 lambda tp: P(None, tp)),
    ("wo",                 lambda tp: P(tp, None)),        # (H*dh, D)
    ("w_gate",             lambda tp: P(None, tp)),        # (D, F)
    ("w_up",               lambda tp: P(None, tp)),
    ("w_down",             lambda tp: P(tp, None)),        # (F, D)
    ("router",             lambda tp: P(None, None)),      # (D, E)
    ("expert_gate",        lambda tp: P(tp, None, None)),  # (E, D, Fe)
    ("expert_up",          lambda tp: P(tp, None, None)),
    ("expert_down",        lambda tp: P(tp, None, None)),  # (E, Fe, D)
    ("in_proj",            lambda tp: P(None, tp)),        # mamba (D, 2*din)
    ("conv_w",             lambda tp: P(tp, None)),        # (din, width)
    ("conv_b",             lambda tp: P(tp,)),
    ("dt_proj",            lambda tp: P(None, tp)),        # (rank, din)
    ("x_proj",             lambda tp: P(tp, None)),        # (din, rank+2N)
    ("A_log",              lambda tp: P(tp, None)),        # (din, N)
    ("D_skip",             lambda tp: P(tp,)),
    ("out_proj",           lambda tp: P(tp, None)),        # (din, D)
    # rwkv6: time-mix runs replicated over model (40 heads % 16 != 0 —
    # see DESIGN.md §4 and the roofline hillclimb); channel-mix shards.
    ("rwkv_r",             lambda tp: P(None, None)),
    ("rwkv_k",             lambda tp: P(None, None)),
    ("rwkv_v",             lambda tp: P(None, None)),
    ("rwkv_g",             lambda tp: P(None, None)),
    ("rwkv_w",             lambda tp: P(None, None)),
    ("rwkv_o",             lambda tp: P(None, None)),
    ("rwkv_mix",           lambda tp: P(None,)),
    ("rwkv_decay_mix",     lambda tp: P(None, None)),
    ("rwkv_u",             lambda tp: P(None, None)),
    ("scale",              lambda tp: P(None,)),           # norms
    ("bias",               lambda tp: P(None,)),
]


def spec_for_param(path: str, tp: Optional[str]):
    """Partition spec for a parameter, by name suffix; replicated default."""
    name = path.rsplit("/", 1)[-1]
    for suffix, fn in _RULES:
        if name == suffix:
            return fn(tp)
    return P()


def param_spec_rules():
    return list(_RULES)
