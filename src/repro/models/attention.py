"""GQA attention with three sharding modes and a KV cache.

Modes (see ``repro.sharding.specs.attn_mode_for``):

* HEADS — q/k/v sharded over heads on the ``model`` axis.
* QSEQ  — query sequence sharded over ``model``; KV gathered. Used when
  head counts don't divide the model-axis size (whisper 8H, llama3.2 24H).
* KVSEQ — decode only: the KV cache's *sequence* axis sharded over
  ``model``; the softmax over a sharded axis lowers to the flash-decode
  partial-max/partial-sum collective combine.

The math is written once (plain einsums + masked softmax); modes differ
only in the sharding constraints applied to the intermediates, so GSPMD
does the partitioning. ``impl="pallas"`` swaps in the flash-attention
kernel for the unsharded core (kernels/flash_attention.py).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.specs import AttnMode
from .layers import dense_init, rope

__all__ = ["init_attn", "attn_apply", "init_kv_cache", "decode_attn_apply"]


def init_attn(key, d: int, num_heads: int, num_kv_heads: int, head_dim: int,
              dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, num_heads * head_dim), dtype=dtype),
        "wk": dense_init(ks[1], (d, num_kv_heads * head_dim), dtype=dtype),
        "wv": dense_init(ks[2], (d, num_kv_heads * head_dim), dtype=dtype),
        "wo": dense_init(ks[3], (num_heads * head_dim, d), dtype=dtype),
    }


def _causal_mask(sq: int, sk: int, window: Optional[int],
                 q_offset: int = 0) -> jnp.ndarray:
    qi = jnp.arange(sq)[:, None] + q_offset
    ki = jnp.arange(sk)[None, :]
    ok = ki <= qi
    if window is not None:
        ok &= ki > qi - window
    return ok  # (sq, sk)


def attn_apply(p: dict, x: jnp.ndarray, ctx, cfg, *,
               kv_x: Optional[jnp.ndarray] = None,
               causal: bool = True,
               positions: Optional[jnp.ndarray] = None,
               impl: str = "ref") -> jnp.ndarray:
    """Full (training/prefill) attention. x: (B, S, D).

    ``kv_x`` switches to cross-attention (keys/values from the encoder
    memory; never causal)."""
    a = cfg.attn
    B, S, D = x.shape
    src = x if kv_x is None else kv_x
    Sk = src.shape[1]
    H, KV, dh = a.num_heads, a.num_kv_heads, a.head_dim
    groups = H // KV

    q = (x @ p["wq"]).reshape(B, S, H, dh)
    k = (src @ p["wk"]).reshape(B, Sk, KV, dh)
    v = (src @ p["wv"]).reshape(B, Sk, KV, dh)

    if a.rope_theta is not None and kv_x is None:
        pos = jnp.arange(S) if positions is None else positions
        q = rope(q, pos, a.rope_theta)
        k = rope(k, pos, a.rope_theta)

    mode = ctx.attn_mode
    if mode == AttnMode.HEADS:
        q = ctx.constrain(q, ctx.dp, None, ctx.tp, None)
        k = ctx.constrain(k, ctx.dp, None,
                          ctx.tp if KV % max(ctx.model_size, 1) == 0 else None,
                          None)
        v = ctx.constrain(v, ctx.dp, None,
                          ctx.tp if KV % max(ctx.model_size, 1) == 0 else None,
                          None)
    elif mode == AttnMode.QSEQ:
        q = ctx.constrain(q, ctx.dp, ctx.tp, None, None)
        k = ctx.constrain(k, ctx.dp, None, None, None)
        v = ctx.constrain(v, ctx.dp, None, None, None)

    if impl == "pallas" and kv_x is None:
        from ..kernels.ops import flash_attention
        o = flash_attention(q, k, v, causal=causal,
                            window=a.sliding_window)
    else:
        # grouped-query: fold groups into the head axis of scores
        kq = jnp.repeat(k, groups, axis=2)      # (B, Sk, H, dh)
        vq = jnp.repeat(v, groups, axis=2)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kq) / jnp.sqrt(dh)
        scores = scores.astype(jnp.float32)
        if causal and kv_x is None:
            mask = _causal_mask(S, Sk, a.sliding_window)
            scores = jnp.where(mask[None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", w, vq)

    o = o.reshape(B, S, H * dh)
    if mode == AttnMode.HEADS:
        o = ctx.constrain(o, ctx.dp, None, ctx.tp)
    out = o @ p["wo"]
    return ctx.constrain(out, ctx.dp, None, ctx.tp)


# ---------------------------------------------------------------------------
# KV cache + single-token decode
# ---------------------------------------------------------------------------

def init_kv_cache(batch: int, max_len: int, num_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> dict:
    return {
        "k": jnp.zeros((batch, max_len, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, num_kv_heads, head_dim), dtype),
    }


def decode_attn_apply(p: dict, x: jnp.ndarray, cache: dict,
                      cache_len: jnp.ndarray, ctx, cfg,
                      static_cache: bool = False
                      ) -> Tuple[jnp.ndarray, dict]:
    """One-token decode. x: (B, 1, D); cache k/v: (B, Smax, KV, dh).

    ``static_cache=True`` (dry-run serve_step over a full cache) skips the
    dynamic-update-slice so the cache stays read-only; the fresh token's
    k/v still participate via a concat-free correction term.
    """
    a = cfg.attn
    B, _, D = x.shape
    H, KV, dh = a.num_heads, a.num_kv_heads, a.head_dim
    groups = H // KV
    Smax = cache["k"].shape[1]

    q = (x @ p["wq"]).reshape(B, 1, H, dh)
    k_new = (x @ p["wk"]).reshape(B, 1, KV, dh)
    v_new = (x @ p["wv"]).reshape(B, 1, KV, dh)
    if a.rope_theta is not None:
        pos = jnp.full((B, 1), cache_len, dtype=jnp.int32)
        q = rope(q, pos, a.rope_theta)
        k_new = rope(k_new, pos, a.rope_theta)

    if not static_cache:
        cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k_new.astype(cache["k"].dtype), cache_len, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v_new.astype(cache["v"].dtype), cache_len, axis=1),
        }

    mode = ctx.attn_mode
    seq_shard = ctx.tp if mode == AttnMode.KVSEQ else None
    head_shard = ctx.tp if mode == AttnMode.HEADS else None
    kc = ctx.constrain(cache["k"], ctx.dp, seq_shard, head_shard, None)
    vc = ctx.constrain(cache["v"], ctx.dp, seq_shard, head_shard, None)

    kq = jnp.repeat(kc, groups, axis=2).astype(x.dtype)   # (B, Smax, H, dh)
    vq = jnp.repeat(vc, groups, axis=2).astype(x.dtype)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kq) / jnp.sqrt(dh)
    scores = scores.astype(jnp.float32)
    positions = jnp.arange(Smax)[None, None, None, :]
    # static: cache holds tokens [0, cache_len) — the new token is handled
    # by the online-softmax correction below. dynamic: the new token was
    # just written at index cache_len, so include it.
    valid = positions < cache_len if static_cache else positions < cache_len + 1
    if a.sliding_window is not None:
        valid = valid & (positions > cache_len - a.sliding_window)
    scores = jnp.where(valid, scores, -1e30)
    # sharded softmax over Smax => flash-decode style collective combine
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, vq)
    if static_cache:
        # Include the fresh token's (k, v), which is not in the read-only
        # cache: exact online-softmax combine of the cached result with the
        # single new score. All correction tensors are (B, H, 1, 1).
        s_new = (jnp.einsum(
            "bqhd,bkhd->bhqk", q,
            jnp.repeat(k_new, groups, axis=2).astype(x.dtype))
            / jnp.sqrt(dh)).astype(jnp.float32)
        m_old = jnp.max(scores, axis=-1, keepdims=True)
        l_old = jnp.sum(jnp.exp(scores - m_old), axis=-1, keepdims=True)
        m = jnp.maximum(m_old, s_new)
        alpha = jnp.exp(m_old - m) * l_old        # old mass
        beta = jnp.exp(s_new - m)                 # new-token mass
        c_old = (alpha / (alpha + beta))          # (B, H, 1, 1)
        c_new = (beta / (alpha + beta))
        # reshape coefficients to broadcast over o: (B, 1, H, 1)
        c_old = jnp.transpose(c_old, (0, 2, 1, 3)).astype(x.dtype)
        c_new = jnp.transpose(c_new, (0, 2, 1, 3)).astype(x.dtype)
        v_newg = jnp.repeat(v_new, groups, axis=2).astype(x.dtype)
        o = o * c_old + v_newg * c_new

    o = o.reshape(B, 1, H * dh)
    out = o @ p["wo"]
    return ctx.constrain(out, ctx.dp, None, ctx.tp), cache
