"""Basic layers: norms, embeddings, RoPE, MLP. Pure-JAX (no flax): params
are nested dicts whose leaf names drive the sharding rules in
``repro.sharding.specs``."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["init_norm", "norm_apply", "init_embedding", "init_mlp",
           "mlp_apply", "rope", "dense_init"]


def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (the common transformer default)."""
    if scale is None:
        scale = shape[0] ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def init_norm(d: int, kind: str, dtype=jnp.float32) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p: dict, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    """RMSNorm or LayerNorm depending on whether a bias is present.
    Statistics in float32 for stability regardless of activation dtype."""
    xf = x.astype(jnp.float32)
    if "bias" in p:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def init_embedding(key, vocab: int, d: int, tie: bool, max_pos: int = 0,
                   learned_pos: bool = False, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"embed": dense_init(k1, (vocab, d), scale=d ** -0.5, dtype=dtype)}
    if not tie:
        p["unembed"] = dense_init(k2, (d, vocab), dtype=dtype)
    if learned_pos:
        p["pos_embed"] = dense_init(k3, (max_pos, d), scale=0.02, dtype=dtype)
    return p


def init_mlp(key, d: int, d_ff: int, act: str, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d, d_ff), dtype=dtype),
         "w_down": dense_init(ks[1], (d_ff, d), dtype=dtype)}
    if act == "swiglu":
        p["w_gate"] = dense_init(ks[2], (d, d_ff), dtype=dtype)
    return p


def mlp_apply(p: dict, x: jnp.ndarray, act: str, ctx) -> jnp.ndarray:
    """(B, S, D) -> (B, S, D); hidden sharded over the model axis."""
    h = x @ p["w_up"]
    if act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    h = ctx.constrain(h, ctx.dp, None, ctx.tp)
    return h @ p["w_down"]


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (B, S, H, Dh); positions: (B, S) or (S,)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
