"""Mixture-of-Experts with token-choice top-k routing and capacity.

TPU-native design (DESIGN.md §4): experts are sharded over the ``model``
mesh axis via ``shard_map``; tokens stay local to their data shard and are
*replicated* across the model axis, so the dispatch (argsort + gather +
scatter) is entirely local — the only collective is one psum combining the
per-shard expert outputs. This avoids the (tokens × experts × capacity)
dense dispatch tensor (intractable at Kimi-K2 scale) and avoids sorting a
sharded axis (collective-heavy under GSPMD).

Routing: softmax router, top-k experts per token, per-expert capacity
``C = ceil(T_local * k / E_global * capacity_factor)``; overflow tokens are
dropped (token-choice with capacity, as in DeepSeekMoE/Switch). Shared
experts (DeepSeekMoE) run as a dense SwiGLU on every token, hidden sharded
over ``model``. Aux load-balance loss follows Switch: ``E * Σ_e f_e · p_e``.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .layers import dense_init

__all__ = ["init_moe", "moe_apply"]


def init_moe(key, d: int, cfg_moe, act: str, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    E, fe = cfg_moe.num_experts, cfg_moe.d_expert
    p = {
        "router": dense_init(ks[0], (d, E), dtype=jnp.float32),
        "expert_up": _expert_init(ks[1], E, d, fe, dtype),
        "expert_down": _expert_init(ks[2], E, fe, d, dtype),
    }
    if act == "swiglu":
        p["expert_gate"] = _expert_init(ks[3], E, d, fe, dtype)
    if cfg_moe.num_shared_experts:
        fs = cfg_moe.d_shared * cfg_moe.num_shared_experts
        p["shared"] = {
            "w_up": dense_init(ks[4], (d, fs), dtype=dtype),
            "w_down": dense_init(ks[5], (fs, d), dtype=dtype),
        }
        if act == "swiglu":
            p["shared"]["w_gate"] = dense_init(
                jax.random.fold_in(ks[4], 1), (d, fs), dtype=dtype)
    return p


def _expert_init(key, E: int, din: int, dout: int, dtype):
    keys = jax.random.split(key, E)
    return jax.vmap(lambda k: dense_init(k, (din, dout), dtype=dtype))(keys)


def _local_moe(x, router_w, gate_w, up_w, down_w, *, k: int, E: int,
               capacity: int, act: str, model_size: int,
               model_axis: Optional[str], shard_idx,
               scatter_output: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-device MoE. x: (T, D) local tokens (replicated over model axis);
    expert weights: (E_local, ...) — this shard's slice. Returns
    (out (T, D) partial — needs psum over model, aux_loss scalar)."""
    T, D = x.shape
    E_local = up_w.shape[0]
    lo = shard_idx * E_local

    logits = (x.astype(jnp.float32) @ router_w)            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                   # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss (computed identically on every shard — replicated):
    # f_e = fraction of tokens routed to e (top-1..k), p_e = mean prob.
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)    # (T, k, E)
    f = onehot.sum(axis=(0, 1)) / (T * k)
    pbar = probs.mean(axis=0)
    aux = E * jnp.sum(f * pbar)

    # ---- local dispatch: keep only assignments to this shard's experts
    flat_e = topi.reshape(T * k)                           # global expert ids
    flat_w = topw.reshape(T * k)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    local_e = flat_e - lo
    is_local = (local_e >= 0) & (local_e < E_local)
    sort_key = jnp.where(is_local, local_e, E_local)       # non-local last
    order = jnp.argsort(sort_key)
    e_sorted = sort_key[order]
    tok_sorted = flat_tok[order]
    w_sorted = jnp.where(is_local[order], flat_w[order], 0.0)

    # position of each assignment within its expert group
    group_start = jnp.searchsorted(e_sorted, jnp.arange(E_local + 1),
                                   side="left")
    pos = jnp.arange(T * k) - group_start[e_sorted]
    keep = (e_sorted < E_local) & (pos < capacity)
    slot = jnp.where(keep, e_sorted * capacity + pos, E_local * capacity)

    # gather tokens -> expert buffers (E_local, C, D); dropped -> dummy row
    xb = x[tok_sorted]                                     # (T*k, D)
    buf = jnp.zeros((E_local * capacity + 1, D), x.dtype).at[slot].set(
        xb, mode="drop")
    buf = buf[:-1].reshape(E_local, capacity, D)

    # ---- expert FFN (grouped matmul; this is the kernels/moe_gmm target)
    h = jnp.einsum("ecd,edf->ecf", buf, up_w)
    if act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, gate_w)) * h
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("ecf,efd->ecd", h, down_w)              # (E_local, C, D)

    # ---- combine: weighted scatter-add back to tokens
    y_flat = y.reshape(E_local * capacity, D)
    contrib = jnp.where(keep[:, None], y_flat[jnp.minimum(slot, E_local * capacity - 1)]
                        * w_sorted[:, None].astype(y.dtype), 0.0)
    out = jnp.zeros((T, D), y.dtype).at[tok_sorted].add(contrib)

    if model_axis is not None:
        if scatter_output:
            # reduce-scatter into the d-sharded residual stream: each model
            # shard keeps its D/ms slice — half the ICI bytes of the
            # all-reduce whose result would immediately be re-sliced anyway
            out = jax.lax.psum_scatter(out, model_axis, scatter_dimension=1,
                                       tiled=True)
        else:
            out = jax.lax.psum(out, model_axis)
    return out, aux


def moe_apply(p: dict, x: jnp.ndarray, ctx, cfg,
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    m = cfg.moe
    B, S, D = x.shape
    E = m.num_experts
    ms = max(ctx.model_size, 1)
    assert E % ms == 0, f"{E} experts not divisible by model={ms}"
    act = cfg.mlp_act

    if ctx.mesh is None or ms == 1:
        T = B * S
        capacity = _capacity(T, m.experts_per_token, E, m.capacity_factor)
        out, aux = _local_moe(
            x.reshape(T, D), p["router"], p.get("expert_gate"),
            p["expert_up"], p["expert_down"], k=m.experts_per_token, E=E,
            capacity=capacity, act=act, model_size=1, model_axis=None,
            shard_idx=0)
        out = out.reshape(B, S, D)
    else:
        dp_axes = ctx.dp_axes if ctx.shard_batch else ()
        dp_total = 1
        for a in dp_axes:
            dp_total *= ctx.mesh.shape[a]
        T_local = (B // dp_total) * S
        capacity = _capacity(T_local, m.experts_per_token, E,
                             m.capacity_factor)
        dp_spec = None if not dp_axes else (
            dp_axes if len(dp_axes) > 1 else dp_axes[0])
        model_axis = ctx.model_axis

        # 2D expert-weight sharding (kimi-scale): weights additionally
        # sharded over the dp axes for STORAGE (FSDP/ZeRO-3-style) and
        # gathered per layer before use. Per-device storage drops by |dp|.
        two_d = m.shard_experts_2d and bool(ctx.dp_axes)
        w_dp = ((ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0])
                if two_d else None)

        scatter = D % ms == 0

        def mapped(xl, rw, gw, uw, dw):
            xl2 = xl.reshape(-1, D)
            idx = jax.lax.axis_index(model_axis)
            if two_d:
                uw = jax.lax.all_gather(uw, ctx.dp_axes, axis=2, tiled=True)
                dw = jax.lax.all_gather(dw, ctx.dp_axes, axis=1, tiled=True)
                if gw.ndim:
                    gw = jax.lax.all_gather(gw, ctx.dp_axes, axis=2,
                                            tiled=True)
            out, aux = _local_moe(
                xl2, rw, gw, uw, dw, k=m.experts_per_token, E=E,
                capacity=capacity, act=act, model_size=ms,
                model_axis=model_axis, shard_idx=idx,
                scatter_output=scatter)
            # aux is identical across model shards (same tokens/router);
            # average across data shards so the P() out-spec is truthful.
            if dp_axes:
                aux = jax.lax.pmean(aux, dp_axes)
            out_shape = xl.shape if not scatter else \
                (xl.shape[0], xl.shape[1], xl.shape[2] // ms)
            return out.reshape(out_shape), aux

        up_spec = P(model_axis, None, w_dp)
        out_spec = P(dp_spec, None, model_axis) if scatter \
            else P(dp_spec, None, None)
        out, aux = shard_map(
            mapped, mesh=ctx.mesh,
            in_specs=(P(dp_spec, None, None), P(None, None),
                      up_spec if "expert_gate" in p else P(),
                      up_spec, P(model_axis, w_dp, None)),
            out_specs=(out_spec, P()),
            check_rep=False,
        )(x, p["router"], p.get("expert_gate", jnp.zeros((), x.dtype)),
          p["expert_up"], p["expert_down"])

    if "shared" in p:
        sh = p["shared"]
        h = x @ sh["w_up"]
        if act == "swiglu":
            h = jax.nn.silu(x @ sh["w_gate"]) * h
        else:
            h = jax.nn.gelu(h)
        h = ctx.constrain(h, ctx.dp, None, ctx.tp)
        out = out + h @ sh["w_down"]
    return out, aux * m.router_aux_weight


def _capacity(T_local: int, k: int, E: int, factor: float) -> int:
    c = int(math.ceil(T_local * k / E * factor))
    return max(8, min(c, T_local))
