"""Top-level model: ``build_model(cfg) -> Model`` with init/apply/loss/
prefill/decode — the public modelling API used by the trainer, the serving
engine, and the dry-run launcher."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.specs import ShardCtx
from .layers import init_embedding, init_norm, norm_apply
from .transformer import (init_stage, init_stage_cache, stage_apply,
                          stage_decode)

__all__ = ["Model", "build_model"]


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # ---------------- params ----------------
    def init_params(self, key) -> dict:
        cfg = self.cfg
        dt = _dtype(cfg)
        keys = jax.random.split(key, len(cfg.stages) + 3)
        params: Dict[str, Any] = {
            "embed": init_embedding(
                keys[0], cfg.vocab_size, cfg.d_model, cfg.tie_embeddings,
                max_pos=cfg.max_seq_len if cfg.pos_embed == "learned" else 0,
                learned_pos=cfg.pos_embed == "learned", dtype=dt),
            "final_norm": init_norm(cfg.d_model, cfg.norm, dt),
            "stages": [init_stage(keys[i + 1], cfg, s)
                       for i, s in enumerate(cfg.stages)],
        }
        if cfg.encoder is not None:
            ek = jax.random.split(keys[-1], len(cfg.encoder.stages) + 1)
            params["encoder"] = {
                "stages": [init_stage(ek[i], cfg, s)
                           for i, s in enumerate(cfg.encoder.stages)],
                "final_norm": init_norm(cfg.d_model, cfg.norm, dt),
            }
        return params

    # ---------------- embedding helpers ----------------
    def _embed(self, params, tokens, ctx, offset: int = 0):
        cfg = self.cfg
        x = params["embed"]["embed"][tokens]            # (B, S, D)
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        if cfg.pos_embed == "learned":
            S = tokens.shape[1]
            pos = params["embed"]["pos_embed"][offset:offset + S]
            x = x + pos[None]
        return ctx.res(x)

    def _logits(self, params, x, ctx):
        cfg = self.cfg
        x = norm_apply(params["final_norm"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = x @ params["embed"]["embed"].T
        else:
            logits = x @ params["embed"]["unembed"]
        return ctx.constrain(logits, ctx.dp, None, ctx.tp)

    def _encode(self, params, frames, ctx, impl="ref"):
        """Audio encoder: frames (B, F, D) stub embeddings -> memory."""
        cfg = self.cfg
        x = frames.astype(_dtype(cfg))
        if cfg.pos_embed == "learned":
            F = x.shape[1]
            x = x + params["embed"]["pos_embed"][:F][None]
        enc_ctx = dataclasses.replace(ctx, attn_mode="qseq") \
            if ctx.mesh is not None else ctx
        for sp, s in zip(params["encoder"]["stages"], cfg.encoder.stages):
            x, _ = stage_apply(sp, x, s, enc_ctx, cfg, impl=impl)
        return norm_apply(params["encoder"]["final_norm"], x, cfg.norm_eps)

    # ---------------- forward / loss ----------------
    def apply(self, params, tokens, ctx: Optional[ShardCtx] = None, *,
              extra_embeds=None, frames=None, remat: bool = False,
              impl: str = "ref"):
        """Forward pass -> (logits, aux_loss).

        ``extra_embeds``: (B, N, D) VLM patch embeddings, prepended.
        ``frames``: (B, F, D) audio-stub embeddings for enc-dec models.
        """
        cfg = self.cfg
        ctx = ctx or ShardCtx.null()
        x = self._embed(params, tokens, ctx)
        n_prefix = 0
        if extra_embeds is not None:
            x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
            n_prefix = extra_embeds.shape[1]
            x = ctx.constrain(x, ctx.dp, None, ctx.tp)
        memory = None
        if cfg.encoder is not None:
            assert frames is not None, "enc-dec model needs frames"
            memory = self._encode(params, frames, ctx, impl=impl)
        aux_total = jnp.zeros((), jnp.float32)
        for sp, s in zip(params["stages"], cfg.stages):
            x, aux = stage_apply(sp, x, s, ctx, cfg, memory=memory,
                                 remat=remat, impl=impl)
            aux_total = aux_total + aux
        if n_prefix:
            x = x[:, n_prefix:]
        return self._logits(params, x, ctx), aux_total

    def loss(self, params, batch: dict, ctx: Optional[ShardCtx] = None, *,
             remat: bool = False, impl: str = "ref",
             example_weights=None) -> Tuple[jnp.ndarray, dict]:
        """Next-token CE (+ MoE aux + z-loss). ``example_weights`` (B,)
        realizes the m-sync participation mask (core/sync_engine)."""
        ctx = ctx or ShardCtx.null()
        logits, aux = self.apply(
            params, batch["tokens"], ctx,
            extra_embeds=batch.get("patch_embeds"),
            frames=batch.get("frames"), remat=remat, impl=impl)
        labels = batch["labels"]                        # (B, S)
        lg = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)             # (B, S)
        gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
        nll = lse - gold
        w = batch.get("loss_mask")
        w = jnp.ones_like(nll) if w is None else w.astype(jnp.float32)
        if example_weights is not None:
            w = w * example_weights[:, None].astype(jnp.float32)
        denom = jnp.maximum(w.sum(), 1.0)
        ce = (nll * w).sum() / denom
        zloss = 1e-4 * ((lse ** 2) * w).sum() / denom
        total = ce + zloss + aux
        return total, {"ce": ce, "z_loss": zloss, "aux_loss": aux}

    # ---------------- serving ----------------
    def init_cache(self, batch: int, max_len: int) -> dict:
        cfg = self.cfg
        return {
            "stages": [init_stage_cache(cfg, s, batch, max_len)
                       for s in cfg.stages],
            "len": jnp.zeros((), jnp.int32),
        }

    def prefill(self, params, tokens, ctx: Optional[ShardCtx] = None, *,
                frames=None, extra_embeds=None, impl: str = "ref"):
        """Prefill = full forward (the cost the dry-run measures); returns
        last-position logits."""
        logits, _ = self.apply(params, tokens, ctx, frames=frames,
                               extra_embeds=extra_embeds, impl=impl)
        return logits[:, -1]

    def decode_step(self, params, token, cache, ctx: Optional[ShardCtx]
                    = None, *, memory=None, static_cache: bool = False):
        """One decode step. token: (B, 1) int32 -> (logits (B, V), cache)."""
        cfg = self.cfg
        ctx = ctx or ShardCtx.null()
        cache_len = cache["len"]
        x = self._embed(params, token, ctx)
        if cfg.pos_embed == "learned":
            # _embed added pos[0]; shift to pos[cache_len]
            x = x - params["embed"]["pos_embed"][0][None, None] \
                + params["embed"]["pos_embed"][cache_len][None, None]
        new_stages = []
        for sp, sc, s in zip(params["stages"], cache["stages"], cfg.stages):
            x, nc = stage_decode(sp, x, sc, s, cache_len, ctx, cfg,
                                 memory=memory, static_cache=static_cache)
            new_stages.append(nc)
        logits = self._logits(params, x, ctx)[:, 0]
        new_len = cache_len if static_cache else cache_len + 1
        return logits, {"stages": new_stages, "len": new_len}


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
