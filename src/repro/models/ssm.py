"""State-space mixers: RWKV-6 ("Finch") and Mamba-style selective SSM.

Both are diagonal-decay outer-product linear recurrences over the state
``S_t ∈ R^{K×V}`` per head:

    S_t = diag(w_t) · S_{t-1} + k_t ⊗ v_t          (decay per K channel)
    y_t = q_t · S_t  (+ bonus u · (q_t·k_t) v_t for RWKV's current token)

* RWKV-6: q=r (receptance), data-dependent decay ``w_t = exp(-exp(ww_t))``
  from a low-rank token-shift mix; heads of size 64; "bonus" u term gives
  the current token a separate weight. [arXiv:2404.05892]
* Mamba: per-channel state h[d, n]: decay ``exp(A[d,n]·dt_t[d])``, input
  ``dt_t[d]·B_t[n]·x_t[d]``, readout ``C_t[n]`` — the same recurrence with
  K=n, V=d channels elementwise (V-dim enters through broadcasting).

Training/prefill uses :func:`chunked_scan` — within-chunk parallel matmul
form (the kernels/rwkv_scan.py Pallas target), across-chunk ``lax.scan``.
Decode is the O(1) single-step update (this is why SSM archs run
``long_500k`` trivially).

Sharding: batch over dp. RWKV time-mix is replicated over ``model``
(40 heads % 16 != 0 — see DESIGN.md §4; padding heads to 48 is the
documented hillclimb); Mamba shards d_inner over ``model`` since the whole
recurrence is elementwise in d.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init

__all__ = ["init_rwkv", "rwkv_apply", "rwkv_decode", "init_rwkv_state",
           "init_mamba", "mamba_apply", "mamba_decode", "init_mamba_state",
           "chunked_scan", "reference_scan"]


# ---------------------------------------------------------------------------
# Generic decay-outer-product recurrence
# ---------------------------------------------------------------------------

def reference_scan(q, k, v, w, u: Optional[jnp.ndarray] = None,
                   state0: Optional[jnp.ndarray] = None):
    """Oracle: step-by-step recurrence via lax.scan.

    Shapes: q,k,w: (B, T, H, K); v: (B, T, H, V); u: (H, K) or None;
    state0: (B, H, K, V). Returns (y (B,T,H,V), state (B,H,K,V)).
    All math in float32.
    """
    B, T, H, K = q.shape
    V = v.shape[-1]
    f32 = jnp.float32
    q, k, v, w = (a.astype(f32) for a in (q, k, v, w))
    s0 = (jnp.zeros((B, H, K, V), f32) if state0 is None
          else state0.astype(f32))

    def step(s, inp):
        qt, kt, vt, wt = inp          # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        kv = kt[..., :, None] * vt[..., None, :]      # (B,H,K,V)
        if u is not None:
            cur = s + u.astype(f32)[None, :, :, None] * kv
        else:
            s = s * wt[..., :, None] + kv
            cur = s
        y = jnp.einsum("bhk,bhkv->bhv", qt, cur)
        if u is not None:
            s = s * wt[..., :, None] + kv
        return s, y

    xs = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0),
          jnp.moveaxis(v, 1, 0), jnp.moveaxis(w, 1, 0))
    s, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s


def chunked_scan(q, k, v, w, u: Optional[jnp.ndarray] = None,
                 state0: Optional[jnp.ndarray] = None, chunk: int = 64):
    """Chunk-parallel form of :func:`reference_scan` (same signature).

    Within a chunk of length c: let ``P_t = prod_{s<=t} w_s`` (inclusive
    cumulative decay). Then

      y_t = (q_t * P_t) · S_in                      (carry-in term)
            + Σ_{j<t} (q_t·P_t/P_j) ·(k_j v_j)      (intra-chunk, lower-tri)
            + u·(q_t·k_t) v_t                       (RWKV bonus, diagonal)
      S_out = diag(P_c) S_in + Σ_j diag(P_c/P_j) k_j ⊗ v_j

    Computed with two matmuls per chunk — the Pallas kernel mirrors this.
    """
    B, T, H, K = q.shape
    V = v.shape[-1]
    if T % chunk:
        pad = chunk - T % chunk
        zq = jnp.zeros((B, pad, H, K), q.dtype)
        q = jnp.concatenate([q, zq], 1)
        k = jnp.concatenate([k, zq.astype(k.dtype)], 1)
        v = jnp.concatenate([v, jnp.zeros((B, pad, H, V), v.dtype)], 1)
        w = jnp.concatenate([w, jnp.ones((B, pad, H, K), w.dtype)], 1)
    Tp = q.shape[1]
    n_chunks = Tp // chunk
    f32 = jnp.float32

    def reshape(a):
        return (a.astype(f32)
                .reshape(B, n_chunks, chunk, H, a.shape[-1])
                .transpose(1, 0, 3, 2, 4))           # (N, B, H, c, K/V)

    qc, kc, vc, wc = map(reshape, (q, k, v, w))
    s0 = (jnp.zeros((B, H, K, V), f32) if state0 is None
          else state0.astype(f32))

    def chunk_step(s, inp):
        qt, kt, vt, wt = inp                          # (B,H,c,·)
        logw = jnp.log(jnp.maximum(wt, 1e-30))
        P = jnp.exp(jnp.cumsum(logw, axis=2))         # inclusive ∏_{s<=t} w_s
        Ptot = P[:, :, -1:, :]                        # (B,H,1,K)
        if u is None:
            # Mamba convention: y_t reads S_t (after this step's decay+write)
            # => carry-in decays by the inclusive P_t, diagonal included.
            Pq = P
            tri = jnp.tril(jnp.ones((chunk, chunk), f32), k=0)
        else:
            # RWKV-6 convention: y_t reads S_{t-1} + u·k_t v_t
            # => carry-in decays by the EXCLUSIVE ∏_{s<t} w_s = P_t / w_t,
            # strict lower triangle, and the u-weighted diagonal.
            Pq = P / jnp.maximum(wt, 1e-30)
            tri = jnp.tril(jnp.ones((chunk, chunk), f32), k=-1)
        q_in = qt * Pq
        y = jnp.einsum("bhck,bhkv->bhcv", q_in, s)    # carry-in readout
        # intra-chunk: att_{tj} = Σ_k q_t[k]·(decay t<-j)[k]·k_j[k]
        kP = kt / jnp.maximum(P, 1e-30)
        att = jnp.einsum("bhck,bhjk->bhcj", q_in, kP) * tri
        if u is not None:
            diag = jnp.einsum("bhck,hk,bhck->bhc", qt, u.astype(f32), kt)
            att = att + jnp.eye(chunk, dtype=f32) * diag[..., None]
        y = y + jnp.einsum("bhcj,bhjv->bhcv", att, vt)
        # carry-out: S' = diag(Ptot) S + Σ_j diag(Ptot/P_j) k_j ⊗ v_j
        s = s * Ptot[:, :, 0, :, None] \
            + jnp.einsum("bhjk,bhjv->bhkv", (Ptot * kP), vt)
        return s, y

    # remat each chunk (same rationale as mamba_apply: don't save the
    # per-chunk decay/attention intermediates for the backward)
    s, ys = jax.lax.scan(jax.checkpoint(chunk_step), s0, (qc, kc, vc, wc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, Tp, H, V)
    return y[:, :T], s


# ---------------------------------------------------------------------------
# RWKV-6 time-mix layer
# ---------------------------------------------------------------------------

def init_rwkv(key, d: int, head_dim: int, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 9)
    H = d // head_dim
    return {
        "rwkv_r": dense_init(ks[0], (d, d), dtype=dtype),
        "rwkv_k": dense_init(ks[1], (d, d), dtype=dtype),
        "rwkv_v": dense_init(ks[2], (d, d), dtype=dtype),
        "rwkv_g": dense_init(ks[3], (d, d), dtype=dtype),
        "rwkv_w": dense_init(ks[4], (d, d), scale=0.1 * d ** -0.5,
                             dtype=dtype),
        "rwkv_o": dense_init(ks[5], (d, d), dtype=dtype),
        # static token-shift mix coefficients for (r, k, v, g, w)
        "rwkv_mix": jnp.full((5 * d,), 0.5, dtype),
        # decay base: w = exp(-exp(ww + base)); base ~ log-spaced decays
        "rwkv_decay_mix": jnp.tile(
            jnp.linspace(-6.0, -0.5, head_dim, dtype=jnp.float32)[None, :],
            (H, 1)).astype(dtype),
        "rwkv_u": (0.1 * jax.random.normal(ks[6], (H, head_dim),
                                           jnp.float32)).astype(dtype),
    }


def init_rwkv_state(batch: int, d: int, head_dim: int,
                    dtype=jnp.float32) -> dict:
    H = d // head_dim
    return {"s": jnp.zeros((batch, H, head_dim, head_dim), jnp.float32),
            "x_prev": jnp.zeros((batch, d), dtype)}


def _rwkv_projections(p, x, x_shift, d, head_dim):
    """Shared by train/decode: token-shift mix + projections.
    x, x_shift: (..., D). Returns q(r),k,v,w,(gate) each (..., H, K)."""
    H = d // head_dim
    mix = p["rwkv_mix"].astype(jnp.float32).reshape(5, d)

    def lerp(i):
        m = mix[i]
        return (x.astype(jnp.float32) * (1 - m)
                + x_shift.astype(jnp.float32) * m).astype(x.dtype)

    r = lerp(0) @ p["rwkv_r"]
    k = lerp(1) @ p["rwkv_k"]
    v = lerp(2) @ p["rwkv_v"]
    g = lerp(3) @ p["rwkv_g"]
    ww = (lerp(4) @ p["rwkv_w"]).astype(jnp.float32)
    shape = x.shape[:-1] + (H, head_dim)
    ww = ww.reshape(shape) + p["rwkv_decay_mix"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(jnp.clip(ww, -8.0, 1.0)))   # data-dependent decay
    return (r.reshape(shape), k.reshape(shape), v.reshape(shape), w,
            jax.nn.silu(g))


def _head_groupnorm(y, eps=1e-5):
    """Per-head normalization (RWKV's GroupNorm, scale-free variant)."""
    yf = y.astype(jnp.float32)
    mu = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    return ((yf - mu) * jax.lax.rsqrt(var + eps)).astype(y.dtype)


def rwkv_apply(p: dict, x: jnp.ndarray, ctx, cfg, chunk: int = 64,
               impl: str = "ref") -> jnp.ndarray:
    """Training/prefill RWKV-6 time-mix. x: (B, S, D).

    Head sharding: RWKV-6's 40 heads don't divide a 16-way model axis, so
    the scan inputs are zero-PADDED to the next multiple of model_size
    (40 -> 48 heads; +20% head flops) and the heads sharded 16-way — a
    16x/1.2 = 13x per-device reduction of the scan's compute and traffic
    vs running it replicated (EXPERIMENTS.md §Perf hillclimb 4). Padded
    heads carry k=v=0 so they contribute exact zeros and are sliced away.
    """
    d = cfg.d_model
    hd = cfg.ssm.head_dim
    B, S, D = x.shape
    H = d // hd
    x_shift = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, w, g = _rwkv_projections(p, x, x_shift, d, hd)
    u = p["rwkv_u"].astype(jnp.float32)
    ms = max(ctx.model_size, 1)
    pad_h = (-H) % ms
    if pad_h and ctx.mesh is not None:
        zeros = ((0, 0), (0, 0), (0, pad_h), (0, 0))
        r = jnp.pad(r, zeros)
        k = jnp.pad(k, zeros)
        v = jnp.pad(v, zeros)
        w = jnp.pad(w, zeros, constant_values=1.0)
        u = jnp.pad(u, ((0, pad_h), (0, 0)))
        hspec = (ctx.dp, None, ctx.tp, None)
        r = ctx.constrain(r, *hspec)
        k = ctx.constrain(k, *hspec)
        v = ctx.constrain(v, *hspec)
        w = ctx.constrain(w, *hspec)
    elif ctx.mesh is not None and H % ms == 0:
        r = ctx.constrain(r, ctx.dp, None, ctx.tp, None)
    if impl == "pallas":
        from ..kernels.ops import rwkv_scan
        y, _ = rwkv_scan(r, k, v, w, u)
    else:
        y, _ = chunked_scan(r, k, v, w, u=u, chunk=chunk)
    if pad_h and ctx.mesh is not None:
        y = y[:, :, :H]
    y = _head_groupnorm(y).reshape(B, S, D).astype(x.dtype) * g
    out = y @ p["rwkv_o"]
    return ctx.constrain(out, ctx.dp, None, ctx.tp)


def rwkv_decode(p: dict, x: jnp.ndarray, state: dict, ctx, cfg
                ) -> Tuple[jnp.ndarray, dict]:
    """O(1) single-token decode. x: (B, 1, D)."""
    d, hd = cfg.d_model, cfg.ssm.head_dim
    B = x.shape[0]
    xt = x[:, 0]
    r, k, v, w, g = _rwkv_projections(p, xt, state["x_prev"].astype(x.dtype),
                                      d, hd)
    s = state["s"]                                     # (B, H, K, V) f32
    kv = (k.astype(jnp.float32)[..., :, None]
          * v.astype(jnp.float32)[..., None, :])
    cur = s + p["rwkv_u"].astype(jnp.float32)[None, :, :, None] * kv
    y = jnp.einsum("bhk,bhkv->bhv", r.astype(jnp.float32), cur)
    s = s * w[..., :, None] + kv
    y = _head_groupnorm(y).reshape(B, d).astype(x.dtype) * g
    out = (y @ p["rwkv_o"])[:, None]
    out = ctx.constrain(out, ctx.dp, None, ctx.tp)
    return out, {"s": s, "x_prev": xt}


# ---------------------------------------------------------------------------
# Mamba-style selective SSM layer
# ---------------------------------------------------------------------------

def init_mamba(key, d: int, cfg_ssm, dtype=jnp.float32) -> dict:
    din = cfg_ssm.d_inner_mult * d
    N = cfg_ssm.d_state
    rank = cfg_ssm.dt_rank or d // 16
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (din, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * din), dtype=dtype),
        "conv_w": (0.1 * jax.random.normal(
            ks[1], (din, cfg_ssm.conv_width), jnp.float32)).astype(dtype),
        "conv_b": jnp.zeros((din,), dtype),
        "x_proj": dense_init(ks[2], (din, rank + 2 * N), dtype=dtype),
        "dt_proj": dense_init(ks[3], (rank, din), scale=rank ** -0.5,
                              dtype=dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(
                ks[4], (din,), jnp.float32,
                jnp.log(1e-3), jnp.log(1e-1))))).astype(dtype),
        "A_log": jnp.log(A).astype(dtype),
        "D_skip": jnp.ones((din,), dtype),
        "out_proj": dense_init(ks[5], (din, d), dtype=dtype),
    }


def init_mamba_state(batch: int, d: int, cfg_ssm, dtype=jnp.float32) -> dict:
    din = cfg_ssm.d_inner_mult * d
    return {
        "h": jnp.zeros((batch, din, cfg_ssm.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg_ssm.conv_width - 1, din), dtype),
    }


def _mamba_core(p, xz, cfg_ssm, d):
    """Split in_proj output, returns (x_conv_input, z, static params)."""
    din = cfg_ssm.d_inner_mult * d
    return xz[..., :din], xz[..., din:]


def mamba_apply(p: dict, x: jnp.ndarray, ctx, cfg, chunk: int = 64
                ) -> jnp.ndarray:
    """Training/prefill selective SSM. x: (B, S, D).

    The (B, S, d_inner, N) decay tensor is only ever materialized one chunk
    at a time (chunk-lazy), which keeps transient memory bounded at
    production shapes. d_inner is sharded over the model axis (the whole
    recurrence is elementwise in d_inner).
    """
    s = cfg.ssm
    d = cfg.d_model
    din = s.d_inner_mult * d
    N = s.d_state
    rank = s.dt_rank or d // 16
    B, S, D = x.shape

    xz = x @ p["in_proj"]                             # (B, S, 2*din)
    xz = ctx.constrain(xz, ctx.dp, None, ctx.tp)
    xs, z = _mamba_core(p, xz, s, d)
    # causal depthwise conv, width W
    W = s.conv_width
    xpad = jnp.pad(xs, ((0, 0), (W - 1, 0), (0, 0)))
    xc = sum(xpad[:, i:S + i] * p["conv_w"][:, i] for i in range(W))
    xc = jax.nn.silu(xc + p["conv_b"])
    dbc = xc @ p["x_proj"]                            # (B, S, rank+2N)
    dt = jax.nn.softplus(dbc[..., :rank] @ p["dt_proj"]
                         + p["dt_bias"])              # (B, S, din)
    Bc = dbc[..., rank:rank + N]                      # (B, S, N)
    Cc = dbc[..., rank + N:]                          # (B, S, N)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))      # (din, N)

    # chunk-lazy scan
    if S % chunk:
        pad = chunk - S % chunk
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
    Sp = dt.shape[1]
    nch = Sp // chunk

    def resh(a):
        return (a.astype(jnp.float32)
                .reshape(B, nch, chunk, a.shape[-1]).transpose(1, 0, 2, 3))

    dtc, Bcc, Ccc, xcc = map(resh, (dt, Bc, Cc, xc))

    def chunk_step(h, inp):
        dtk, Bk, Ck, xk = inp                          # (B, c, din/N)
        logw = dtk[..., None] * A[None, None]          # (B, c, din, N)
        cs = jnp.cumsum(logw, axis=1)                  # inclusive
        P = jnp.exp(cs)
        Ptot = P[:, -1]                                # (B, din, N)
        kin = dtk[..., None] * Bk[:, :, None, :]       # (B, c, din, N)
        qin = Ck[:, :, None, :] * P                    # (B, c, din, N)
        y = jnp.einsum("bcdn,bdn->bcd", qin, h)        # carry-in
        kP = kin / jnp.maximum(P, 1e-30)
        att = jnp.einsum("bcdn,bjdn->bdcj", qin, kP)
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
        att = att * tri[None, None]
        y = y + jnp.einsum("bdcj,bjd->bcd", att, xk)
        h = h * Ptot + jnp.einsum("bjdn,bjd->bdn", Ptot[:, None] * kP, xk)
        return h, y

    h0 = jnp.zeros((B, din, N), jnp.float32)
    # remat each chunk: the backward otherwise saves every per-chunk
    # (B, c, din, N) intermediate — ~25 GB/layer at jamba production
    # shapes (see EXPERIMENTS.md §Perf hillclimb 1). Recomputing the chunk
    # forward costs ~1 extra pass over a compute-cheap (elementwise) body.
    _, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0,
                         (dtc, Bcc, Ccc, xcc))
    y = ys.transpose(1, 0, 2, 3).reshape(B, Sp, din)[:, :S]
    y = (y + xc[:, :S] * p["D_skip"].astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = ctx.constrain(y, ctx.dp, None, ctx.tp)
    out = y @ p["out_proj"]
    return ctx.constrain(out, ctx.dp, None, ctx.tp)


def mamba_decode(p: dict, x: jnp.ndarray, state: dict, ctx, cfg
                 ) -> Tuple[jnp.ndarray, dict]:
    """O(1) single-token decode. x: (B, 1, D)."""
    s = cfg.ssm
    d = cfg.d_model
    N = s.d_state
    rank = s.dt_rank or d // 16
    B = x.shape[0]
    xz = x[:, 0] @ p["in_proj"]
    xs, z = _mamba_core(p, xz, s, d)
    conv_hist = jnp.concatenate(
        [state["conv"], xs[:, None].astype(state["conv"].dtype)], axis=1)
    xc = jnp.einsum("bwd,dw->bd", conv_hist.astype(x.dtype), p["conv_w"])
    xc = jax.nn.silu(xc + p["conv_b"])
    dbc = xc @ p["x_proj"]
    dt = jax.nn.softplus(dbc[..., :rank] @ p["dt_proj"] + p["dt_bias"])
    Bc = dbc[..., rank:rank + N].astype(jnp.float32)
    Cc = dbc[..., rank + N:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf[..., None] * A[None])          # (B, din, N)
    h = state["h"] * decay + (dtf * xc.astype(jnp.float32))[..., None] \
        * Bc[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cc)
    y = (y + xc.astype(jnp.float32) * p["D_skip"].astype(jnp.float32)
         ).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None]
    return ctx.constrain(out, ctx.dp, None, ctx.tp), {
        "h": h, "conv": conv_hist[:, 1:]}
