"""Block/stage composition: scan-over-layers, remat, enc-dec, decode.

Every stage's repeated pattern is stacked (leading ``repeats`` axis on all
leaves) and executed under ``lax.scan`` — the lowered HLO contains one copy
of the pattern regardless of depth, which is what makes the 61-/88-layer
dry-runs compile on a single CPU host. Training bodies are wrapped in
``jax.checkpoint`` (per-layer remat).

Residual-stream activations between blocks carry the sharding constraint
``P(dp, None, model)`` so scan-carried values never replicate d_model.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import Block, ModelConfig, Stage
from .attention import (attn_apply, decode_attn_apply, init_attn,
                        init_kv_cache)
from .layers import init_mlp, init_norm, mlp_apply, norm_apply
from .moe import init_moe, moe_apply
from .ssm import (init_mamba, init_mamba_state, init_rwkv, init_rwkv_state,
                  mamba_apply, mamba_decode, rwkv_apply, rwkv_decode)

__all__ = ["init_block", "init_stage", "stage_apply", "stage_decode",
           "init_stage_cache"]


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def init_block(key, cfg: ModelConfig, block: Block) -> dict:
    dt = _dtype(cfg)
    d = cfg.d_model
    a = cfg.attn
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": init_norm(d, cfg.norm, dt)}
    if block.mixer in ("attn", "cross"):
        p["mixer"] = init_attn(ks[0], d, a.num_heads, a.num_kv_heads,
                               a.head_dim, dtype=dt)
    elif block.mixer == "mamba":
        p["mixer"] = init_mamba(ks[0], d, cfg.ssm, dtype=dt)
    elif block.mixer == "rwkv":
        p["mixer"] = init_rwkv(ks[0], d, cfg.ssm.head_dim, dtype=dt)
    else:
        raise ValueError(block.mixer)
    if block.ff != "none":
        p["norm2"] = init_norm(d, cfg.norm, dt)
        if block.ff == "mlp":
            p["ff"] = init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_act, dtype=dt)
        else:
            p["ff"] = init_moe(ks[1], d, cfg.moe, cfg.mlp_act, dtype=dt)
    return p


def init_stage(key, cfg: ModelConfig, stage: Stage) -> dict:
    """Stacked params: every leaf gets a leading (repeats,) axis."""
    def init_unit(k):
        ks = jax.random.split(k, len(stage.pattern))
        return {f"b{i}": init_block(ks[i], cfg, b)
                for i, b in enumerate(stage.pattern)}

    keys = jax.random.split(key, stage.repeats)
    return jax.vmap(init_unit)(keys)


def _block_apply(p: dict, x, block: Block, ctx, cfg, *, memory=None,
                 impl: str = "ref"):
    """One block forward (train/prefill). Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = norm_apply(p["norm1"], x, cfg.norm_eps)
    if block.mixer == "attn":
        h = attn_apply(p["mixer"], h, ctx, cfg, causal=cfg.attn.causal,
                       impl=impl)
    elif block.mixer == "cross":
        h = attn_apply(p["mixer"], h, ctx, cfg, kv_x=memory, causal=False,
                       impl=impl)
    elif block.mixer == "mamba":
        h = mamba_apply(p["mixer"], h, ctx, cfg)
    elif block.mixer == "rwkv":
        h = rwkv_apply(p["mixer"], h, ctx, cfg, impl=impl)
    x = x + h
    x = ctx.res(x)
    if block.ff != "none":
        h = norm_apply(p["norm2"], x, cfg.norm_eps)
        if block.ff == "mlp":
            h = mlp_apply(p["ff"], h, cfg.mlp_act, ctx)
        else:
            h, aux = moe_apply(p["ff"], h, ctx, cfg)
        x = x + h
        x = ctx.res(x)
    return x, aux


def stage_apply(params: dict, x, stage: Stage, ctx, cfg, *, memory=None,
                remat: bool = False, impl: str = "ref"):
    """Forward through a stage. Returns (x, aux_loss_sum)."""

    def unit(x, unit_params):
        aux_total = jnp.zeros((), jnp.float32)
        for i, b in enumerate(stage.pattern):
            x, aux = _block_apply(unit_params[f"b{i}"], x, b, ctx, cfg,
                                  memory=memory, impl=impl)
            aux_total = aux_total + aux
        return x, aux_total

    if remat:
        unit = jax.checkpoint(unit)

    def body(carry, unit_params):
        x, aux_sum = carry
        x, aux = unit(x, unit_params)
        return (x, aux_sum + aux), None

    (x, aux_sum), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params)
    return x, aux_sum


# ---------------------------------------------------------------------------
# Decode (single token, stacked caches threaded through the scan)
# ---------------------------------------------------------------------------

def init_block_cache(cfg: ModelConfig, block: Block, batch: int,
                     max_len: int) -> Optional[dict]:
    dt = _dtype(cfg)
    a = cfg.attn
    if block.mixer == "attn":
        return init_kv_cache(batch, max_len, a.num_kv_heads, a.head_dim, dt)
    if block.mixer == "cross":
        # cross-attention reads the (static) encoder memory — no cache
        return {}
    if block.mixer == "mamba":
        return init_mamba_state(batch, cfg.d_model, cfg.ssm, dt)
    if block.mixer == "rwkv":
        return init_rwkv_state(batch, cfg.d_model, cfg.ssm.head_dim, dt)
    raise ValueError(block.mixer)


def init_stage_cache(cfg: ModelConfig, stage: Stage, batch: int,
                     max_len: int) -> dict:
    """Stacked (repeats, ...) caches matching init_stage's layout."""
    def one(_):
        return {f"b{i}": init_block_cache(cfg, b, batch, max_len)
                for i, b in enumerate(stage.pattern)}

    return jax.vmap(one)(jnp.arange(stage.repeats))


def _block_decode(p: dict, x, cache, block: Block, cache_len, ctx, cfg, *,
                  memory=None, static_cache: bool = False):
    h = norm_apply(p["norm1"], x, cfg.norm_eps)
    if block.mixer == "attn":
        h, cache = decode_attn_apply(p["mixer"], h, cache, cache_len, ctx,
                                     cfg, static_cache=static_cache)
    elif block.mixer == "cross":
        h = attn_apply(p["mixer"], h, ctx, cfg, kv_x=memory, causal=False)
    elif block.mixer == "mamba":
        h, cache = mamba_decode(p["mixer"], h, cache, ctx, cfg)
    elif block.mixer == "rwkv":
        h, cache = rwkv_decode(p["mixer"], h, cache, ctx, cfg)
    x = x + h
    if block.ff != "none":
        h = norm_apply(p["norm2"], x, cfg.norm_eps)
        if block.ff == "mlp":
            h = mlp_apply(p["ff"], h, cfg.mlp_act, ctx)
        else:
            h, _ = moe_apply(p["ff"], h, ctx, cfg)
        x = x + h
    x = ctx.constrain(x, ctx.dp, None, ctx.tp)
    return x, cache


def stage_decode(params: dict, x, caches: dict, stage: Stage, cache_len,
                 ctx, cfg, *, memory=None, static_cache: bool = False):
    """One-token decode through a stage. Returns (x, new_caches)."""

    def body(x, scanned):
        unit_params, unit_cache = scanned
        new_cache = {}
        for i, b in enumerate(stage.pattern):
            x, c = _block_decode(unit_params[f"b{i}"], x,
                                 unit_cache[f"b{i}"], b, cache_len, ctx,
                                 cfg, memory=memory,
                                 static_cache=static_cache)
            new_cache[f"b{i}"] = c
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (params, caches))
    return x, new_caches
