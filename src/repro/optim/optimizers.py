"""Hand-rolled optimizers (no optax in this container).

SGD (+momentum) is the paper's update; AdamW is the practical default the
paper's §1 footnote acknowledges ("all our conclusions potentially apply to
other updates"). All states are plain pytrees mirroring the param tree, so
the dry-run's train_step includes realistic optimizer memory/compute.
Optimizer math runs in float32 regardless of param dtype.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "sgd", "adamw", "cosine_schedule", "clip_by_global_norm"]


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def sgd(lr: float | Callable = 0.1, momentum: float = 0.0,
        clip_norm: Optional[float] = None,
        momentum_dtype=jnp.float32) -> Optimizer:
    """``momentum_dtype=bf16`` halves optimizer-state HBM — the documented
    production choice for the 1T kimi-k2 config (DESIGN.md/EXPERIMENTS.md)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": jax.tree.map(
            lambda p: jnp.zeros(p.shape, momentum_dtype), params)}

    def update(grads, state, params, step):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        lrv = lr_fn(step)
        if momentum == 0.0:
            new = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - lrv * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new, state
        mu = jax.tree.map(
            lambda m, g: (momentum * m.astype(jnp.float32)
                          + g.astype(jnp.float32)).astype(momentum_dtype),
            state["mu"], grads)
        new = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32)
                          - lrv * m.astype(jnp.float32)).astype(p.dtype),
            params, mu)
        return new, {"mu": mu}

    return Optimizer(init, update)


def adamw(lr: float | Callable = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: Optional[float] = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        t = jnp.asarray(step, jnp.float32) + 1.0
        lrv = lr_fn(step)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1)
                         * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2)
                         * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        mh = jax.tree.map(lambda m_: m_ / (1 - b1 ** t), m)
        vh = jax.tree.map(lambda v_: v_ / (1 - b2 ** t), v)

        def upd(p, mh_, vh_):
            step_ = mh_ / (jnp.sqrt(vh_) + eps)
            if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
                step_ = step_ + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lrv * step_).astype(p.dtype)

        new = jax.tree.map(upd, params, mh, vh)
        return new, {"m": m, "v": v}

    return Optimizer(init, update)


def _newton_schulz_orthogonalize(g, steps: int = 5):
    """Approximate UV^T of g's SVD via the quintic Newton-Schulz iteration
    (Jordan et al. 2024). g: (m, n) float32."""
    a, b, c = 3.4445, -4.7750, 2.0315
    x = g / (jnp.linalg.norm(g) + 1e-7)
    transpose = x.shape[0] > x.shape[1]
    if transpose:
        x = x.T
    for _ in range(steps):
        xxt = x @ x.T
        x = a * x + (b * xxt + c * (xxt @ xxt)) @ x
    return x.T if transpose else x


def muon(lr: float | Callable = 0.02, momentum: float = 0.95,
         ns_steps: int = 5, adamw_lr: float = 3e-4) -> Optimizer:
    """Muon (Jordan et al. 2024) — the paper's footnote 1 names it among
    the synchronous updates its conclusions extend to. Hidden 2-D matrices
    get orthogonalized momentum (Newton-Schulz); everything else (embeds,
    norms, vectors, stacked >2-D expert tensors) falls back to AdamW.
    """
    lr_fn = lr if callable(lr) else (lambda _: lr)
    fallback = adamw(lr=adamw_lr)

    def _is_matrix(p):
        return p.ndim == 2 and min(p.shape) > 1

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(z, params),
                "adam": fallback.init(params)}

    def update(grads, state, params, step):
        mu = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32),
            state["mu"], grads)
        adam_params, adam_state = fallback.update(grads, state["adam"],
                                                  params, step)
        lrv = lr_fn(step)

        def upd(p, m, ap):
            if not _is_matrix(p):
                return ap  # AdamW path
            o = _newton_schulz_orthogonalize(m, ns_steps)
            scale = jnp.sqrt(jnp.maximum(p.shape[0], p.shape[1]))
            return (p.astype(jnp.float32) - lrv * scale * o).astype(p.dtype)

        new = jax.tree.map(upd, params, mu, adam_params)
        return new, {"mu": mu, "adam": adam_state}

    return Optimizer(init, update)
