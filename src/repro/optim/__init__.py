from .optimizers import (Optimizer, adamw, clip_by_global_norm,
                         cosine_schedule, muon, sgd)
