"""String-keyed registry of the paper's compute regimes.

``SCENARIOS`` mirrors :data:`repro.core.strategies.STRATEGIES`: every
compute-time regime the paper simulates gets a name, so benchmarks,
examples and ad-hoc sweeps select ``(method, scenario)`` pairs by string
instead of hand-constructing models. A scenario factory takes ``n`` (the
worker count) plus regime-specific keyword overrides and returns the
:class:`~repro.core.time_models.TimeModel` /
:class:`~repro.core.time_models.UniversalModel` instance.

Registered regimes:

===================== ======================================= ============
name                  model                                   assumption
===================== ======================================= ============
fixed_sqrt            tau_i = tau1·sqrt(i)                    2.2 (Fig 5)
fixed_linear          tau_i = tau1·i                          2.2 (Thm 2.3)
fixed_power           tau_i = tau1·i^alpha                    2.2 (eq. 10)
truncnorm             N(mu_i, sigma²) truncated to [0, ∞)     3.1
exponential           Exp(lam), i.i.d. workers                3.1 (§3)
shifted_exp           mu_i + Exp(lam_i)                       3.1 (§D.1)
gamma                 Gamma(mean tau_i, common var)           3.1 (§K.3)
uniform               Unif(tau_i − w, tau_i + w)              3.1 (§K.3/4)
chi2                  chi²_{k_i}                              3.1 (§D.1)
universal_fig3        sin-powers grid (Figure 3)              5.1
universal_fig4        offset sin-powers grid (Figure 4)       5.1
partial_participation rotating ≤ p·n dead workers             5.4
===================== ======================================= ============
"""

from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from repro.core.time_models import (FixedTimes, PartialParticipationModel,
                                    chi2_times, exponential_times,
                                    gamma_times, powers_figure3,
                                    powers_figure4,
                                    shifted_exponential_times,
                                    truncated_normal_times, uniform_times)

__all__ = ["SCENARIOS", "register_scenario", "make_scenario"]

SCENARIOS: Dict[str, Callable] = {}


def register_scenario(name: str):
    def deco(factory):
        SCENARIOS[name] = factory
        return factory
    return deco


def make_scenario(name: str, n: int, **kwargs):
    """``SCENARIOS[name](n, **kwargs)`` with a helpful error."""
    try:
        factory = SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"known: {sorted(SCENARIOS)}") from None
    return factory(n, **kwargs)


# --------------------------------------------------------------- fixed (2.2)
@register_scenario("fixed_sqrt")
def fixed_sqrt(n: int, tau1: float = 1.0):
    return FixedTimes.sqrt_law(n, tau1)


@register_scenario("fixed_linear")
def fixed_linear(n: int, tau1: float = 1.0):
    return FixedTimes.linear(n, tau1)


@register_scenario("fixed_power")
def fixed_power(n: int, alpha: float = 1.2, tau1: float = 1.0):
    return FixedTimes.power_law(n, alpha, tau1)


# ------------------------------------------------------ sub-exponential (3.1)
@register_scenario("truncnorm")
def truncnorm(n: int, sigma: float = 0.5):
    return truncated_normal_times(np.sqrt(np.arange(1, n + 1)), sigma)


@register_scenario("exponential")
def exponential(n: int, lam: float = 1.0):
    return exponential_times(lam, n)


@register_scenario("shifted_exp")
def shifted_exp(n: int, lam: float = 1.0):
    return shifted_exponential_times(np.sqrt(np.arange(1, n + 1)),
                                     np.full(n, lam))


@register_scenario("gamma")
def gamma(n: int, var: float = 0.25):
    return gamma_times(np.sqrt(np.arange(1, n + 1)), var)


@register_scenario("uniform")
def uniform(n: int, half_width: float = 0.5):
    return uniform_times(np.ones(n), half_width)


@register_scenario("chi2")
def chi2(n: int, max_dof: int = 8):
    return chi2_times(1 + np.arange(n) % max_dof)


# ------------------------------------------------------------ universal (5.1)
@register_scenario("universal_fig3")
def universal_fig3(n: int, seed: int = 0, t_max: float = 400.0):
    return powers_figure3(n=n, seed=seed, t_max=t_max)


@register_scenario("universal_fig4")
def universal_fig4(n: int, seed: int = 0, t_max: float = 400.0):
    return powers_figure4(n=n, seed=seed, t_max=t_max)


# -------------------------------------------------- partial participation (5.4)
@register_scenario("partial_participation")
def partial_participation(n: int, v: float = 1.0, p: float = 0.2,
                          period: float = 40.0, t_max: float = 4000.0):
    return PartialParticipationModel(n=n, v=v, p=p, period=period,
                                     t_max=t_max)
