"""String-keyed registry of the paper's compute regimes.

``SCENARIOS`` mirrors :data:`repro.core.strategies.STRATEGIES`: every
compute-time regime the paper simulates gets a name, so benchmarks,
examples and ad-hoc sweeps select ``(method, scenario)`` pairs by string
instead of hand-constructing models. A scenario factory takes ``n`` (the
worker count) plus regime-specific keyword overrides and returns the
:class:`~repro.core.time_models.TimeModel` /
:class:`~repro.core.time_models.UniversalModel` instance.

Registered regimes:

===================== ======================================= ============
name                  model                                   assumption
===================== ======================================= ============
fixed_sqrt            tau_i = tau1·sqrt(i)                    2.2 (Fig 5)
fixed_linear          tau_i = tau1·i                          2.2 (Thm 2.3)
fixed_power           tau_i = tau1·i^alpha                    2.2 (eq. 10)
truncnorm             N(mu_i, sigma²) truncated to [0, ∞)     3.1
exponential           Exp(lam), i.i.d. workers                3.1 (§3)
exp_het               Exp(mean tau1·sqrt(i)) per worker       3.1 (§D.1)
exp_powerlaw          Exp(mean tau1·i^alpha) per worker       3.1 (atlas)
fixed_powerlaw        tau_i = tau1·i^alpha (= fixed_power)    2.2 (atlas)
shifted_exp           mu_i + Exp(lam_i)                       3.1 (§D.1)
fixed_bimodal         tau_i = tau1, one straggler tau1·R      2.2 (atlas)
gamma                 Gamma(mean tau_i, common var)           3.1 (§K.3)
uniform               Unif(tau_i − w, tau_i + w)              3.1 (§K.3/4)
chi2                  chi²_{k_i}                              3.1 (§D.1)
universal_fig3        sin-powers grid (Figure 3)              5.1
universal_fig4        offset sin-powers grid (Figure 4)       5.1
partial_participation rotating ≤ p·n dead workers             5.4
crash_restart         Exp(lam) + crash/restart renewals       fault layer
crash_fixed           tau1·sqrt(i) + crash/restart renewals   fault layer
transient_slowdown    mu_i + Exp(lam) + Markov slow episodes  fault layer
correlated_bursts     Exp(lam) + shared-clock burst subsets   fault layer
heavy_tail_spikes     Exp(lam) + Lomax straggler spikes       fault layer
faulty_mix            Exp(lam) + crash + bursts + spikes      fault layer
===================== ======================================= ============

The ``fault layer`` scenarios wrap a base regime with
:mod:`repro.core.faults` transformations (DESIGN.md §3c): identical
engine coverage to their base scenario — the wrapper is itself a
``SubExponentialTimes`` — with fault draws on disjoint, sweep-independent
streams.
"""

from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from repro.core.faults import (CorrelatedBursts, CrashRestart,
                               HeavyTailSpike, TransientSlowdown,
                               with_faults)
from repro.core.time_models import (FixedTimes, PartialParticipationModel,
                                    chi2_times, exponential_times,
                                    gamma_times, powers_figure3,
                                    powers_figure4,
                                    shifted_exponential_times,
                                    truncated_normal_times, uniform_times)

__all__ = ["SCENARIOS", "register_scenario", "make_scenario"]

SCENARIOS: Dict[str, Callable] = {}


def register_scenario(name: str):
    def deco(factory):
        SCENARIOS[name] = factory
        return factory
    return deco


def make_scenario(name: str, n: int, **kwargs):
    """``SCENARIOS[name](n, **kwargs)`` with a helpful error."""
    try:
        factory = SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"known: {sorted(SCENARIOS)}") from None
    return factory(n, **kwargs)


# --------------------------------------------------------------- fixed (2.2)
@register_scenario("fixed_sqrt")
def fixed_sqrt(n: int, tau1: float = 1.0):
    return FixedTimes.sqrt_law(n, tau1)


@register_scenario("fixed_linear")
def fixed_linear(n: int, tau1: float = 1.0):
    return FixedTimes.linear(n, tau1)


@register_scenario("fixed_power")
def fixed_power(n: int, alpha: float = 1.2, tau1: float = 1.0):
    return FixedTimes.power_law(n, alpha, tau1)


@register_scenario("fixed_bimodal")
def fixed_bimodal(n: int, tau1: float = 1.0, straggler: float = 25.0):
    """``n - 1`` identical fast workers plus ONE deterministic straggler
    ``straggler`` times slower — the textbook regime where waiting for
    everyone is catastrophic and discard-free async methods shine
    (time-complexity atlas)."""
    taus = np.full(n, tau1)
    taus[-1] = tau1 * straggler
    return FixedTimes(taus)


# ------------------------------------------------------ sub-exponential (3.1)
@register_scenario("truncnorm")
def truncnorm(n: int, sigma: float = 0.5):
    return truncated_normal_times(np.sqrt(np.arange(1, n + 1)), sigma)


@register_scenario("exponential")
def exponential(n: int, lam: float = 1.0):
    return exponential_times(lam, n)


@register_scenario("exp_het")
def exp_het(n: int, tau1: float = 1.0):
    """Heterogeneous-RATE exponential workers: worker ``i`` is
    Exp with mean ``tau1 * sqrt(i)`` (zero shift). The memoryless
    heterogeneous regime the time-complexity atlas probes for the
    paper's "async may be necessary" boundary — same sqrt speed ladder
    as ``fixed_sqrt``/``shifted_exp`` but with all the mass in the
    random part."""
    means = tau1 * np.sqrt(np.arange(1, n + 1))
    return shifted_exponential_times(np.zeros(n), 1.0 / means)


@register_scenario("exp_powerlaw")
def exp_powerlaw(n: int, alpha: float = 1.2, tau1: float = 1.0):
    """Memoryless workers on a power-law speed ladder: worker ``i`` is
    Exp with mean ``tau1 * i^alpha`` (zero shift). The skewed-rate
    regime the ragged chain layout exists for — mean rates span a
    factor ``n^alpha``, so a rectangular (same-length-per-worker) chain
    budget over-draws the slow tail by that same factor while the
    ragged layout sizes each worker's chain to its own rate."""
    means = tau1 * np.arange(1, n + 1, dtype=float) ** alpha
    return shifted_exponential_times(np.zeros(n), 1.0 / means)


@register_scenario("fixed_powerlaw")
def fixed_powerlaw(n: int, alpha: float = 1.2, tau1: float = 1.0):
    """Deterministic counterpart of ``exp_powerlaw``: ``tau_i =
    tau1 * i^alpha`` with zero variance (same model as ``fixed_power``,
    registered under the paired name so ``(exp_powerlaw,
    fixed_powerlaw)`` selects the skewed-rate regime with and without
    randomness)."""
    return FixedTimes.power_law(n, alpha, tau1)


@register_scenario("shifted_exp")
def shifted_exp(n: int, lam: float = 1.0):
    return shifted_exponential_times(np.sqrt(np.arange(1, n + 1)),
                                     np.full(n, lam))


@register_scenario("gamma")
def gamma(n: int, var: float = 0.25):
    return gamma_times(np.sqrt(np.arange(1, n + 1)), var)


@register_scenario("uniform")
def uniform(n: int, half_width: float = 0.5):
    return uniform_times(np.ones(n), half_width)


@register_scenario("chi2")
def chi2(n: int, max_dof: int = 8):
    return chi2_times(1 + np.arange(n) % max_dof)


# ------------------------------------------------------------ universal (5.1)
@register_scenario("universal_fig3")
def universal_fig3(n: int, seed: int = 0, t_max: float = 400.0):
    return powers_figure3(n=n, seed=seed, t_max=t_max)


@register_scenario("universal_fig4")
def universal_fig4(n: int, seed: int = 0, t_max: float = 400.0):
    return powers_figure4(n=n, seed=seed, t_max=t_max)


# -------------------------------------------------- partial participation (5.4)
@register_scenario("partial_participation")
def partial_participation(n: int, v: float = 1.0, p: float = 0.2,
                          period: float = 40.0, t_max: float = 4000.0):
    return PartialParticipationModel(n=n, v=v, p=p, period=period,
                                     t_max=t_max)


# ------------------------------------------------- fault regimes (DESIGN §3c)
@register_scenario("crash_restart")
def crash_restart(n: int, lam: float = 1.0, p: float = 0.05,
                  mean_downtime: float = 2.0):
    """Exp(lam) workers that crash with prob ``p`` per draw (downtime +
    redraw, at most one crash per renewal)."""
    return with_faults(exponential_times(lam, n),
                       CrashRestart(p=p, mean_downtime=mean_downtime))


@register_scenario("crash_fixed")
def crash_fixed(n: int, tau1: float = 1.0, p: float = 0.05,
                mean_downtime: float = 2.0):
    """Deterministic sqrt-law workers turned stochastic by crash/restart
    — the smallest perturbation of the paper's Figure 5 setup."""
    return with_faults(FixedTimes.sqrt_law(n, tau1),
                       CrashRestart(p=p, mean_downtime=mean_downtime))


@register_scenario("transient_slowdown")
def transient_slowdown(n: int, lam: float = 1.0, rate: float = 0.2,
                       mean_episode: float = 1.0, factor: float = 4.0):
    """Shifted-exponential workers with Markov on/off degradation
    episodes arriving on the work clock (x``factor`` while degraded)."""
    return with_faults(
        shifted_exponential_times(np.sqrt(np.arange(1, n + 1)),
                                  np.full(n, lam)),
        TransientSlowdown(rate=rate, mean_episode=mean_episode,
                          factor=factor))


@register_scenario("correlated_bursts")
def correlated_bursts(n: int, lam: float = 1.0, p_episode: float = 0.1,
                      frac: float = 0.5, mean_extra: float = 4.0):
    """Exp(lam) workers hit by correlated failure bursts: a shared
    episode clock fires with prob ``p_episode`` per round and delays a
    random ``frac`` subset."""
    return with_faults(exponential_times(lam, n),
                       CorrelatedBursts(p_episode=p_episode, frac=frac,
                                        mean_extra=mean_extra))


@register_scenario("heavy_tail_spikes")
def heavy_tail_spikes(n: int, lam: float = 1.0, p: float = 0.05,
                      alpha: float = 1.5, scale: float = 5.0):
    """Exp(lam) workers with Lomax(alpha, scale) straggler spikes — the
    wrapped model is genuinely heavy-tailed (R = inf)."""
    return with_faults(exponential_times(lam, n),
                       HeavyTailSpike(p=p, alpha=alpha, scale=scale))


@register_scenario("faulty_mix")
def faulty_mix(n: int, lam: float = 1.0, p_crash: float = 0.03,
               mean_downtime: float = 2.0, p_episode: float = 0.05,
               frac: float = 0.5, mean_extra: float = 4.0,
               p_spike: float = 0.02, alpha: float = 1.5,
               scale: float = 5.0):
    """All three failure modes stacked on Exp(lam) workers — the
    adversarial composite regime for the fault-frontier benchmark."""
    return with_faults(
        exponential_times(lam, n),
        CrashRestart(p=p_crash, mean_downtime=mean_downtime),
        CorrelatedBursts(p_episode=p_episode, frac=frac,
                         mean_extra=mean_extra),
        HeavyTailSpike(p=p_spike, alpha=alpha, scale=scale))
