"""Experiment layer: named scenarios × strategies × seed sweeps.

``repro.exp`` sits on top of the core Strategy API and the batched
simulator (DESIGN.md):

* :data:`~repro.exp.scenarios.SCENARIOS` — string-keyed registry of the
  paper's compute regimes (fixed sqrt/linear/power-law times, each
  sub-exponential family, universal and partial-participation powers),
  mirroring :data:`repro.core.strategies.STRATEGIES`.
* :func:`~repro.exp.runner.run_experiment` — one call for "run this
  method under this scenario across S seeds and a parameter grid",
  returning mean ± std / time-to-target summaries with JSON output.
"""

from .runner import ExperimentResult, csv_rows, run_experiment
from .scenarios import SCENARIOS, make_scenario, register_scenario

__all__ = ["SCENARIOS", "make_scenario", "register_scenario",
           "run_experiment", "ExperimentResult", "csv_rows"]
