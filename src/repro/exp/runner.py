"""``run_experiment`` — the one driver every figure/benchmark goes through.

Couples a strategy spec, a named scenario (or a model instance), a seed
sweep and an optional parameter grid into a single
:func:`repro.core.simulate_batch` call, then reduces the
:class:`~repro.core.batch.TraceBatch` into summary rows (mean ± std
across seeds, time-to-target quantiles) with JSON output for CI
artifacts. :func:`csv_rows` renders a summary as plain harness-style
``(name, value, derived)`` triples for callers that don't need custom
derived columns (the in-tree benchmarks hand-format richer ones).
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Mapping, Optional, Sequence, Union

import numpy as np

from repro.core.batch import (TraceBatch, _as_spec, _grid_points,
                              simulate_batch)
from repro.core.strategies import Trace

from .scenarios import make_scenario

__all__ = ["ExperimentResult", "run_experiment", "csv_rows",
           "atomic_write_json"]


def atomic_write_json(path: str, obj: Any, *, indent: int = 2,
                      default=None) -> None:
    """Write ``obj`` as JSON via tmp-file + :func:`os.replace` so a
    crash mid-write never leaves a truncated artifact: readers see
    either the previous complete file or the new complete file. The
    tmp file lives next to the target (same filesystem — ``os.replace``
    is atomic only within one)."""
    path = os.fspath(path)
    tmp = f"{path}.tmp"
    with open(tmp, "w") as fh:
        json.dump(obj, fh, indent=indent, default=default)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)


@dataclasses.dataclass
class ExperimentResult:
    """A named experiment: its meta, the raw TraceBatch and summary rows."""

    name: str
    meta: Dict[str, Any]
    batch: TraceBatch
    rows: List[Dict[str, Any]]

    def to_json(self, path: str) -> None:
        atomic_write_json(path, sanitize_json(self.as_dict()),
                          default=_jsonable)

    def as_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "meta": self.meta, "rows": self.rows}


def _jsonable(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    return str(o)


def sanitize_json(obj):
    """Replace non-finite floats with strings: ``json.dump`` would emit
    the bare token ``Infinity`` (invalid JSON — rejected by jq /
    ``JSON.parse``) for inf time-to-target quantiles."""
    if isinstance(obj, dict):
        return {k: sanitize_json(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize_json(v) for v in obj]
    if isinstance(obj, (float, np.floating)) and not np.isfinite(obj):
        return str(obj)                          # "inf" / "-inf" / "nan"
    return obj


def _join_labels(labels: Sequence[str]) -> str:
    """Replicate :func:`simulate_batch`'s backend/scheme label join so a
    checkpoint-reassembled batch reports identically to a one-shot run."""
    return labels[0] if len(set(labels)) == 1 \
        else "+".join(sorted(set(labels)))


def _checkpointed_batch(strategy, model, K, *, problem, gamma, seed_list,
                        grid, record_every, tol_grad_sq, backend,
                        rng_scheme, use_pallas, x64, checkpoint_dir,
                        resume) -> TraceBatch:
    """Crash-safe sweep: one :func:`simulate_batch` call per grid point,
    each checkpointed to ``checkpoint_dir/point-NNNNN.json`` with an
    atomic tmp-then-rename write the moment it finishes. Per-seed draw
    streams are sweep-independent (DESIGN §3b), so per-point results
    equal the one-shot sweep's; the final batch is assembled by reading
    every checkpoint back, so a resumed run and an uninterrupted run
    flow through byte-identical data. With ``resume=True`` points whose
    checkpoint already exists are skipped (a ``manifest.json``
    fingerprint guards against resuming someone else's sweep)."""
    name, _factory, _kw = _as_spec(strategy)
    points = _grid_points(grid)
    os.makedirs(checkpoint_dir, exist_ok=True)

    manifest = {"version": 1, "strategy": name,
                "model": getattr(model, "name", type(model).__name__),
                "n": int(model.n), "K": int(K),
                "seeds": [int(s) for s in seed_list],
                "grid": points, "gamma": float(gamma),
                "record_every": int(record_every),
                "tol_grad_sq": tol_grad_sq, "backend": backend,
                "rng_scheme": rng_scheme, "math": problem is not None,
                "use_pallas": bool(use_pallas), "x64": bool(x64)}
    # normalize through a JSON round trip so the fingerprint comparison
    # sees exactly what a reloaded manifest would
    manifest = json.loads(json.dumps(sanitize_json(manifest),
                                     default=_jsonable))
    manifest_path = os.path.join(checkpoint_dir, "manifest.json")
    if resume and os.path.exists(manifest_path):
        with open(manifest_path) as fh:
            prev = json.load(fh)
        if prev != manifest:
            raise ValueError(
                f"checkpoint dir {checkpoint_dir!r} holds a different "
                "sweep (manifest mismatch); refusing to resume into it")
    atomic_write_json(manifest_path, manifest)

    def _point_path(g: int) -> str:
        return os.path.join(checkpoint_dir, f"point-{g:05d}.json")

    for g, pt in enumerate(points):
        if resume and os.path.exists(_point_path(g)):
            continue
        sub = simulate_batch(strategy, model, K, problem=problem,
                             gamma=gamma, seeds=seed_list,
                             grid={k: [v] for k, v in pt.items()} or None,
                             record_every=record_every,
                             tol_grad_sq=tol_grad_sq, backend=backend,
                             rng_scheme=rng_scheme, use_pallas=use_pallas,
                             x64=x64)
        rec = {"version": 1, "point": pt, "backend": sub.backend,
               "rng_scheme": sub.rng_scheme,
               "routing": sub.routing[0] if sub.routing else None,
               "traces": [t.as_dict() for t in sub.traces[0]]}
        atomic_write_json(_point_path(g), sanitize_json(rec),
                          default=_jsonable)

    traces: List[List[Trace]] = []
    backends: List[str] = []
    schemes: List[str] = []
    routing: List[Any] = []
    for g in range(len(points)):
        with open(_point_path(g)) as fh:
            rec = json.load(fh)
        traces.append([Trace.from_dict(t) for t in rec["traces"]])
        backends.append(rec["backend"])
        schemes.append(rec["rng_scheme"])
        routing.append(rec["routing"])
    return TraceBatch(strategy=name, grid=points,
                      seeds=np.asarray(seed_list), traces=traces,
                      backend=_join_labels(backends),
                      rng_scheme=_join_labels(schemes), routing=routing)


def run_experiment(strategy,
                   scenario: Union[str, object],
                   n: int,
                   K: int,
                   *,
                   seeds: Union[int, Sequence[int]] = 8,
                   grid: Optional[Mapping[str, Sequence]] = None,
                   problem=None,
                   gamma: float = 0.0,
                   record_every: int = 1,
                   tol_grad_sq: Optional[float] = None,
                   backend: str = "fastest",
                   rng_scheme: str = "counter",
                   use_pallas: bool = False,
                   x64: bool = False,
                   scenario_kwargs: Optional[Dict[str, Any]] = None,
                   target_frac: Optional[float] = None,
                   json_path: Optional[str] = None,
                   name: Optional[str] = None,
                   checkpoint_dir: Optional[str] = None,
                   resume: bool = False) -> ExperimentResult:
    """Run ``strategy`` under ``scenario`` across ``seeds`` × ``grid``.

    ``scenario`` is a name from :data:`~repro.exp.scenarios.SCENARIOS`
    (built with ``n`` and ``scenario_kwargs``) or an already-constructed
    time model (then ``n`` must equal ``model.n``). ``target_frac``
    enables time-to-target reporting: wall-clock until ``||∇f||²`` falls
    to that fraction of its initial value, quantiled across seeds.
    ``json_path`` writes the summary as a JSON artifact.

    The default ``backend="fastest"`` routes each grid point through the
    per-engine cost model
    (:func:`repro.core.batch.estimate_backend_seconds`): the host engine
    and the jax engine that would run the combination are priced as a
    function of engine kind (round scan / arrival scan / event loop),
    S, K, n, math vs timing-only and accelerator presence, and the
    cheaper one runs. The backend that actually ran is recorded in the
    JSON artifact's ``meta.backend`` (plus per-row
    ``backend``/``rng_scheme``) and the full per-grid-point routing
    decision — estimates, accelerator flag, reason — lands in
    ``meta.routing``. On multi-device hosts the router may pick
    ``backend="jax_sharded"`` (the :mod:`repro.launch.sweep` fused
    sweep); its per-bucket shard/compile/cache meta appears under each
    routing entry's ``shard`` key. ``x64=True`` runs jax grid points in
    float64 for per-run tie parity on tie-heavy instances (partial
    participation).

    ``json_path`` is written only on the coordinator process
    (:func:`repro.launch.sweep.is_coordinator`) so a multi-host launch
    produces one artifact, not one per host.

    ``checkpoint_dir`` makes the sweep crash-safe (DESIGN §3c): each
    grid point runs as its own :func:`simulate_batch` call and lands in
    ``checkpoint_dir/point-NNNNN.json`` the moment it completes
    (atomic tmp-then-rename, like every JSON this module writes). A
    killed run restarted with ``resume=True`` skips every point whose
    checkpoint exists and produces a final artifact byte-identical to
    the uninterrupted checkpointed run's — both assemble the batch from
    the checkpoint files, and DESIGN §3b sweep independence makes
    per-point results equal the one-shot sweep's. (Two caveats: grid
    points are never *fused* into one sharded program in checkpoint
    mode, and sharded routing records carry wall-clock compile times —
    use a deterministic backend when asserting byte equality.)
    """
    if isinstance(scenario, str):
        model = make_scenario(scenario, n, **(scenario_kwargs or {}))
        scen_name = scenario
    else:
        model = scenario
        scen_name = getattr(model, "name", type(model).__name__)
    if model.n != n:
        raise ValueError(f"scenario has n={model.n}, asked for n={n}")

    if checkpoint_dir is not None:
        seed_list = list(range(seeds)) \
            if isinstance(seeds, (int, np.integer)) \
            else [int(s) for s in seeds]
        batch = _checkpointed_batch(
            strategy, model, K, problem=problem, gamma=gamma,
            seed_list=seed_list, grid=grid, record_every=record_every,
            tol_grad_sq=tol_grad_sq, backend=backend,
            rng_scheme=rng_scheme, use_pallas=use_pallas, x64=x64,
            checkpoint_dir=checkpoint_dir, resume=resume)
    else:
        batch = simulate_batch(strategy, model, K, problem=problem,
                               gamma=gamma, seeds=seeds, grid=grid,
                               record_every=record_every,
                               tol_grad_sq=tol_grad_sq, backend=backend,
                               rng_scheme=rng_scheme, use_pallas=use_pallas,
                               x64=x64)
    rows = batch.summary(target_frac=target_frac)
    for row in rows:
        row["scenario"] = scen_name
        row["n"] = n
        row["K"] = K
    meta = {"strategy": batch.strategy, "scenario": scen_name, "n": n,
            "K": K, "seeds": list(map(int, batch.seeds)),
            "backend": batch.backend,
            "rng_scheme": batch.rng_scheme,
            "routing": batch.routing,
            "grid": batch.grid if grid else None}
    result = ExperimentResult(name=name or f"{batch.strategy}@{scen_name}",
                              meta=meta, batch=batch, rows=rows)
    if json_path:
        from repro.launch.sweep import is_coordinator
        if is_coordinator():
            result.to_json(json_path)
    return result


def csv_rows(result: ExperimentResult, prefix: str,
             value_key: str = "total_time_mean"):
    """Benchmark-harness ``(name, value, derived)`` triples: one per grid
    point, value = ``value_key``, derived = ``± std`` plus seed count."""
    out = []
    std_key = value_key.replace("_mean", "_std")
    for row in result.rows:
        params = "/".join(f"{k}={v}" for k, v in row["params"].items())
        label = f"{prefix}/{params}" if params else prefix
        std = row.get(std_key)
        derived = (f"±{std:.4g} over {row['seeds']} seeds"
                   if std is not None else f"{row['seeds']} seeds")
        out.append((label, row[value_key], derived))
    return out
