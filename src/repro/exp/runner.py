"""``run_experiment`` — the one driver every figure/benchmark goes through.

Couples a strategy spec, a named scenario (or a model instance), a seed
sweep and an optional parameter grid into a single
:func:`repro.core.simulate_batch` call, then reduces the
:class:`~repro.core.batch.TraceBatch` into summary rows (mean ± std
across seeds, time-to-target quantiles) with JSON output for CI
artifacts. :func:`csv_rows` renders a summary as plain harness-style
``(name, value, derived)`` triples for callers that don't need custom
derived columns (the in-tree benchmarks hand-format richer ones).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Mapping, Optional, Sequence, Union

import numpy as np

from repro.core.batch import TraceBatch, simulate_batch

from .scenarios import make_scenario

__all__ = ["ExperimentResult", "run_experiment", "csv_rows"]


@dataclasses.dataclass
class ExperimentResult:
    """A named experiment: its meta, the raw TraceBatch and summary rows."""

    name: str
    meta: Dict[str, Any]
    batch: TraceBatch
    rows: List[Dict[str, Any]]

    def to_json(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(sanitize_json(self.as_dict()), fh, indent=2,
                      default=_jsonable)

    def as_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "meta": self.meta, "rows": self.rows}


def _jsonable(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    return str(o)


def sanitize_json(obj):
    """Replace non-finite floats with strings: ``json.dump`` would emit
    the bare token ``Infinity`` (invalid JSON — rejected by jq /
    ``JSON.parse``) for inf time-to-target quantiles."""
    if isinstance(obj, dict):
        return {k: sanitize_json(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize_json(v) for v in obj]
    if isinstance(obj, (float, np.floating)) and not np.isfinite(obj):
        return str(obj)                          # "inf" / "-inf" / "nan"
    return obj


def run_experiment(strategy,
                   scenario: Union[str, object],
                   n: int,
                   K: int,
                   *,
                   seeds: Union[int, Sequence[int]] = 8,
                   grid: Optional[Mapping[str, Sequence]] = None,
                   problem=None,
                   gamma: float = 0.0,
                   record_every: int = 1,
                   tol_grad_sq: Optional[float] = None,
                   backend: str = "fastest",
                   rng_scheme: str = "counter",
                   use_pallas: bool = False,
                   x64: bool = False,
                   scenario_kwargs: Optional[Dict[str, Any]] = None,
                   target_frac: Optional[float] = None,
                   json_path: Optional[str] = None,
                   name: Optional[str] = None) -> ExperimentResult:
    """Run ``strategy`` under ``scenario`` across ``seeds`` × ``grid``.

    ``scenario`` is a name from :data:`~repro.exp.scenarios.SCENARIOS`
    (built with ``n`` and ``scenario_kwargs``) or an already-constructed
    time model (then ``n`` must equal ``model.n``). ``target_frac``
    enables time-to-target reporting: wall-clock until ``||∇f||²`` falls
    to that fraction of its initial value, quantiled across seeds.
    ``json_path`` writes the summary as a JSON artifact.

    The default ``backend="fastest"`` routes each grid point through the
    per-engine cost model
    (:func:`repro.core.batch.estimate_backend_seconds`): the host engine
    and the jax engine that would run the combination are priced as a
    function of engine kind (round scan / arrival scan / event loop),
    S, K, n, math vs timing-only and accelerator presence, and the
    cheaper one runs. The backend that actually ran is recorded in the
    JSON artifact's ``meta.backend`` (plus per-row
    ``backend``/``rng_scheme``) and the full per-grid-point routing
    decision — estimates, accelerator flag, reason — lands in
    ``meta.routing``. On multi-device hosts the router may pick
    ``backend="jax_sharded"`` (the :mod:`repro.launch.sweep` fused
    sweep); its per-bucket shard/compile/cache meta appears under each
    routing entry's ``shard`` key. ``x64=True`` runs jax grid points in
    float64 for per-run tie parity on tie-heavy instances (partial
    participation).

    ``json_path`` is written only on the coordinator process
    (:func:`repro.launch.sweep.is_coordinator`) so a multi-host launch
    produces one artifact, not one per host.
    """
    if isinstance(scenario, str):
        model = make_scenario(scenario, n, **(scenario_kwargs or {}))
        scen_name = scenario
    else:
        model = scenario
        scen_name = getattr(model, "name", type(model).__name__)
    if model.n != n:
        raise ValueError(f"scenario has n={model.n}, asked for n={n}")

    batch = simulate_batch(strategy, model, K, problem=problem, gamma=gamma,
                           seeds=seeds, grid=grid, record_every=record_every,
                           tol_grad_sq=tol_grad_sq, backend=backend,
                           rng_scheme=rng_scheme, use_pallas=use_pallas,
                           x64=x64)
    rows = batch.summary(target_frac=target_frac)
    for row in rows:
        row["scenario"] = scen_name
        row["n"] = n
        row["K"] = K
    meta = {"strategy": batch.strategy, "scenario": scen_name, "n": n,
            "K": K, "seeds": list(map(int, batch.seeds)),
            "backend": batch.backend,
            "rng_scheme": batch.rng_scheme,
            "routing": batch.routing,
            "grid": batch.grid if grid else None}
    result = ExperimentResult(name=name or f"{batch.strategy}@{scen_name}",
                              meta=meta, batch=batch, rows=rows)
    if json_path:
        from repro.launch.sweep import is_coordinator
        if is_coordinator():
            result.to_json(json_path)
    return result


def csv_rows(result: ExperimentResult, prefix: str,
             value_key: str = "total_time_mean"):
    """Benchmark-harness ``(name, value, derived)`` triples: one per grid
    point, value = ``value_key``, derived = ``± std`` plus seed count."""
    out = []
    std_key = value_key.replace("_mean", "_std")
    for row in result.rows:
        params = "/".join(f"{k}={v}" for k, v in row["params"].items())
        label = f"{prefix}/{params}" if params else prefix
        std = row.get(std_key)
        derived = (f"±{std:.4g} over {row['seeds']} seeds"
                   if std is not None else f"{row['seeds']} seeds")
        out.append((label, row[value_key], derived))
    return out
