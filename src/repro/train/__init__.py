from .checkpoint import load_checkpoint, save_checkpoint
from .trainer import Trainer, TrainHistory, TrainState
