"""Trainer: the paper's m-Synchronous SGD as a first-class training policy.

Every step:
  1. the straggler model (Assumption 2.2/3.1 instance) draws per-worker
     compute times in one vectorized call and the aggregation strategy
     (:mod:`repro.core.strategies`; ``sync`` / ``msync`` / ``auto_m`` /
     ``deadline`` — or a legacy :class:`~repro.core.sync_engine.SyncPolicy`)
     resolves the participation mask;
  2. the mask is folded into per-example loss weights
     (:func:`participation_example_weights`) so the ordinary data-parallel
     all-reduce computes exactly the Algorithm 3 estimator;
  3. simulated wall-clock advances by the m-th order statistic of the drawn
     times — loss curves are reported against *time*, like the paper's
     figures.

Works on CPU (smoke scale) and, unchanged, on a real mesh: the jitted step
is shape-identical; only `ctx` changes.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Any, Dict, Iterator, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.strategies import (AggregationStrategy, make_strategy)
from ..core.sync_engine import (SimulatedStraggler, SyncPolicy, SyncMode,
                                participation_example_weights)
from ..core.time_models import TimeModel
from ..models import Model, build_model
from ..optim.optimizers import Optimizer
from ..sharding.specs import ShardCtx

__all__ = ["TrainState", "Trainer", "TrainHistory"]


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int = 0


@dataclasses.dataclass
class TrainHistory:
    steps: list = dataclasses.field(default_factory=list)
    sim_seconds: list = dataclasses.field(default_factory=list)
    losses: list = dataclasses.field(default_factory=list)
    m_used: list = dataclasses.field(default_factory=list)
    wall_seconds: list = dataclasses.field(default_factory=list)
    step_times: list = dataclasses.field(default_factory=list)


class Trainer:
    def __init__(self, model: Model, optimizer: Optimizer, *,
                 n_workers: int = 8,
                 sync_policy: Optional[SyncPolicy] = None,
                 strategy: Optional[Union[str, AggregationStrategy]] = None,
                 time_model: Optional[TimeModel] = None,
                 ctx: Optional[ShardCtx] = None,
                 remat: bool = False, seed: int = 0,
                 impl: str = "ref", grad_delay: int = 0) -> None:
        """``strategy`` is any mesh-capable aggregation strategy (an
        :class:`~repro.core.strategies.AggregationStrategy` instance or a
        ``STRATEGIES`` registry name); ``sync_policy`` is the deprecated
        enum-based spelling of the same thing and must not be combined
        with it.

        ``grad_delay=d > 0`` runs the SPMD-realizable form of
        Asynchronous SGD (Algorithm 2): the gradient is computed at the
        parameters from ``d`` steps ago and applied to the current ones —
        the pipelined/delayed-gradient schedule a synchronous pod can
        actually execute (Stich & Karimireddy 2020). Incompatible with an
        m-sync policy (the paper's point is you don't need both)."""
        self.model = model
        self.optimizer = optimizer
        self.n_workers = n_workers
        self.ctx = ctx or ShardCtx.null()
        self.remat = remat
        self.impl = impl
        if strategy is not None and sync_policy is not None:
            raise ValueError("pass either strategy= or sync_policy=, "
                             "not both")
        if isinstance(strategy, str):
            strategy = make_strategy(strategy)
        if strategy is None:
            strategy = (sync_policy or SyncPolicy(SyncMode.FULL)) \
                .to_strategy()
        self.strategy = strategy
        self.straggler = (SimulatedStraggler(time_model, strategy,
                                             seed=seed)
                          if time_model is not None else None)
        self.grad_delay = grad_delay
        if grad_delay and strategy.name != "sync":
            raise ValueError("grad_delay is an asynchronous-baseline mode; "
                             "combine with the full-sync strategy only")
        self._param_fifo: deque = deque()   # delayed-gradient params, O(1) popleft
        self._seed = seed
        self._step_fn = None

    # -------------------------------------------------------------- init
    def init_state(self, key=None) -> TrainState:
        key = jax.random.key(self._seed) if key is None else key
        params = self.model.init_params(key)
        return TrainState(params, self.optimizer.init(params), 0)

    # -------------------------------------------------------------- step
    def _build_step(self):
        model, opt = self.model, self.optimizer
        ctx, remat, impl = self.ctx, self.remat, self.impl

        def step_fn(params, opt_state, batch, example_weights, step,
                    grad_params):
            # grad_params=None => synchronous (gradient at current params);
            # passing params twice would alias a donated buffer.
            gp = params if grad_params is None else grad_params

            def loss_fn(p):
                return model.loss(p, batch, ctx, remat=remat, impl=impl,
                                  example_weights=example_weights)
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(gp)
            new_params, new_opt = opt.update(grads, opt_state, params, step)
            # per-step gradient variance proxy for AUTO_M's sigma estimate
            gsq = sum(jnp.sum(g.astype(jnp.float32) ** 2)
                      for g in jax.tree.leaves(grads))
            metrics = dict(metrics, loss=loss, grad_sq=gsq)
            return new_params, new_opt, metrics

        # grad_delay keeps old params alive in the FIFO — donating them
        # would be use-after-free; donate only the optimizer state then.
        donate = (1,) if self.grad_delay else (0, 1)
        return jax.jit(step_fn, donate_argnums=donate)

    def step(self, state: TrainState, batch: Dict[str, Any]):
        if self._step_fn is None:
            self._step_fn = self._build_step()
        B = batch["tokens"].shape[0]
        if self.straggler is not None:
            mask, m, dur = self.straggler.step()
            weights = participation_example_weights(
                jnp.asarray(mask), self.n_workers, B)
        else:
            mask, m, dur = None, self.n_workers, 0.0
            weights = None
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if self.grad_delay:
            self._param_fifo.append(state.params)
            grad_params = self._param_fifo[0]
            if len(self._param_fifo) > self.grad_delay:
                self._param_fifo.popleft()
        else:
            grad_params = None
        params, opt_state, metrics = self._step_fn(
            state.params, state.opt_state, batch, weights,
            jnp.asarray(state.step, jnp.int32), grad_params)
        return (TrainState(params, opt_state, state.step + 1),
                metrics, m, dur)

    # -------------------------------------------------------------- run
    def run(self, state: TrainState, batches: Iterator[Dict[str, Any]],
            num_steps: int, log_every: int = 10,
            history: Optional[TrainHistory] = None) -> TrainHistory:
        hist = history or TrainHistory()
        sim_t = hist.sim_seconds[-1] if hist.sim_seconds else 0.0
        wall0 = time.perf_counter()
        for i in range(num_steps):
            t0 = time.perf_counter()
            batch = next(batches)
            state, metrics, m, dur = self.step(state, batch)
            step_wall = time.perf_counter() - t0
            sim_t += dur
            if self.straggler is not None:
                # feed measured variance proxy into AUTO_M's estimator
                self.straggler.estimator.update_sigma2(
                    float(metrics["grad_sq"]))
            if state.step % log_every == 0 or i == num_steps - 1:
                hist.steps.append(state.step)
                hist.sim_seconds.append(sim_t)
                hist.losses.append(float(metrics["loss"]))
                hist.m_used.append(m)
                hist.wall_seconds.append(time.perf_counter() - wall0)
                hist.step_times.append(step_wall)
        self.final_state = state
        return hist
