"""Checkpointing: flat-key .npz snapshots of (params, opt_state, step).

Path-keyed (``stages/0/b0/mixer/wq``) so checkpoints survive refactors that
preserve the tree structure; list indices are path components. Restores
onto an existing example tree (shapes/dtypes validated leaf-by-leaf).
"""

from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint"]


def _flatten(tree) -> dict:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k2, v in node.items():
                walk(f"{prefix}/{k2}" if prefix else str(k2), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}/{i}" if prefix else str(i), v)
        else:
            flat[prefix] = np.asarray(node)

    walk("", tree)
    return flat


def _unflatten_onto(example, flat: dict):
    def walk(prefix, node):
        if isinstance(node, dict):
            return {k2: walk(f"{prefix}/{k2}" if prefix else str(k2), v)
                    for k2, v in node.items()}
        if isinstance(node, (list, tuple)):
            out = [walk(f"{prefix}/{i}" if prefix else str(i), v)
                   for i, v in enumerate(node)]
            return type(node)(out) if isinstance(node, tuple) else out
        arr = flat[prefix]
        if tuple(arr.shape) != tuple(np.shape(node)):
            raise ValueError(
                f"checkpoint mismatch at {prefix}: {arr.shape} vs "
                f"{np.shape(node)}")
        return jax.numpy.asarray(arr, dtype=node.dtype)

    return walk("", example)


def save_checkpoint(path: str, params, opt_state, step: int) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = {f"p:{k}": v for k, v in _flatten(params).items()}
    flat.update({f"o:{k}": v for k, v in _flatten(opt_state).items()})
    np.savez(path, __step__=np.int64(step), **flat)


def load_checkpoint(path: str, params_example, opt_example
                    ) -> Tuple[Any, Any, int]:
    with np.load(path) as z:
        step = int(z["__step__"])
        pf = {k[2:]: z[k] for k in z.files if k.startswith("p:")}
        of = {k[2:]: z[k] for k in z.files if k.startswith("o:")}
    params = _unflatten_onto(params_example, pf)
    opt = _unflatten_onto(opt_example, of)
    return params, opt, step
