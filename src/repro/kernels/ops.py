"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only; the
``REPRO_PALLAS_INTERPRET`` environment variable overrides it
(``REPRO_PALLAS_INTERPRET=0`` compiles the kernels — the real-TPU CI
lane and the launcher set this; anything else, or unset, keeps the
CPU-safe interpreter). The model code reaches these via
``cfg/impl == "pallas"`` (models/attention.py, models/ssm.py).
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax

from .flash_attention import flash_attention_pallas
from .moe_gmm import moe_gmm_pallas
from .order_stats import mth_smallest as _mth_smallest_dispatch
from .rwkv_scan import rwkv_scan_pallas

__all__ = ["flash_attention", "rwkv_scan", "moe_gmm", "mth_smallest"]

# CPU container default: interpret. REPRO_PALLAS_INTERPRET=0 => compiled
# Pallas lowering (real TPU runs / the opt-in CI lane).
INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


@functools.partial(jax.jit, static_argnames=("m",))
def mth_smallest(x, *, m: int):
    # CPU (INTERPRET=True): fused iterative/top_k dispatch; on TPU the
    # VMEM-resident Pallas partial-sort kernel
    return _mth_smallest_dispatch(x, m, use_pallas=not INTERPRET,
                                  interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None):
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=INTERPRET)


@jax.jit
def rwkv_scan(r, k, v, w, u):
    return rwkv_scan_pallas(r, k, v, w, u, interpret=INTERPRET)


@jax.jit
def moe_gmm(x, w):
    return moe_gmm_pallas(x, w, interpret=INTERPRET)
