"""Top-m partial-sort / m-th order statistic kernels.

The batched m-sync simulator (:mod:`repro.core.batch_jax`) needs the m-th
smallest candidate finish time per round — an ``O(m · n)`` partial
selection, not a full sort. XLA's CPU ``lax.top_k``/``sort`` lowerings
are per-round catastrophically slow (~2 ms for ``(32, 1000)``, dominating
the whole scan), so the default path is an *iterative tie-class
extraction* built from elementwise ops only, which XLA fuses into the
surrounding scan body: repeatedly drop the current row minimum's whole
tie class and remember the value once ``m`` elements have been covered.
For ``m = n`` the statistic degenerates to ``max``; for large ``m < n``
we fall back to ``lax.top_k`` (fine on TPU, the intended accelerator).

``mth_smallest_pallas`` is the same selection as a Pallas TPU kernel
(whole block in VMEM, ``fori_loop`` extraction) — validated in interpret
mode on CPU, worth using compiled on TPU where VMEM-resident iteration
beats a full sort for small ``m``.

For large ``m`` (``m > _MAX_ITERATIVE_M``, the Rennala/Malenia
``batch >> 64`` pools) the extraction loop's ``O(m · n)`` cost loses, but
``lax.top_k`` still forces the slow XLA sort lowering out of the fused
scan body. ``mth_smallest_counting`` keeps big-batch selection on the
fused path: a value-domain counting bisection (elementwise
``count(x <= mid)`` passes only) narrows an interval around the
statistic, a short snap loop lands on the exact element, and the result
is *verified* by rank counts — the rare unverified row (pathological tie
mass at the row minimum) falls back to ``lax.top_k`` behind a
``lax.cond``, so correctness never depends on the bisection converging.

Tie semantics everywhere: the m-th order statistic counts multiplicity
(``mth_smallest(x, m) == jnp.sort(x)[..., m-1]``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["mth_smallest", "mth_smallest_iterative", "mth_smallest_counting",
           "mth_smallest_rowwise", "mth_smallest_pallas", "smallest_k"]

# above this m the O(m*n) extraction loop loses to top_k even on CPU
_MAX_ITERATIVE_M = 64

# counting selection: value-bisection passes, then snap-to-element passes
_COUNT_BISECT_ITERS = 26
_COUNT_SNAP_ITERS = 8


def _extract_mth(x: jnp.ndarray, m: int) -> jnp.ndarray:
    """The shared tie-class-extraction loop (plain jax AND Pallas body).

    Each of the ``m`` iterations removes the entire tie class of the
    running minimum, so duplicated values are counted with multiplicity
    and the loop can stop early (per row) once ``m`` elements are
    covered. Elementwise ops only — fuses into enclosing scans and is
    legal inside a Pallas kernel.
    """
    batch = x.shape[:-1]

    def body(_, carry):
        rest, killed, val, done = carry
        mn = rest.min(axis=-1)
        # explicit int32: under x64 a bool sum defaults to int64, which
        # would promote the carried counter and break the fori_loop carry
        c = (rest == mn[..., None]).sum(axis=-1, dtype=jnp.int32)
        hit = (~done) & (killed + c >= m)
        val = jnp.where(hit, mn, val)
        done = done | hit
        rest = jnp.where(rest == mn[..., None], jnp.inf, rest)
        return rest, killed + c, val, done

    init = (x, jnp.zeros(batch, jnp.int32), jnp.zeros(batch, x.dtype),
            jnp.zeros(batch, bool))
    _, _, val, _ = lax.fori_loop(0, m, body, init)
    return val


def mth_smallest_iterative(x: jnp.ndarray, m: int) -> jnp.ndarray:
    """m-th smallest along the last axis via tie-class extraction."""
    return _extract_mth(x, m)


def _counting_select(x: jnp.ndarray, m: int):
    """Value-domain counting bisection for the m-th smallest.

    Returns ``(value, verified)``: per-row candidates plus one scalar
    flag that every row's candidate passed the exact rank check
    (``count(x < v) < m <= count(x <= v)``). Elementwise ops only, so
    XLA fuses the whole selection into an enclosing scan body — no
    ``sort``/``top_k`` lowering on the hot path.
    """
    batch = x.shape[:-1]
    # invariants: count(x <= lo) < m (lo below the whole row at start),
    # count(x <= hi) >= m (hi is the row max, count = n >= m)
    lo = x.min(axis=-1) - 1.0
    hi = x.max(axis=-1)

    def bisect(_, lh):
        lo, hi = lh
        mid = 0.5 * (lo + hi)
        ge = (x <= mid[..., None]).sum(axis=-1) >= m
        return jnp.where(ge, lo, mid), jnp.where(ge, mid, hi)

    lo, hi = lax.fori_loop(0, _COUNT_BISECT_ITERS, bisect, (lo, hi))

    # snap to the smallest element above lo; while the interval is still
    # wider than the gap between distinct row values, sub-threshold
    # elements can sit in (lo, answer) — advance lo past them (each
    # iteration consumes at least one tie class, and after the bisection
    # above more than one leftover is pathological)
    def cond(c):
        _, _, done, it = c
        return jnp.any(~done) & (it < _COUNT_SNAP_ITERS)

    def body(c):
        lo, val, done, it = c
        cand = jnp.where(x > lo[..., None], x, jnp.inf).min(axis=-1)
        ok = (x <= cand[..., None]).sum(axis=-1) >= m
        val = jnp.where(done, val, cand)
        lo = jnp.where(done | ok, lo, cand)
        return lo, val, done | ok, it + 1

    _, val, done, _ = lax.while_loop(
        cond, body,
        (lo, jnp.zeros(batch, x.dtype), jnp.zeros(batch, bool),
         jnp.zeros((), jnp.int32)))
    exact = ((x < val[..., None]).sum(axis=-1) < m) & done
    return val, jnp.all(exact)


def mth_smallest_counting(x: jnp.ndarray, m: int) -> jnp.ndarray:
    """m-th smallest along the last axis via counting bisection.

    The big-``m`` fused-path selection (``batch >> 64`` Rennala/Malenia
    pools): elementwise counting passes instead of a ``top_k`` sort
    lowering. Self-verifying — rows the bisection cannot certify fall
    back to ``lax.top_k`` behind a ``lax.cond`` (paid only when taken).
    """
    val, ok = _counting_select(x, m)
    return lax.cond(ok, lambda: val,
                    lambda: -lax.top_k(-x, m)[0][..., m - 1])


def mth_smallest_rowwise(x: jnp.ndarray, m: jnp.ndarray) -> jnp.ndarray:
    """m-th smallest along the last axis with a TRACED per-row ``m``.

    The sharded sweep backend fuses grid points with different ``m``
    into one compiled program, so ``m`` arrives as an ``(rows,)`` int32
    tensor instead of a static Python int. :func:`_counting_select`
    only consumes ``m`` through rank comparisons, so the same
    elementwise bisection works unchanged; the unverified-row fallback
    swaps ``lax.top_k`` (static ``k`` only) for a full-sort gather,
    paid only when the ``lax.cond`` is actually taken. Tie semantics
    are identical to :func:`mth_smallest`: the statistic counts
    multiplicity, so the snapped value equals
    ``jnp.sort(x)[..., m-1]`` bitwise (both select an element of
    ``x``).
    """
    m = jnp.asarray(m, jnp.int32)
    val, ok = _counting_select(x, m)

    def sort_select():
        order = jnp.sort(x, axis=-1)
        return jnp.take_along_axis(order, (m - 1)[..., None],
                                   axis=-1)[..., 0]

    return lax.cond(ok, lambda: val, sort_select)


def smallest_k(x, k: int, *, prefer_host: bool = None):
    """``(values, indices)`` of the ``k`` smallest entries per row in
    ascending order, ties broken by index (stable).

    This is the arrival-scan async engine's ONE-TIME merge of the
    ``(S, n*L)`` renewal-chain pool into global arrival order — it runs
    *between* jitted programs, not inside one, so the backend is free to
    pick the fastest sort for the platform:

    * **host** (default on CPU) — NumPy's stable argsort. XLA's CPU sort
      lowering is catastrophically slow for this shape (~115 ms for
      ``(32, 16000)`` vs ~15 ms in NumPy), the same lowering problem
      that motivated the iterative/counting selections above.
    * **device** (default on accelerators) — ``jnp.argsort`` keeps the
      pool resident; TPU/GPU sorts don't share the CPU lowering cliff.

    The host path is NOT jit-traceable (it materializes ``x``); pass
    ``prefer_host=False`` to force the device sort if you must call this
    under a trace.

    **Tie contract (rectangular AND ragged pools).** Equal values order
    by flat index — a stable sort in both backends. The rectangular
    ``(S, n, L)`` pool flattens worker-major, so ties break by (worker,
    within-worker arrival index); the ragged layout
    (:func:`repro.core.time_models.ragged_layout`) keeps that contract
    *by construction*: its flat buffer is still worker-major (worker
    ``i``'s whole budget precedes worker ``i+1``'s), so
    ``widx[flat_index]`` is nondecreasing and equal arrival times
    resolve to the same (worker, slot) winner as the rectangle would —
    which is why uniform-budget ragged runs are bitwise equal to
    rectangular ones even through tie rounds.

    **Full-merge fast path.** The ragged pool is sized to the arrival
    budget, so the arrival-scan engine routinely asks for ``k == n``
    (merge the ENTIRE pool) where the rectangular layout asked for a
    small prefix of a huge pool. For ``k == n`` the post-sort slice is
    skipped — NumPy's trailing slice would alias anyway, but on device
    the elided slice op lets XLA return the argsort buffer as-is
    instead of staging a copy of the full ``(S, n)`` order.
    """
    n = x.shape[-1]
    if not 1 <= k <= n:
        raise ValueError(f"k={k} out of range [1, {n}]")
    if prefer_host is None:
        prefer_host = jax.default_backend() == "cpu"
    if prefer_host and not isinstance(x, jax.core.Tracer):
        import numpy as np
        xh = np.asarray(x)
        order = np.argsort(xh, axis=-1, kind="stable")
        if k < n:
            order = order[..., :k]
        return (jnp.asarray(np.take_along_axis(xh, order, axis=-1)),
                jnp.asarray(order))
    order = jnp.argsort(x, axis=-1, stable=True)
    if k < n:
        order = order[..., :k]
    return jnp.take_along_axis(x, order, axis=-1), order


def _mth_smallest_kernel(m: int, x_ref, o_ref):
    o_ref[...] = _extract_mth(x_ref[...], m)[..., None]


def mth_smallest_pallas(x: jnp.ndarray, m: int, *,
                        interpret: bool = True) -> jnp.ndarray:
    """Pallas top-m partial-sort kernel: ``(S, n) -> (S,)``.

    One VMEM-resident block; the selection loop never leaves on-chip
    memory. ``interpret=True`` runs the kernel body in Python on CPU
    (this container); pass ``interpret=False`` on TPU.
    """
    if x.ndim != 2:
        raise ValueError(f"expected (rows, n), got {x.shape}")
    out = pl.pallas_call(
        functools.partial(_mth_smallest_kernel, m),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], 1), x.dtype),
        interpret=interpret,
    )(x)
    return out[:, 0]


def mth_smallest(x: jnp.ndarray, m: int, *, use_pallas: bool = False,
                 interpret: bool = True) -> jnp.ndarray:
    """m-th smallest along the last axis, backend chosen by shape/flags."""
    n = x.shape[-1]
    if not 1 <= m <= n:
        raise ValueError(f"m={m} out of range [1, {n}]")
    if use_pallas:
        shape = x.shape
        return mth_smallest_pallas(x.reshape(-1, n), m,
                                   interpret=interpret).reshape(shape[:-1])
    if m == n:
        return x.max(axis=-1)
    if m <= _MAX_ITERATIVE_M:
        return mth_smallest_iterative(x, m)
    return mth_smallest_counting(x, m)
