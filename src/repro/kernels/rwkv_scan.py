"""RWKV-6 chunked linear-recurrence Pallas kernel (TPU target).

One program instance per (batch, head): the kernel walks the sequence in
chunks of ``chunk`` tokens, carrying the (K, V) state in VMEM scratch. Per
chunk (mirroring models/ssm.chunked_scan exactly, RWKV convention):

    P      = cumprod(w) along the chunk (inclusive)        [VPU]
    y_in   = (r * P/w) @ S                                 [MXU KxV]
    att    = ((r * P/w) @ (k/P)^T) * strict_lower + diag(u·r·k)
    y      = y_in + att @ v                                [MXU cxc, cxV]
    S      = diag(P_tot) S + ((P_tot/P) * k)^T @ v         [MXU Kxc @ cxV]

VMEM footprint per instance: chunk x K x 5 + K x V + chunk x chunk floats
= 64x64x5 + 64x64 + 64x64 ≈ 110 KiB at (chunk, K, V) = (64, 64, 64) —
MXU-aligned matmuls throughout (the head_dim of RWKV-6 is 64; two heads
could be fused per instance to fill the 128-lane MXU, which is the
documented follow-up optimization).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rwkv_scan_pallas"]


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref,
                 s_scratch, *, chunk: int, seq: int):
    K = r_ref.shape[-1]
    V = v_ref.shape[-1]
    n_chunks = seq // chunk

    s_scratch[...] = jnp.zeros((K, V), jnp.float32)

    def chunk_body(c, _):
        sl = pl.dslice(c * chunk, chunk)
        r = pl.load(r_ref, (sl, slice(None))).astype(jnp.float32)
        k = pl.load(k_ref, (sl, slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (sl, slice(None))).astype(jnp.float32)
        w = pl.load(w_ref, (sl, slice(None))).astype(jnp.float32)
        u = u_ref[...].astype(jnp.float32)            # (K,)
        s = s_scratch[...]

        logw = jnp.log(jnp.maximum(w, 1e-30))
        P = jnp.exp(jnp.cumsum(logw, axis=0))         # inclusive (c, K)
        Pq = P / jnp.maximum(w, 1e-30)                # exclusive
        q_in = r * Pq
        y = q_in @ s                                  # (c, V)
        kP = k / jnp.maximum(P, 1e-30)
        att = q_in @ kP.T                             # (c, c)
        ti = jax.lax.iota(jnp.int32, chunk)
        tri = (ti[:, None] > ti[None, :]).astype(jnp.float32)
        att = att * tri
        diag = jnp.sum(r * u[None, :] * k, axis=1)    # (c,)
        att = att + jnp.eye(chunk, dtype=jnp.float32) * diag[:, None]
        y = y + att @ v
        Ptot = P[-1]                                  # (K,)
        # state writes use (Ptot / P_j) * k_j — kP already holds k_j / P_j
        s_new = s * Ptot[:, None] + (Ptot[None, :] * kP).T @ v
        s_scratch[...] = s_new
        pl.store(y_ref, (sl, slice(None)), y.astype(y_ref.dtype))
        return 0

    jax.lax.fori_loop(0, n_chunks, chunk_body, 0)
    s_out_ref[...] = s_scratch[...].astype(s_out_ref.dtype)


def rwkv_scan_pallas(r, k, v, w, u, *, chunk: int = 64,
                     interpret: bool = True):
    """r,k,w: (B, T, H, K); v: (B, T, H, V); u: (H, K).
    Returns (y (B, T, H, V), state (B, H, K, V)). T padded to chunk."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    pad = (-T) % chunk
    if pad:
        z = jnp.zeros((B, pad, H, K), r.dtype)
        r = jnp.concatenate([r, z], 1)
        k = jnp.concatenate([k, z], 1)
        v = jnp.concatenate([v, jnp.zeros((B, pad, H, V), v.dtype)], 1)
        w = jnp.concatenate([w, jnp.ones((B, pad, H, K), w.dtype)], 1)
    Tp = r.shape[1]

    rt = r.transpose(0, 2, 1, 3)                     # (B, H, T, K)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    wt = w.transpose(0, 2, 1, 3)

    kernel = functools.partial(_rwkv_kernel, chunk=chunk, seq=Tp)
    y, s = pl.pallas_call(
        kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((None, None, Tp, K), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, Tp, K), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, Tp, V), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, Tp, K), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, K), lambda b, h: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, None, Tp, V), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, K, V), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Tp, V), jnp.float32),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u)
    y = y.transpose(0, 2, 1, 3)[:, :T]
    return y, s
