"""Flash attention Pallas kernel (TPU target, interpret-mode validated).

Online-softmax tiled attention: for each (batch, q-head, q-block) program
instance, stream KV blocks through VMEM, maintaining the running max ``m``,
normalizer ``l`` and accumulator ``acc``:

    s   = q @ k_j^T * scale           (MXU: block_q x block_k)
    m'  = max(m, rowmax(s))
    p   = exp(s - m')
    acc = acc * exp(m - m') + p @ v_j (MXU: block_q x head_dim)
    l   = l * exp(m - m') + rowsum(p)
    out = acc / l

Block sizes default to 128x128 — MXU-aligned (the systolic array is
128x128; VMEM footprint per instance is
``block_q*dh + 2*block_k*dh + block_q*block_k`` floats ≈ 190 KiB at
dh=128, far under the ~16 MiB/core VMEM budget, leaving room for
double-buffered prefetch of the next KV block).

GQA is handled by folding the group into the q-head grid axis and indexing
the KV head as ``h // group_size`` in the BlockSpec index maps — no
repeated KV materialization in HBM.

Causal + sliding-window masking is applied inside the kernel; fully-masked
KV blocks are skipped via the grid's block-level early-out (mask computed
from block indices).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
                 window: Optional[int], block_q: int, block_k: int,
                 seq_k: int):
    qi = pl.program_id(2)
    nk = pl.cdiv(seq_k, block_k)

    q = q_ref[...].astype(jnp.float32) * scale        # (bq, dh)
    m = jnp.full((block_q,), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q,), jnp.float32)
    acc = jnp.zeros_like(q)

    def body(kj, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(kj * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(kj * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        s = q @ k.T                                   # (bq, bk)
        q_pos = qi * block_q + jax.lax.iota(jnp.int32, block_q)
        k_pos = kj * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        mask &= (k_pos < seq_k)[None, :]
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    # causal early-out: KV blocks strictly above the diagonal contribute
    # nothing; stop the streaming loop at the last needed block.
    if causal:
        upper = jnp.minimum(nk, (qi + 1) * block_q // block_k + 1)
    else:
        upper = nk
    lower = 0
    if window is not None:
        lower = jnp.maximum(0, (qi * block_q - window) // block_k)
    m, l, acc = jax.lax.fori_loop(lower, upper, body, (m, l, acc))
    l = jnp.maximum(l, 1e-30)
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q: (B, Sq, H, dh); k, v: (B, Sk, KV, dh) with H % KV == 0.
    Returns (B, Sq, H, dh). ``interpret=True`` runs the kernel body in
    Python on CPU (this container); on TPU pass ``interpret=False``.
    """
    B, Sq, H, dh = q.shape
    _, Sk, KV, _ = k.shape
    assert H % KV == 0
    group = H // KV
    scale = 1.0 / math.sqrt(dh)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    # pad seq to block multiples (masked out inside the kernel)
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sqp, Skp = q.shape[1], k.shape[1]

    # layout: (B, H, S, dh) so the head is a grid axis
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, Sqp // block_q)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_k=Sk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, dh),
                         lambda b, h, i: (b, h, i, 0)),
            # whole KV stream for this kv-head stays in VMEM-addressable
            # blocks; the kernel dslices block_k chunks out of it
            pl.BlockSpec((None, None, Skp, dh),
                         lambda b, h, i, g=group: (b, h // g, 0, 0)),
            pl.BlockSpec((None, None, Skp, dh),
                         lambda b, h, i, g=group: (b, h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, dh),
                               lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sqp, dh), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    out = out.transpose(0, 2, 1, 3)
    if pad_q:
        out = out[:, :Sq]
    return out
