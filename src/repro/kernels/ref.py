"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..models.ssm import reference_scan

__all__ = ["attention_ref", "rwkv_scan_ref", "moe_gmm_ref"]


def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None):
    """Naive masked softmax attention. q: (B,Sq,H,dh); k,v: (B,Sk,KV,dh)."""
    B, Sq, H, dh = q.shape
    _, Sk, KV, _ = k.shape
    group = H // KV
    kq = jnp.repeat(k, group, axis=2).astype(jnp.float32)
    vq = jnp.repeat(v, group, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kq) \
        / jnp.sqrt(dh)
    if causal or window is not None:
        qi = jnp.arange(Sq)[:, None]
        ki = jnp.arange(Sk)[None, :]
        ok = jnp.ones((Sq, Sk), bool)
        if causal:
            ok &= ki <= qi
        if window is not None:
            ok &= ki > qi - window
        s = jnp.where(ok[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vq)
    return o.astype(q.dtype)


def rwkv_scan_ref(r, k, v, w, u, state0=None):
    """Step-by-step RWKV-6 recurrence (models/ssm.reference_scan, u-form)."""
    return reference_scan(r, k, v, w, u=u, state0=state0)


def moe_gmm_ref(x, w):
    """x: (E, C, din); w: (E, din, dout)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
