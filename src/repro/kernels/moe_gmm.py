"""Grouped matmul (GMM) Pallas kernel — the MoE expert-FFN hot loop.

Computes ``out[e] = x[e] @ w[e]`` for ``E`` expert buffers of shape
(capacity, din) against per-expert weights (din, dout), i.e. the
``einsum("ecd,edf->ecf")`` at the heart of models/moe.py.

Grid: (E, capacity/block_m, dout/block_n); the contraction dim din is
streamed through VMEM in block_k slices with a float32 accumulator in
scratch. Blocks default to 128x128x128 (MXU-aligned); VMEM per instance =
(block_m + block_n) * block_k + block_m * block_n floats ≈ 190 KiB.

A production variant would take ragged ``group_sizes`` (dropless MoE) and
skip empty tiles via scalar prefetch; with fixed capacity the dense grid
is already the exact cost model the dry-run measures.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["moe_gmm_pallas"]


def _gmm_kernel(x_ref, w_ref, o_ref, acc, *, n_k: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    acc[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                        w_ref[...].astype(jnp.float32),
                        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == n_k - 1)
    def _flush():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def moe_gmm_pallas(x, w, *, block_m: int = 128, block_n: int = 128,
                   block_k: int = 128, interpret: bool = True):
    """x: (E, C, din); w: (E, din, dout) -> (E, C, dout)."""
    E, C, din = x.shape
    _, _, dout = w.shape
    block_m = min(block_m, C)
    block_n = min(block_n, dout)
    block_k = min(block_k, din)
    pad_m = (-C) % block_m
    pad_n = (-dout) % block_n
    pad_k = (-din) % block_k
    if pad_m or pad_k:
        x = jnp.pad(x, ((0, 0), (0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        w = jnp.pad(w, ((0, 0), (0, pad_k), (0, pad_n)))
    Cp, dinp, doutp = x.shape[1], x.shape[2], w.shape[2]
    n_k = dinp // block_k

    out = pl.pallas_call(
        functools.partial(_gmm_kernel, n_k=n_k),
        grid=(E, Cp // block_m, doutp // block_n, n_k),
        in_specs=[
            pl.BlockSpec((None, block_m, block_k),
                         lambda e, i, j, kk: (e, i, kk)),
            pl.BlockSpec((None, block_k, block_n),
                         lambda e, i, j, kk: (e, kk, j)),
        ],
        out_specs=pl.BlockSpec((None, block_m, block_n),
                               lambda e, i, j, kk: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, doutp), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:, :C, :dout]
