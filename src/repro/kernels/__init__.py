from . import ops, ref
