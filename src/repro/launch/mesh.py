"""Production mesh builders.

Kept as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* jax
initializes, and smoke tests must see 1 device.
"""

from __future__ import annotations

import jax

__all__ = ["make_mesh_auto", "make_production_mesh", "POD_SHAPE",
           "MULTIPOD_SHAPE"]

POD_SHAPE = (16, 16)                 # 256 chips / pod (v5e-256)
MULTIPOD_SHAPE = (2, 16, 16)         # 2 pods = 512 chips


def make_mesh_auto(shape, axes):
    """``jax.make_mesh`` with Auto axis types, portable across jax versions.

    We shard via in_shardings + constraints (GSPMD), not the
    explicit-sharding API. ``AxisType`` only exists on jax >= 0.5; older
    jax is Auto-only, so plain ``make_mesh`` is equivalent there.
    """
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTIPOD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_auto(shape, axes)
