"""Production mesh builders.

Kept as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* jax
initializes, and smoke tests must see 1 device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "POD_SHAPE", "MULTIPOD_SHAPE"]

POD_SHAPE = (16, 16)                 # 256 chips / pod (v5e-256)
MULTIPOD_SHAPE = (2, 16, 16)         # 2 pods = 512 chips


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTIPOD_SHAPE if multi_pod else POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # Auto axis types: we shard via in_shardings + constraints (GSPMD),
    # not the explicit-sharding API.
    from jax.sharding import AxisType
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))
