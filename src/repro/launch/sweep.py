"""Sharded sweep backend: ``shard_map`` the flattened (grid point × seed)
work units of a :func:`repro.core.simulate_batch` sweep across devices.

The paper's claims are statements about whole (scenario × strategy ×
seed) grids, but every jax engine in :mod:`repro.core.batch_jax` vmaps
seeds on a single device, so paper-scale sweeps serialize over grid
points — and the closure-compiled programs (sampled models, oracles)
recompile per point. This module is the ``backend="jax_sharded"``
orchestrator that fixes both:

* **Flatten** — every (grid point, seed) pair becomes one *work unit*;
  the unit axis is the thing sharded. Per-seed draw streams are already
  sweep-independent pure functions of ``PRNGKey(seed)`` (the DESIGN §3b
  RNG contract), so flattening units across grid points needs no RNG
  re-plumbing and preserves per-seed bitwise parity with the unsharded
  ``backend="jax"`` path.
* **Shape-bucket** — units whose compiled program would be identical
  (same engine family, ``(n, K)``, model/oracle identity, static
  strategy params) share one *bucket* → one compiled program. The
  m-sync family goes further: timing-only buckets fuse heterogeneous
  ``m`` (traced row-wise selection) and math buckets fuse heterogeneous
  ``gamma`` (traced per-unit stepsize), so a whole ``m``- or
  ``gamma``-sweep is ONE program instead of one compile per point.
* **Shard** — each bucket's unit batch is padded to a multiple of the
  mesh size (repeating unit 0 — rows are independent, so padding is
  inert) and ``shard_map``ped over the 1-D ``data`` axis built from
  :func:`repro.launch.mesh.make_mesh_auto`; the per-device programs hit
  the same jit cache. Outputs come back replicated/gathered (GSPMD
  all-gather on the unit axis), are sliced back per point, and packaged
  with the same :func:`repro.core.batch_jax.assemble_traces` the
  unsharded backend uses.

Engine support: the m-sync round scan (fused + sharded), the
Async/Ringmaster arrival scan (chain build + scan sharded over units;
pool merge and compaction host-side as in the unsharded engine), and
the whole round-scan family — Rennala and Malenia renewal round scans
and the Ringleader chunked ragged-chain round scan — each
``shard_map``ped over the unit rows with AOT program caching. No
engine family routes to per-point ``fallback`` anymore; the branch
remains only as the safety net for future non-shardable kinds.

Multi-host: the mesh covers the local process's devices;
:func:`is_coordinator` (``jax.process_index() == 0``) gates artifact
writing in :func:`repro.exp.run_experiment` so an N-host launch writes
one JSON, not N.

Instrumentation: every bucket records compile vs execute wall time and
program-cache hits (AOT ``lower().compile()`` in the engine layer);
:func:`repro.core.simulate_batch` surfaces the record per grid point in
``TraceBatch.routing`` meta.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["SweepPoint", "sweep_device_count", "is_coordinator",
           "sweep_mesh", "sweep_shard_ctx", "shardable_kind",
           "run_sharded_sweep"]

#: jax engine families with a sharded program (everything else falls
#: back to the per-point unsharded jax engine inside the sweep)
SHARDED_KINDS = ("msync", "async", "ringmaster", "optimal_asgd",
                 "rennala", "malenia", "ringleader")


@dataclasses.dataclass
class SweepPoint:
    """One grid point of a sharded sweep: a bound strategy plus the
    per-point :func:`simulate` arguments the grid may override."""

    index: int                         # position in the TraceBatch grid
    strategy: Any                      # bound AggregationStrategy
    K: int
    gamma: float = 0.0
    record_every: int = 1


def sweep_device_count() -> int:
    """Devices visible to this process (the 1-D ``data`` mesh size)."""
    import jax

    return jax.local_device_count()


def is_coordinator() -> bool:
    """True on the process that should write gathered artifacts."""
    import jax

    return jax.process_index() == 0


def sweep_mesh(devices: Optional[int] = None):
    """The sweep's 1-D ``("data",)`` mesh over the local devices."""
    from .mesh import make_mesh_auto

    return make_mesh_auto((devices or sweep_device_count(),), ("data",))


def sweep_shard_ctx(devices: Optional[int] = None):
    """A :class:`repro.sharding.specs.ShardCtx` for the sweep mesh:
    data-parallel only (``model_axis=None``) — sweeps shard work units,
    never parameters."""
    from ..sharding.specs import ShardCtx

    return ShardCtx(mesh=sweep_mesh(devices), dp_axes=("data",),
                    model_axis=None)


def shardable_kind(strategy, model, problem) -> Optional[str]:
    """The engine family a sharded program exists for, or None (the
    point still runs inside the sweep, via per-point fallback)."""
    from ..core.batch_jax import _classify

    kind = _classify(strategy)
    return kind if kind in SHARDED_KINDS else None


def _bucket_key(kind: Optional[str], point: SweepPoint, math: bool):
    """Static program signature: points with equal keys share one
    compiled program. ``m`` is traced for timing m-sync (any ``m``
    fuses), static for math m-sync (the oracle batch splits ``m``
    ways); ``gamma`` is traced for math m-sync, static for the arrival
    scan."""
    if kind == "msync":
        if math:
            return ("msync-math", int(point.K), int(point.strategy._m))
        return ("msync-timing", int(point.K))
    if kind in ("async", "ringmaster", "optimal_asgd"):
        md = int(point.strategy.max_delay) \
            if kind in ("ringmaster", "optimal_asgd") else int(point.K) + 1
        adaptive = bool(getattr(point.strategy, "delay_adaptive", False))
        return ("arrival", kind, int(point.K), md, adaptive,
                float(point.gamma) if math else 0.0)
    if kind == "rennala":
        return ("rennala", int(point.K), int(point.strategy.batch),
                float(point.gamma) if math else 0.0)
    if kind == "malenia":
        return ("malenia", int(point.K), float(point.strategy.S),
                float(point.gamma) if math else 0.0)
    if kind == "ringleader":
        return ("ringleader", int(point.K),
                float(point.gamma) if math else 0.0)
    return ("fallback", point.index)


def run_sharded_sweep(points: Sequence[SweepPoint], model, problem,
                      seeds: Sequence[int], use_pallas: bool = False,
                      x64: bool = False, mesh=None,
                      ) -> Dict[int, Tuple[List[Any], Dict[str, Any]]]:
    """Run every grid point × seed as one sharded, shape-bucketed sweep.

    Returns ``{point.index: (traces, record)}`` where ``traces`` is the
    per-seed :class:`~repro.core.strategies.Trace` list (bitwise equal
    to the unsharded ``backend="jax"`` run of that point) and
    ``record`` is the per-point shard meta for ``TraceBatch.routing``.
    """
    import jax

    if x64 and not jax.config.jax_enable_x64:
        from jax.experimental import enable_x64
        with enable_x64():
            return run_sharded_sweep(points, model, problem, seeds,
                                     use_pallas=use_pallas, x64=False,
                                     mesh=mesh)

    from ..core import batch_jax as bj

    if mesh is None:
        mesh = sweep_mesh()
    D = int(mesh.devices.size)
    n = model.n
    S = len(seeds)
    math = problem is not None
    for p in points:
        p.strategy.bind(n)
        bj._check_supported(p.strategy, model, problem)

    buckets: Dict[tuple, List[SweepPoint]] = {}
    for p in points:
        kind = shardable_kind(p.strategy, model, problem)
        buckets.setdefault(_bucket_key(kind, p, math), []).append(p)

    def _run_bucket(bkey, bpoints
                    ) -> Dict[int, Tuple[List[Any], Dict[str, Any]]]:
        out: Dict[int, Tuple[List[Any], Dict[str, Any]]] = {}
        base_rec = {"bucket": "/".join(str(b) for b in bkey),
                    "devices": D, "points_in_bucket": len(bpoints),
                    "units": len(bpoints) * S}
        if bkey[0] == "fallback":
            # no sharded program for this family yet: plain jax engine
            p = bpoints[0]
            traces = bj.simulate_batch_jax(
                p.strategy, model, p.K, problem=problem, gamma=p.gamma,
                seeds=seeds, record_every=p.record_every,
                use_pallas=use_pallas)
            out[p.index] = (traces, {**base_rec, "fallback": True})
            return out

        # flatten point-major so each point's seeds are one column slice
        unit_seeds = [int(s) for p in bpoints for s in seeds]
        U0 = len(unit_seeds)
        pad = (-U0) % D
        unit_seeds += [unit_seeds[0]] * pad         # inert: rows independent
        meta: Dict[str, Any] = {}

        if bkey[0].startswith("msync"):
            K = bpoints[0].K
            m_units = [int(p.strategy._m) for p in bpoints for _ in seeds]
            g_units = [float(p.gamma) for p in bpoints for _ in seeds]
            m_units += [m_units[0]] * pad
            g_units += [g_units[0]] * pad
            comp, x, T, val, gn = bj.sharded_msync_run(
                model, problem, n, len(unit_seeds), K, unit_seeds,
                m_units, g_units, use_pallas, mesh, meta=meta)
            comp, T = np.asarray(comp), np.asarray(T)
            if math:
                x, val, gn = np.asarray(x), np.asarray(val), np.asarray(gn)
            for i, p in enumerate(bpoints):
                c = slice(i * S, (i + 1) * S)
                traces = bj.assemble_traces(
                    comp[c], None if not math else x[c], T[:, c],
                    None if not math else val[:, c],
                    None if not math else gn[:, c],
                    int(p.strategy._m) * K, S, K, p.record_every, problem)
                out[p.index] = (traces, {**base_rec, "padded_units": pad,
                                         **meta})
        elif bkey[0] == "arrival":
            _, kind, K, md, adaptive, gamma = bkey
            comp, x, T, val, gn = bj._chain_scan_run(
                model, problem, kind in ("ringmaster", "optimal_asgd"),
                md, adaptive, n, len(unit_seeds), K, gamma, unit_seeds,
                mesh=mesh, meta=meta)
            comp, T = np.asarray(comp), np.asarray(T)
            for i, p in enumerate(bpoints):
                c = slice(i * S, (i + 1) * S)
                traces = bj.assemble_traces(
                    comp[c], None if not math else np.asarray(x)[c],
                    T[:, c],
                    None if not math else np.asarray(val)[:, c],
                    None if not math else np.asarray(gn)[:, c],
                    K, S, K, p.record_every, problem)
                out[p.index] = (traces, {**base_rec, "padded_units": pad,
                                         **meta})
        else:                                       # round-scan family
            fam = bkey[0]
            if fam == "rennala":
                _, K, B, gamma = bkey
                comp, x, T, val, gn = bj._rennala_run(
                    model, problem, B, n, len(unit_seeds), K, gamma,
                    use_pallas, unit_seeds, mesh=mesh, meta=meta)
                used = np.full(len(unit_seeds), B * K)
            elif fam == "malenia":
                _, K, S_t, gamma = bkey
                comp, x, T, val, gn, used = bj._malenia_run(
                    model, problem, S_t, n, len(unit_seeds), K, gamma,
                    unit_seeds, mesh=mesh, meta=meta)
                used = np.asarray(used)
            else:                                   # ringleader
                _, K, gamma = bkey
                comp, x, T, val, gn, used = bj._ringleader_run(
                    model, problem, n, len(unit_seeds), K, gamma,
                    unit_seeds, mesh=mesh, meta=meta)
                used = np.asarray(used)
            comp, T = np.asarray(comp), np.asarray(T)
            for i, p in enumerate(bpoints):
                c = slice(i * S, (i + 1) * S)
                traces = bj.assemble_traces(
                    comp[c], None if not math else np.asarray(x)[c],
                    T[:, c],
                    None if not math else np.asarray(val)[:, c],
                    None if not math else np.asarray(gn)[:, c],
                    used[c], S, K, p.record_every, problem)
                out[p.index] = (traces, {**base_rec, "padded_units": pad,
                                         **meta})
        return out

    # Per-bucket degradation (DESIGN §3c): a failing sharded bucket is
    # retried once, then its points run the plain per-point jax engine
    # with the downgrade recorded in the per-point shard meta. Only if
    # the per-point engine also fails does the exception propagate (the
    # simulate_batch fused ladder takes over from there).
    out: Dict[int, Tuple[List[Any], Dict[str, Any]]] = {}
    for bkey, bpoints in buckets.items():
        try:
            out.update(_run_bucket(bkey, bpoints))
        except Exception:
            try:
                out.update(_run_bucket(bkey, bpoints))
            except Exception as exc:
                down = {"from": "jax_sharded:bucket", "to": "jax",
                        "error": type(exc).__name__,
                        "reason": str(exc)[:300], "retried": True}
                for p in bpoints:
                    traces = bj.simulate_batch_jax(
                        p.strategy, model, p.K, problem=problem,
                        gamma=p.gamma, seeds=seeds,
                        record_every=p.record_every,
                        use_pallas=use_pallas)
                    out[p.index] = (traces, {
                        "bucket": "/".join(str(b) for b in bkey),
                        "devices": D, "fallback": True,
                        "downgrades": [down]})
    return out
