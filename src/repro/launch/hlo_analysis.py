"""Post-SPMD HLO analysis: collective bytes + roofline terms.

``compiled.cost_analysis()`` gives HLO FLOPs and bytes-accessed but not
collective traffic; we parse the per-partition HLO text instead:

1. build a symbol table ``%name -> bytes`` from every defining line;
2. for each all-gather / all-reduce / reduce-scatter / all-to-all /
   collective-permute instruction, sum its *operand* sizes;
3. collectives inside ``while`` bodies (our scan-over-layers) execute
   ``trip_count`` times: trip counts are recovered from the loop-condition
   comparison constant and attributed to the body computation.

Everything is per-device (post-GSPMD HLO is the per-partition program).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (constants below; override per call if targeting another part).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

__all__ = ["CollectiveStats", "collective_bytes", "Roofline",
           "roofline_terms", "PEAK_FLOPS", "HBM_BW", "ICI_BW"]

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    total_bytes: float
    by_kind: Dict[str, float]
    count: int


_COMP_HDR = re.compile(
    r"^\s*(?:ENTRY\s+)?(%[\w.\-]+)\s+\(.*\)\s*->\s*\S.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=(%[\w.\-]+),\s*body=(%[\w.\-]+)")
_TRIPS_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")


def _split_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text (headers have nested parens/brackets,
    so match greedily on the arrow + trailing brace)."""
    comps = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line)
        if m:
            if cur_name is not None:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name = m.group(1).lstrip("%")
            cur_lines = []
        elif line.strip() == "}":
            if cur_name is not None:
                comps[cur_name] = "\n".join(cur_lines)
                cur_name = None
                cur_lines = []
        elif cur_name is not None:
            cur_lines.append(line)
    if cur_name is not None:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


def _trip_count_from_line(line: str, comps: Dict[str, str],
                          cond_name: str) -> int:
    """Trip count of one while instruction: prefer XLA's own
    backend_config known_trip_count; fall back to the condition compare."""
    m = _TRIPS_RE.search(line)
    if m:
        return int(m.group(1))
    cond_body = comps.get(cond_name.lstrip("%"), "")
    consts = {}
    for cm in re.finditer(
            r"(%[\w.\-]+)\s*=\s*s(?:32|64)\[\]\s*constant\((\d+)\)",
            cond_body):
        consts[cm.group(1)] = int(cm.group(2))
    cmp = re.search(r"compare\(([^)]*)\)", cond_body)
    if cmp:
        for op in cmp.group(1).split(","):
            op = op.strip().split(" ")[-1]
            if op in consts:
                return consts[op]
    return max(consts.values()) if consts else 1


def _body_multipliers(comps: Dict[str, str]) -> Dict[str, int]:
    """computation name -> execution multiplier (nested loops compose)."""
    # edges: computation -> (body, trips) for each while it contains
    edges = {}
    for cname, body in comps.items():
        for line in body.splitlines():
            m = _WHILE_RE.search(line)
            if m:
                trips = _trip_count_from_line(line, comps, m.group(1))
                edges.setdefault(cname, []).append(
                    (m.group(2).lstrip("%"), trips))
    mult = {c: 1 for c in comps}
    for _ in range(6):  # fixpoint over nesting depth
        changed = False
        for parent, kids in edges.items():
            for child, trips in kids:
                want = mult.get(parent, 1) * trips
                if mult.get(child, 1) < want:
                    mult[child] = want
                    changed = True
        if not changed:
            break
    return mult


def collective_bytes(hlo: str) -> CollectiveStats:
    comps = _split_computations(hlo)
    # symbol table per computation: name -> result bytes
    by_kind: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    count = 0
    body_trips = _body_multipliers(comps)

    for cname, body in comps.items():
        mult = body_trips.get(cname, 1)
        symbols: Dict[str, int] = {}
        for line in body.splitlines():
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            name, rhs = dm.group(1), dm.group(2)
            tm = re.match(r"^\(?([a-z0-9]+\[[0-9,]*\][^)]*|\([^)]*\))", rhs)
            symbols[name] = _shape_bytes(rhs.split(" ")[0])
            for kind in _COLLECTIVES:
                if re.search(rf"\b{kind}(?:-start|-done)?\(", rhs):
                    if f"{kind}-done(" in rhs:
                        continue  # counted at -start
                    args = re.search(rf"{kind}(?:-start)?\(([^)]*)\)", rhs)
                    # operand types may carry layout braces (f32[8,4]{1,0}),
                    # so pick out the %names rather than splitting on ","
                    ops = [] if not args else re.findall(r"%[\w.\-]+",
                                                         args.group(1))
                    b = sum(symbols.get(o, 0) for o in ops)
                    if b == 0:
                        # operand defined in another computation (rare) —
                        # fall back to the result size
                        b = symbols.get(name, 0)
                    by_kind[kind] += b * mult
                    count += mult
    return CollectiveStats(sum(by_kind.values()), by_kind, count)


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_hbm: float
    bytes_coll: float
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0


def roofline_terms(cost: dict, coll: CollectiveStats, *,
                   n_chips: int, model_flops: float = 0.0,
                   peak_flops: float = PEAK_FLOPS, hbm_bw: float = HBM_BW,
                   ici_bw: float = ICI_BW) -> Roofline:
    """cost: ``compiled.cost_analysis()``. The post-GSPMD module is the
    *per-partition* program, so its flops/bytes are already per-device
    (verified empirically: a (512,512)@(512,512) matmul sharded over 8
    devices reports 2*512^3/8 flops). ``model_flops`` is the whole-step
    6·N·D and is divided by n_chips for the per-device comparison."""
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    return Roofline(
        compute_s=flops / peak_flops,
        memory_s=bytes_hbm / hbm_bw,
        collective_s=coll.total_bytes / ici_bw,   # already per-device
        flops=flops, bytes_hbm=bytes_hbm, bytes_coll=coll.total_bytes,
        model_flops=model_flops / n_chips)


# ---------------------------------------------------------------------------
# Loop-aware HLO cost: XLA's HloCostAnalysis counts while bodies ONCE, so a
# scan-over-layers program underreports flops/bytes by ~num_layers. We
# re-derive both from the HLO text with trip-count multipliers.
# ---------------------------------------------------------------------------

_DOT_RE = re.compile(r"=\s*(?:[a-z0-9]+\[[0-9,]*\][^ ]*\s+)?dot\(")
_DNUMS_RE = re.compile(
    r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS_RE = re.compile(r"dot\(([^)]*)\)")


def _result_elems(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, 0
    dims = [int(d) for d in m.group(2).split(",") if d]
    n = 1
    for d in dims:
        n *= d
    return dims, n


def hlo_cost(hlo: str) -> dict:
    """Loop-aware (flops, bytes) estimate.

    flops: 2 * |result| * prod(lhs contracting dims) per ``dot``.
    bytes: per top-level instruction, result + operand sizes (mirrors
    HloCostAnalysis's operands+outputs accounting, at fusion granularity).
    Both scaled by the enclosing while loop's trip count.
    """
    comps = _split_computations(hlo)
    body_trips = _body_multipliers(comps)

    total_flops = 0.0
    total_bytes = 0.0
    for cname, body in comps.items():
        mult = body_trips.get(cname, 1)
        # symbol table: name -> (dims, bytes)
        sym: Dict[str, tuple] = {}
        for line in body.splitlines():
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            name, rhs = dm.group(1), dm.group(2)
            tstr = rhs.split(" ")[0]
            dims, _ = _result_elems(tstr)
            b = _shape_bytes(tstr)
            sym[name] = (dims, b)
            # zero-cost ops: aliases and control flow. A while's result IS
            # its (possibly multi-GB) carry tuple, and gte/tuple/bitcast
            # move no bytes — counting them inflates loop-carried state by
            # trip_count x carry_size (hundreds of TB at jamba scale).
            opm_ = re.search(r"\s([a-z][a-z\-]*)\(", " " + rhs)
            opname = opm_.group(1) if opm_ else ""
            if opname in ("constant", "parameter", "get-tuple-element",
                          "tuple", "bitcast", "while", "conditional",
                          "after-all", "add-dependency"):
                continue
            # ---- bytes: result + operands of this instruction
            op_list = []
            first_paren = re.search(r"[\w\-]+\(([^)]*)\)", rhs)
            if first_paren:
                # %names only: operand types may carry layout braces
                # (f32[8,4]{1,0}) whose commas break naive splitting
                for a in re.findall(r"%[\w.\-]+", first_paren.group(1)):
                    if a in sym:
                        op_list.append(sym[a][1])
            if "dynamic-update-slice" in rhs or \
                    "dynamic-update-slice" in name:
                # in-place slice write: touches the update (non-buffer
                # operands) twice, NOT the whole buffer — counting the
                # buffer inflates loop bodies by trip_count x buffer_size
                upd = sum(op_list) - (max(op_list) if op_list else 0)
                total_bytes += 2 * upd * mult
            elif "dynamic-slice" in rhs or "dynamic-slice" in name:
                total_bytes += 2 * b * mult          # slice read + write
            else:
                total_bytes += (b + sum(op_list)) * mult
            # ---- flops for dots
            if re.search(r"\bdot\(", rhs):
                _, res_elems = _result_elems(tstr)
                cd = _DNUMS_RE.search(rhs)
                k = 1
                opm = _OPERANDS_RE.search(rhs)
                if cd and opm:
                    names = re.findall(r"%[\w.\-]+", opm.group(1))
                    lhs_name = names[0] if names else ""
                    lhs_dims = sym.get(lhs_name, (None, 0))[0]
                    if lhs_dims is not None:
                        for d in cd.group(1).split(","):
                            if d:
                                k *= lhs_dims[int(d)]
                total_flops += 2.0 * res_elems * k * mult
    return {"flops": total_flops, "bytes": total_bytes,
            "bytes accessed": total_bytes}
