"""Training launcher: the end-to-end driver.

Examples (CPU, reduced scale):
  PYTHONPATH=src python -m repro.launch.train --arch nanogpt-paper \
      --steps 200 --policy m_sync --m 6 --workers 8 --time-model sqrt
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --reduced \
      --steps 50 --policy auto_m

On a real TPU mesh the same entry point takes ``--mesh single|multi`` and
builds the production mesh + ShardCtx (this container is CPU-only, so the
mesh path is exercised by the dry-run instead).
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np

from ..configs import get_config, reduced as reduce_cfg
from ..core import (FixedTimes, SyncMode, SyncPolicy, exponential_times,
                    truncated_normal_times, uniform_times)
from ..data import SyntheticLM
from ..models import build_model
from ..optim import adamw, cosine_schedule, sgd
from ..train import Trainer, save_checkpoint


def build_time_model(name: str, n: int):
    if name == "none":
        return None
    if name == "sqrt":
        return FixedTimes.sqrt_law(n)
    if name == "linear":
        return FixedTimes.linear(n)
    if name == "uniform":
        return uniform_times(np.ones(n), half_width=0.5)
    if name == "exp":
        return exponential_times(lam=1.0, n=n)
    if name == "truncnorm_sqrt":
        return truncated_normal_times(np.sqrt(np.arange(1, n + 1)), 0.5)
    raise ValueError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="nanogpt-paper")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale same-family variant")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "sgd", "sgdm"])
    ap.add_argument("--policy", default="full",
                    choices=["full", "m_sync", "auto_m", "deadline"])
    ap.add_argument("--m", type=int, default=None)
    ap.add_argument("--deadline", type=float, default=None)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--time-model", default="sqrt",
                    choices=["none", "sqrt", "linear", "uniform", "exp",
                             "truncnorm_sqrt"])
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced or cfg.param_count() > 1e9:
        cfg = reduce_cfg(cfg, d_model=args.d_model, layers_per_stage=2,
                         vocab=min(cfg.vocab_size, 2048))
    model = build_model(cfg)

    sched = cosine_schedule(args.lr, warmup=max(args.steps // 20, 1),
                            total=args.steps)
    opt = {"adamw": lambda: adamw(lr=sched),
           "sgd": lambda: sgd(lr=sched),
           "sgdm": lambda: sgd(lr=sched, momentum=0.9)}[args.optimizer]()

    policy = SyncPolicy(
        mode=SyncMode(args.policy),
        m=args.m, deadline=args.deadline)
    tm = build_time_model(args.time_model, args.workers)
    if policy.mode != SyncMode.FULL and tm is None:
        raise SystemExit("--policy requires a --time-model")

    trainer = Trainer(model, opt, n_workers=args.workers,
                      sync_policy=policy, time_model=tm,
                      remat=args.remat, seed=args.seed)
    state = trainer.init_state()
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       batch_size=args.batch, seed=args.seed)

    print(f"arch={cfg.name} params={cfg.param_count() / 1e6:.1f}M "
          f"policy={policy.mode.value} workers={args.workers} "
          f"time_model={args.time_model}")
    hist = trainer.run(state, iter(data), num_steps=args.steps,
                       log_every=args.log_every)
    for s, t, l, m in zip(hist.steps, hist.sim_seconds, hist.losses,
                          hist.m_used):
        print(f"step {s:5d}  sim {t:9.1f}s  loss {l:7.4f}  m={m}")
    if args.ckpt:
        fs = trainer.final_state
        save_checkpoint(args.ckpt, fs.params, fs.opt_state, fs.step)
        print(f"saved checkpoint to {args.ckpt}")
    print(json.dumps({"final_loss": hist.losses[-1],
                      "sim_seconds": hist.sim_seconds[-1],
                      "wall_seconds": hist.wall_seconds[-1]}))


if __name__ == "__main__":
    main()
