import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

For each combination this builds the production mesh (single-pod 16x16 or
multi-pod 2x16x16 over 512 placeholder host devices), the real train/serve
step with full sharding, then ``jit(...).lower(<ShapeDtypeStructs>)
.compile()`` — no arrays are ever allocated. The compiled artifact yields
``memory_analysis()`` (fits-in-HBM proof) and ``cost_analysis()`` +
parsed collective bytes (the §Roofline inputs). Results are cached as JSON
under ``experiments/dryrun/``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import INPUT_SHAPES, get_config
from ..configs.base import InputShape, ModelConfig
from ..models import build_model
from ..optim import sgd
from ..sharding.specs import AttnMode, ShardCtx, attn_mode_for, spec_for_param
from .hlo_analysis import collective_bytes, hlo_cost, roofline_terms
from .mesh import make_production_mesh

# long_500k eligibility (DESIGN.md §5): SSM/hybrid natively; mistral-nemo
# via an explicit sliding-window-4096 variant.
LONG_OK = {"rwkv6-3b", "jamba-v0.1-52b"}
LONG_SWA = {"mistral-nemo-12b": 4096}

ARCHS = ["whisper-base", "phi-3-vision-4.2b", "llama3.2-3b", "granite-8b",
         "rwkv6-3b", "granite-34b", "jamba-v0.1-52b", "kimi-k2-1t-a32b",
         "mistral-nemo-12b", "deepseek-moe-16b"]


def make_ctx(cfg: ModelConfig, shape: InputShape, mesh) -> ShardCtx:
    multi = "pod" in mesh.axis_names
    dp_axes = ("pod", "data") if multi else ("data",)
    ms = mesh.shape["model"]
    mode = attn_mode_for(cfg.attn.num_heads, cfg.attn.num_kv_heads, ms,
                         shape.kind, shape.global_batch)
    dp_total = int(np.prod([mesh.shape[a] for a in dp_axes]))
    shard_batch = shape.global_batch % dp_total == 0 and \
        shape.global_batch >= dp_total
    return ShardCtx(mesh=mesh, dp_axes=dp_axes, model_axis="model",
                    attn_mode=mode, shard_batch=shard_batch)


def _maybe(mesh, shape_tuple, spec):
    """NamedSharding, dropping axes that don't divide the dimension."""
    # left-pad shorter specs with None: stacked (repeats, ...) params keep
    # their per-layer rule on the trailing dims
    entries = [None] * (len(shape_tuple) - len(spec)) + list(spec) \
        if len(spec) < len(shape_tuple) else list(spec)[:len(shape_tuple)]
    fixed = []
    for dim, e in zip(shape_tuple, entries):
        if e is None:
            fixed.append(None)
            continue
        axes = e if isinstance(e, tuple) else (e,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        fixed.append(e if dim % size == 0 else None)
    return NamedSharding(mesh, P(*fixed))


def param_shardings(mesh, params_shapes, cfg: Optional[ModelConfig] = None,
                    zero1: bool = False):
    """Partition specs for a param-shaped tree. zero1=True (optimizer
    states of >=30B models) additionally shards the first divisible free
    dim over the dp axes — ZeRO-1: the elementwise update runs fully
    sharded; XLA inserts one all-gather of the updated params per step."""
    multi = "pod" in mesh.axis_names
    dp = ("pod", "data") if multi else "data"
    dp_total = int(np.prod([mesh.shape[a] for a in
                            (("pod", "data") if multi else ("data",))]))
    two_d = cfg is not None and cfg.moe is not None \
        and cfg.moe.shard_experts_2d

    def one(path, leaf):
        parts = []
        for k in path:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
        name = parts[-1] if parts else ""
        if two_d and name in ("expert_up", "expert_gate"):
            return _maybe(mesh, leaf.shape, ("model", None, dp))
        if two_d and name == "expert_down":
            return _maybe(mesh, leaf.shape, ("model", dp, None))
        spec = spec_for_param("/".join(parts), "model")
        entries = [None] * (len(leaf.shape) - len(spec)) + list(spec) \
            if len(spec) < len(leaf.shape) else list(spec)[:len(leaf.shape)]
        if zero1:
            for i, (dim, e) in enumerate(zip(leaf.shape, entries)):
                if e is None and dim % dp_total == 0 and dim >= dp_total:
                    entries[i] = dp
                    break
        return _maybe(mesh, leaf.shape, tuple(entries))

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def cache_shardings(mesh, cache_shapes, ctx: ShardCtx, shape: InputShape):
    multi = "pod" in mesh.axis_names
    dp = ctx.dp
    kv_seq_axes = None
    if ctx.attn_mode == AttnMode.KVSEQ:
        if ctx.shard_batch:
            kv_seq_axes = "model"
        else:  # batch=1 long-context: shard seq over everything
            kv_seq_axes = ("pod", "data", "model") if multi \
                else ("data", "model")

    def one(path, leaf):
        name = None
        for k in path:
            if hasattr(k, "key"):
                name = str(k.key)
        if name in ("k", "v"):
            if ctx.attn_mode == AttnMode.KVSEQ:
                return _maybe(mesh, leaf.shape, (dp, kv_seq_axes, None, None))
            return _maybe(mesh, leaf.shape, (dp, None, "model", None))
        if name == "s":      # rwkv state (B, H, K, V)
            return _maybe(mesh, leaf.shape, (dp, None, None, None))
        if name == "x_prev":
            return _maybe(mesh, leaf.shape, (dp, None))
        if name == "h":      # mamba state (B, din, N)
            return _maybe(mesh, leaf.shape, (dp, "model", None))
        if name == "conv":   # (B, W-1, din)
            return _maybe(mesh, leaf.shape, (dp, None, "model"))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def build_lowerable(arch: str, shape_name: str, mesh, residual: str = "d"):
    """Returns (fn, args_shapes, args_shardings, meta) ready to jit/lower."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape_name == "long_500k" and arch in LONG_SWA:
        cfg = dataclasses.replace(
            cfg, attn=dataclasses.replace(cfg.attn,
                                          sliding_window=LONG_SWA[arch]))
    model = build_model(cfg)
    ctx = make_ctx(cfg, shape, mesh)
    if residual == "seq" and shape.kind in ("train", "prefill"):
        ctx = dataclasses.replace(ctx, residual="seq")
    B, S = shape.global_batch, shape.seq_len
    dp = ctx.dp
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    params_shapes = jax.eval_shape(
        lambda: model.init_params(jax.random.key(0)))
    p_shard = param_shardings(mesh, params_shapes, cfg)

    meta = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "attn_mode": ctx.attn_mode, "shard_batch": ctx.shard_batch,
        "residual": ctx.residual,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }

    if shape.kind == "train":
        # bf16 momentum above 30B params: fp32 optimizer state alone
        # exceeds HBM for granite-34b/jamba/kimi (EXPERIMENTS.md §Perf)
        mdt = jnp.bfloat16 if cfg.param_count() > 30e9 else jnp.float32
        opt = sgd(lr=1e-2, momentum=0.9, momentum_dtype=mdt)
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        # ZeRO-1/2 above 30B: optimizer state AND gradients sharded
        # over dp (grads constrained below => the dp-psum of the backward
        # fuses into a reduce-scatter; update runs sharded; params
        # all-gathered once per step)
        zero = cfg.param_count() > 30e9
        o_shard = param_shardings(mesh, opt_shapes, cfg, zero1=zero)
        g_shard = param_shardings(mesh, params_shapes, cfg, zero1=zero) \
            if zero else None
        n_groups = 16
        batch_shapes = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        batch_shard = {
            "tokens": _maybe(mesh, (B, S), (dp, None)),
            "labels": _maybe(mesh, (B, S), (dp, None)),
        }
        if cfg.vision_tokens:
            batch_shapes["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_tokens, cfg.d_model), dt)
            batch_shard["patch_embeds"] = _maybe(
                mesh, batch_shapes["patch_embeds"].shape, (dp, None, None))
        if cfg.encoder is not None:
            batch_shapes["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder.frontend_len, cfg.d_model), dt)
            batch_shard["frames"] = _maybe(
                mesh, batch_shapes["frames"].shape, (dp, None, None))
        w_shapes = jax.ShapeDtypeStruct((B,), jnp.float32)
        w_shard = _maybe(mesh, (B,), (dp,))

        def train_step(params, opt_state, batch, weights):
            def loss_fn(p):
                return model.loss(p, batch, ctx, remat=True,
                                  example_weights=weights)
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params)
            if g_shard is not None:   # ZeRO-2: keep grads dp-sharded
                grads = jax.tree.map(
                    lambda g, sh: jax.lax.with_sharding_constraint(g, sh),
                    grads, g_shard)
            new_p, new_o = opt.update(grads, opt_state, params, 0)
            return new_p, new_o, loss

        args_shapes = (params_shapes, opt_shapes, batch_shapes, w_shapes)
        args_shard = (p_shard, o_shard, batch_shard, w_shard)
        # tokens processed per step * 6 * active params
        meta["model_flops"] = 6.0 * cfg.active_param_count() * B * S
        return train_step, args_shapes, args_shard, meta

    if shape.kind == "prefill":
        batch_shapes = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        batch_shard = {"tokens": _maybe(mesh, (B, S), (dp, None))}
        if cfg.vision_tokens:
            batch_shapes["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_tokens, cfg.d_model), dt)
            batch_shard["patch_embeds"] = _maybe(
                mesh, batch_shapes["patch_embeds"].shape, (dp, None, None))
        if cfg.encoder is not None:
            batch_shapes["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder.frontend_len, cfg.d_model), dt)
            batch_shard["frames"] = _maybe(
                mesh, batch_shapes["frames"].shape, (dp, None, None))

        def prefill_step(params, batch):
            return model.prefill(params, batch["tokens"], ctx,
                                 frames=batch.get("frames"),
                                 extra_embeds=batch.get("patch_embeds"))

        meta["model_flops"] = 2.0 * cfg.active_param_count() * B * S
        return (prefill_step, (params_shapes, batch_shapes),
                (p_shard, batch_shard), meta)

    # ---- decode
    cache_shapes = jax.eval_shape(lambda: model.init_cache(B, S))
    c_shard = cache_shardings(mesh, cache_shapes, ctx, shape)
    tok_shapes = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_shard = _maybe(mesh, (B, 1), (dp, None))
    static = ctx.attn_mode == AttnMode.KVSEQ
    mem_shapes = None
    if cfg.encoder is not None:
        mem_shapes = jax.ShapeDtypeStruct(
            (B, cfg.encoder.frontend_len, cfg.d_model), dt)
        mem_shard = _maybe(mesh, mem_shapes.shape, (dp, None, None))

    if mem_shapes is None:
        def serve_step(params, token, cache):
            return model.decode_step(params, token, cache, ctx,
                                     static_cache=static)
        args = (params_shapes, tok_shapes, cache_shapes)
        shards = (p_shard, tok_shard, c_shard)
    else:
        def serve_step(params, token, cache, memory):
            return model.decode_step(params, token, cache, ctx,
                                     memory=memory, static_cache=static)
        args = (params_shapes, tok_shapes, cache_shapes, mem_shapes)
        shards = (p_shard, tok_shard, c_shard, mem_shard)
    meta["model_flops"] = 2.0 * cfg.active_param_count() * B
    return serve_step, args, shards, meta


def run_one(arch: str, shape_name: str, mesh_kind: str,
            out_dir: str = "experiments/dryrun",
            save_hlo: bool = False, residual: str = "d") -> dict:
    cfg = get_config(arch)
    if shape_name == "long_500k" and arch not in (LONG_OK | set(LONG_SWA)):
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "skipped", "wall_s": 0.0,
               "reason": "full attention; long_500k requires sub-quadratic "
                         "(DESIGN.md §5)"}
        _save(rec, out_dir)
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    try:
        fn, args, shards, meta = build_lowerable(arch, shape_name, mesh,
                                                 residual=residual)
        with mesh:
            jitted = jax.jit(fn, in_shardings=shards)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        # loop-aware costs: XLA's cost_analysis counts while bodies once,
        # underreporting scan-over-layers programs by ~num_layers
        parsed = hlo_cost(hlo)
        roof = roofline_terms(parsed, coll, n_chips=n_chips,
                              model_flops=meta.get("model_flops", 0.0))
        rec = {
            **meta, "mesh": mesh_kind, "status": "ok",
            "n_chips": n_chips,
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
            },
            "cost": {"flops": parsed["flops"],
                     "bytes_accessed": parsed["bytes"],
                     "xla_flops_raw": float(cost.get("flops", 0.0)),
                     "xla_bytes_raw": float(cost.get("bytes accessed", 0.0))},
            "collectives": {"total_bytes": coll.total_bytes,
                            "count": coll.count, "by_kind": coll.by_kind},
            "roofline": {
                "compute_s": roof.compute_s, "memory_s": roof.memory_s,
                "collective_s": roof.collective_s,
                "dominant": roof.dominant,
                "model_flops": roof.model_flops,
                "useful_flops_ratio": roof.useful_flops_ratio,
            },
        }
        if save_hlo:
            hpath = os.path.join(out_dir, f"{_key(rec)}.hlo.txt")
            os.makedirs(out_dir, exist_ok=True)
            with open(hpath, "w") as f:
                f.write(hlo)
    except Exception as e:  # a failure here is a bug in our sharding
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    rec["wall_s"] = round(time.time() - t0, 2)
    _save(rec, out_dir)
    return rec


def _key(rec):
    return f"{rec['arch']}_{rec['shape']}_{rec['mesh']}".replace(".", "p")


def _save(rec, out_dir):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, _key(rec) + ".json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--residual", default="d", choices=["d", "seq"])
    args = ap.parse_args()

    archs = ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                key = f"{arch}_{shape}_{mk}".replace(".", "p")
                path = os.path.join(args.out, key + ".json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[skip] {key}: cached {prev['status']}")
                        results.append(prev)
                        continue
                rec = run_one(arch, shape, mk, args.out,
                              save_hlo=args.save_hlo,
                              residual=args.residual)
                st = rec["status"]
                extra = ""
                if st == "ok":
                    r = rec["roofline"]
                    extra = (f" dominant={r['dominant']}"
                             f" c={r['compute_s']:.3e}s"
                             f" m={r['memory_s']:.3e}s"
                             f" n={r['collective_s']:.3e}s"
                             f" peakMB={rec['memory']['peak_bytes']/2**20:.0f}")
                elif st == "error":
                    extra = " " + rec["error"][:200]
                print(f"[{st}] {key} ({rec['wall_s']}s){extra}", flush=True)
                results.append(rec)
    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    err = sum(r["status"] == "error" for r in results)
    print(f"done: {ok} ok, {sk} skipped, {err} errors")
    return 0 if err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
