"""Roofline report generator: reads experiments/dryrun/*.json and emits the
§Roofline markdown table (per arch × shape, single-pod mesh) plus the
dominant-bottleneck summary and hillclimb-candidate ranking.

  PYTHONPATH=src python -m repro.launch.roofline [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import List


def load(dir_: str, mesh: str = "single") -> List[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") == mesh:
            recs.append(r)
    return recs


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-4:
        return f"{x * 1e6:.1f}µs"
    if x < 0.1:
        return f"{x * 1e3:.2f}ms"
    return f"{x:.3f}s"


def table(recs: List[dict]) -> str:
    lines = [
        "| arch | shape | mode | compute | memory | collective | dominant |"
        " useful-FLOPs | peak GB/chip |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                f"skipped | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | ERROR | | | "
                         f"| | |")
            continue
        ro = r["roofline"]
        ur = ro.get("useful_flops_ratio", 0.0)
        peak = r["memory"]["peak_bytes"] / 2 ** 30
        over = "**" if peak > 16 else ""
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r.get('attn_mode', '')} | "
            f"{fmt_s(ro['compute_s'])} | {fmt_s(ro['memory_s'])} | "
            f"{fmt_s(ro['collective_s'])} | {ro['dominant']} | "
            f"{ur:.2f} | {over}{peak:.2f}{over} |")
    return "\n".join(lines)


def hillclimb_candidates(recs: List[dict]) -> str:
    """Rank pairs: worst roofline fraction (useful/total on the dominant
    axis), most collective-bound, most m-sync-representative (train)."""
    ok = [r for r in recs if r["status"] == "ok"]
    out = []

    def total(r):
        ro = r["roofline"]
        return max(ro["compute_s"], ro["memory_s"], ro["collective_s"])

    worst_useful = sorted(
        (r for r in ok if r["kind"] == "train"),
        key=lambda r: r["roofline"].get("useful_flops_ratio", 1.0))[:3]
    most_coll = sorted(
        ok, key=lambda r: -(r["roofline"]["collective_s"]
                            / max(total(r), 1e-30)))[:3]
    out.append("worst useful-FLOPs ratio (train): " + ", ".join(
        f"{r['arch']}/{r['shape']}={r['roofline']['useful_flops_ratio']:.2f}"
        for r in worst_useful))
    out.append("most collective-bound: " + ", ".join(
        f"{r['arch']}/{r['shape']}="
        f"{r['roofline']['collective_s'] / max(total(r), 1e-30):.2f}"
        for r in most_coll))
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(args.dir, args.mesh)
    print(f"## Roofline — {args.mesh}-pod "
          f"({'256' if args.mesh == 'single' else '512'} chips, "
          "197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI)\n")
    print(table(recs))
    print()
    print(hillclimb_candidates(recs))


if __name__ == "__main__":
    main()
