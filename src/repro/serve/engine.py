"""Batched serving engine: prefill + decode with a shared KV cache.

A deliberately small but real engine: fixed-size decode batch, slot-based
request management (a finished request's slot is refilled by the next
queued request), greedy or temperature sampling. ``serve_step`` — one
batched decode step — is the unit the decode dry-run shapes lower.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import Model
from ..sharding.specs import ShardCtx

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    prompt: np.ndarray                 # (P,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, *, batch_size: int = 8,
                 max_len: int = 512, ctx: Optional[ShardCtx] = None,
                 eos_id: Optional[int] = None, seed: int = 0) -> None:
        self.model = model
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self.ctx = ctx or ShardCtx.null()
        self.eos_id = eos_id
        self.rng = jax.random.key(seed)
        self._decode = jax.jit(
            lambda tok, cache: model.decode_step(params, tok, cache,
                                                 self.ctx))

    # --------------------------------------------------------- serving
    def generate(self, requests: List[Request]) -> List[Request]:
        """Run all requests to completion with slot-based batching."""
        queue = deque(requests)            # O(1) popleft on refill
        slots: List[Optional[Request]] = [None] * self.B
        caches = [self.model.init_cache(1, self.max_len)
                  for _ in range(self.B)]
        budgets = [0] * self.B

        def refill():
            for i in range(self.B):
                if slots[i] is None and queue:
                    req = queue.popleft()
                    slots[i] = req
                    caches[i] = self.model.init_cache(1, self.max_len)
                    # prefill token-by-token (simple; a production engine
                    # would run a chunked prefill kernel here)
                    for t in req.prompt[:-1]:
                        _, caches[i] = self._decode(
                            jnp.asarray([[t]], jnp.int32), caches[i])
                    req._next = int(req.prompt[-1])
                    budgets[i] = req.max_new_tokens

        refill()
        while any(s is not None for s in slots):
            for i in range(self.B):
                req = slots[i]
                if req is None:
                    continue
                logits, caches[i] = self._decode(
                    jnp.asarray([[req._next]], jnp.int32), caches[i])
                nxt = self._sample(logits[0], req.temperature)
                req.out_tokens.append(nxt)
                req._next = nxt
                budgets[i] -= 1
                if budgets[i] <= 0 or (self.eos_id is not None
                                       and nxt == self.eos_id):
                    req.done = True
                    slots[i] = None
            refill()
        return requests

    def _sample(self, logits: jnp.ndarray, temperature: float) -> int:
        if temperature <= 0.0:
            return int(jnp.argmax(logits))
        self.rng, k = jax.random.split(self.rng)
        return int(jax.random.categorical(k, logits / temperature))
