"""NanoGPT config from the paper's Section J: vocab 50304, block 512,
6 layers, 6 heads, d_model 384 — used for the R-estimation study and the
K.5 Sync-vs-Async comparison."""

from .base import AttnConfig, Block, ModelConfig, Stage

CONFIG = ModelConfig(
    name="nanogpt-paper",
    arch_type="dense",
    d_model=384,
    vocab_size=50304,
    d_ff=1536,
    stages=(Stage(pattern=(Block("attn", "mlp"),), repeats=6),),
    attn=AttnConfig(num_heads=6, num_kv_heads=6, head_dim=64,
                    rope_theta=None, causal=True),
    pos_embed="learned",
    mlp_act="gelu",
    norm="layernorm",
    tie_embeddings=True,
    max_seq_len=512,
    citation="github.com/karpathy/nanoGPT (paper §J)",
)
