"""whisper-base [audio]: 6L enc + 6L dec, d_model=512, 8H (kv=8), d_ff=2048,
vocab=51865. Enc-dec; mel+conv frontend is a STUB (precomputed frame
embeddings). [arXiv:2212.04356]"""

from .base import (AttnConfig, Block, EncoderConfig, ModelConfig, Stage)

CONFIG = ModelConfig(
    name="whisper-base",
    arch_type="audio",
    d_model=512,
    vocab_size=51865,
    d_ff=2048,
    # decoder: self-attn + cross-attn per layer (whisper decoder block)
    stages=(Stage(pattern=(Block("attn", "none"), Block("cross", "mlp")),
                  repeats=6),),
    attn=AttnConfig(num_heads=8, num_kv_heads=8, head_dim=64,
                    rope_theta=None, causal=True),
    encoder=EncoderConfig(
        stages=(Stage(pattern=(Block("attn", "mlp"),), repeats=6),),
        frontend_len=1500),
    pos_embed="learned",
    mlp_act="gelu",
    norm="layernorm",
    tie_embeddings=True,
    max_seq_len=32768,   # assignment shapes exceed whisper's native 448
    citation="arXiv:2212.04356",
)
