"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2. Mamba:attention 7:1 interleave, MoE
every other layer. [arXiv:2403.19887]"""

from .base import (AttnConfig, Block, ModelConfig, MoEConfig, SSMConfig,
                   Stage)

# 8-layer group: attention at index 4, MoE on odd layers (1,3,5,7).
_PATTERN = (
    Block("mamba", "mlp"), Block("mamba", "moe"),
    Block("mamba", "mlp"), Block("mamba", "moe"),
    Block("attn", "mlp"), Block("mamba", "moe"),
    Block("mamba", "mlp"), Block("mamba", "moe"),
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    d_model=4096,
    vocab_size=65536,
    d_ff=14336,
    stages=(Stage(pattern=_PATTERN, repeats=4),),
    attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                    rope_theta=None, causal=True),   # jamba: no RoPE
    moe=MoEConfig(num_experts=16, experts_per_token=2, d_expert=14336,
              shard_experts_2d=True),
    ssm=SSMConfig(kind="mamba", d_state=16, d_inner_mult=2, conv_width=4),
    mlp_act="swiglu",
    max_seq_len=262144,
    citation="arXiv:2403.19887",
)
