"""Architecture registry: ``get_config(arch_id)``.

One module per assigned architecture under ``repro.configs``; this registry
imports them lazily and exposes the arch ids for ``--arch``.
"""

from __future__ import annotations

import importlib

from .base import ModelConfig

ARCH_IDS = [
    "whisper_base",
    "phi3_vision_4p2b",
    "llama3p2_3b",
    "granite_8b",
    "rwkv6_3b",
    "granite_34b",
    "jamba_v0p1_52b",
    "kimi_k2_1t_a32b",
    "mistral_nemo_12b",
    "deepseek_moe_16b",
    # paper's own experiment model (Section J / K.5)
    "nanogpt_paper",
]

# canonical dashed names from the assignment card -> module name
ALIASES = {
    "whisper-base": "whisper_base",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "llama3.2-3b": "llama3p2_3b",
    "granite-8b": "granite_8b",
    "rwkv6-3b": "rwkv6_3b",
    "granite-34b": "granite_34b",
    "jamba-v0.1-52b": "jamba_v0p1_52b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "nanogpt-paper": "nanogpt_paper",
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch).replace("-", "_").replace(".", "p")
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCH_IDS}
