"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536.
RWKV-6 "Finch": data-dependent decay linear recurrence. [arXiv:2404.05892]"""

from .base import AttnConfig, Block, ModelConfig, SSMConfig, Stage

CONFIG = ModelConfig(
    name="rwkv6-3b",
    arch_type="ssm",
    d_model=2560,
    vocab_size=65536,
    d_ff=8960,
    stages=(Stage(pattern=(Block("rwkv", "mlp"),), repeats=32),),
    # attn config unused by rwkv blocks but harmless (head_dim for specs)
    attn=AttnConfig(num_heads=40, num_kv_heads=40, head_dim=64,
                    rope_theta=None, causal=True),
    ssm=SSMConfig(kind="rwkv6", head_dim=64),
    mlp_act="gelu",   # rwkv channel-mix uses squared-relu; gelu stands in
    max_seq_len=1 << 20,
    citation="arXiv:2404.05892",
)
