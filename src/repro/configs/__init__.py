from .base import (AttnConfig, Block, EncoderConfig, InputShape,
                   INPUT_SHAPES, ModelConfig, MoEConfig, SSMConfig, Stage,
                   reduced)
from .registry import ALIASES, ARCH_IDS, all_configs, get_config
