"""Config system: composable model/run configuration.

A model is a sequence of *stages*; each stage is a repeated *pattern* of
blocks (``attn``/``mamba``/``rwkv``), each paired with a feed-forward kind
(``mlp``/``moe``/``none``). Stages with ``repeats > 1`` are stacked and run
under ``lax.scan`` (one lowered copy of the pattern regardless of depth —
this is what keeps 61-layer/88-layer dry-runs compilable on one CPU).

Examples:
  llama3.2-3b   : [Stage(pattern=[attn+mlp], repeats=28)]
  kimi-k2       : [Stage([attn+mlp], 1), Stage([attn+moe], 60)]
  jamba-v0.1    : [Stage([mamba+mlp, mamba+moe, mamba+mlp, mamba+moe,
                          attn+mlp,  mamba+moe, mamba+mlp, mamba+moe], 4)]
  whisper-base  : encoder stage + decoder stage (cross-attention)
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["AttnConfig", "MoEConfig", "SSMConfig", "Block", "Stage",
           "ModelConfig", "InputShape", "INPUT_SHAPES", "reduced"]


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: Optional[float] = 10000.0   # None => learned/none (whisper)
    causal: bool = True
    sliding_window: Optional[int] = None    # tokens; None => full attention


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    d_expert: int                  # per-expert FFN hidden size
    num_shared_experts: int = 0
    d_shared: int = 0              # shared-expert FFN hidden size
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # 2D expert-weight sharding: experts over `model`, FFN dim over the dp
    # axes (FSDP-style storage, gathered per layer). Required when total
    # expert params exceed model-axis-only capacity (kimi-k2 1T).
    shard_experts_2d: bool = False


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba"            # "mamba" | "rwkv6"
    d_state: int = 16              # mamba N
    d_inner_mult: int = 2          # mamba expansion
    conv_width: int = 4
    head_dim: int = 64             # rwkv6 head size
    dt_rank: int = 0               # 0 => d_model // 16


@dataclasses.dataclass(frozen=True)
class Block:
    """One transformer block: a mixer plus a feed-forward."""
    mixer: str                     # "attn" | "mamba" | "rwkv" | "cross"
    ff: str = "mlp"                # "mlp" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class Stage:
    pattern: Tuple[Block, ...]
    repeats: int = 1

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.repeats


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder for enc-dec models (whisper). Frontend is a stub: the input
    is precomputed frame embeddings of shape (B, frontend_len, d_model)."""
    stages: Tuple[Stage, ...]
    frontend_len: int = 1500       # whisper 30s @ 50 Hz after conv stub


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense|moe|ssm|hybrid|vlm|audio
    d_model: int
    vocab_size: int
    d_ff: int
    stages: Tuple[Stage, ...]
    attn: AttnConfig
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None   # audio enc-dec
    vision_tokens: int = 0          # VLM stub: patch embeddings prepended
    pos_embed: str = "none"         # "none" | "learned"
    mlp_act: str = "swiglu"         # "swiglu" | "gelu"
    norm: str = "rmsnorm"           # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"         # activation / param dtype
    max_seq_len: int = 8192
    citation: str = ""

    @property
    def num_layers(self) -> int:
        return sum(s.num_layers for s in self.stages)

    @property
    def sub_quadratic(self) -> bool:
        """True if every attention is windowed or the mixer stack is SSM —
        the long_500k eligibility rule."""
        has_full_attn = any(
            b.mixer in ("attn", "cross") and self.attn.sliding_window is None
            for s in self.stages for b in s.pattern)
        if self.encoder is not None:
            return False
        # hybrid archs qualify: their attention layers use KVSEQ decode
        if self.arch_type in ("ssm", "hybrid"):
            return True
        return not has_full_attn

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, v = self.d_model, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        for s in self.stages:
            for b in s.pattern:
                total += s.repeats * _block_params(self, b)
        if self.encoder is not None:
            for s in self.encoder.stages:
                for b in s.pattern:
                    total += s.repeats * _block_params(self, b)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed-to experts)."""
        d, v = self.d_model, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        for s in self.stages:
            for b in s.pattern:
                total += s.repeats * _block_params(self, b, active=True)
        if self.encoder is not None:
            for s in self.encoder.stages:
                for b in s.pattern:
                    total += s.repeats * _block_params(self, b, active=True)
        return total


def _block_params(cfg: ModelConfig, b: Block, active: bool = False) -> int:
    d = cfg.d_model
    n = 0
    if b.mixer in ("attn", "cross"):
        a = cfg.attn
        qkv = d * a.num_heads * a.head_dim + 2 * d * a.num_kv_heads * a.head_dim
        o = a.num_heads * a.head_dim * d
        n += qkv + o
        if b.mixer == "cross":
            n += qkv + o   # separate cross-attention projections
    elif b.mixer == "mamba":
        s = cfg.ssm
        din = s.d_inner_mult * d
        dt_rank = s.dt_rank or d // 16
        n += d * 2 * din            # in_proj (x and gate)
        n += din * s.conv_width     # conv1d
        n += din * (dt_rank + 2 * s.d_state) + dt_rank * din  # dt/B/C proj
        n += din * s.d_state + din  # A, D
        n += din * d                # out_proj
    elif b.mixer == "rwkv":
        n += 6 * d * d              # r,k,v,g,w,o projections (+ small mixes)
    if b.ff == "mlp":
        mult = 3 if cfg.mlp_act == "swiglu" else 2
        n += mult * d * cfg.d_ff
    elif b.ff == "moe":
        m = cfg.moe
        mult = 3 if cfg.mlp_act == "swiglu" else 2
        per_expert = mult * d * m.d_expert
        routed = (m.experts_per_token if active else m.num_experts)
        n += routed * per_expert
        n += m.num_shared_experts * mult * d * m.d_shared
        n += d * m.num_experts      # router
    n += 2 * d                      # norms
    return n


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, d_model: int = 128, layers_per_stage: int = 1,
            max_experts: int = 4, vocab: int = 512) -> ModelConfig:
    """Reduced same-family variant for CPU smoke tests (2 layers, d<=512,
    <=4 experts), preserving the block pattern and ff kinds."""
    assert d_model <= 512
    a = cfg.attn
    heads = max(2, min(4, a.num_heads))
    kv = 1 if a.num_kv_heads == 1 else max(1, min(2, a.num_kv_heads))
    attn = dataclasses.replace(
        a, num_heads=heads, num_kv_heads=kv, head_dim=d_model // heads)
    moe = None
    if cfg.moe is not None:
        m = cfg.moe
        moe = dataclasses.replace(
            m, num_experts=min(m.num_experts, max_experts),
            experts_per_token=min(m.experts_per_token, 2),
            d_expert=d_model, d_shared=d_model if m.num_shared_experts else 0,
            num_shared_experts=min(m.num_shared_experts, 1),
            # no token drops in the reduced variant so decode == forward
            # exactly (the full configs keep the production 1.25 factor)
            capacity_factor=float(2 * max_experts))
    ssm = cfg.ssm
    if ssm is not None:
        ssm = dataclasses.replace(ssm, d_state=8, head_dim=32)
    def _shrink_pattern(pattern):
        # keep one block per distinct (mixer, ff) kind, preserving order —
        # the reduced model exercises every layer *family* in <=3 blocks
        seen, out = set(), []
        for b in pattern:
            key = (b.mixer, b.ff)
            if key not in seen:
                seen.add(key)
                out.append(b)
        return tuple(out[:4])

    stages = tuple(
        Stage(pattern=_shrink_pattern(s.pattern),
              repeats=min(s.repeats, layers_per_stage))
        for s in cfg.stages)
    # keep total depth tiny: at most 2 stages
    stages = stages[:2]
    enc = cfg.encoder
    if enc is not None:
        enc = EncoderConfig(
            stages=tuple(Stage(s.pattern, min(s.repeats, 1))
                         for s in enc.stages[:1]),
            frontend_len=16)
    return dataclasses.replace(
        cfg, name=cfg.name + "-reduced", d_model=d_model, vocab_size=vocab,
        d_ff=2 * d_model, stages=stages, attn=attn, moe=moe, ssm=ssm,
        encoder=enc, vision_tokens=min(cfg.vision_tokens, 4),
        dtype="float32", max_seq_len=512)
