"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064. phi3-mini backbone + CLIP frontend (STUB: 576 precomputed
patch embeddings prepended). [hf:microsoft/Phi-3-vision-128k-instruct]"""

from .base import AttnConfig, Block, ModelConfig, Stage

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    d_model=3072,
    vocab_size=32064,
    d_ff=8192,
    stages=(Stage(pattern=(Block("attn", "mlp"),), repeats=32),),
    attn=AttnConfig(num_heads=32, num_kv_heads=32, head_dim=96,
                    rope_theta=10000.0, causal=True),
    vision_tokens=576,
    mlp_act="swiglu",
    max_seq_len=131072,
    citation="hf:microsoft/Phi-3-vision-128k-instruct",
)
