"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048,
vocab=163840, MoE 384 experts top-8 + 1 shared; first layer dense.
Trillion-parameter MoE (paper-table). [arXiv:2501.kimi2]"""

from .base import AttnConfig, Block, ModelConfig, MoEConfig, Stage

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    d_model=7168,
    vocab_size=163840,
    d_ff=18432,            # dense-layer FFN (DeepSeek-V3-style first layer)
    stages=(
        Stage(pattern=(Block("attn", "mlp"),), repeats=1),
        Stage(pattern=(Block("attn", "moe"),), repeats=60),
    ),
    attn=AttnConfig(num_heads=64, num_kv_heads=8, head_dim=112,
                    rope_theta=50000.0, causal=True),
    moe=MoEConfig(num_experts=384, experts_per_token=8, d_expert=2048,
                  num_shared_experts=1, d_shared=2048,
                  shard_experts_2d=True),
    mlp_act="swiglu",
    max_seq_len=131072,
    citation="arXiv:2501.kimi2",
)
