"""granite-34b [dense]: 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152. Llama-arch code model, multi-query attention.
[arXiv:2405.04324]"""

from .base import AttnConfig, Block, ModelConfig, Stage

CONFIG = ModelConfig(
    name="granite-34b",
    arch_type="dense",
    d_model=6144,
    vocab_size=49152,
    d_ff=24576,
    stages=(Stage(pattern=(Block("attn", "mlp"),), repeats=88),),
    attn=AttnConfig(num_heads=48, num_kv_heads=1, head_dim=128,
                    rope_theta=10000.0, causal=True),
    mlp_act="gelu",
    max_seq_len=8192,
    citation="arXiv:2405.04324",
)
