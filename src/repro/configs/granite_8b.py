"""granite-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152. Llama-arch code model. [arXiv:2405.04324]"""

from .base import AttnConfig, Block, ModelConfig, Stage

CONFIG = ModelConfig(
    name="granite-8b",
    arch_type="dense",
    d_model=4096,
    vocab_size=49152,
    d_ff=14336,
    stages=(Stage(pattern=(Block("attn", "mlp"),), repeats=36),),
    attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                    rope_theta=10000.0, causal=True),
    mlp_act="swiglu",
    max_seq_len=32768,
    citation="arXiv:2405.04324",
)
