"""llama3.2-3b [dense]: 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256. [hf:meta-llama/Llama-3.2-1B family]"""

from .base import AttnConfig, Block, ModelConfig, Stage

CONFIG = ModelConfig(
    name="llama3.2-3b",
    arch_type="dense",
    d_model=3072,
    vocab_size=128256,
    d_ff=8192,
    stages=(Stage(pattern=(Block("attn", "mlp"),), repeats=28),),
    attn=AttnConfig(num_heads=24, num_kv_heads=8, head_dim=128,
                    rope_theta=500000.0, causal=True),
    mlp_act="swiglu",
    tie_embeddings=True,
    max_seq_len=131072,
    citation="hf:meta-llama/Llama-3.2-1B",
)
