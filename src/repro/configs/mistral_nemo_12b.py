"""mistral-nemo-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072, 128k ctx. [hf:mistralai/Mistral-Nemo-Base-2407]

``long_500k`` runs via an explicit sliding-window-4096 attention VARIANT
(``sliding_window`` set by the dry-run for that shape only) — the base
config is full attention, matching the model card."""

from .base import AttnConfig, Block, ModelConfig, Stage

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    arch_type="dense",
    d_model=5120,
    vocab_size=131072,
    d_ff=14336,
    stages=(Stage(pattern=(Block("attn", "mlp"),), repeats=40),),
    attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                    rope_theta=1000000.0, causal=True),
    mlp_act="swiglu",
    max_seq_len=131072,
    citation="hf:mistralai/Mistral-Nemo-Base-2407",
)
