"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16) d_ff=1408(expert),
vocab=102400, 64 routed experts top-6 + 2 shared, fine-grained; first layer
dense. [arXiv:2401.06066]"""

from .base import AttnConfig, Block, ModelConfig, MoEConfig, Stage

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    d_model=2048,
    vocab_size=102400,
    d_ff=10944,            # dense first-layer FFN per the DeepSeekMoE card
    stages=(
        Stage(pattern=(Block("attn", "mlp"),), repeats=1),
        Stage(pattern=(Block("attn", "moe"),), repeats=27),
    ),
    attn=AttnConfig(num_heads=16, num_kv_heads=16, head_dim=128,
                    rope_theta=10000.0, causal=True),
    moe=MoEConfig(num_experts=64, experts_per_token=6, d_expert=1408,
                  num_shared_experts=2, d_shared=1408),
    mlp_act="swiglu",
    max_seq_len=16384,
    citation="arXiv:2401.06066",
)
