"""Closed-form time complexities and bound recursions from the paper.

Every formula carries its equation number from the paper:

* eq. (1)  — Synchronous SGD (all workers) under Assumption 2.2.
* eq. (2)  — optimal asynchronous complexity ``T_optimal``.
* eq. (3)  — SGD iteration complexity ``K`` (Theorem 2.1, Lan 2020).
* eq. (4)  — ``T_sync`` of m-Synchronous SGD with the optimal ``m``.
* eq. (5)  — near-optimality: ``T_sync = O(T_optimal * log(n+1))``.
* eq. (7)  — ``E[T_rand]`` upper bound under Assumption 3.1 (Theorem 3.2).
* eq. (12) — lower-bound recursion ``t_k`` under Assumption 5.1 (Thm 5.2).
* eq. (13) — m-Sync upper-bound recursion ``t̄_k`` (Theorem 5.3).
* eq. (16) — optimal heterogeneous complexity (Malenia SGD).

Conventions: the paper's Theorem 2.1 constant 16 is used wherever the paper
uses it; ``T_optimal`` is stated up to Θ — we expose ``c`` so benchmarks can
use the paper's own choice (c1=16, c2=1, footnote 6) for fair gap ratios.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import numpy as np

from .time_models import UniversalModel

__all__ = [
    "iteration_complexity",
    "t_sync_full",
    "t_optimal",
    "t_sync",
    "t_rand_upper",
    "t_malenia",
    "lower_bound_recursion",
    "msync_upper_recursion",
    "log_factor",
]


def iteration_complexity(L: float, Delta: float, eps: float, sigma2: float,
                         m: int) -> int:
    """Eq. (3): ``K = ceil(16 * max(L*Delta/eps, sigma^2*L*Delta/(m*eps^2)))``."""
    return int(math.ceil(16.0 * max(L * Delta / eps,
                                    sigma2 * L * Delta / (m * eps ** 2))))


def t_sync_full(taus: np.ndarray, L: float, Delta: float, eps: float,
                sigma2: float, c: float = 16.0) -> float:
    """Eq. (1): Synchronous SGD (m=n) — ``tau_n * max(LΔ/ε, σ²LΔ/(nε²))``."""
    taus = np.sort(np.asarray(taus, dtype=float))
    n = len(taus)
    return c * taus[-1] * max(L * Delta / eps,
                              sigma2 * L * Delta / (n * eps ** 2))


def t_optimal(taus: np.ndarray, L: float, Delta: float, eps: float,
              sigma2: float, c: float = 1.0) -> Tuple[float, int]:
    """Eq. (2): ``min_m [(1/m Σ_{i<=m} 1/τ_i)^(-1) max(LΔ/ε, σ²LΔ/(mε²))]``.

    Returns ``(value, argmin_m)`` (1-indexed m).
    """
    taus = np.sort(np.asarray(taus, dtype=float))
    n = len(taus)
    ms = np.arange(1, n + 1, dtype=float)
    harm = np.cumsum(1.0 / taus) / ms          # (1/m) Σ 1/τ_i
    iters = np.maximum(L * Delta / eps, sigma2 * L * Delta / (ms * eps ** 2))
    vals = (1.0 / harm) * iters
    j = int(np.argmin(vals))
    return c * float(vals[j]), j + 1


def t_sync(taus: np.ndarray, L: float, Delta: float, eps: float,
           sigma2: float, c: float = 16.0) -> Tuple[float, int]:
    """Eq. (4): ``(cLΔ/ε) min_m [τ_m max(1, σ²/(mε))]``; returns (T, m*)."""
    taus = np.sort(np.asarray(taus, dtype=float))
    n = len(taus)
    ms = np.arange(1, n + 1, dtype=float)
    g = taus * np.maximum(1.0, sigma2 / (ms * eps))
    j = int(np.argmin(g))
    return c * (L * Delta / eps) * float(g[j]), j + 1


def t_rand_upper(taus: np.ndarray, R: float, L: float, Delta: float,
                 eps: float, sigma2: float, m: int, c: float = 16.0) -> float:
    """Eq. (7): ``E[T_rand] = O((LΔ/ε)(τ_m + R log n) max(1, σ²/(mε)))``."""
    taus = np.sort(np.asarray(taus, dtype=float))
    n = len(taus)
    return c * (L * Delta / eps) * (taus[m - 1] + R * math.log(max(n, 2))) \
        * max(1.0, sigma2 / (m * eps))


def t_malenia(taus: np.ndarray, L: float, Delta: float, eps: float,
              sigma2: float, c: float = 1.0) -> float:
    """Eq. (16): heterogeneous optimum ``τ_n LΔ/ε + mean(τ) σ²LΔ/(nε²)``."""
    taus = np.sort(np.asarray(taus, dtype=float))
    n = len(taus)
    return c * (taus[-1] * L * Delta / eps
                + float(np.mean(taus)) * sigma2 * L * Delta / (n * eps ** 2))


def log_factor(n: int) -> float:
    """The near-optimality factor ``log(n + 1)`` of eq. (5)."""
    return math.log(n + 1)


# ---------------------------------------------------------------------------
# Universal computation model recursions (Theorems 5.2 / 5.3).
# ---------------------------------------------------------------------------

def lower_bound_recursion(model: UniversalModel, L: float, Delta: float,
                          eps: float, sigma2: float,
                          c1: float = 16.0, c2: float = 1.0,
                          t_cap: float = 1e9) -> float:
    """Eq. (12): ``t_k = min{t : Σ_i N_i(t_{k-1}, t) >= c2 * ceil(σ²/ε)}``.

    Returns ``t_K`` with ``K = ceil(c1 * LΔ/ε)``. The paper's footnote 6
    uses (c1, c2) = (16, 1) so ratios against Theorem 5.3 are fair.
    """
    K = int(math.ceil(c1 * L * Delta / eps))
    target = c2 * math.ceil(sigma2 / eps)
    t = 0.0
    for _ in range(K):
        t = _min_time_total_batch(model, t, target, t_cap)
        if not math.isfinite(t):
            return math.inf
    return t


def _min_time_total_batch(model: UniversalModel, t0: float, target: float,
                          t_cap: float) -> float:
    """Smallest ``t >= t0`` with ``Σ_i floor(∫_{t0}^{t} v_i) >= target``."""

    def total(t: float) -> int:
        return int(sum(model.N(i, t0, t) for i in range(model.n)))

    hi = max(t0 + 1.0, t0 * 1.5 + 1.0)
    while total(hi) < target:
        hi = t0 + 2 * (hi - t0)
        if hi > t_cap:
            return math.inf
    lo = t0
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if total(mid) >= target:
            hi = mid
        else:
            lo = mid
    return hi


def msync_upper_recursion(model: UniversalModel, L: float, Delta: float,
                          eps: float, sigma2: float, m: int,
                          c: float = 16.0, n_grads: float = 2.0) -> float:
    """Eq. (13): ``t̄_{k+1} = min{t : max_{|S|=m} min_{i∈S} N_i(t̄_k, t) = 2}``.

    Equivalently the m-th smallest of the per-worker times to accumulate
    integral ``n_grads`` after ``t̄_k`` (the best set S is the m workers
    whose integral reaches it first).
    ``K̄ = ceil(c * max(LΔ/ε, σ²LΔ/(mε²)))``.

    ``n_grads=2`` is the theorem's worst case (a stale gradient must finish
    before the fresh one starts — §3 Remark). ``n_grads=1`` is the
    idle-start evaluation: with synchronized iterations, the selected m
    workers are idle at each iteration boundary and compute exactly one
    gradient. The paper's §5.3 numerical gaps (1.52/1.85/1.11/1.37) match
    the idle-start variant; the worst-case recursion is exactly 2x it for
    near-constant powers (we report both in benchmarks/sec53_gap.py).
    """
    K = int(math.ceil(c * max(L * Delta / eps,
                              sigma2 * L * Delta / (m * eps ** 2))))
    t = 0.0
    for _ in range(K):
        finish = np.array([model.time_for_integral(i, t, n_grads)
                           for i in range(model.n)])
        finish.sort()
        t = float(finish[m - 1])
        if not math.isfinite(t):
            return math.inf
    return t


def universal_gap(model: UniversalModel, L: float, Delta: float, eps: float,
                  sigma2: float, m: int) -> Tuple[float, float, float]:
    """Return ``(t̄_K̄, t_K, ratio)`` for the §5.3 numerical-gap experiment."""
    ub = msync_upper_recursion(model, L, Delta, eps, sigma2, m)
    lb = lower_bound_recursion(model, L, Delta, eps, sigma2)
    return ub, lb, ub / lb
