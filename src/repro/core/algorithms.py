"""Event-driven simulators of the paper's algorithms.

Implements, with exact wall-clock accounting (bubbles, stale computations,
discards), the five methods the paper analyses/compares:

* :func:`run_sync_sgd` — Algorithm 1 (``m = n`` special case below).
* :func:`run_m_sync_sgd` — Algorithm 3 (m-Synchronous SGD): aggregate one
  stochastic gradient from the first ``m`` workers to finish *for the
  current iterate*, discard late arrivals.
* :func:`run_async_sgd` — Algorithm 2 (Asynchronous SGD): update on every
  arrival, delay-aware stepsize optional.
* :func:`run_rennala_sgd` — Rennala SGD (Tyurin & Richtárik 2023):
  asynchronous batch accumulation at the current iterate; batch size ``B``.
* :func:`run_malenia_sgd` — Malenia SGD (heterogeneous): per-worker batches
  ``B_i``, stop collecting when the harmonic mean of ``B_i`` reaches ``S``.

The simulators share a single event engine: a priority queue of
``(finish_time, worker, iterate_version)`` events driven by a
:class:`repro.core.time_models.TimeModel` (Assumptions 2.2/3.1) or a
:class:`~repro.core.time_models.UniversalModel` (Assumption 5.1).

Semantics follow the paper's accounting exactly: a worker that is busy with
a stale gradient finishes it first (the Remark in §3: computations cannot be
stopped), then starts a gradient at the current iterate.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Optional, Union

import numpy as np

from .time_models import TimeModel, UniversalModel

__all__ = [
    "Trace",
    "Problem",
    "run_m_sync_sgd",
    "run_sync_sgd",
    "run_async_sgd",
    "run_rennala_sgd",
    "run_malenia_sgd",
    "msync_wallclock",
]


@dataclasses.dataclass
class Trace:
    """Wall-clock trace of one optimization run."""

    times: np.ndarray          # wall-clock seconds at each recorded event
    values: np.ndarray         # f(x) at those times (nan if not recorded)
    grad_norms: np.ndarray     # ||grad f(x)||^2 at those times
    iterations: int            # server updates performed
    total_time: float          # wall-clock at termination
    gradients_used: int        # stochastic gradients aggregated into updates
    gradients_computed: int    # total computed (incl. discarded)

    @property
    def discard_fraction(self) -> float:
        if self.gradients_computed == 0:
            return 0.0
        return 1.0 - self.gradients_used / self.gradients_computed


@dataclasses.dataclass
class Problem:
    """An optimization problem with a stochastic first-order oracle."""

    x0: np.ndarray
    f: Callable[[np.ndarray], float]
    grad: Callable[[np.ndarray], np.ndarray]                    # exact (for eval)
    stoch_grad: Callable[[np.ndarray, np.random.Generator], np.ndarray]


class _Engine:
    """Shared worker event engine."""

    def __init__(self, model: Union[TimeModel, UniversalModel],
                 rng: np.random.Generator) -> None:
        self.model = model
        self.rng = rng
        self.n = model.n
        self.heap: list = []        # (finish_time, seq, worker, version)
        self._seq = 0
        self.busy_until = np.zeros(self.n)
        self.computed = 0

    def start(self, worker: int, t_now: float, version: int) -> None:
        """Worker begins one gradient at time ``t_now`` for ``version``."""
        if isinstance(self.model, UniversalModel):
            t_fin = self.model.time_for_integral(worker, t_now, 1.0)
        else:
            t_fin = t_now + self.model.sample_time(worker, self.rng)
        self._seq += 1
        self.busy_until[worker] = t_fin
        heapq.heappush(self.heap, (t_fin, self._seq, worker, version))

    def pop(self):
        t, _, w, v = heapq.heappop(self.heap)
        self.computed += 1
        return t, w, v


def _recorder(problem: Optional[Problem], record_every: int):
    times, vals, gnorms = [], [], []

    def record(t: float, x: Optional[np.ndarray], k: int) -> None:
        if problem is None or x is None:
            return
        if k % record_every:
            return
        times.append(t)
        vals.append(problem.f(x))
        g = problem.grad(x)
        gnorms.append(float(np.dot(g, g)))

    return times, vals, gnorms, record


def run_m_sync_sgd(model: Union[TimeModel, UniversalModel],
                   K: int,
                   m: int,
                   problem: Optional[Problem] = None,
                   gamma: float = 0.0,
                   seed: int = 0,
                   record_every: int = 1,
                   tol_grad_sq: Optional[float] = None) -> Trace:
    """Algorithm 3. With ``problem=None`` runs timing-only (no math).

    Each iteration: every *idle* worker starts a gradient at ``x^k``; busy
    workers finish their stale gradient (discarded) and then start at
    ``x^k``. The iteration ends when ``m`` gradients for version ``k`` have
    arrived; late version-``k`` gradients are discarded (Algorithm 3 line 6).
    """
    rng = np.random.default_rng(seed)
    eng = _Engine(model, rng)
    n = eng.n
    if not (1 <= m <= n):
        raise ValueError(f"m={m} out of range [1, {n}]")
    x = None if problem is None else problem.x0.copy()
    times, vals, gnorms, record = _recorder(problem, record_every)
    record(0.0, x, 0)

    t = 0.0
    used = 0
    # All workers idle at t=0.
    idle = set(range(n))
    for k in range(K):
        # Idle workers start now; busy ones will start (for version k) when
        # their stale computation finishes — we model that by re-queueing on
        # pop (see below).
        for w in list(idle):
            eng.start(w, t, k)
        idle.clear()
        got = 0
        acc = None if x is None else np.zeros_like(x)
        while got < m:
            t_fin, w, v = eng.pop()
            t = t_fin
            if v == k:
                got += 1
                used += 1
                if x is not None:
                    acc += problem.stoch_grad(x, rng)
                idle.add(w)  # done for this iteration
            else:
                # stale gradient: discard, start a fresh one at x^k
                eng.start(w, t_fin, k)
        if x is not None:
            x = x - gamma * (acc / m)
        record(t, x, k + 1)
        if tol_grad_sq is not None and x is not None:
            g = problem.grad(x)
            if float(np.dot(g, g)) <= tol_grad_sq:
                K = k + 1
                break
        # workers still computing version-k gradients: their results will be
        # discarded; they stay busy (Remark §3: cannot stop computations).
    return Trace(np.array(times), np.array(vals), np.array(gnorms),
                 iterations=K, total_time=t, gradients_used=used,
                 gradients_computed=eng.computed)


def run_sync_sgd(model, K, problem=None, gamma=0.0, seed=0, record_every=1,
                 tol_grad_sq=None) -> Trace:
    """Algorithm 1 = m-Synchronous SGD with m = n."""
    return run_m_sync_sgd(model, K, model.n, problem, gamma, seed,
                          record_every, tol_grad_sq)


def run_async_sgd(model: Union[TimeModel, UniversalModel],
                  K: int,
                  problem: Optional[Problem] = None,
                  gamma: float = 0.0,
                  seed: int = 0,
                  record_every: int = 10,
                  delay_adaptive: bool = False,
                  tol_grad_sq: Optional[float] = None) -> Trace:
    """Algorithm 2 — update on every arrival.

    ``delay_adaptive`` uses the Koloskova et al. (2022)-style rule
    ``gamma_k = gamma / (1 + delay_k / n)`` which keeps the method stable
    under unbounded delays without per-run tuning.
    """
    rng = np.random.default_rng(seed)
    eng = _Engine(model, rng)
    n = eng.n
    x = None if problem is None else problem.x0.copy()
    times, vals, gnorms, record = _recorder(problem, record_every)
    record(0.0, x, 0)

    # Worker w is computing at iterate version[w]; server iterate has
    # version k. Each arrival applies one update.
    snapshots = {}  # version -> x at that version (for stale gradients)
    if x is not None:
        snapshots[0] = x.copy()
    version = [0] * n
    t = 0.0
    for w in range(n):
        eng.start(w, 0.0, 0)
    used = 0
    last_needed = np.zeros(n, dtype=int)  # min version still being computed
    for k in range(K):
        t, w, v = eng.pop()
        delay = k - v
        g_step = gamma / (1.0 + delay / max(n, 1)) if delay_adaptive else gamma
        if x is not None:
            gx = problem.stoch_grad(snapshots[v], rng)
            x = x - g_step * gx
        used += 1
        if x is not None:
            snapshots[k + 1] = x.copy()
        version[w] = k + 1
        last_needed[w] = k + 1
        eng.start(w, t, k + 1)
        # prune snapshots no longer needed
        if x is not None and (k % (4 * n) == 0):
            low = int(min(version))
            for vv in [key for key in snapshots if key < low]:
                del snapshots[vv]
        record(t, x, k + 1)
        if tol_grad_sq is not None and x is not None and k % record_every == 0:
            g = problem.grad(x)
            if float(np.dot(g, g)) <= tol_grad_sq:
                K = k + 1
                break
    return Trace(np.array(times), np.array(vals), np.array(gnorms),
                 iterations=K, total_time=t, gradients_used=used,
                 gradients_computed=eng.computed)


def run_rennala_sgd(model: Union[TimeModel, UniversalModel],
                    K: int,
                    batch: int,
                    problem: Optional[Problem] = None,
                    gamma: float = 0.0,
                    seed: int = 0,
                    record_every: int = 1,
                    tol_grad_sq: Optional[float] = None) -> Trace:
    """Rennala SGD: asynchronous accumulation of ``batch`` gradients at x^k.

    Workers compute continuously; a gradient computed at a stale iterate is
    discarded and the worker immediately restarts at the current iterate.
    When ``batch`` current-iterate gradients have accumulated, the server
    steps.
    """
    rng = np.random.default_rng(seed)
    eng = _Engine(model, rng)
    n = eng.n
    x = None if problem is None else problem.x0.copy()
    times, vals, gnorms, record = _recorder(problem, record_every)
    record(0.0, x, 0)
    t = 0.0
    used = 0
    for w in range(n):
        eng.start(w, 0.0, 0)
    for k in range(K):
        got = 0
        acc = None if x is None else np.zeros_like(x)
        while got < batch:
            t, w, v = eng.pop()
            if v == k:
                got += 1
                used += 1
                if x is not None:
                    acc += problem.stoch_grad(x, rng)
            eng.start(w, t, k if got < batch else k + 1)
        if x is not None:
            x = x - gamma * (acc / batch)
        record(t, x, k + 1)
        if tol_grad_sq is not None and x is not None:
            g = problem.grad(x)
            if float(np.dot(g, g)) <= tol_grad_sq:
                K = k + 1
                break
    return Trace(np.array(times), np.array(vals), np.array(gnorms),
                 iterations=K, total_time=t, gradients_used=used,
                 gradients_computed=eng.computed)


def run_malenia_sgd(model: Union[TimeModel, UniversalModel],
                    K: int,
                    S: float,
                    problem: Optional[Problem] = None,
                    gamma: float = 0.0,
                    seed: int = 0,
                    record_every: int = 1,
                    grads_by_worker: Optional[Callable[
                        [int, np.ndarray, np.random.Generator], np.ndarray]] = None,
                    tol_grad_sq: Optional[float] = None) -> Trace:
    """Malenia SGD (heterogeneous §6): collect per-worker batches ``B_i`` at
    the current iterate until ``(1/n * sum_i 1/B_i)^{-1} >= S`` with all
    ``B_i >= 1``; update with ``g = 1/n sum_i mean_j g_ij``.

    ``grads_by_worker(i, x, rng)`` supplies worker-``i``-specific gradients
    (``∇f_i``); defaults to the homogeneous oracle.
    """
    rng = np.random.default_rng(seed)
    eng = _Engine(model, rng)
    n = eng.n
    x = None if problem is None else problem.x0.copy()
    times, vals, gnorms, record = _recorder(problem, record_every)
    record(0.0, x, 0)
    t = 0.0
    used = 0
    for w in range(n):
        eng.start(w, 0.0, 0)
    for k in range(K):
        B = np.zeros(n, dtype=int)
        acc = (None if x is None
               else [np.zeros_like(x) for _ in range(n)])

        def ready() -> bool:
            if np.any(B == 0):
                return False
            return n / float(np.sum(1.0 / B)) >= S

        while not ready():
            t, w, v = eng.pop()
            if v == k:
                B[w] += 1
                used += 1
                if x is not None:
                    if grads_by_worker is not None:
                        acc[w] += grads_by_worker(w, x, rng)
                    else:
                        acc[w] += problem.stoch_grad(x, rng)
            eng.start(w, t, k if not ready() else k + 1)
        if x is not None:
            g = sum(acc[i] / B[i] for i in range(n)) / n
            x = x - gamma * g
        record(t, x, k + 1)
        if tol_grad_sq is not None and x is not None:
            g = problem.grad(x)
            if float(np.dot(g, g)) <= tol_grad_sq:
                K = k + 1
                break
    return Trace(np.array(times), np.array(vals), np.array(gnorms),
                 iterations=K, total_time=t, gradients_used=used,
                 gradients_computed=eng.computed)


def msync_wallclock(model: Union[TimeModel, UniversalModel], K: int, m: int,
                    seed: int = 0) -> float:
    """Wall-clock seconds for K iterations of Algorithm 3 (timing only)."""
    return run_m_sync_sgd(model, K, m, problem=None, seed=seed).total_time


def run_ringmaster_asgd(model: Union[TimeModel, UniversalModel],
                        K: int,
                        max_delay: int,
                        problem: Optional[Problem] = None,
                        gamma: float = 0.0,
                        seed: int = 0,
                        record_every: int = 10,
                        tol_grad_sq: Optional[float] = None) -> Trace:
    """Ringmaster ASGD (Maranjyan, Tyurin & Richtárik 2025b) — the first
    Asynchronous SGD with optimal time complexity: like Algorithm 2, but a
    gradient whose delay exceeds ``max_delay`` is DISCARDED (and the worker
    restarted at the current iterate) instead of applied. This bounds the
    effective staleness, allowing a constant stepsize.
    """
    rng = np.random.default_rng(seed)
    eng = _Engine(model, rng)
    n = eng.n
    x = None if problem is None else problem.x0.copy()
    times, vals, gnorms, record = _recorder(problem, record_every)
    record(0.0, x, 0)
    snapshots = {}
    if x is not None:
        snapshots[0] = x.copy()
    t = 0.0
    used = 0
    version = [0] * n
    for w in range(n):
        eng.start(w, 0.0, 0)
    k = 0
    while k < K:
        t, w, v = eng.pop()
        delay = k - v
        if delay <= max_delay:
            if x is not None:
                gx = problem.stoch_grad(snapshots[v], rng)
                x = x - gamma * gx
                snapshots[k + 1] = x.copy()
            used += 1
            k += 1
            if tol_grad_sq is not None and x is not None \
                    and k % record_every == 0:
                g = problem.grad(x)
                if float(np.dot(g, g)) <= tol_grad_sq:
                    K = k
            record(t, x, k)
        # in either case the worker restarts at the current iterate
        version[w] = k
        eng.start(w, t, k)
        if x is not None and (k % (4 * n) == 0):
            low = min(version)
            for vv in [key for key in snapshots if key < low]:
                del snapshots[vv]
    return Trace(np.array(times), np.array(vals), np.array(gnorms),
                 iterations=K, total_time=t, gradients_used=used,
                 gradients_computed=eng.computed)
