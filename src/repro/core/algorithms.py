"""Deprecated per-method entry points for the event-driven simulators.

.. deprecated::
    The five hand-rolled event loops that used to live here (plus
    Ringmaster ASGD) are now ~20-line strategy classes in
    :mod:`repro.core.strategies`, all driven by the single vectorized
    :func:`repro.core.strategies.simulate` engine. Prefer::

        from repro.core import STRATEGIES, simulate
        trace = simulate(STRATEGIES["msync"](m=10), model, K, ...)

    The ``run_*`` functions below are kept as thin shims with their exact
    historical signatures; each delegates to ``simulate`` with the matching
    strategy, so a seeded shim call is bitwise-identical to the new API.

Implements, with exact wall-clock accounting (bubbles, stale computations,
discards), the methods the paper analyses/compares:

* :func:`run_sync_sgd` — Algorithm 1 (``m = n`` special case below).
* :func:`run_m_sync_sgd` — Algorithm 3 (m-Synchronous SGD): aggregate one
  stochastic gradient from the first ``m`` workers to finish *for the
  current iterate*, discard late arrivals.
* :func:`run_async_sgd` — Algorithm 2 (Asynchronous SGD): update on every
  arrival, delay-aware stepsize optional.
* :func:`run_rennala_sgd` — Rennala SGD (Tyurin & Richtárik 2023):
  asynchronous batch accumulation at the current iterate; batch size ``B``.
* :func:`run_malenia_sgd` — Malenia SGD (heterogeneous): per-worker batches
  ``B_i``, stop collecting when the harmonic mean of ``B_i`` reaches ``S``.
* :func:`run_ringmaster_asgd` — Ringmaster ASGD (Maranjyan, Tyurin &
  Richtárik 2025b): Asynchronous SGD with delay-capped discards.

Semantics follow the paper's accounting exactly: a worker that is busy with
a stale gradient finishes it first (the Remark in §3: computations cannot be
stopped), then starts a gradient at the current iterate.
"""

from __future__ import annotations

import warnings
from typing import Callable, Optional, Union

import numpy as np

from .strategies import (Async, Malenia, MSync, Problem, Rennala, Ringmaster,
                         Trace, simulate)
from .time_models import TimeModel, UniversalModel

__all__ = [
    "Trace",
    "Problem",
    "run_m_sync_sgd",
    "run_sync_sgd",
    "run_async_sgd",
    "run_rennala_sgd",
    "run_malenia_sgd",
    "run_ringmaster_asgd",
    "msync_wallclock",
]

_Model = Union[TimeModel, UniversalModel]


def _deprecated(old: str, new: str) -> None:
    warnings.warn(f"{old} is deprecated; use "
                  f"simulate(STRATEGIES[{new!r}](...), model, K, ...) "
                  "from repro.core.strategies",
                  DeprecationWarning, stacklevel=3)


def run_m_sync_sgd(model: _Model,
                   K: int,
                   m: int,
                   problem: Optional[Problem] = None,
                   gamma: float = 0.0,
                   seed: int = 0,
                   record_every: int = 1,
                   tol_grad_sq: Optional[float] = None) -> Trace:
    """Algorithm 3 (shim). With ``problem=None`` runs timing-only."""
    _deprecated("run_m_sync_sgd", "msync")
    return simulate(MSync(m=m), model, K, problem=problem, gamma=gamma,
                    seed=seed, record_every=record_every,
                    tol_grad_sq=tol_grad_sq)


def run_sync_sgd(model, K, problem=None, gamma=0.0, seed=0, record_every=1,
                 tol_grad_sq=None) -> Trace:
    """Algorithm 1 = m-Synchronous SGD with m = n (shim)."""
    _deprecated("run_sync_sgd", "sync")
    return simulate(MSync(m=model.n), model, K, problem=problem, gamma=gamma,
                    seed=seed, record_every=record_every,
                    tol_grad_sq=tol_grad_sq)


def run_async_sgd(model: _Model,
                  K: int,
                  problem: Optional[Problem] = None,
                  gamma: float = 0.0,
                  seed: int = 0,
                  record_every: int = 10,
                  delay_adaptive: bool = False,
                  tol_grad_sq: Optional[float] = None) -> Trace:
    """Algorithm 2 (shim) — update on every arrival.

    ``delay_adaptive`` uses the Koloskova et al. (2022)-style rule
    ``gamma_k = gamma / (1 + delay_k / n)`` which keeps the method stable
    under unbounded delays without per-run tuning.
    """
    _deprecated("run_async_sgd", "async")
    return simulate(Async(delay_adaptive=delay_adaptive), model, K,
                    problem=problem, gamma=gamma, seed=seed,
                    record_every=record_every, tol_grad_sq=tol_grad_sq)


def run_rennala_sgd(model: _Model,
                    K: int,
                    batch: int,
                    problem: Optional[Problem] = None,
                    gamma: float = 0.0,
                    seed: int = 0,
                    record_every: int = 1,
                    tol_grad_sq: Optional[float] = None) -> Trace:
    """Rennala SGD (shim): asynchronous accumulation of ``batch`` at x^k."""
    _deprecated("run_rennala_sgd", "rennala")
    return simulate(Rennala(batch=batch), model, K, problem=problem,
                    gamma=gamma, seed=seed, record_every=record_every,
                    tol_grad_sq=tol_grad_sq)


def run_malenia_sgd(model: _Model,
                    K: int,
                    S: float,
                    problem: Optional[Problem] = None,
                    gamma: float = 0.0,
                    seed: int = 0,
                    record_every: int = 1,
                    grads_by_worker: Optional[Callable[
                        [int, np.ndarray, np.random.Generator], np.ndarray]] = None,
                    tol_grad_sq: Optional[float] = None) -> Trace:
    """Malenia SGD (shim, heterogeneous §6)."""
    _deprecated("run_malenia_sgd", "malenia")
    return simulate(Malenia(S=S, grads_by_worker=grads_by_worker), model, K,
                    problem=problem, gamma=gamma, seed=seed,
                    record_every=record_every, tol_grad_sq=tol_grad_sq)


def run_ringmaster_asgd(model: _Model,
                        K: int,
                        max_delay: int,
                        problem: Optional[Problem] = None,
                        gamma: float = 0.0,
                        seed: int = 0,
                        record_every: int = 10,
                        tol_grad_sq: Optional[float] = None) -> Trace:
    """Ringmaster ASGD (shim) — delay-capped Asynchronous SGD."""
    _deprecated("run_ringmaster_asgd", "ringmaster")
    return simulate(Ringmaster(max_delay=max_delay), model, K,
                    problem=problem, gamma=gamma, seed=seed,
                    record_every=record_every, tol_grad_sq=tol_grad_sq)


def msync_wallclock(model: _Model, K: int, m: int, seed: int = 0) -> float:
    """Wall-clock seconds for K iterations of Algorithm 3 (timing only)."""
    return simulate(MSync(m=m), model, K, seed=seed).total_time
