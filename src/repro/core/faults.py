"""Fault injection: composable transformations of ``TimeModel`` draws.

The paper's robustness claims are about *adversarial computation-time
dynamics* — crash/restart workers, transient slowdowns, correlated
failure bursts, heavy-tail straggler spikes (cf. the arbitrary-dynamics
framework of arXiv 2408.04929). This module makes those regimes
first-class: a :class:`FaultModel` is a renewal-preserving transformation
of one per-gradient duration draw, and :func:`with_faults` composes any
number of them over a base :class:`~repro.core.time_models.FixedTimes`
or :class:`~repro.core.time_models.SubExponentialTimes` model, producing
a :class:`FaultyTimes` that IS a ``SubExponentialTimes`` — so every
engine (the scalar event heap, the vectorized tensor path, the jitted
round scans, the renewal-chain arrival scan, and the sharded sweep)
accepts it unchanged.

Contracts
---------

* **Renewal preservation.** Every fault transforms a single draw
  ``t -> g(t, xi)`` with fresh fault noise ``xi`` per draw; transformed
  draws stay i.i.d. across renewals. This is load-bearing: the
  device-resident engines (``jax_chain_draws`` chain pools, the round
  scans) assume renewal structure. Temporal dynamics live *inside* one
  draw (e.g. :class:`TransientSlowdown`'s on/off episodes arrive on the
  work clock of the computation being transformed).
* **Identity is bitwise a no-op.** A :class:`FaultModel` with
  ``is_identity=True`` consumes zero RNG, and :class:`FaultyTimes`
  passes the base model's samplers through *by object identity* when no
  active fault remains — wrapped runs are bitwise-identical to
  unwrapped runs on every backend (and even share the jit program
  caches, which key on sampler identity).
* **Disjoint fault streams (jax).** Device-side fault noise is keyed by
  ``fold_in(draw_key, _FAULT_TAG)`` off the same per-(seed, worker/slot)
  key the base draw consumes, so fault draws are pure functions of the
  seed value — sweep-independent like every counter-scheme stream — and
  the base draw under a given key is unchanged by wrapping: a faulted
  draw is a transformation *of the same base sample*.
* **NumPy stream order.** The host paths draw fault noise from the
  engine-provided generator immediately after the base draw of the same
  call, so serial runs stay deterministic per seed. Consequence: faulted
  models keep the ``counter`` contract but NOT ``stream`` scalar-replay
  parity (the tensor path applies fault noise per seed after the bulk
  base draw); the identity wrapper keeps both, bitwise.
* **Correlation granularity.** :class:`CorrelatedBursts` shares one
  episode draw per *row* — a full ``jax_sampler`` round, one
  ``sample_times`` call, or one ``sample_times_tensor`` round-row. The
  single-draw paths (``sample_time``, ``jax_sampler_item``) see the
  exact per-worker marginal (episode x inclusion); cross-worker
  correlation is a row-level property, so serial vs jax parity for
  bursts is distribution-level (as all serial-vs-jax parity is).

``mean_times``/``sub_exponential_R`` of the wrapper are exact for the
mean transformations documented per fault and *conservative upper
bounds* for ``R`` (:class:`HeavyTailSpike` is genuinely heavy-tailed:
``R = inf``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence, Tuple, Union

import numpy as np

from .time_models import FixedTimes, SubExponentialTimes, _as_rng

__all__ = ["FaultModel", "IdentityFault", "CrashRestart",
           "TransientSlowdown", "CorrelatedBursts", "HeavyTailSpike",
           "FaultyTimes", "with_faults", "FAULT_TAG"]

# fold_in tag separating device-side fault-noise streams from base-draw
# streams (see module docstring); "faul" in ASCII.
FAULT_TAG = 0x6661756C


class FaultModel:
    """One renewal-preserving transformation of a duration draw.

    Subclasses override the three ``transform*`` hooks plus the
    ``mean``/``R`` maps. The base class is the identity: it touches
    neither the draw nor any RNG, which is exactly the bitwise no-op
    contract :class:`FaultyTimes` relies on.
    """

    name = "identity"
    is_identity = True

    def transform_rows(self, t: np.ndarray, workers: np.ndarray,
                       rng: np.random.Generator,
                       redraw: Callable[[np.random.Generator], np.ndarray]
                       ) -> np.ndarray:
        """NumPy path: transform a ``(rows, workers)`` block of draws.

        One "row" is one shared episode clock tick (one engine draw
        call / one tensor round). ``redraw(rng)`` yields a same-shaped
        block of fresh base draws (crash/restart redraws).
        """
        return t

    def jax_transform_rows(self, t, key, redraw):
        """jax path: transform one ``(n,)`` round row under ``key``."""
        return t

    def jax_transform_item(self, t, key, i, redraw):
        """jax path: transform ONE worker draw (``i`` may be traced)."""
        return t

    def transform_means(self, taus: np.ndarray) -> np.ndarray:
        """Exact per-worker mean of the transformed draw."""
        return taus

    def transform_R(self, R: float, taus: np.ndarray) -> float:
        """Conservative sub-exponential parameter of the transformed draw."""
        return R


class IdentityFault(FaultModel):
    """The explicit no-op (useful as a sweep axis / ablation control)."""


def _check_prob(p: float, what: str) -> float:
    p = float(p)
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"{what} must be in [0, 1], got {p}")
    return p


def _check_pos(x: float, what: str) -> float:
    x = float(x)
    if x <= 0.0:
        raise ValueError(f"{what} must be positive, got {x}")
    return x


@dataclasses.dataclass(frozen=True)
class CrashRestart(FaultModel):
    """Crash/restart as a renewal transformation.

    With probability ``p`` a computation crashes partway through: the
    draw becomes ``u*t + d + t2`` — progress lost after a uniform
    fraction ``u`` of the original duration ``t``, downtime
    ``d ~ Exp(mean_downtime)``, then one fresh redraw ``t2`` of the full
    computation (at most one crash per draw; the truncation keeps the
    mean map closed-form). Mean map:
    ``tau -> tau*(1 + p/2) + p*mean_downtime``.
    """

    p: float
    mean_downtime: float
    name: str = dataclasses.field(default="crash", init=False)
    is_identity = False

    def __post_init__(self) -> None:
        _check_prob(self.p, "CrashRestart.p")
        _check_pos(self.mean_downtime, "CrashRestart.mean_downtime")

    def transform_rows(self, t, workers, rng, redraw):
        crash = rng.random(t.shape) < self.p
        u = rng.random(t.shape)
        down = rng.exponential(self.mean_downtime, size=t.shape)
        t2 = np.asarray(redraw(rng), dtype=float)
        return np.where(crash, u * t + down + t2, t)

    def jax_transform_rows(self, t, key, redraw):
        import jax
        import jax.numpy as jnp
        kc, ku, kd, kr = jax.random.split(key, 4)
        shape = jnp.shape(t)
        crash = jax.random.bernoulli(kc, self.p, shape)
        u = jax.random.uniform(ku, shape, dtype=t.dtype)
        down = jax.random.exponential(kd, shape,
                                      dtype=t.dtype) * self.mean_downtime
        return jnp.where(crash, u * t + down + redraw(kr), t)

    def jax_transform_item(self, t, key, i, redraw):
        import jax
        import jax.numpy as jnp
        kc, ku, kd, kr = jax.random.split(key, 4)
        crash = jax.random.bernoulli(kc, self.p)
        u = jax.random.uniform(ku, dtype=t.dtype)
        down = jax.random.exponential(kd, dtype=t.dtype) \
            * self.mean_downtime
        return jnp.where(crash, u * t + down + redraw(kr), t)

    def transform_means(self, taus):
        return taus * (1.0 + self.p / 2.0) + self.p * self.mean_downtime

    def transform_R(self, R, taus):
        # t' <= t + d + t2 stochastically; sum of sub-exps is sub-exp
        # with parameter bounded by the sum.
        return 2.0 * R + self.mean_downtime


@dataclasses.dataclass(frozen=True)
class TransientSlowdown(FaultModel):
    """Multiplicative slowdown episodes with Markov on/off dynamics.

    Degradation episodes arrive on the *work clock* of one computation
    (rate ``rate`` per unit of base duration — the off->on transition of
    the on/off chain); each episode slows the worker by ``factor`` for
    an ``Exp(mean_episode)`` stretch (the on->off transition), adding
    ``(factor-1) * Exp(mean_episode)`` wall time. With ``N ~
    Poisson(rate * t)`` episodes the draw becomes ``t + (factor-1) *
    Gamma(N, mean_episode)`` — the within-draw embedding of the Markov
    chain that keeps draws i.i.d. across renewals (see module
    docstring). Mean map: ``tau -> tau * (1 + rate*mean_episode*(factor-1))``.
    """

    rate: float
    mean_episode: float
    factor: float
    name: str = dataclasses.field(default="slowdown", init=False)
    is_identity = False

    def __post_init__(self) -> None:
        _check_pos(self.rate, "TransientSlowdown.rate")
        _check_pos(self.mean_episode, "TransientSlowdown.mean_episode")
        if self.factor < 1.0:
            raise ValueError("TransientSlowdown.factor must be >= 1")

    def transform_rows(self, t, workers, rng, redraw):
        n_ep = rng.poisson(self.rate * np.maximum(t, 0.0))
        extra = rng.gamma(np.maximum(n_ep, 1), self.mean_episode) \
            * (self.factor - 1.0)
        return t + np.where(n_ep > 0, extra, 0.0)

    def jax_transform_rows(self, t, key, redraw):
        import jax
        import jax.numpy as jnp
        kn, kg = jax.random.split(key)
        n_ep = jax.random.poisson(kn, self.rate * jnp.maximum(t, 0.0))
        shape = jnp.maximum(n_ep, 1).astype(t.dtype)
        extra = jax.random.gamma(kg, shape) * jnp.asarray(
            self.mean_episode * (self.factor - 1.0), dtype=t.dtype)
        return t + jnp.where(n_ep > 0, extra.astype(t.dtype), 0.0)

    def jax_transform_item(self, t, key, i, redraw):
        return self.jax_transform_rows(t, key, redraw)

    def transform_means(self, taus):
        return taus * (1.0 + self.rate * self.mean_episode
                       * (self.factor - 1.0))

    def transform_R(self, R, taus):
        inflate = self.mean_episode * (self.factor - 1.0)
        return R * (1.0 + self.rate * inflate) + inflate


@dataclasses.dataclass(frozen=True)
class CorrelatedBursts(FaultModel):
    """Correlated failure bursts: a shared episode clock hits a subset.

    Each *row* (one engine draw call — a full jax round row, one
    ``sample_times`` call, one tensor round) shares a single episode
    coin: with probability ``p_episode`` a burst is live, and each
    worker in the row is independently hit with probability ``frac``,
    receiving ``Exp(mean_extra)`` extra delay. Single-draw paths see the
    exact marginal (``p_episode * frac``). Mean map:
    ``tau -> tau + p_episode*frac*mean_extra``.
    """

    p_episode: float
    frac: float
    mean_extra: float
    name: str = dataclasses.field(default="bursts", init=False)
    is_identity = False

    def __post_init__(self) -> None:
        _check_prob(self.p_episode, "CorrelatedBursts.p_episode")
        _check_prob(self.frac, "CorrelatedBursts.frac")
        _check_pos(self.mean_extra, "CorrelatedBursts.mean_extra")

    def transform_rows(self, t, workers, rng, redraw):
        rows = t.shape[0]
        episode = rng.random((rows, 1)) < self.p_episode
        hit = rng.random(t.shape) < self.frac
        extra = rng.exponential(self.mean_extra, size=t.shape)
        return t + np.where(episode & hit, extra, 0.0)

    def jax_transform_rows(self, t, key, redraw):
        import jax
        import jax.numpy as jnp
        ke, kh, kx = jax.random.split(key, 3)
        episode = jax.random.bernoulli(ke, self.p_episode)  # shared clock
        hit = jax.random.bernoulli(kh, self.frac, jnp.shape(t))
        extra = jax.random.exponential(kx, jnp.shape(t),
                                       dtype=t.dtype) * self.mean_extra
        return t + jnp.where(episode & hit, extra, 0.0)

    def jax_transform_item(self, t, key, i, redraw):
        import jax
        import jax.numpy as jnp
        kh, kx = jax.random.split(key)
        hit = jax.random.bernoulli(kh, self.p_episode * self.frac)
        extra = jax.random.exponential(kx, dtype=t.dtype) * self.mean_extra
        return t + jnp.where(hit, extra, 0.0)

    def transform_means(self, taus):
        return taus + self.p_episode * self.frac * self.mean_extra

    def transform_R(self, R, taus):
        return R + self.mean_extra


@dataclasses.dataclass(frozen=True)
class HeavyTailSpike(FaultModel):
    """Heavy-tail straggler spikes: Pareto (Lomax) extra delay.

    With probability ``p`` a draw picks up ``scale * (U^{-1/alpha} - 1)``
    extra delay — a Lomax(alpha, scale) spike. ``alpha > 1`` is required
    so the mean exists (``tau -> tau + p*scale/(alpha-1)``); the tail is
    genuinely polynomial, so the wrapped model is NOT sub-exponential
    and reports ``R = inf``.
    """

    p: float
    alpha: float
    scale: float
    name: str = dataclasses.field(default="spikes", init=False)
    is_identity = False

    def __post_init__(self) -> None:
        _check_prob(self.p, "HeavyTailSpike.p")
        _check_pos(self.scale, "HeavyTailSpike.scale")
        if float(self.alpha) <= 1.0:
            raise ValueError("HeavyTailSpike.alpha must be > 1 "
                             "(finite mean)")

    def transform_rows(self, t, workers, rng, redraw):
        spiked = rng.random(t.shape) < self.p
        u = np.maximum(rng.random(t.shape), 1e-12)
        spike = self.scale * (u ** (-1.0 / self.alpha) - 1.0)
        return t + np.where(spiked, spike, 0.0)

    def jax_transform_rows(self, t, key, redraw):
        import jax
        import jax.numpy as jnp
        ks, ku = jax.random.split(key)
        shape = jnp.shape(t)
        spiked = jax.random.bernoulli(ks, self.p, shape)
        u = jax.random.uniform(ku, shape, dtype=t.dtype,
                               minval=1e-7, maxval=1.0)
        spike = self.scale * (u ** (-1.0 / self.alpha) - 1.0)
        return t + jnp.where(spiked, spike, 0.0)

    def jax_transform_item(self, t, key, i, redraw):
        return self.jax_transform_rows(t, key, redraw)

    def transform_means(self, taus):
        return taus + self.p * self.scale / (self.alpha - 1.0)

    def transform_R(self, R, taus):
        return math.inf


def _compose_jax_rows(base_rows: Callable, active: Tuple[FaultModel, ...]
                      ) -> Callable:
    def jax_sampler(key):
        import jax
        t = base_rows(key)
        fkey = jax.random.fold_in(key, FAULT_TAG)
        for idx, fault in enumerate(active):
            t = fault.jax_transform_rows(
                t, jax.random.fold_in(fkey, idx), base_rows)
        return t
    return jax_sampler


def _compose_jax_item(base_item: Callable, active: Tuple[FaultModel, ...]
                      ) -> Callable:
    def jax_sampler_item(key, i):
        import jax
        t = base_item(key, i)
        fkey = jax.random.fold_in(key, FAULT_TAG)
        for idx, fault in enumerate(active):
            t = fault.jax_transform_item(
                t, jax.random.fold_in(fkey, idx), i,
                lambda k: base_item(k, i))
        return t
    return jax_sampler_item


class FaultyTimes(SubExponentialTimes):
    """A base time model with a stack of fault transformations applied.

    IS a :class:`SubExponentialTimes` — ``isinstance`` checks, the jax
    engine support predicate, the chain builders' sampler-identity jit
    caches and the sharded sweep all treat it as an ordinary sampled
    model. When every fault in the stack is the identity, the base
    samplers are passed through by object identity and every path is
    bitwise-identical to the unwrapped model (see module docstring).
    """

    def __init__(self, base: Union[FixedTimes, SubExponentialTimes],
                 faults: Sequence[FaultModel]) -> None:
        faults = tuple(faults)
        for f in faults:
            if not isinstance(f, FaultModel):
                raise TypeError(f"not a FaultModel: {f!r}")
        active = tuple(f for f in faults if not f.is_identity)

        if isinstance(base, FixedTimes):
            base_taus, base_r = np.asarray(base.taus, float), 0.0
            base_name = "fixed"
            taus_arr = base.taus

            def base_rows(workers, rng):
                return taus_arr[np.asarray(workers, dtype=int)]

            def base_jax_rows(key):
                import jax.numpy as jnp
                return jnp.asarray(taus_arr)

            def base_jax_item(key, i):
                import jax.numpy as jnp
                return jnp.asarray(taus_arr)[i]
        elif isinstance(base, SubExponentialTimes):
            base_taus, base_r = np.asarray(base.taus, float), float(base.R)
            base_name = base.name
            base_rows = base.sample_times
            base_jax_rows = base.jax_sampler
            base_jax_item = base.jax_sampler_item
        else:
            raise TypeError(
                "with_faults wraps FixedTimes / SubExponentialTimes; "
                f"got {type(base).__name__} (universal/participation "
                "models define dynamics, not renewal draws)")

        self.base = base
        self.faults = faults
        self._active = active
        self._base_rows = base_rows

        taus, r = base_taus, base_r
        for f in active:
            r = f.transform_R(r, taus)
            taus = f.transform_means(np.asarray(taus, dtype=float))

        if active:
            jax_rows = (_compose_jax_rows(base_jax_rows, active)
                        if base_jax_rows is not None else None)
            jax_item = (_compose_jax_item(base_jax_item, active)
                        if base_jax_item is not None else None)
            name = base_name + "+" + "+".join(f.name for f in active)
        else:
            jax_rows, jax_item = base_jax_rows, base_jax_item
            name = base_name

        def scalar_sampler(i: int, rng: np.random.Generator) -> float:
            return float(self.sample_times(np.asarray([i]), rng)[0])

        super().__init__(taus=taus, sampler=scalar_sampler, R=r, name=name,
                         batch_sampler=None, jax_sampler=jax_rows,
                         jax_sampler_item=jax_item)

    def _redraw(self, workers: np.ndarray, rounds: int) -> Callable:
        workers = np.asarray(workers, dtype=int)

        def redraw(rng: np.random.Generator) -> np.ndarray:
            tiled = np.tile(workers, rounds)
            return np.asarray(self._base_rows(tiled, rng),
                              dtype=float).reshape(rounds, len(workers))
        return redraw

    def sample_time(self, i: int, rng: np.random.Generator) -> float:
        if not self._active:
            return self.base.sample_time(i, rng)
        return float(self.sample_times(np.asarray([i]), rng)[0])

    def sample_times(self, workers: Sequence[int],
                     rng: np.random.Generator) -> np.ndarray:
        workers = np.asarray(workers, dtype=int)
        t = np.asarray(self._base_rows(workers, rng), dtype=float)
        if not self._active:
            return t
        rows = t[None, :]
        redraw = self._redraw(workers, 1)
        for fault in self._active:
            rows = fault.transform_rows(rows, workers, rng, redraw)
        return rows[0]

    def sample_times_tensor(self, workers: Sequence[int], rounds: int,
                            seed_keys: Sequence,
                            rng_scheme: str = "counter") -> np.ndarray:
        if not self._active:
            return self.base.sample_times_tensor(workers, rounds,
                                                 seed_keys, rng_scheme)
        if rng_scheme not in ("counter", "stream"):
            raise ValueError(f"unknown rng_scheme {rng_scheme!r}; "
                             "use 'counter' or 'stream'")
        workers = np.asarray(workers, dtype=int)
        rngs = [_as_rng(k, rng_scheme) for k in seed_keys]
        out = self.base.sample_times_tensor(workers, rounds, rngs,
                                            rng_scheme)
        redraw = self._redraw(workers, int(rounds))
        for si, rng in enumerate(rngs):
            rows = out[si]
            for fault in self._active:
                rows = fault.transform_rows(rows, workers, rng, redraw)
            out[si] = rows
        return out


def with_faults(model: Union[FixedTimes, SubExponentialTimes],
                *faults: FaultModel) -> FaultyTimes:
    """Wrap ``model`` with a stack of fault transformations.

    ``with_faults(m)`` / ``with_faults(m, IdentityFault())`` are bitwise
    no-ops on every backend (the base samplers pass through by object
    identity). Faults apply left to right::

        model = with_faults(exponential_times(1.0, n),
                            CrashRestart(p=0.05, mean_downtime=2.0),
                            CorrelatedBursts(p_episode=0.1, frac=0.5,
                                             mean_extra=3.0))
    """
    return FaultyTimes(model, faults)
