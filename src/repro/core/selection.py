"""Optimal active-worker selection (§4) and online estimation utilities.

* :func:`g_of_m` / :func:`h_of_m` — eq. (8)/(9).
* :func:`optimal_m` — Proposition 4.1: minimize ``g`` restricted to
  ``m <= min(ceil(σ²/ε), n)``.
* :func:`power_law_m` — Proposition 4.2: under ``τ_m = τ_1 m^α + δ_m`` take
  ``m = min(ceil(σ²/ε), n)``.
* :func:`estimate_R` — Section J: smallest ``R`` with
  ``mean_j exp(|τ_j - τ̄| / R) = 2`` (bisection; the empirical
  sub-exponential certificate of recorded step times).
* :class:`OnlineTauEstimator` — EWMA per-worker mean step times + empirical
  σ² of stochastic gradients, feeding :func:`optimal_m` at run time. This is
  the bridge between the paper's theory and the trainer's ``AUTO_M`` policy.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

__all__ = ["g_of_m", "h_of_m", "optimal_m", "power_law_m", "estimate_R",
           "fit_power_law", "OnlineTauEstimator"]


def g_of_m(taus: np.ndarray, sigma2: float, eps: float) -> np.ndarray:
    """Eq. (8): ``g(m) = τ_m max(1, σ²/(mε))`` for m = 1..n (sorted τ)."""
    taus = np.sort(np.asarray(taus, dtype=float))
    ms = np.arange(1, len(taus) + 1, dtype=float)
    return taus * np.maximum(1.0, sigma2 / (ms * eps))


def h_of_m(taus: np.ndarray) -> np.ndarray:
    """Eq. (9): ``h(m) = τ_m / m``."""
    taus = np.sort(np.asarray(taus, dtype=float))
    return taus / np.arange(1, len(taus) + 1, dtype=float)


def optimal_m(taus: np.ndarray, sigma2: float, eps: float) -> int:
    """Proposition 4.1 minimizer of g(m) (1-indexed).

    Searches only ``m <= min(ceil(σ²/ε), n)`` — Prop 4.1 shows g is
    non-decreasing past that point. If ``σ²/ε <= 1`` the optimum is m=1.
    """
    n = len(taus)
    if sigma2 / eps <= 1.0:
        return 1
    cap = min(int(math.ceil(sigma2 / eps)), n)
    g = g_of_m(taus, sigma2, eps)[:cap]
    return int(np.argmin(g)) + 1


def power_law_m(n: int, sigma2: float, eps: float) -> int:
    """Proposition 4.2 choice ``m = min(ceil(σ²/ε), n)``."""
    return min(int(math.ceil(sigma2 / eps)), n)


def estimate_R(times: Sequence[float], mean: Optional[float] = None,
               target: float = 2.0, iters: int = 200) -> float:
    """Section J estimator: smallest R with ``mean exp(|t - τ̄|/R) = target``.

    The LHS is strictly decreasing in R (→ 1 as R → ∞, → ∞ as R → 0 unless
    all samples equal the mean), so bisection applies.
    """
    t = np.asarray(times, dtype=float)
    mu = float(np.mean(t)) if mean is None else mean
    dev = np.abs(t - mu)
    if np.max(dev) == 0.0:
        return 0.0

    def val(R: float) -> float:
        return float(np.mean(np.exp(dev / R)))

    lo = 1e-12
    hi = max(np.max(dev), 1e-9)
    while val(hi) > target:
        hi *= 2.0
        if hi > 1e18:
            return hi
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if val(mid) > target:
            lo = mid
        else:
            hi = mid
    return hi


def fit_power_law(taus: np.ndarray) -> tuple:
    """Least-squares fit of ``τ_m ≈ τ_1 m^α`` in log space → (τ_1, α)."""
    taus = np.sort(np.asarray(taus, dtype=float))
    m = np.arange(1, len(taus) + 1, dtype=float)
    A = np.stack([np.ones_like(m), np.log(m)], axis=1)
    coef, *_ = np.linalg.lstsq(A, np.log(taus), rcond=None)
    return float(np.exp(coef[0])), float(coef[1])


@dataclasses.dataclass
class OnlineTauEstimator:
    """Online (τ̂_i, σ̂²) tracking for the trainer's AUTO_M policy.

    * per-worker EWMA of observed step times (decay ``beta``);
    * running estimate of the stochastic-gradient variance σ² from the
      spread of per-worker gradients around their mean (unbiased up to the
      1/(m-1) correction);
    * :meth:`suggest_m` applies Proposition 4.1 to the current estimates.
    """

    n: int
    beta: float = 0.9
    eps_target: float = 1e-2

    def __post_init__(self) -> None:
        self.tau_hat = np.zeros(self.n)
        self.seen = np.zeros(self.n, dtype=bool)
        self.sigma2_hat: float = 0.0
        self._sigma_steps = 0

    def update_times(self, times: Sequence[float],
                     workers: Optional[Sequence[int]] = None) -> None:
        idx = range(self.n) if workers is None else workers
        for i, t in zip(idx, times):
            if not self.seen[i]:
                self.tau_hat[i] = t
                self.seen[i] = True
            else:
                self.tau_hat[i] = self.beta * self.tau_hat[i] \
                    + (1 - self.beta) * t

    def update_sigma2(self, per_worker_grad_sq_dev: float) -> None:
        """Feed ``mean_i ||g_i - ḡ||² * m/(m-1)`` for one step."""
        self._sigma_steps += 1
        w = 1.0 / self._sigma_steps
        self.sigma2_hat = (1 - w) * self.sigma2_hat + w * per_worker_grad_sq_dev

    def suggest_m(self, eps: Optional[float] = None) -> int:
        eps = self.eps_target if eps is None else eps
        taus = np.where(self.seen, self.tau_hat,
                        np.max(self.tau_hat[self.seen])
                        if self.seen.any() else 1.0)
        sigma2 = max(self.sigma2_hat, 1e-12)
        return optimal_m(taus, sigma2, eps)
