"""The paper's contribution: synchronous-method scheduling theory + engine.

Submodules:
  time_models   — Assumptions 2.2 / 3.1 / 5.1 / 5.4
  faults        — FaultModel transformations (crash/restart, slowdown
                  episodes, correlated bursts, heavy-tail spikes) over
                  any fixed/sub-exponential time model
  strategies    — AggregationStrategy protocol, STRATEGIES registry, and
                  the single vectorized simulate() event engine
  batch         — simulate_batch()/TraceBatch: multi-seed × grid sweeps
                  (seed-batched NumPy fast path; serial fallback)
  batch_jax     — JAX backend for simulate_batch (vmap over seeds,
                  optional Pallas top-m kernel); JaxProblem oracle
  algorithms    — deprecated run_* shims over strategies.simulate
  complexity    — closed forms (1),(2),(4),(7),(16); recursions (12),(13)
  selection     — Prop 4.1/4.2 m*, R estimator (§J), online τ̂/σ̂
  oracle        — eq. (27) worst-case quadratic; JAX-model bridge
  sync_engine   — participation-masked aggregation on a real mesh, driven
                  by the same strategy objects as the simulator
"""

from .algorithms import (Problem, Trace, msync_wallclock, run_async_sgd,
                         run_m_sync_sgd, run_malenia_sgd, run_rennala_sgd,
                         run_ringmaster_asgd, run_sync_sgd)
from .batch import TraceBatch, simulate_batch
from .faults import (CorrelatedBursts, CrashRestart, FaultModel,
                     FaultyTimes, HeavyTailSpike, IdentityFault,
                     TransientSlowdown, with_faults)
from .complexity import (iteration_complexity, log_factor,
                         lower_bound_recursion, msync_upper_recursion,
                         t_malenia, t_optimal, t_rand_upper, t_sync,
                         t_sync_full)
from .oracle import quadratic_worst_case
from .selection import (OnlineTauEstimator, estimate_R, g_of_m, h_of_m,
                        optimal_m, power_law_m)
from .strategies import (STRATEGIES, AggregationStrategy, Arrival, Async,
                         AutoM, DeadlineSync, Decision, Dropout, FullSync,
                         Malenia, MSync, Rennala, Ringmaster,
                         SimState, make_strategy, simulate)
from .sync_engine import (SimulatedStraggler, SyncMode, SyncPolicy,
                          first_m_mask, masked_group_mean,
                          participation_example_weights)
from .time_models import (FixedTimes, PartialParticipationModel,
                          SubExponentialTimes, UniversalModel,
                          chi2_times, exponential_times, gamma_times,
                          powers_figure3, powers_figure4,
                          shifted_exponential_times, truncated_normal_times,
                          uniform_times)
