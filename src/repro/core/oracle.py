"""Stochastic first-order oracles used in the paper's experiments (§K).

* :func:`quadratic_worst_case` — the tridiagonal quadratic (§K) with the
  progress-gated Bernoulli noise oracle of eq. (27). This is the standard
  Carmon-style hard instance: coordinates must be "discovered" one by one,
  and undiscovered coordinates carry multiplicative noise ``ξ/p`` with
  ``ξ ~ Bernoulli(p)`` — variance grows as ``p`` shrinks.
* :func:`from_jax` — wrap a JAX loss/params pytree into the flat-numpy
  :class:`~repro.core.algorithms.Problem` interface, so the event simulators
  can drive real models (two-layer NN §K.4, NanoGPT §K.5 analogues).
"""

from __future__ import annotations

from typing import Callable

import numpy as np

from .algorithms import Problem

__all__ = ["quadratic_worst_case", "prog", "from_jax"]


def prog(x: np.ndarray) -> int:
    """``prog(x) = max{i >= 1 : x_i != 0}`` with ``prog(0) = 0`` (1-indexed)."""
    nz = np.nonzero(x)[0]
    return 0 if len(nz) == 0 else int(nz[-1]) + 1


def quadratic_worst_case(d: int = 1000, p: float = 0.1,
                         scale: float = 0.25) -> Problem:
    """§K quadratic: ``f(x) = ½ xᵀAx - bᵀx`` with A = ¼·tridiag(-1, 2, -1),
    ``b = ¼·(-1, 0, …, 0)`` and the eq. (27) stochastic oracle.

    ``x0 = (√d, 0, …, 0)`` as in §K. L = ||A||₂ ≤ 1 (A/4 has eigenvalues in
    [0, 1]).
    """
    main = 2.0 * scale * np.ones(d)
    off = -scale * np.ones(d - 1)
    b = np.zeros(d)
    b[0] = -scale

    def matvec(x: np.ndarray) -> np.ndarray:
        y = main * x
        y[:-1] += off * x[1:]
        y[1:] += off * x[:-1]
        return y

    # exact minimizer for f-gap reporting (tridiagonal solve, cached)
    A = (np.diag(main) + np.diag(off, 1) + np.diag(off, -1))
    x_star = np.linalg.solve(A, b)
    f_star = 0.5 * float(x_star @ matvec(x_star)) - float(b @ x_star)

    def f(x: np.ndarray) -> float:
        return 0.5 * float(x @ matvec(x)) - float(b @ x) - f_star

    def grad(x: np.ndarray) -> np.ndarray:
        return matvec(x) - b

    def stoch_grad(x: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        g = grad(x)
        pr = prog(x)
        xi = float(rng.random() < p)
        gate = np.ones(d)
        gate[pr:] = 1.0 + (xi / p - 1.0)
        return g * gate

    x0 = np.zeros(d)
    x0[0] = np.sqrt(d)
    return Problem(x0=x0, f=f, grad=grad, stoch_grad=stoch_grad)


def from_jax(loss_fn: Callable, params0, batch_sampler: Callable,
             jit: bool = True) -> Problem:
    """Bridge a JAX model into the event simulators.

    ``loss_fn(params, batch) -> scalar``; ``batch_sampler(rng) -> batch``
    draws one stochastic mini-batch. Parameters are flattened to a single
    numpy vector so the numpy-side simulators stay generic.
    """
    import jax
    import jax.numpy as jnp
    from jax.flatten_util import ravel_pytree

    flat0, unravel = ravel_pytree(params0)
    flat0 = np.asarray(flat0, dtype=np.float32)

    vg = jax.value_and_grad(loss_fn)
    if jit:
        vg = jax.jit(vg)

    def f(x: np.ndarray) -> float:
        v, _ = vg(unravel(jnp.asarray(x)), batch_sampler(np.random.default_rng(0)))
        return float(v)

    def grad(x: np.ndarray) -> np.ndarray:
        # "exact" gradient approximated with a fixed large batch
        _, g = vg(unravel(jnp.asarray(x)), batch_sampler(np.random.default_rng(0)))
        gf, _ = ravel_pytree(g)
        return np.asarray(gf, dtype=np.float32)

    def stoch_grad(x: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        _, g = vg(unravel(jnp.asarray(x)), batch_sampler(rng))
        gf, _ = ravel_pytree(g)
        return np.asarray(gf, dtype=np.float32)

    return Problem(x0=flat0, f=f, grad=grad, stoch_grad=stoch_grad)


def heterogeneous_quadratics(n_workers: int, d_per: int = 10,
                             seed: int = 0):
    """§6 heterogeneous setting: worker i holds f_i(x) = ½||x_Bi - c_i||²
    on its own coordinate block B_i; f = (1/n) Σ f_i. Information about
    block B_i exists ONLY at worker i — the paper's argument for why
    Algorithm 3 with m < n cannot work here.

    Returns (Problem with the full-average oracle, grads_by_worker for
    Malenia, x_star).
    """
    rng = np.random.default_rng(seed)
    d = n_workers * d_per
    centers = rng.normal(0, 1, size=(n_workers, d_per))
    x_star = centers.reshape(-1).copy()

    def f(x):
        diff = x.reshape(n_workers, d_per) - centers
        return 0.5 * float(np.sum(diff ** 2)) / n_workers

    def grad(x):
        diff = x.reshape(n_workers, d_per) - centers
        return diff.reshape(-1) / n_workers

    def grad_i(i, x, rng_):
        g = np.zeros(d)
        blk = slice(i * d_per, (i + 1) * d_per)
        g[blk] = (x[blk] - centers[i]) + rng_.normal(0, 0.1, d_per)
        return g

    def stoch_grad(x, rng_):
        # the HOMOGENEOUS-style oracle a mistaken m-sync deployment would
        # use: sample a random worker's f_i (biased toward fast workers
        # under m-sync scheduling)
        i = int(rng_.integers(0, n_workers))
        return grad_i(i, x, rng_)

    return (Problem(x0=np.zeros(d), f=f, grad=grad,
                    stoch_grad=stoch_grad),
            grad_i, x_star)
