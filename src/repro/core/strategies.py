"""Composable aggregation strategies + the single event-driven simulator.

This module unifies the previously copy-pasted per-method event loops
(``run_sync_sgd`` … ``run_ringmaster_asgd`` in :mod:`repro.core.algorithms`)
and the mesh-side ``SyncPolicy`` behind ONE API (DESIGN.md):

* :class:`AggregationStrategy` — the protocol. A strategy looks at each
  gradient *arrival* and returns a :class:`Decision` (``ACCEPT`` it into the
  current aggregate, ``DISCARD`` it, or ``STEP`` — accept and complete the
  server iteration), plus small hooks for the stepsize schedule, the iterate
  the gradient is evaluated at, the aggregate combination rule, and worker
  restart behaviour.
* :func:`simulate` — the one generic driver. It owns the event heap,
  wall-clock accounting, iterate snapshots (for delayed gradients), value
  recording, tolerance-based early exit, and the :class:`Trace` — exactly
  once, for every method.
* :data:`STRATEGIES` — a string-keyed registry so benchmarks, examples, the
  trainer and ad-hoc scripts can select methods by name.

The same strategy objects drive the real-mesh path: synchronous-family
strategies implement :meth:`AggregationStrategy.mesh_mask`, which
:class:`repro.core.sync_engine.SimulatedStraggler` (and therefore
:class:`repro.train.trainer.Trainer`) uses to resolve per-step
participation masks — one API from event-driven simulation to TPU
all-reduce.

The engine's hot path is vectorized: every bulk (re)start of workers draws
all finish times with one :meth:`~repro.core.time_models.TimeModel.sample_times`
call instead of ``n`` Python-level ``sample_time`` calls, which makes the
paper-scale (``n = 1000``) benchmarks measurably faster while leaving the
RNG stream of the scalar fallback untouched.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import math
from typing import Callable, Dict, List, Optional, Union

import numpy as np

from .time_models import FixedTimes, TimeModel, UniversalModel

__all__ = [
    "Trace",
    "Problem",
    "Decision",
    "Arrival",
    "SimState",
    "AggregationStrategy",
    "FullSync",
    "MSync",
    "AutoM",
    "Async",
    "Rennala",
    "Malenia",
    "Ringmaster",
    "Ringleader",
    "OptimalASGD",
    "DeadlineSync",
    "Dropout",
    "STRATEGIES",
    "register_strategy",
    "make_strategy",
    "simulate",
    "first_m_mask",
]


# ---------------------------------------------------------------------------
# Trace / Problem (moved here from algorithms.py; re-exported there).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Trace:
    """Wall-clock trace of one optimization run."""

    times: np.ndarray          # wall-clock seconds at each recorded event
    values: np.ndarray         # f(x) at those times (nan if not recorded)
    grad_norms: np.ndarray     # ||grad f(x)||^2 at those times
    iterations: int            # server updates performed
    total_time: float          # wall-clock at termination
    gradients_used: int        # stochastic gradients aggregated into updates
    gradients_computed: int    # total computed (incl. discarded)
    x_final: Optional[np.ndarray] = None   # last iterate (math runs only)

    @property
    def discard_fraction(self) -> float:
        if self.gradients_computed == 0:
            return 0.0
        return 1.0 - self.gradients_used / self.gradients_computed

    # ------------------------------------------------- checkpoint payload
    def as_dict(self) -> dict:
        """JSON-serializable form for per-grid-point experiment
        checkpoints. Floats survive the round trip exactly (``repr`` of
        a double is exact), so a trace restored by :meth:`from_dict`
        reproduces every summary statistic bit-for-bit; arrays are
        normalized to float64 on restore either way."""
        return {
            "times": np.asarray(self.times, dtype=float).tolist(),
            "values": np.asarray(self.values, dtype=float).tolist(),
            "grad_norms": np.asarray(self.grad_norms,
                                     dtype=float).tolist(),
            "iterations": int(self.iterations),
            "total_time": float(self.total_time),
            "gradients_used": int(self.gradients_used),
            "gradients_computed": int(self.gradients_computed),
            "x_final": None if self.x_final is None
            else np.asarray(self.x_final, dtype=float).tolist(),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Trace":
        """Inverse of :meth:`as_dict` (tolerates the ``"inf"``/``"nan"``
        strings :func:`repro.exp.runner.sanitize_json` substitutes for
        non-finite floats)."""
        def arr(v):
            return np.asarray([float(x) for x in v], dtype=float)

        return cls(times=arr(d["times"]), values=arr(d["values"]),
                   grad_norms=arr(d["grad_norms"]),
                   iterations=int(d["iterations"]),
                   total_time=float(d["total_time"]),
                   gradients_used=int(d["gradients_used"]),
                   gradients_computed=int(d["gradients_computed"]),
                   x_final=None if d.get("x_final") is None
                   else arr(d["x_final"]))


@dataclasses.dataclass
class Problem:
    """An optimization problem with a stochastic first-order oracle."""

    x0: np.ndarray
    f: Callable[[np.ndarray], float]
    grad: Callable[[np.ndarray], np.ndarray]                    # exact (for eval)
    stoch_grad: Callable[[np.ndarray, np.random.Generator], np.ndarray]


# ---------------------------------------------------------------------------
# The protocol.
# ---------------------------------------------------------------------------

class Decision(enum.Enum):
    ACCEPT = "accept"    # use this gradient; iteration continues
    DISCARD = "discard"  # drop it (stale / over-delayed / adversarial)
    STEP = "step"        # use it AND complete the server iteration now


class Arrival:
    """One gradient finishing on a worker.

    The engine reuses one scratch instance across events (hot path);
    strategies must not retain a reference past the ``on_arrival`` call.
    """

    __slots__ = ("t", "worker", "version", "delay")

    def __init__(self, t: float = 0.0, worker: int = 0, version: int = 0,
                 delay: int = 0) -> None:
        self.t = t            # wall-clock finish time
        self.worker = worker
        self.version = version  # server iteration the gradient started at
        self.delay = delay      # current server iteration minus version


@dataclasses.dataclass
class SimState:
    """Engine state visible to strategies (read-only by convention)."""

    n: int
    k: int = 0           # server iteration
    t: float = 0.0       # wall clock
    got: int = 0         # gradients accepted into the current aggregate
    counts: Optional[np.ndarray] = None  # per-worker accepts (per_worker)


class AggregationStrategy:
    """Base strategy: how arrivals become server updates (see DESIGN.md).

    Subclasses typically override :meth:`on_arrival` (+ :meth:`restart`)
    only; the remaining hooks have method-appropriate defaults. A strategy
    instance carries mutable per-run state and is reset by :meth:`bind`,
    which :func:`simulate` calls once at the start of every run.
    """

    name: str = "base"
    needs_snapshots = False   # evaluate gradients at their (stale) snapshot
    per_worker = False        # engine keeps per-worker sums (Malenia)
    tol_on_record = False     # tol-exit checked on record cadence only
    tol_offset = 0            # tol cadence anchor: check when
    #                           (k - tol_offset) % stride == 0 (Async's
    #                           historical loop counted pre-increment)
    idle_on_accept = False    # accepted workers idle until the next step
    # Restart policy (engine-applied, after any step): a DISCARDed worker
    # always restarts immediately at the current iterate (§3 Remark); an
    # ACCEPTed/STEPped worker restarts immediately too unless
    # ``idle_on_accept`` (synchronous families park it until the round
    # ends, then all parked workers restart in one vectorized batch).

    # -- lifecycle ---------------------------------------------------------
    def bind(self, n: int) -> None:
        """Resolve ``n``-dependent parameters and reset per-run state."""

    # -- event simulation --------------------------------------------------
    def on_arrival(self, ev: Arrival, st: SimState) -> Decision:
        raise NotImplementedError

    def stepsize(self, k: int, delay: int) -> float:
        """Multiplier on the base stepsize ``gamma`` for this update."""
        return 1.0

    def gradient(self, worker: int, x: np.ndarray,
                 rng: np.random.Generator, problem: Problem) -> np.ndarray:
        return problem.stoch_grad(x, rng)

    def combine(self, acc: "_Accumulator", st: SimState) -> np.ndarray:
        return acc.total / max(st.got, 1)

    def on_step(self, st: SimState) -> None:
        """Reset per-iteration state after the server stepped."""

    # -- timer events (strategies that step on a clock, not an arrival) ----
    uses_alarm = False  # True => engine re-arms next_alarm after each step

    def next_alarm(self, st: SimState) -> Optional[float]:
        return None

    def on_alarm(self, st: SimState) -> Decision:
        return Decision.DISCARD

    # -- mesh path ---------------------------------------------------------
    mesh = False  # True: usable as a Trainer/SimulatedStraggler policy

    def mesh_mask(self, times: np.ndarray, estimator=None):
        """``(mask, m, duration)`` for one mesh round with drawn ``times``."""
        raise NotImplementedError(
            f"{self.name} is not realizable as a synchronous mesh round")


def first_m_mask(times: np.ndarray, m: int) -> np.ndarray:
    """Boolean mask of the first ``m`` finishers (ties broken by index)."""
    order = np.argsort(times, kind="stable")
    mask = np.zeros(len(times), dtype=bool)
    mask[order[:m]] = True
    return mask


# ---------------------------------------------------------------------------
# The registry.
# ---------------------------------------------------------------------------

STRATEGIES: Dict[str, Callable[..., AggregationStrategy]] = {}


def register_strategy(name: str):
    def deco(factory):
        STRATEGIES[name] = factory
        return factory
    return deco


def make_strategy(name: str, **kwargs) -> AggregationStrategy:
    """``STRATEGIES[name](**kwargs)`` with a helpful error."""
    try:
        factory = STRATEGIES[name]
    except KeyError:
        raise KeyError(f"unknown strategy {name!r}; "
                       f"known: {sorted(STRATEGIES)}") from None
    return factory(**kwargs)


# ---------------------------------------------------------------------------
# The six paper methods as ~20-line strategies.
# ---------------------------------------------------------------------------

@register_strategy("msync")
class MSync(AggregationStrategy):
    """Algorithm 3 — aggregate the first ``m`` version-``k`` gradients.

    Accepted workers idle until the step; late version-``k`` results are
    discarded (the worker restarts at the new iterate: §3 Remark,
    computations cannot be stopped).

    ``grads_by_worker(i, x, rng)`` supplies worker-specific oracles
    (``∇f_i``) exactly as for :class:`Malenia` — used by the §6
    heterogeneous experiment to show why m-sync with ``m < n`` plateaus
    when worker ``i`` exclusively holds ``f_i``. Defaults to the problem's
    homogeneous oracle.
    """

    name = "msync"
    mesh = True
    idle_on_accept = True

    def __init__(self, m: Optional[int] = None,
                 grads_by_worker: Optional[Callable] = None) -> None:
        self.m = m
        self.grads_by_worker = grads_by_worker

    def bind(self, n: int) -> None:
        self._m = n if self.m is None else self.m
        if not (1 <= self._m <= n):
            raise ValueError(f"m={self._m} out of range [1, {n}]")

    def on_arrival(self, ev: Arrival, st: SimState) -> Decision:
        if ev.version != st.k:
            return Decision.DISCARD
        return Decision.STEP if st.got + 1 == self._m else Decision.ACCEPT

    def gradient(self, worker, x, rng, problem):
        if self.grads_by_worker is not None:
            return self.grads_by_worker(worker, x, rng)
        return problem.stoch_grad(x, rng)

    def mesh_mask(self, times: np.ndarray, estimator=None):
        m = min(self._m, len(times))
        mask = first_m_mask(times, m)
        return mask, m, float(np.sort(times)[m - 1])


@register_strategy("sync")
class FullSync(MSync):
    """Algorithm 1 — m-Synchronous SGD with ``m = n``."""

    name = "sync"

    def __init__(self) -> None:
        super().__init__(m=None)


@register_strategy("auto_m")
class AutoM(MSync):
    """Algorithm 3 + Proposition 4.1: ``m`` chosen online from τ̂/σ̂.

    On the mesh the participation mask adapts each round via the
    :class:`~repro.core.selection.OnlineTauEstimator`; in the event
    simulator (no estimator feedback loop) it warms up as full sync,
    matching the legacy ``SyncMode.AUTO_M`` warmup behaviour.
    """

    name = "auto_m"

    def __init__(self, eps_target: float = 1e-2) -> None:
        super().__init__(m=None)
        self.eps_target = eps_target

    def mesh_mask(self, times: np.ndarray, estimator=None):
        n = len(times)
        m = n
        if estimator is not None and estimator.seen.any():
            m = min(max(int(estimator.suggest_m(self.eps_target)), 1), n)
        mask = first_m_mask(times, m)
        return mask, m, float(np.sort(times)[m - 1])


@register_strategy("async")
class Async(AggregationStrategy):
    """Algorithm 2 — every arrival is an update at its (stale) snapshot."""

    name = "async"
    needs_snapshots = True
    tol_on_record = True
    tol_offset = 1            # legacy run_async_sgd checked pre-increment k

    def __init__(self, delay_adaptive: bool = False) -> None:
        self.delay_adaptive = delay_adaptive

    def bind(self, n: int) -> None:
        self._n = n

    def on_arrival(self, ev: Arrival, st: SimState) -> Decision:
        return Decision.STEP

    def stepsize(self, k: int, delay: int) -> float:
        if self.delay_adaptive:
            return 1.0 / (1.0 + delay / max(self._n, 1))
        return 1.0


@register_strategy("rennala")
class Rennala(AggregationStrategy):
    """Rennala SGD — asynchronous accumulation of ``batch`` at ``x^k``."""

    name = "rennala"

    def __init__(self, batch: int = 1) -> None:
        self.batch = batch

    def on_arrival(self, ev: Arrival, st: SimState) -> Decision:
        if ev.version != st.k:
            return Decision.DISCARD
        return Decision.STEP if st.got + 1 == self.batch else Decision.ACCEPT


@register_strategy("malenia")
class Malenia(AggregationStrategy):
    """Malenia SGD (heterogeneous §6) — per-worker batches ``B_i`` until
    the harmonic mean reaches ``S``; update ``(1/n) Σ_i mean_j g_ij``.

    ``grads_by_worker(i, x, rng)`` supplies worker-specific oracles
    (``∇f_i``); defaults to the problem's homogeneous oracle.
    """

    name = "malenia"
    per_worker = True

    def __init__(self, S: float = 1.0,
                 grads_by_worker: Optional[Callable] = None) -> None:
        self.S = S
        self.grads_by_worker = grads_by_worker

    def _ready(self, B: np.ndarray, n: int) -> bool:
        if np.any(B == 0):
            return False
        return n / float(np.sum(1.0 / B)) >= self.S

    def on_arrival(self, ev: Arrival, st: SimState) -> Decision:
        if ev.version != st.k:
            return Decision.DISCARD
        B = st.counts.copy()
        B[ev.worker] += 1
        return Decision.STEP if self._ready(B, st.n) else Decision.ACCEPT

    def gradient(self, worker, x, rng, problem):
        if self.grads_by_worker is not None:
            return self.grads_by_worker(worker, x, rng)
        return problem.stoch_grad(x, rng)

    def combine(self, acc, st) -> np.ndarray:
        B = np.maximum(st.counts, 1)
        return sum(acc.per_worker[i] / B[i] for i in range(st.n)) / st.n


@register_strategy("ringmaster")
class Ringmaster(AggregationStrategy):
    """Ringmaster ASGD — Async SGD that discards gradients whose delay
    exceeds ``max_delay`` (bounded staleness => constant stepsize)."""

    name = "ringmaster"
    needs_snapshots = True
    tol_on_record = True

    def __init__(self, max_delay: int = 1) -> None:
        self.max_delay = max_delay

    def on_arrival(self, ev: Arrival, st: SimState) -> Decision:
        return Decision.STEP if ev.delay <= self.max_delay \
            else Decision.DISCARD


@register_strategy("ringleader")
class Ringleader(AggregationStrategy):
    """Ringleader ASGD (modeled after arXiv 2509.22860): fully
    asynchronous and waste-free — no arrival is ever discarded. Every
    delivery joins its worker's buffer (evaluated at the snapshot the
    worker started from) and the server steps as soon as every worker
    has delivered at least once since the last step, averaging the
    per-worker means ``(1/n) sum_i mean_j g_ij``. Workers restart
    immediately on delivery, so staleness is bounded by one round
    (delay <= 1) and ``gradients_used == gradients_computed``."""

    name = "ringleader"
    per_worker = True
    needs_snapshots = True

    def on_arrival(self, ev: Arrival, st: SimState) -> Decision:
        B = st.counts.copy()
        B[ev.worker] += 1
        return Decision.STEP if B.min() >= 1 else Decision.ACCEPT

    def combine(self, acc, st) -> np.ndarray:
        B = np.maximum(st.counts, 1)
        return sum(acc.per_worker[i] / B[i] for i in range(st.n)) / st.n


@register_strategy("optimal_asgd")
class OptimalASGD(AggregationStrategy):
    """Optimal ASGD (the Maranjyan dissertation line, arXiv 2601.02523):
    bounded-staleness Async SGD with the delay threshold resolved from
    the worker count at bind time (``max_delay = ceil(delay_c * n)`` —
    steady-state async delays concentrate near ``n``, so an n-scaled
    threshold accepts the bulk and truncates only straggler tails) and
    the delay-adaptive stepsize ``1 / (1 + delay/n)``."""

    name = "optimal_asgd"
    needs_snapshots = True
    tol_on_record = True
    delay_adaptive = True

    def __init__(self, max_delay: Optional[int] = None,
                 delay_c: float = 1.0) -> None:
        if delay_c <= 0:
            raise ValueError("delay_c must be positive")
        self.delay_c = float(delay_c)
        self._md_user = None if max_delay is None else int(max_delay)
        self.max_delay = self._md_user if self._md_user is not None else 1

    def bind(self, n: int) -> None:
        self._n = n
        self.max_delay = (self._md_user if self._md_user is not None
                          else max(1, int(np.ceil(self.delay_c * n))))

    def on_arrival(self, ev: Arrival, st: SimState) -> Decision:
        return Decision.STEP if ev.delay <= self.max_delay \
            else Decision.DISCARD

    def stepsize(self, k: int, delay: int) -> float:
        return 1.0 / (1.0 + delay / max(self._n, 1))


# ---------------------------------------------------------------------------
# New strategies the old API could not express cheaply.
# ---------------------------------------------------------------------------

@register_strategy("deadline")
class DeadlineSync(AggregationStrategy):
    """Deadline aggregation: step at ``deadline`` seconds after the round
    starts with whatever fresh gradients arrived (early if all ``n`` did;
    on the first arrival if none made the deadline — never stall).

    This is the event-simulator twin of the mesh ``SyncMode.DEADLINE``
    policy; the old per-method API had no way to express a clock-triggered
    step.
    """

    name = "deadline"
    mesh = True
    idle_on_accept = True
    uses_alarm = True

    def __init__(self, deadline: float = 1.0) -> None:
        if deadline <= 0:
            raise ValueError(f"deadline={deadline} must be positive")
        self.deadline = deadline

    def bind(self, n: int) -> None:
        self._overdue = False

    def on_arrival(self, ev: Arrival, st: SimState) -> Decision:
        if ev.version != st.k:
            return Decision.DISCARD
        if self._overdue or st.got + 1 == st.n:
            return Decision.STEP
        return Decision.ACCEPT

    def next_alarm(self, st: SimState) -> float:
        return st.t + self.deadline

    def on_alarm(self, st: SimState) -> Decision:
        if st.got >= 1:
            return Decision.STEP
        self._overdue = True          # step on the next fresh arrival
        return Decision.DISCARD

    def on_step(self, st: SimState) -> None:
        self._overdue = False

    def mesh_mask(self, times: np.ndarray, estimator=None):
        mask = times <= self.deadline
        if not mask.any():
            mask = first_m_mask(times, 1)
        dur = min(float(self.deadline), float(times[mask].max()))
        return mask, int(mask.sum()), dur


@register_strategy("dropout")
class Dropout(AggregationStrategy):
    """Rotating-adversary partial participation composed over ANY inner
    strategy (Assumption 5.4): at any instant at most ``ceil(p*n)`` workers
    are "dead"; a dead worker's finished gradient is suppressed (discarded
    and recomputed) no matter what the inner strategy would have done.

    The dead set rotates every ``period`` seconds — the worst *stationary*
    adversary for m-sync, since no fixed subset of workers stays alive.
    """

    name = "dropout"

    def __init__(self, inner: Optional[AggregationStrategy] = None,
                 p: float = 0.1, period: float = 1.0) -> None:
        if not 0.0 <= p < 1.0:
            # p = 1 kills every worker forever: no arrival is ever used
            # and the simulation can never finish K iterations
            raise ValueError(f"dropout fraction p={p} must be in [0, 1)")
        if period <= 0:
            raise ValueError(f"rotation period={period} must be positive")
        self.inner = inner if inner is not None else MSync()
        self.p = p
        self.period = period
        self.name = f"dropout({self.inner.name})"
        self.needs_snapshots = self.inner.needs_snapshots
        self.per_worker = self.inner.per_worker
        self.tol_on_record = self.inner.tol_on_record
        self.tol_offset = self.inner.tol_offset
        self.idle_on_accept = self.inner.idle_on_accept
        self.uses_alarm = self.inner.uses_alarm

    def bind(self, n: int) -> None:
        self._n = n
        self._dead_k = int(math.floor(self.p * n))
        self.inner.bind(n)

    def dead_set(self, t: float) -> set:
        k, n = self._dead_k, self._n
        if k == 0:
            return set()
        start = int(t / self.period) * k % n
        return {(start + j) % n for j in range(k)}

    def on_arrival(self, ev: Arrival, st: SimState) -> Decision:
        if ev.worker in self.dead_set(ev.t):
            return Decision.DISCARD
        return self.inner.on_arrival(ev, st)

    # pure delegation below — the wrapper only filters arrivals
    def stepsize(self, k, delay):
        return self.inner.stepsize(k, delay)

    def gradient(self, worker, x, rng, problem):
        return self.inner.gradient(worker, x, rng, problem)

    def combine(self, acc, st):
        return self.inner.combine(acc, st)

    def on_step(self, st):
        self.inner.on_step(st)

    def next_alarm(self, st):
        return self.inner.next_alarm(st)

    def on_alarm(self, st):
        return self.inner.on_alarm(st)


# ---------------------------------------------------------------------------
# The one generic driver.
# ---------------------------------------------------------------------------

class _Accumulator:
    """Running aggregate of accepted gradients for one iteration."""

    def __init__(self, x: Optional[np.ndarray], n: int,
                 per_worker: bool) -> None:
        self._shape_src = x
        self._per = per_worker
        self.n = n
        self.reset()

    def reset(self) -> None:
        x = self._shape_src
        self.total = None if x is None else np.zeros_like(x)
        self.per_worker = (None if x is None or not self._per
                           else [np.zeros_like(x) for _ in range(self.n)])

    def add(self, worker: int, g: np.ndarray) -> None:
        self.total += g
        if self.per_worker is not None:
            self.per_worker[worker] += g


def _recorder(problem: Optional[Problem], record_every: int):
    times, vals, gnorms = [], [], []

    def record(t: float, x: Optional[np.ndarray], k: int) -> None:
        if problem is None or x is None:
            return
        if k % record_every:
            return
        times.append(t)
        vals.append(problem.f(x))
        g = problem.grad(x)
        gnorms.append(float(np.dot(g, g)))

    return times, vals, gnorms, record


def _fast_msync_timing(m: int, model: TimeModel, K: int,
                       rng: np.random.Generator) -> Trace:
    """Round-vectorized timing-only m-sync (the paper-scale hot case).

    Exploits the m-sync invariant that every worker always has exactly one
    pending event, so a whole round reduces to order statistics over
    ``n``-vectors: the round ends at the m-th smallest version-``k``
    arrival, where a worker stale at round start contributes the arrival
    ``stale_finish + fresh_draw`` (it restarts at the current iterate when
    its stale computation pops — §3 Remark). Events are ordered by the
    exact ``(time, seq)`` key of the event engine, so for deterministic
    models this is bitwise-identical to the generic loop; for random
    models only the RNG draw order differs (same distribution).

    Universal models (Assumption 5.1) run the same recursion with draws
    replaced by the deterministic ``finish_times`` inversion (a restart at
    time ``t`` finishes at the smallest ``t' >= t`` with unit power
    integral) — the same vectorized inversion the generic engine uses, so
    results are bitwise-identical to the event loop there too.
    """
    n = model.n
    universal = isinstance(model, UniversalModel)
    if universal:
        ft = np.asarray(model.finish_times(np.arange(n), 0.0),
                        dtype=float).copy()
    else:
        ft = np.asarray(model.sample_times(np.arange(n), rng),
                        dtype=float).copy()
    fseq = np.arange(1, n + 1, dtype=np.int64)   # heap tie-break seqs
    ver = np.zeros(n, dtype=np.int64)
    seq_c = n
    computed = used = 0
    t = 0.0
    for k in range(K):
        stale = np.flatnonzero(ver < k)
        if stale.size:
            # stale pops happen in (finish, seq) order; restarts draw then
            sp = stale[np.lexsort((fseq[stale], ft[stale]))]
            if universal:
                e_time = np.asarray(model.finish_times(sp, ft[sp]),
                                    dtype=float)
            else:
                d = np.asarray(model.sample_times(sp, rng), dtype=float)
                e_time = ft[sp] + d
            rseq = seq_c + 1 + np.arange(sp.size, dtype=np.int64)
            seq_c += sp.size
            fresh = np.flatnonzero(ver == k)
            cand_t = np.concatenate([ft[fresh], e_time])
            cand_seq = np.concatenate([fseq[fresh], rseq])
            cand_w = np.concatenate([fresh, sp])
        else:
            sp = e_time = rseq = None
            cand_t, cand_seq, cand_w = ft, fseq, np.arange(n)
        order = np.lexsort((cand_seq, cand_t))
        end = order[m - 1]
        T, end_seq = float(cand_t[end]), cand_seq[end]
        acc_workers = cand_w[order[:m]]
        if sp is not None:
            popped = (ft[sp] < T) | ((ft[sp] == T) & (fseq[sp] < end_seq))
            ps = sp[popped]
            ft[ps] = e_time[popped]
            fseq[ps] = rseq[popped]
            ver[ps] = k
            computed += int(popped.sum())
        computed += m
        used += m
        t = T
        aw = np.sort(acc_workers)                 # bulk restart, worker order
        if universal:
            ft[aw] = np.asarray(model.finish_times(aw, T), dtype=float)
        else:
            ft[aw] = T + np.asarray(model.sample_times(aw, rng), dtype=float)
        fseq[aw] = seq_c + 1 + np.arange(m, dtype=np.int64)
        seq_c += m
        ver[aw] = k + 1
    e = np.array([])
    return Trace(e, e, e, iterations=K, total_time=t, gradients_used=used,
                 gradients_computed=computed)


def _row_lexsort(t_key: np.ndarray, seq_key: np.ndarray) -> np.ndarray:
    """Per-row ``np.lexsort((seq, t))`` for ``(S, n)`` keys.

    Two-pass stable-argsort lexsort, vectorized along axis 1 (row-wise C
    sorts — ~5x faster than one flattened global lexsort with a row key):
    pre-sort by the secondary key, then a stable sort by the primary key
    preserves the secondary order within ties.
    """
    o1 = np.argsort(seq_key, axis=1, kind="stable")
    o2 = np.argsort(np.take_along_axis(t_key, o1, axis=1), axis=1,
                    kind="stable")
    return np.take_along_axis(o1, o2, axis=1)


def _counter_msync_timing_batch(m: int, model: TimeModel, K: int,
                                rngs: List[np.random.Generator]
                                ) -> List[Trace]:
    """The ``rng_scheme="counter"`` engine for sampled (continuous-draw)
    models: the whole ``(seeds, rounds, workers)`` time tensor comes from
    chunked :meth:`TimeModel.sample_times_tensor` bulk draws and the round
    body is pure O(n) array work.

    Two deliberate departures from the exact-parity engine, both valid
    because continuous draws tie with probability zero (distribution-equal
    contract, DESIGN.md §3b):

    * no event-heap sequence bookkeeping — wall-clock ties break by
      worker index (the full per-row lexsorts were ~60% of the exact
      engine's cost; ``np.partition`` selection replaces them);
    * one shared draw row per round — the workers accepted in round ``k``
      and the workers restarting from a stale pop in round ``k+1`` are
      provably disjoint (an accepted worker's version is ``k+1``, so it
      cannot be stale in round ``k+1``), so both consume entries of the
      same fresh ``(S, n)`` row and the tensor needs ``K+1`` rows, not
      ``2K+1``.
    """
    n = model.n
    S = len(rngs)
    all_w = np.arange(n)
    # chunked pre-draw: <= ~48 MB of buffered rows at a time; generators
    # are stateful, so successive chunks continue each seed's stream
    chunk = min(K + 1, max(2, int(48e6 // max(S * n * 8, 1))))
    buf = model.sample_times_tensor(all_w, chunk, rngs,
                                    rng_scheme="counter")
    pos = 0

    def next_row() -> np.ndarray:
        nonlocal buf, pos
        if pos == buf.shape[1]:
            buf = model.sample_times_tensor(all_w, chunk, rngs,
                                            rng_scheme="counter")
            pos = 0
        row = buf[:, pos]
        pos += 1
        return row

    ft = next_row().copy()
    ver = np.zeros((S, n), dtype=np.int64)
    computed = np.zeros(S, dtype=np.int64)
    T = np.zeros((S, 1))
    row = None                       # round k's stale-restart durations
    for k in range(K):
        stale = ver < k
        any_stale = bool(stale.any())
        if any_stale:
            e_time = ft + row        # full row; only stale entries used
            cand = np.where(stale, e_time, ft)
        else:
            cand = ft
        T = np.partition(cand, m - 1, axis=1)[:, m - 1:m]     # (S, 1)
        leq = cand <= T
        if (leq.sum(axis=1) == m).all():
            acc = leq
        else:                        # boundary ties: quota by worker index
            lt = cand < T
            tie = cand == T
            acc = lt | (tie & ((np.cumsum(tie, axis=1) - 1)
                               < (m - lt.sum(axis=1))[:, None]))
        if any_stale:
            popped = stale & (ft < T)
            ft = np.where(popped, e_time, ft)
            ver = np.where(popped, k, ver)
            computed += popped.sum(axis=1)
        computed += m
        row = next_row()             # accepted restarts now, stale next
        ft = np.where(acc, T + row, ft)
        ver = np.where(acc, k + 1, ver)

    e = np.array([])
    total = T[:, 0]
    return [Trace(e, e, e, iterations=K, total_time=float(total[s]),
                  gradients_used=m * K, gradients_computed=int(computed[s]))
            for s in range(S)]


def _fast_msync_timing_batch(m: int, model: TimeModel, K: int,
                             rngs: List[np.random.Generator],
                             rng_scheme: str = "stream") -> List[Trace]:
    """Seed-batched :func:`_fast_msync_timing`: ``S`` independent runs as
    one ``(seeds, workers)`` array program over ``K`` rounds.

    State is carried in ``(S, n)`` matrices (finish times, tie-break seqs,
    versions) and each round reduces to masked order statistics — the
    ``(seeds, rounds, workers)`` batching of the scalar fast path.

    ``rng_scheme`` (DESIGN.md §3b) selects how random models draw:

    * ``"stream"`` — exact per-seed RNG parity: each seed's generator is
      consumed in the scalar path's exact order (stale restarts in pop
      order, then accepted restarts in worker order), so
      ``batch[rngs=[default_rng(s)]]`` is bitwise-identical to the scalar
      fast path at seed ``s`` for every model. The per-round per-seed
      draw loops are the price of that parity.
    * ``"counter"`` — sampled models delegate to
      :func:`_counter_msync_timing_batch`: the whole
      ``(seeds, rounds, workers)`` time tensor comes from
      :meth:`TimeModel.sample_times_tensor` bulk draws (callers pass
      :func:`~repro.core.time_models.philox_rngs` generators) and the
      round body is partition-based O(n) selection. Distribution-equal
      to ``"stream"``, not stream-equal — and the per-round body loses
      both the per-seed draw loops and the full lexsorts, which is where
      the sweep-scale speedup lives.

    Deterministic models draw with no RNG at all (a pure broadcast of
    ``tau``; both schemes identical). Universal models (Assumption 5.1)
    are deterministic too: one scalar fast-path run is computed and
    replicated across seeds.
    """
    n = model.n
    S = len(rngs)
    if isinstance(model, UniversalModel):
        tr = _fast_msync_timing(m, model, K, np.random.default_rng(0))
        return [dataclasses.replace(tr) for _ in range(S)]
    taus = model.taus if type(model) is FixedTimes else None
    if rng_scheme == "counter" and taus is None:
        return _counter_msync_timing_batch(m, model, K, rngs)
    all_w = np.arange(n)
    ft = model.sample_times_seeds(all_w, rngs).astype(float)
    fseq = np.broadcast_to(np.arange(1, n + 1, dtype=np.int64),
                           (S, n)).copy()
    ver = np.zeros((S, n), dtype=np.int64)
    seq_c = np.full(S, n, dtype=np.int64)
    computed = np.zeros(S, dtype=np.int64)
    t = np.zeros(S)
    srows = np.arange(S)[:, None]
    INF = np.inf

    for k in range(K):
        stale = ver < k
        if stale.any():
            if taus is not None:
                d = np.broadcast_to(taus, (S, n))
            else:
                d = np.zeros((S, n))
                for s, rng in enumerate(rngs):
                    sw = np.flatnonzero(stale[s])
                    if sw.size:        # draw in the scalar path's pop order
                        sp = sw[np.lexsort((fseq[s, sw], ft[s, sw]))]
                        d[s, sp] = np.asarray(model.sample_times(sp, rng),
                                              dtype=float)
            e_time = ft + d
            # restart seqs follow pop order: rank stale workers by (ft, seq)
            pop_order = _row_lexsort(np.where(stale, ft, INF), fseq)
            rank = np.empty((S, n), dtype=np.int64)
            np.put_along_axis(rank, pop_order,
                              np.broadcast_to(np.arange(n, dtype=np.int64),
                                              (S, n)), axis=1)
            rseq = seq_c[:, None] + 1 + rank
            n_stale = stale.sum(axis=1)
            cand_t = np.where(stale, e_time, ft)
            cand_seq = np.where(stale, rseq, fseq)
        else:
            e_time = rseq = None
            n_stale = 0
            cand_t, cand_seq = ft, fseq
        seq_c = seq_c + n_stale
        order = _row_lexsort(cand_t, cand_seq)
        end = order[:, m - 1:m]
        T = np.take_along_axis(cand_t, end, axis=1)          # (S, 1)
        end_seq = np.take_along_axis(cand_seq, end, axis=1)
        if e_time is not None:
            popped = stale & ((ft < T) | ((ft == T) & (fseq < end_seq)))
            ft = np.where(popped, e_time, ft)
            fseq = np.where(popped, rseq, fseq)
            ver = np.where(popped, k, ver)
            computed += popped.sum(axis=1)
        computed += m
        t = T[:, 0]
        # bulk restart of the m accepted workers, in worker order
        acc = np.zeros((S, n), dtype=bool)
        acc[srows, order[:, :m]] = True
        if taus is not None:
            new_d = np.broadcast_to(taus, (S, n))
        else:
            new_d = np.zeros((S, n))
            for s, rng in enumerate(rngs):
                aw = np.flatnonzero(acc[s])
                new_d[s, aw] = np.asarray(model.sample_times(aw, rng),
                                          dtype=float)
        acc_rank = np.cumsum(acc, axis=1) - 1
        ft = np.where(acc, T + new_d, ft)
        fseq = np.where(acc, seq_c[:, None] + 1 + acc_rank, fseq)
        ver = np.where(acc, k + 1, ver)
        seq_c = seq_c + m

    e = np.array([])
    return [Trace(e, e, e, iterations=K, total_time=float(t[s]),
                  gradients_used=m * K, gradients_computed=int(computed[s]))
            for s in range(S)]


def simulate(strategy: Union[str, AggregationStrategy],
             model: Union[TimeModel, UniversalModel],
             K: int,
             problem: Optional[Problem] = None,
             gamma: float = 0.0,
             seed: int = 0,
             record_every: int = 1,
             tol_grad_sq: Optional[float] = None) -> Trace:
    """Run ``K`` server iterations of ``strategy`` under ``model``.

    The single event engine shared by every method: a priority queue of
    ``(finish_time, seq, worker, version)`` events (plus strategy-armed
    timer events with ``worker = -1``), exact wall-clock accounting
    (bubbles, stale computations, discards — §3 Remark: computations cannot
    be stopped), iterate snapshots with pruning for delayed gradients,
    recording every ``record_every`` iterations, and tolerance-based early
    exit. With ``problem=None`` runs timing-only (no math).
    """
    if isinstance(strategy, str):
        strategy = make_strategy(strategy)
    rng = np.random.default_rng(seed)
    n = model.n
    strategy.bind(n)

    # Timing-only m-sync admits an exact round-vectorized evaluation —
    # worth ~10-100x at paper scale (n = 1000). Only for strategies with
    # unmodified m-sync arrival semantics (subclasses that override
    # on_arrival/on_step, wrappers, or alarms fall through to the generic
    # event loop). Universal models run the same recursion with the
    # deterministic finish-time inversion in place of draws.
    if (problem is None
            and not strategy.uses_alarm
            and isinstance(strategy, MSync)
            and type(strategy).on_arrival is MSync.on_arrival
            and type(strategy).on_step is AggregationStrategy.on_step
            and K > 0):
        return _fast_msync_timing(strategy._m, model, K, rng)

    x = None if problem is None else problem.x0.copy()
    times, vals, gnorms, record = _recorder(problem, record_every)
    record(0.0, x, 0)

    heap: List[tuple] = []
    seq = 0
    computed = 0
    used = 0
    working = [0] * n                  # version each worker is computing
    snapshots: Dict[int, np.ndarray] = {}
    needs_snapshots = strategy.needs_snapshots
    idle_on_accept = strategy.idle_on_accept
    if needs_snapshots and x is not None:
        snapshots[0] = x.copy()

    st = SimState(n=n, counts=np.zeros(n, dtype=int)
                  if strategy.per_worker else None)
    acc = _Accumulator(x, n, strategy.per_worker)
    tol_stride = record_every if strategy.tol_on_record else 1
    universal = isinstance(model, UniversalModel)
    heappush, heappop = heapq.heappush, heapq.heappop
    on_arrival = strategy.on_arrival
    ev = Arrival()                     # scratch, reused across events

    # Bulk starts keep their (sorted) finish times in numpy arrays popped
    # by pointer increment; the heap only holds single restarts, alarms and
    # leftovers of a superseded bulk. The merged pop preserves the exact
    # (time, seq) order of a single global heap, bitwise.
    b_times = b_workers = None
    b_ptr = b_len = b_seq0 = b_ver = 0

    def start_batch(workers: List[int], t_now: float, version: int) -> None:
        nonlocal seq, b_times, b_workers, b_ptr, b_len, b_seq0, b_ver
        if not workers:
            return
        if universal:
            finish = np.asarray(model.finish_times(workers, t_now))
        else:
            finish = t_now + model.sample_times(workers, rng)
        for w in workers:
            working[w] = version
        if len(workers) == 1:
            seq += 1
            heappush(heap, (float(finish[0]), seq, workers[0], version))
            return
        for i in range(b_ptr, b_len):    # flush superseded bulk leftovers
            heappush(heap, (float(b_times[i]), b_seq0 + i,
                            b_workers[i], b_ver))
        order = np.argsort(finish, kind="stable")  # ties: worker order
        b_times = finish[order]
        b_workers = [workers[i] for i in order]
        b_seq0 = seq + 1
        seq += len(workers)
        b_ptr, b_len, b_ver = 0, len(workers), version

    uses_alarm = strategy.uses_alarm

    def arm_alarm() -> None:
        nonlocal seq
        ta = strategy.next_alarm(st)
        if ta is not None:
            seq += 1
            heappush(heap, (float(ta), seq, -1, st.k))

    # all workers start idle at t = 0, version 0 — one vectorized draw
    start_batch(list(range(n)), 0.0, 0)
    if uses_alarm:
        arm_alarm()

    t = 0.0
    idle: List[int] = []
    k = 0
    while k < K:
        if b_ptr < b_len and (not heap
                              or (b_times[b_ptr], b_seq0 + b_ptr)
                              <= (heap[0][0], heap[0][1])):
            t = float(b_times[b_ptr])
            w = b_workers[b_ptr]
            v = b_ver
            b_ptr += 1
        else:
            t, _, w, v = heappop(heap)
        st.t = t
        if w < 0:                                   # timer event
            if v != k:
                continue                            # stale alarm
            arrival = False
            decision = strategy.on_alarm(st)
        else:
            arrival = True
            computed += 1
            ev.t = t
            ev.worker = w
            ev.version = v
            ev.delay = k - v
            decision = on_arrival(ev, st)

        if decision is Decision.DISCARD:
            if arrival:                             # restart at the iterate
                if universal:
                    tf = float(model.finish_times([w], t)[0])
                else:
                    tf = t + model.sample_time(w, rng)
                seq += 1
                heappush(heap, (tf, seq, w, k))
                working[w] = k
            continue

        if arrival:                                 # ACCEPT or STEP: use it
            used += 1
            st.got += 1
            if st.counts is not None:
                st.counts[w] += 1
            if x is not None:
                x_eval = snapshots[v] if needs_snapshots else x
                acc.add(w, strategy.gradient(w, x_eval, rng, problem))

        if decision is Decision.STEP:
            if x is not None:
                mult = strategy.stepsize(k, ev.delay if arrival else 0)
                x = x - gamma * mult * strategy.combine(acc, st)
            k += 1
            st.k = k
            if needs_snapshots and x is not None:
                snapshots[k] = x.copy()
                if k % (4 * n) == 0:                # prune stale snapshots
                    low = min(working)
                    for vv in [key for key in snapshots if key < low]:
                        del snapshots[vv]
            if x is not None:
                record(t, x, k)
                if tol_grad_sq is not None \
                        and (k - strategy.tol_offset) % tol_stride == 0:
                    g = problem.grad(x)
                    if float(np.dot(g, g)) <= tol_grad_sq:
                        break
                acc.reset()
            st.got = 0
            if st.counts is not None:
                st.counts[:] = 0
            strategy.on_step(st)
            if arrival:
                if idle_on_accept:
                    idle.append(w)
                else:
                    if universal:
                        tf = float(model.finish_times([w], t)[0])
                    else:
                        tf = t + model.sample_time(w, rng)
                    seq += 1
                    heappush(heap, (tf, seq, w, k))
                    working[w] = k
            if idle:
                idle.sort()
                start_batch(idle, t, k)             # one vectorized draw
                idle = []
            if uses_alarm:
                arm_alarm()
        elif arrival and idle_on_accept:            # plain ACCEPT
            idle.append(w)
        elif arrival:
            if universal:
                tf = float(model.finish_times([w], t)[0])
            else:
                tf = t + model.sample_time(w, rng)
            seq += 1
            heappush(heap, (tf, seq, w, k))
            working[w] = k

    return Trace(np.array(times), np.array(vals), np.array(gnorms),
                 iterations=k, total_time=t, gradients_used=used,
                 gradients_computed=computed, x_final=x)
