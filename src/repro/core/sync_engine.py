"""m-Synchronous gradient aggregation on a real device mesh.

This is the TPU-native realization of Algorithm 3 (see DESIGN.md §2): every
data-parallel *group* computes a gradient each step; a per-group
participation mask (derived from the straggler/time model, or from a
deadline) zeroes the non-participants, and the all-reduce is rescaled by
``1/m``. Mathematically identical to Algorithm 3's estimator — an unbiased
batch-``m`` gradient — while keeping the collective a plain all-reduce,
which is exactly the practical advantage of synchronous methods the paper's
§8 argues for.

Participation is resolved by the SAME strategy objects that drive the
event simulator (:mod:`repro.core.strategies`): any strategy with
``mesh = True`` (``sync``, ``msync``, ``auto_m``, ``deadline``) exposes
:meth:`~repro.core.strategies.AggregationStrategy.mesh_mask`, which maps
one round's drawn compute times to ``(mask, m, step_seconds)``. The old
:class:`SyncMode`/:class:`SyncPolicy` pair is kept as a deprecated shim
that resolves to a strategy (``SyncPolicy.to_strategy()``) — see the
migration table in DESIGN.md §5.

Two equivalent implementations are provided (tested against each other):

* :func:`participation_example_weights` — fold the mask into *per-example
  loss weights*; the ordinary ``grad(mean(w * loss))`` + GSPMD all-reduce
  then computes the m-sync estimator with zero extra collectives.
* :func:`masked_group_mean` — explicit ``shard_map`` psum of per-group
  gradients with mask/``m`` rescale (useful when the loss is not a plain
  per-example mean).

Participation sources:

* :class:`SimulatedStraggler` — draws per-group compute times from any
  :class:`~repro.core.time_models.TimeModel` (one vectorized
  ``sample_times`` call per round) and hands them to the strategy.
* ``auto_m`` — combines :class:`~repro.core.selection.OnlineTauEstimator`
  with Proposition 4.1 to adapt ``m`` during training.
"""

from __future__ import annotations

import dataclasses
import enum
from functools import partial
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .selection import OnlineTauEstimator
from .strategies import (AggregationStrategy, AutoM, DeadlineSync, FullSync,
                         MSync, first_m_mask)
from .time_models import TimeModel

__all__ = ["SyncMode", "SyncPolicy", "SimulatedStraggler",
           "participation_example_weights", "masked_group_mean",
           "first_m_mask"]


class SyncMode(str, enum.Enum):
    """Deprecated: use the strategy names in STRATEGIES instead."""

    FULL = "full"          # -> STRATEGIES["sync"]
    M_SYNC = "m_sync"      # -> STRATEGIES["msync"]
    AUTO_M = "auto_m"      # -> STRATEGIES["auto_m"]
    DEADLINE = "deadline"  # -> STRATEGIES["deadline"]


@dataclasses.dataclass
class SyncPolicy:
    """Deprecated shim: a named bundle of strategy parameters.

    Kept so existing call sites (``SyncPolicy(SyncMode.M_SYNC, m=4)``)
    continue to work; internally everything resolves through
    :meth:`to_strategy`.
    """

    mode: SyncMode = SyncMode.FULL
    m: Optional[int] = None              # for M_SYNC
    deadline: Optional[float] = None     # seconds, for DEADLINE
    eps_target: float = 1e-2             # ε for AUTO_M (Prop 4.1)

    def to_strategy(self) -> AggregationStrategy:
        if self.mode == SyncMode.FULL:
            return FullSync()
        if self.mode == SyncMode.M_SYNC:
            if self.m is None:
                raise ValueError("M_SYNC requires m")
            return MSync(m=self.m)
        if self.mode == SyncMode.AUTO_M:
            return AutoM(eps_target=self.eps_target)
        if self.mode == SyncMode.DEADLINE:
            if self.deadline is None:
                raise ValueError("DEADLINE requires deadline")
            return DeadlineSync(deadline=self.deadline)
        raise ValueError(f"unknown mode {self.mode}")

    def resolve_m(self, n: int, estimator: Optional[OnlineTauEstimator]
                  ) -> int:
        if self.mode == SyncMode.FULL:
            return n
        if self.mode == SyncMode.M_SYNC:
            if self.m is None:
                raise ValueError("M_SYNC requires m")
            return min(self.m, n)
        if self.mode == SyncMode.AUTO_M:
            if estimator is None or not estimator.seen.any():
                return n
            return estimator.suggest_m(self.eps_target)
        raise ValueError(f"resolve_m undefined for {self.mode}")


@dataclasses.dataclass
class SimulatedStraggler:
    """Per-step participation masks from a computation-time model.

    Tracks simulated wall-clock like Algorithm 3: each round draws all
    per-group compute times with one vectorized ``sample_times`` call and
    lets the strategy pick ``(mask, m, step_seconds)``; drawn times also
    feed the online τ estimator for the ``auto_m`` strategy.

    ``policy`` may be an :class:`~repro.core.strategies.AggregationStrategy`
    (any ``mesh = True`` strategy) or a legacy :class:`SyncPolicy`.
    """

    model: TimeModel
    policy: Union[AggregationStrategy, SyncPolicy]
    seed: int = 0

    def __post_init__(self) -> None:
        self.rng = np.random.default_rng(self.seed)
        self.strategy = (self.policy.to_strategy()
                         if isinstance(self.policy, SyncPolicy)
                         else self.policy)
        if not self.strategy.mesh:
            raise ValueError(
                f"strategy {self.strategy.name!r} cannot drive a "
                "synchronous mesh round (mesh=False)")
        self.strategy.bind(self.model.n)
        eps = getattr(self.strategy, "eps_target", 1e-2)
        self.estimator = OnlineTauEstimator(self.model.n, eps_target=eps)
        self.wallclock = 0.0
        self._workers = np.arange(self.model.n)

    def step(self) -> Tuple[np.ndarray, int, float]:
        """Returns ``(mask, m, step_seconds)`` for one training step."""
        times = self.model.sample_times(self._workers, self.rng)
        mask, m, dur = self.strategy.mesh_mask(times, self.estimator)
        self.estimator.update_times(times)
        self.wallclock += dur
        return mask, int(m), float(dur)


def participation_example_weights(mask: jnp.ndarray, n_groups: int,
                                  batch: int) -> jnp.ndarray:
    """Per-example weights realizing the Algorithm 3 estimator.

    With ``B`` examples split evenly across ``n`` groups and ``m``
    participants, weight ``w_b = mask[group(b)] * n / m`` makes
    ``mean_b(w_b * loss_b)`` equal the mean loss over participating groups —
    so its gradient is the m-sync gradient estimator. Requires
    ``batch % n_groups == 0`` (enforced by the data pipeline).
    """
    mask = mask.astype(jnp.float32)
    m = jnp.maximum(mask.sum(), 1.0)
    per_group = mask * (n_groups / m)
    return jnp.repeat(per_group, batch // n_groups)


@partial(jax.jit, static_argnames=("axis_name",))
def _masked_psum(g, mask_val, m, axis_name):
    g = jax.tree.map(lambda a: a * mask_val, g)
    return jax.tree.map(lambda a: jax.lax.psum(a, axis_name) / m, g)


def masked_group_mean(per_group_grads, mask: jnp.ndarray, axis_name: str):
    """Explicit-collective variant: inside ``shard_map`` over the dp axis,
    each group holds its gradient pytree; returns ``Σ mask_i g_i / m``.

    Call *inside* a ``shard_map`` whose mesh axis is ``axis_name``; ``mask``
    must be the scalar mask value for this group's index.
    """
    m = jnp.maximum(jax.lax.psum(mask.astype(jnp.float32), axis_name), 1.0)
    g = jax.tree.map(lambda a: a * mask.astype(a.dtype), per_group_grads)
    return jax.tree.map(lambda a: jax.lax.psum(a, axis_name) / m, g)
