"""m-Synchronous gradient aggregation on a real device mesh.

This is the TPU-native realization of Algorithm 3 (see DESIGN.md §2): every
data-parallel *group* computes a gradient each step; a per-group
participation mask (derived from the straggler/time model, or from a
deadline) zeroes the non-participants, and the all-reduce is rescaled by
``1/m``. Mathematically identical to Algorithm 3's estimator — an unbiased
batch-``m`` gradient — while keeping the collective a plain all-reduce,
which is exactly the practical advantage of synchronous methods the paper's
§8 argues for.

Two equivalent implementations are provided (tested against each other):

* :func:`participation_example_weights` — fold the mask into *per-example
  loss weights*; the ordinary ``grad(mean(w * loss))`` + GSPMD all-reduce
  then computes the m-sync estimator with zero extra collectives.
* :func:`masked_group_mean` — explicit ``shard_map`` psum of per-group
  gradients with mask/``m`` rescale (useful when the loss is not a plain
  per-example mean).

Participation sources:

* :class:`SimulatedStraggler` — draws per-group compute times from any
  :class:`~repro.core.time_models.TimeModel` and selects the first ``m``
  finishers (Algorithm 3 line 4) or a wall-clock deadline.
* ``AUTO_M`` — combines :class:`~repro.core.selection.OnlineTauEstimator`
  with Proposition 4.1 to adapt ``m`` during training.
"""

from __future__ import annotations

import dataclasses
import enum
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .selection import OnlineTauEstimator, optimal_m
from .time_models import TimeModel

__all__ = ["SyncMode", "SyncPolicy", "SimulatedStraggler",
           "participation_example_weights", "masked_group_mean",
           "first_m_mask"]


class SyncMode(str, enum.Enum):
    FULL = "full"          # Algorithm 1 — wait for everyone
    M_SYNC = "m_sync"      # Algorithm 3 — first m finishers
    AUTO_M = "auto_m"      # Algorithm 3 + Prop 4.1 online m selection
    DEADLINE = "deadline"  # aggregate whoever finished by the deadline


@dataclasses.dataclass
class SyncPolicy:
    mode: SyncMode = SyncMode.FULL
    m: Optional[int] = None              # for M_SYNC
    deadline: Optional[float] = None     # seconds, for DEADLINE
    eps_target: float = 1e-2             # ε for AUTO_M (Prop 4.1)

    def resolve_m(self, n: int, estimator: Optional[OnlineTauEstimator]
                  ) -> int:
        if self.mode == SyncMode.FULL:
            return n
        if self.mode == SyncMode.M_SYNC:
            if self.m is None:
                raise ValueError("M_SYNC requires m")
            return min(self.m, n)
        if self.mode == SyncMode.AUTO_M:
            if estimator is None or not estimator.seen.any():
                return n
            return estimator.suggest_m(self.eps_target)
        raise ValueError(f"resolve_m undefined for {self.mode}")


def first_m_mask(times: np.ndarray, m: int) -> np.ndarray:
    """Boolean mask of the first ``m`` finishers (ties broken by index)."""
    order = np.argsort(times, kind="stable")
    mask = np.zeros(len(times), dtype=bool)
    mask[order[:m]] = True
    return mask


@dataclasses.dataclass
class SimulatedStraggler:
    """Per-step participation masks from a computation-time model.

    Tracks simulated wall-clock like Algorithm 3: the step duration is the
    m-th order statistic of the drawn times; drawn times also feed the
    online τ estimator for AUTO_M.
    """

    model: TimeModel
    policy: SyncPolicy
    seed: int = 0

    def __post_init__(self) -> None:
        self.rng = np.random.default_rng(self.seed)
        self.estimator = OnlineTauEstimator(self.model.n,
                                            eps_target=self.policy.eps_target)
        self.wallclock = 0.0

    def step(self) -> Tuple[np.ndarray, int, float]:
        """Returns ``(mask, m, step_seconds)`` for one training step."""
        n = self.model.n
        times = np.array([self.model.sample_time(i, self.rng)
                          for i in range(n)])
        if self.policy.mode == SyncMode.DEADLINE:
            mask = times <= self.policy.deadline
            if not mask.any():                       # never stall forever
                mask = first_m_mask(times, 1)
            dur = min(float(self.policy.deadline), float(times[mask].max()))
        else:
            m = self.policy.resolve_m(n, self.estimator)
            mask = first_m_mask(times, m)
            dur = float(np.sort(times)[m - 1])
        self.estimator.update_times(times)
        self.wallclock += dur
        return mask, int(mask.sum()), dur


def participation_example_weights(mask: jnp.ndarray, n_groups: int,
                                  batch: int) -> jnp.ndarray:
    """Per-example weights realizing the Algorithm 3 estimator.

    With ``B`` examples split evenly across ``n`` groups and ``m``
    participants, weight ``w_b = mask[group(b)] * n / m`` makes
    ``mean_b(w_b * loss_b)`` equal the mean loss over participating groups —
    so its gradient is the m-sync gradient estimator. Requires
    ``batch % n_groups == 0`` (enforced by the data pipeline).
    """
    mask = mask.astype(jnp.float32)
    m = jnp.maximum(mask.sum(), 1.0)
    per_group = mask * (n_groups / m)
    return jnp.repeat(per_group, batch // n_groups)


@partial(jax.jit, static_argnames=("axis_name",))
def _masked_psum(g, mask_val, m, axis_name):
    g = jax.tree.map(lambda a: a * mask_val, g)
    return jax.tree.map(lambda a: jax.lax.psum(a, axis_name) / m, g)


def masked_group_mean(per_group_grads, mask: jnp.ndarray, axis_name: str):
    """Explicit-collective variant: inside ``shard_map`` over the dp axis,
    each group holds its gradient pytree; returns ``Σ mask_i g_i / m``.

    Call *inside* a ``shard_map`` whose mesh axis is ``axis_name``; ``mask``
    must be the scalar mask value for this group's index.
    """
    m = jnp.maximum(jax.lax.psum(mask.astype(jnp.float32), axis_name), 1.0)
    g = jax.tree.map(lambda a: a * mask.astype(a.dtype), per_group_grads)
    return jax.tree.map(lambda a: jax.lax.psum(a, axis_name) / m, g)
