"""Batched multi-seed simulation: :func:`simulate_batch` and
:class:`TraceBatch` — the vectorized sibling of :func:`repro.core.simulate`.

The paper's claims are statements about *distributions* of wall-clock time
(Assumptions 2.2/3.1/5.1/5.4), so every figure needs seed sweeps, not
single runs. ``simulate_batch`` runs one strategy under one time model
across ``S`` seeds and an optional parameter grid in a single call and
returns a :class:`TraceBatch` with cross-seed summaries (mean ± std,
time-to-target quantiles).

Backends (``backend=``):

* ``"serial"`` — per-(grid-point, seed) :func:`simulate` calls. Works for
  every strategy/model/problem combination and is trace-for-trace
  identical to scalar runs by construction.
* ``"vectorized"`` — the seed-batched round-vectorized m-sync timing
  engine (:func:`repro.core.strategies._fast_msync_timing_batch`): one
  ``(seeds, rounds, workers)`` array program. Timing-only m-sync family,
  including universal models (deterministic — computed once and
  replicated across seeds). ``rng_scheme`` picks the draw contract for
  random models: ``"counter"`` (default) draws the whole time tensor
  from per-seed Philox counter streams in bulk (fast, distribution-equal
  to scalar runs), ``"stream"`` consumes each seed's
  ``default_rng(seed)`` stream in the scalar path's exact order (exact
  per-seed parity with the scalar fast path).
* ``"jax"`` — :mod:`repro.core.batch_jax`: jitted ``lax.scan`` programs
  over ``(seeds, workers)`` state (optionally using the Pallas top-m
  partial-sort kernel for the per-round m-th order statistic). Covers
  the m-sync family, Rennala and Malenia (renewal-batched rounds) and
  Async/Ringmaster (keyed arrival-indexed recursion) under every model
  class — FixedTimes, sampled (``jax_sampler``) and universal
  (``finish_times_jax``) — the full DESIGN.md §3b coverage matrix.
  Distribution-equal, not RNG-stream-equal; matches NumPy within float
  tolerance for deterministic models/oracles in generic position
  (adversarially tie-heavy instances, e.g. partial participation, can
  diverge by whole events under the worker-index tie-break).
* ``"auto"`` (default) — ``vectorized`` when eligible, else ``serial``.
* ``"fastest"`` — like ``auto`` but also considers the ``jax`` backend
  when the sweep is large enough (``seeds * K * n >=``
  :data:`JAX_MIN_WORK`) to amortize jit compilation — or whenever the
  problem is a :class:`~repro.core.batch_jax.JaxProblem`, which only
  jax can execute; this is what :func:`repro.exp.run_experiment` uses.
  One deterministic exception: timing-only m-sync under a universal
  model replicates ONE scalar run across seeds on the ``vectorized``
  backend, so there is nothing for a device sweep to amortize and
  ``fastest`` keeps it there; universal Rennala/Malenia/Async sweeps
  (per-seed identical but with no replication shortcut ONLY in serial)
  do route to jax above the work threshold. The backend that actually
  ran is recorded per grid point in the :class:`TraceBatch`.

Grid semantics: ``grid`` maps parameter names to value sequences and the
cartesian product is swept. Keys in :data:`SIM_GRID_KEYS` override the
corresponding :func:`simulate` argument; every other key is passed to the
strategy factory (so ``{"m": [1, 4, 16]}`` sweeps ``MSync(m=...)``).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Union

import numpy as np

from .strategies import (AggregationStrategy, MSync, STRATEGIES, Trace,
                         _fast_msync_timing_batch, make_strategy, simulate)
from .time_models import FixedTimes, TimeModel, UniversalModel, philox_rngs

__all__ = ["TraceBatch", "simulate_batch", "SIM_GRID_KEYS", "JAX_MIN_WORK"]

# grid keys routed to simulate() itself; everything else goes to the
# strategy factory
SIM_GRID_KEYS = ("K", "gamma", "record_every", "tol_grad_sq")

# backend="fastest" only reaches for jax above this seeds * K * n volume
# (below it, jit compilation dominates and the NumPy engines win)
JAX_MIN_WORK = 1_000_000

StrategySpec = Union[str, AggregationStrategy,
                     "tuple[str, Dict[str, Any]]", Callable[..., Any]]


@dataclasses.dataclass
class TraceBatch:
    """Traces of ``G`` grid points × ``S`` seeds plus cross-seed reducers.

    ``traces[g][s]`` is the full per-run :class:`Trace` (timing-only
    backends leave the recorded arrays empty, exactly like the scalar fast
    path). Scalar per-run fields are exposed as ``(G, S)`` arrays through
    :meth:`stat`, and :meth:`summary` produces the mean ± std rows the
    benchmark layer reports.
    """

    strategy: str                      # display name of the swept strategy
    grid: List[Dict[str, Any]]         # one kwargs dict per grid point
    seeds: np.ndarray                  # (S,) seeds, in run order
    traces: List[List[Trace]]          # [G][S]
    backend: str                       # backend that actually ran
    rng_scheme: str = "counter"        # EFFECTIVE draw contract of the
    #                                    run: the requested scheme for
    #                                    the vectorized engine, "stream"
    #                                    for serial (per-seed parity by
    #                                    construction), "jax.random" for
    #                                    the jax backend

    # ------------------------------------------------------------ arrays
    def stat(self, field: str) -> np.ndarray:
        """``(G, S)`` array of a scalar Trace field/property."""
        return np.array([[getattr(tr, field) for tr in row]
                         for row in self.traces], dtype=float)

    @property
    def total_time(self) -> np.ndarray:
        return self.stat("total_time")

    def time_to_target(self, frac: float = 0.25) -> np.ndarray:
        """``(G, S)`` wall-clock time at which ``||∇f||²`` first drops to
        ``frac`` × its initial recorded value (``inf`` if never; ``nan``
        for timing-only traces)."""
        out = np.full((len(self.traces), len(self.seeds)), np.nan)
        for g, row in enumerate(self.traces):
            for s, tr in enumerate(row):
                if len(tr.grad_norms) == 0:
                    continue
                tgt = frac * tr.grad_norms[0]
                hit = np.flatnonzero(tr.grad_norms <= tgt)
                out[g, s] = tr.times[hit[0]] if hit.size else np.inf
        return out

    # ----------------------------------------------------------- summary
    def summary(self, target_frac: Optional[float] = None,
                quantiles: Sequence[float] = (0.1, 0.5, 0.9)) -> List[dict]:
        """One dict per grid point: mean ± std across seeds of total time,
        seconds per useful gradient and discard fraction, plus
        time-to-target quantiles when ``target_frac`` is given."""
        tt = self.total_time
        used = np.maximum(self.stat("gradients_used"), 1.0)
        per_grad = tt / used
        disc = self.stat("discard_fraction")
        rows = []
        for g, params in enumerate(self.grid):
            row = {
                "strategy": self.strategy,
                "params": dict(params),
                "seeds": len(self.seeds),
                "backend": self.backend,
                "rng_scheme": self.rng_scheme,
                "total_time_mean": float(tt[g].mean()),
                "total_time_std": float(tt[g].std()),
                "s_per_useful_grad_mean": float(per_grad[g].mean()),
                "s_per_useful_grad_std": float(per_grad[g].std()),
                "discard_fraction_mean": float(disc[g].mean()),
                "iterations_mean": float(self.stat("iterations")[g].mean()),
            }
            if target_frac is not None:
                t2t = self.time_to_target(target_frac)[g]
                finite = t2t[np.isfinite(t2t)]
                row["time_to_target_frac"] = target_frac
                row["time_to_target_hit_rate"] = (
                    float(np.mean(np.isfinite(t2t))) if len(t2t) else 0.0)
                for q in quantiles:
                    row[f"time_to_target_q{int(round(q * 100))}"] = (
                        float(np.quantile(finite, q)) if finite.size
                        else float("inf"))
            rows.append(row)
        return rows


# ---------------------------------------------------------------------------
# strategy specs and grids
# ---------------------------------------------------------------------------

def _as_spec(strategy: StrategySpec):
    """Normalize to ``(display_name, factory(**kw), base_kwargs)``."""
    if isinstance(strategy, str):
        if strategy not in STRATEGIES:
            make_strategy(strategy)    # raises KeyError with known names
        return strategy, STRATEGIES[strategy], {}
    if isinstance(strategy, tuple):
        name, kw = strategy
        make_strategy(name, **kw)      # validate early, with a clear error
        return name, STRATEGIES[name], dict(kw)
    if isinstance(strategy, AggregationStrategy):
        inst = strategy

        def factory(**kw):
            if kw:
                raise ValueError(
                    "grid sweeps over strategy parameters need a re-"
                    "instantiable spec — pass a name or (name, kwargs), "
                    f"not the instance {inst.name!r}")
            return inst
        return inst.name, factory, {}
    if callable(strategy):
        return getattr(strategy, "name", getattr(strategy, "__name__",
                                                 "strategy")), strategy, {}
    raise TypeError(f"bad strategy spec: {strategy!r}")


def _grid_points(grid: Optional[Mapping[str, Sequence]]) -> List[Dict]:
    if not grid:
        return [{}]
    keys = list(grid)
    return [dict(zip(keys, combo))
            for combo in itertools.product(*(grid[k] for k in keys))]


def _vectorized_eligible(strategy: AggregationStrategy, model,
                         problem, K: int, tol_grad_sq) -> bool:
    """Mirror of the scalar fast-path guard in :func:`simulate`."""
    return (problem is None and tol_grad_sq is None
            and not strategy.uses_alarm
            and isinstance(strategy, MSync)
            and type(strategy).on_arrival is MSync.on_arrival
            and type(strategy).on_step is AggregationStrategy.on_step
            and K > 0)


def _is_jax_problem(problem) -> bool:
    if problem is None:
        return False
    from .batch_jax import JaxProblem        # deferred import
    return isinstance(problem, JaxProblem)


def _jax_eligible(strategy: AggregationStrategy, model, problem,
                  tol_grad_sq, K: int, S: int) -> bool:
    """True when the jax backend supports the combination AND the sweep
    is big enough (``S * K * n >= JAX_MIN_WORK``) to amortize jit. A
    :class:`~repro.core.batch_jax.JaxProblem` bypasses the size gate:
    jax is the only backend that can execute its oracle at all.
    Support now spans the full strategy × model matrix (m-sync family,
    Rennala, Malenia, Async/Ringmaster × fixed/sampled/universal), so
    ``fastest`` no longer forces Malenia or universal scenarios onto
    the serial path."""
    if tol_grad_sq is not None or K <= 0:
        return False
    if not _is_jax_problem(problem) and S * K * model.n < JAX_MIN_WORK:
        return False
    from .batch_jax import jax_supported
    return jax_supported(strategy, model, problem)


# ---------------------------------------------------------------------------
# the batched driver
# ---------------------------------------------------------------------------

def simulate_batch(strategy: StrategySpec,
                   model: Union[TimeModel, UniversalModel],
                   K: int,
                   problem=None,
                   gamma: float = 0.0,
                   seeds: Union[int, Sequence[int]] = 8,
                   grid: Optional[Mapping[str, Sequence]] = None,
                   record_every: int = 1,
                   tol_grad_sq: Optional[float] = None,
                   backend: str = "auto",
                   rng_scheme: str = "counter",
                   use_pallas: bool = False) -> TraceBatch:
    """Run ``strategy`` under ``model`` across ``seeds`` × ``grid``.

    ``seeds`` is an int (→ ``range(seeds)``) or an explicit sequence.
    With ``seeds=[s]``, the default backends and ``rng_scheme="stream"``
    the result reproduces scalar ``simulate(..., seed=s)``
    trace-for-trace; the default ``rng_scheme="counter"`` draws random
    models from per-seed Philox counter streams instead — equal in
    distribution, much faster for sweeps, and independent of which other
    seeds are in the sweep. ``rng_scheme`` only affects the
    ``vectorized`` backend (``serial`` always consumes the scalar
    streams; ``jax`` always draws with ``jax.random`` — per-seed
    reproducible and sweep-independent like ``counter``, stream-equal
    to nothing). ``backend="jax"`` covers every registered paper
    strategy (m-sync family, rennala, malenia, async, ringmaster) under
    every time-model class, timing-only or with a
    :class:`~repro.core.batch_jax.JaxProblem`; ``deadline``/``dropout``
    and NumPy oracles stay on the host engines. See the module
    docstring for backend and grid semantics.
    """
    seed_list = list(range(seeds)) if isinstance(seeds, (int, np.integer)) \
        else [int(s) for s in seeds]
    if not seed_list:
        raise ValueError("need at least one seed")
    if backend not in ("auto", "fastest", "serial", "vectorized", "jax"):
        raise ValueError(f"unknown backend {backend!r}")
    if rng_scheme not in ("counter", "stream"):
        raise ValueError(f"unknown rng_scheme {rng_scheme!r}; "
                         "use 'counter' or 'stream'")
    name, factory, base_kw = _as_spec(strategy)
    points = _grid_points(grid)

    traces: List[List[Trace]] = []
    used_backends = []
    used_schemes = []
    for pt in points:
        sim_kw = {k: pt[k] for k in pt if k in SIM_GRID_KEYS}
        strat_kw = {**base_kw, **{k: v for k, v in pt.items()
                                  if k not in SIM_GRID_KEYS}}
        K_pt = int(sim_kw.pop("K", K))
        gamma_pt = float(sim_kw.pop("gamma", gamma))
        re_pt = int(sim_kw.pop("record_every", record_every))
        tol_pt = sim_kw.pop("tol_grad_sq", tol_grad_sq)

        strat = factory(**strat_kw)
        if isinstance(strat, str):     # factory returned a registry name
            strat = make_strategy(strat)
        strat.bind(model.n)

        chosen = backend
        if backend == "auto":
            chosen = "vectorized" if _vectorized_eligible(
                strat, model, problem, K_pt, tol_pt) else "serial"
        elif backend == "fastest":
            # an explicit stream request is a parity contract jax cannot
            # honor for sampled models (jax.random draws) — stay on the
            # stream-capable engines there, unless only jax can execute
            # the problem (a JaxProblem oracle), where executability wins
            jax_ok = (_is_jax_problem(problem)
                      or rng_scheme != "stream"
                      or isinstance(model, (FixedTimes, UniversalModel)))
            if (isinstance(model, UniversalModel)
                    and _vectorized_eligible(strat, model, problem, K_pt,
                                             tol_pt)):
                # deterministic universal m-sync timing replicates ONE
                # scalar run across seeds — no sweep for jax to win
                chosen = "vectorized"
            elif jax_ok and _jax_eligible(strat, model, problem, tol_pt,
                                          K_pt, len(seed_list)):
                chosen = "jax"
            elif _is_jax_problem(problem):
                # only jax can execute a JaxProblem oracle; raise the
                # precise unsupported-combination error instead of
                # letting the serial engine crash inside it
                from .batch_jax import _check_supported
                _check_supported(strat, model, problem)
                raise NotImplementedError(
                    "JaxProblem sweeps run on the jax backend only, "
                    "which does not support tol_grad_sq early exit or "
                    "K <= 0; use a NumPy Problem with backend='serial'")
            elif _vectorized_eligible(strat, model, problem, K_pt, tol_pt):
                chosen = "vectorized"
            else:
                chosen = "serial"
        if chosen == "vectorized":
            if not _vectorized_eligible(strat, model, problem, K_pt,
                                        tol_pt):
                raise ValueError(
                    "vectorized backend needs timing-only m-sync arrival "
                    "semantics")
            if rng_scheme == "counter" \
                    and not isinstance(model, UniversalModel):
                rngs = philox_rngs(seed_list)
            else:
                rngs = [np.random.default_rng(s) for s in seed_list]
            row = _fast_msync_timing_batch(strat._m, model, K_pt, rngs,
                                           rng_scheme=rng_scheme)
        elif chosen == "jax":
            if tol_pt is not None:
                raise NotImplementedError(
                    "tol_grad_sq early exit is not supported by the jax "
                    "backend (fixed-length scan); use backend='serial'")
            from .batch_jax import simulate_batch_jax
            row = simulate_batch_jax(strat, model, K_pt, problem=problem,
                                     gamma=gamma_pt, seeds=seed_list,
                                     record_every=re_pt,
                                     use_pallas=use_pallas)
        else:
            row = [simulate(factory(**strat_kw), model, K_pt,
                            problem=problem, gamma=gamma_pt, seed=s,
                            record_every=re_pt, tol_grad_sq=tol_pt)
                   for s in seed_list]
        traces.append(row)
        used_backends.append(chosen)
        used_schemes.append({"serial": "stream",
                             "jax": "jax.random"}.get(chosen, rng_scheme))

    # auto can pick different backends per grid point; report faithfully
    backend_label = used_backends[0] if len(set(used_backends)) == 1 \
        else "+".join(sorted(set(used_backends)))
    scheme_label = used_schemes[0] if len(set(used_schemes)) == 1 \
        else "+".join(sorted(set(used_schemes)))
    return TraceBatch(strategy=name, grid=points,
                      seeds=np.asarray(seed_list), traces=traces,
                      backend=backend_label, rng_scheme=scheme_label)
