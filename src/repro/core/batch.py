"""Batched multi-seed simulation: :func:`simulate_batch` and
:class:`TraceBatch` — the vectorized sibling of :func:`repro.core.simulate`.

The paper's claims are statements about *distributions* of wall-clock time
(Assumptions 2.2/3.1/5.1/5.4), so every figure needs seed sweeps, not
single runs. ``simulate_batch`` runs one strategy under one time model
across ``S`` seeds and an optional parameter grid in a single call and
returns a :class:`TraceBatch` with cross-seed summaries (mean ± std,
time-to-target quantiles).

Backends (``backend=``):

* ``"serial"`` — per-(grid-point, seed) :func:`simulate` calls. Works for
  every strategy/model/problem combination and is trace-for-trace
  identical to scalar runs by construction.
* ``"vectorized"`` — the seed-batched round-vectorized m-sync timing
  engine (:func:`repro.core.strategies._fast_msync_timing_batch`): one
  ``(seeds, rounds, workers)`` array program. Timing-only m-sync family,
  including universal models (deterministic — computed once and
  replicated across seeds). ``rng_scheme`` picks the draw contract for
  random models: ``"counter"`` (default) draws the whole time tensor
  from per-seed Philox counter streams in bulk (fast, distribution-equal
  to scalar runs), ``"stream"`` consumes each seed's
  ``default_rng(seed)`` stream in the scalar path's exact order (exact
  per-seed parity with the scalar fast path).
* ``"jax"`` — :mod:`repro.core.batch_jax`: jitted ``lax.scan`` programs
  over ``(seeds, workers)`` state (optionally using the Pallas top-m
  partial-sort kernel for the per-round m-th order statistic). Covers
  the m-sync family, Rennala and Malenia (renewal-batched rounds) and
  Async/Ringmaster (renewal-chain arrival scan) under every model
  class — FixedTimes, sampled (``jax_sampler``) and universal
  (``finish_times_jax``) — the full DESIGN.md §3b coverage matrix.
  Distribution-equal, not RNG-stream-equal; matches NumPy within float
  tolerance for deterministic models/oracles in generic position
  (adversarially tie-heavy instances, e.g. partial participation, can
  diverge by whole events under the worker-index tie-break).
* ``"jax_sharded"`` — :mod:`repro.launch.sweep`: the jax engines, but
  every (grid point × seed) pair becomes one work unit, units are
  packed into shape buckets (same compiled program — m-sync buckets
  even fuse heterogeneous ``m``/``gamma`` as traced per-unit inputs)
  and each bucket is ``shard_map``ped over a 1-D ``data`` mesh of the
  local devices. Per-seed results are bitwise identical to
  ``backend="jax"`` (the per-seed key streams are sweep-independent);
  the per-point routing records carry the bucket, compile-vs-execute
  wall times and program-cache hits. m-sync and Async/Ringmaster
  shard; Rennala/Malenia fall back to the per-point jax engine inside
  the sweep (recorded as ``fallback``).
* ``"auto"`` (default) — ``vectorized`` when eligible, else ``serial``.
* ``"fastest"`` — like ``auto`` but routes each grid point through a
  **per-engine cost model** (:func:`estimate_backend_seconds`): the
  estimated wall-clock of the host engine and of the jax engine that
  would run this (round scan, arrival scan, or serial event loop — as a
  function of S, K, n, the strategy's batching parameters, math vs
  timing-only, and whether an accelerator is attached) are compared and
  the cheaper one runs. A :class:`~repro.core.batch_jax.JaxProblem`
  bypasses the comparison — only jax can execute it. One deterministic
  exception: timing-only m-sync under a universal model replicates ONE
  scalar run across seeds on the ``vectorized`` backend, so there is
  nothing for a device sweep to win and ``fastest`` keeps it there.
  The backend that actually ran AND the routing decision (estimates,
  accelerator flag, reason) are recorded per grid point in the
  :class:`TraceBatch`. This is what :func:`repro.exp.run_experiment`
  uses.

Engine *execution* failures do not abort a sweep: every grid point runs
under a degradation ladder (``jax_sharded`` → ``jax`` → ``vectorized`` →
``serial``, retry-once per rung, skipping rungs that cannot run the
point) and each downgrade is recorded in the point's
``TraceBatch.routing`` entry (``downgrades``: engine, exception class,
reason, fallback target) instead of raising — only the last rung's
failure propagates. Contract errors on a forced backend (unsupported
strategy/model, ``tol_grad_sq`` on jax) still raise up front. See
DESIGN.md §3c.

Grid semantics: ``grid`` maps parameter names to value sequences and the
cartesian product is swept. Keys in :data:`SIM_GRID_KEYS` override the
corresponding :func:`simulate` argument; every other key is passed to the
strategy factory (so ``{"m": [1, 4, 16]}`` sweeps ``MSync(m=...)``).
"""

from __future__ import annotations

import dataclasses
import itertools
import os
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Union

import numpy as np

from .strategies import (AggregationStrategy, MSync, STRATEGIES, Trace,
                         _fast_msync_timing_batch, make_strategy, simulate)
from .time_models import FixedTimes, TimeModel, UniversalModel, philox_rngs

__all__ = ["TraceBatch", "simulate_batch", "SIM_GRID_KEYS", "JAX_MIN_WORK",
           "estimate_backend_seconds", "load_cost_constants"]

# grid keys routed to simulate() itself; everything else goes to the
# strategy factory
SIM_GRID_KEYS = ("K", "gamma", "record_every", "tol_grad_sq")

#: DEPRECATED — the PR 3/4 flat ``seeds * K * n`` jax gate. Routing now
#: goes through the per-engine cost model (:func:`estimate_backend_seconds`);
#: this name stays importable for downstream callers and survives inside
#: the router as the *probe floor*: sweeps whose element work is below it
#: go straight to the host engines with no support probe or cost
#: estimate — at that scale jit compilation dominates any jax engine,
#: so there is nothing to price.
JAX_MIN_WORK = 1_000_000

# ---------------------------------------------------------------------------
# the per-engine cost model behind backend="fastest"
# ---------------------------------------------------------------------------

#: Hard-coded fallback cost-model constants, calibrated on this
#: container's CPU via ``benchmarks/simbatch_speed.py`` shapes (n=1000,
#: S=32). They only need to get the ORDERING right near the routing
#: boundaries, not absolute wall-clock.
_DEFAULT_COST_CONSTANTS = {
    "heap_event": 2.6e-6,    # serial event-loop seconds per heap pop
    "np_elem": 1.1e-7,       # serial m-sync fast path, per S*K*n element
    "vec_elem": 2.0e-8,      # vectorized counter engine, per element
    "jax_elem": 1.6e-8,      # jitted m-sync round scan, per element (warm)
    "round_elem": 1.6e-8,    # renewal round scans (rennala/malenia/
                             # ringleader), per pool element (warm)
    "pool_elem": 4.0e-8,     # arrival-scan chain draw + merge, per pool elem
    "scan_step": 3.2e-6,     # arrival-scan step at S=32 (scales ~S/32)
    "jit_compile": 0.6,      # closure-compiled program, per call
    "accel_speedup": 20.0,   # discount on jax COMPUTE (not compile) terms
}

#: The ACTIVE cost-model constants the router reads. Self-calibrating:
#: ``benchmarks/simbatch_speed.py --calibrate`` measures this machine's
#: engines and persists a JSON that :func:`load_cost_constants` merges
#: over the defaults (set ``REPRO_COST_CONSTANTS=/path.json`` to load at
#: import, or call the loader explicitly). Mutated in place so every
#: importer sees the calibrated values.
COST_CONSTANTS = dict(_DEFAULT_COST_CONSTANTS)


def load_cost_constants(path: Optional[str] = None,
                        apply: bool = True) -> Dict[str, float]:
    """Merge measured per-machine cost constants over the hard-coded
    defaults and (by default) install them as the active
    :data:`COST_CONSTANTS`.

    ``path`` defaults to the ``REPRO_COST_CONSTANTS`` environment
    variable. The JSON may be flat or ``{"constants": {...}}`` (the
    ``--calibrate`` artifact shape); unknown keys are ignored and an
    unreadable/invalid file (including valid JSON whose top level is
    not an object) falls back to the defaults with a ``UserWarning``
    naming the file and the error, emitted ONCE per path per process —
    routing must never *fail* because a calibration file went stale,
    but it must not silently ignore one either, and a sweep that calls
    the router thousands of times must not drown the log in repeats.
    """
    import json
    import os
    import warnings

    merged = dict(_DEFAULT_COST_CONSTANTS)
    if path is None:
        path = os.environ.get("REPRO_COST_CONSTANTS", "")
    if path:
        try:
            with open(path) as fh:
                data = json.load(fh)
            consts = data.get("constants", data) \
                if isinstance(data, dict) else data
            if not isinstance(consts, dict):
                raise ValueError(
                    f"cost-constants JSON must be an object (or "
                    f"{{'constants': {{...}}}}), got "
                    f"{type(consts).__name__}")
            merged.update({k: float(v) for k, v in consts.items()
                           if k in merged and float(v) > 0.0})
        except (OSError, ValueError, TypeError) as exc:
            # stale/bad calibration: defaults win, but say so once
            if path not in _COST_WARNED_PATHS:
                _COST_WARNED_PATHS.add(path)
                warnings.warn(
                    f"REPRO_COST_CONSTANTS file {path!r} could not be used "
                    f"({type(exc).__name__}: {exc}); falling back to the "
                    f"default cost constants", UserWarning, stacklevel=2)
    if apply:
        COST_CONSTANTS.clear()
        COST_CONSTANTS.update(merged)
    return merged


#: paths already warned about by :func:`load_cost_constants` (one
#: warning per bad file per process, however often the router reloads)
_COST_WARNED_PATHS: set = set()


if os.environ.get("REPRO_COST_CONSTANTS"):
    load_cost_constants()


def _accelerator_present() -> bool:
    """True when jax reports a non-CPU default backend. Cached; only
    called once the probe floor passed, so the jax import it forces is
    already amortized by the sweep."""
    global _ACCEL_PRESENT
    if _ACCEL_PRESENT is None:
        try:
            import jax
            _ACCEL_PRESENT = jax.default_backend() != "cpu"
        except Exception:          # pragma: no cover - jax always present
            _ACCEL_PRESENT = False
    return _ACCEL_PRESENT


_ACCEL_PRESENT = None


def _device_count() -> int:
    """Local jax device count (the sharded sweep's mesh size). Cached
    like :func:`_accelerator_present` — only consulted once a sweep is
    big enough that the jax import is already amortized."""
    global _DEVICE_COUNT
    if _DEVICE_COUNT is None:
        try:
            import jax
            _DEVICE_COUNT = jax.local_device_count()
        except Exception:           # pragma: no cover - jax always present
            _DEVICE_COUNT = 1
    return _DEVICE_COUNT


_DEVICE_COUNT = None


def estimate_backend_seconds(backend: str, strategy: "AggregationStrategy",
                             model, S: int, K: int, n: int,
                             accelerator: bool = False,
                             devices: Optional[int] = None) -> float:
    """Estimated wall-clock seconds for one timing-only grid point.

    ``backend`` is ``"serial"``, ``"vectorized"`` or ``"jax"``;
    ``strategy`` must be bound. The estimate is engine-aware:

    * serial — the event loop pays :data:`COST_CONSTANTS` ``heap_event``
      per pop (K pops for Async, ``~K * (1 + sqrt(n/(max_delay+1)))``
      for Ringmaster's discard storms, ``K * batch`` for Rennala,
      ``>= K * n`` for Malenia), except timing-only m-sync, which runs
      the round-vectorized fast path at ``np_elem`` per S*K*n element.
    * vectorized — ``vec_elem`` per element (m-sync timing only).
    * jax round scans (m-sync / Rennala / Malenia / Ringleader) —
      ``jax_elem`` per scanned element plus one ``jit_compile`` for the
      closure-compiled programs (the FixedTimes timing m-sync program
      is module-cached: no compile term). Ringleader prices its single
      global chain tensor plus the round scan at ``2 * work``.
    * jax arrival scan (Async / Ringmaster / OptimalASGD) — ``pool_elem`` per
      renewal-chain pool element (the same pool the engine would draw,
      via :func:`repro.core.batch_jax.arrival_scan_work`) plus
      ``scan_step`` per window arrival when a scan is needed
      (Ringmaster; timing-only Async is sort-and-slice). These programs
      are jit-cached by shape, so no per-call compile term.

    ``accelerator=True`` divides the jax COMPUTE terms by
    ``accel_speedup`` (compile is host-bound and stays). Host engines
    never get the discount — they run on the CPU regardless.

    ``backend="jax_sharded"`` prices the sharded sweep of THIS point's
    units (its S seeds) on ``devices`` devices (default: the local
    device count): jax compute terms divide by ``min(devices, S)``,
    compile does not — it is host-bound and paid once per shape bucket,
    and the per-point estimate conservatively charges it in full (the
    sweep layer's cross-point fusion can only make reality cheaper).
    """
    C = COST_CONSTANTS
    kind = _engine_kind(strategy)
    if kind is None:
        raise ValueError(
            f"no cost model for {getattr(strategy, 'name', strategy)!r}: "
            f"only strategies with a jax engine classification are "
            f"priced (event-loop-only strategies never route)")
    work = float(S) * float(K) * float(n)
    if backend == "vectorized":
        return work * C["vec_elem"]
    if backend == "serial":
        if kind == "msync":
            return work * C["np_elem"]
        if kind == "async":
            events = float(K)
        elif kind in ("ringmaster", "optimal_asgd"):
            md = int(getattr(strategy, "max_delay", 1))
            events = K * (1.0 + float(np.sqrt(n / (md + 1.0))))
        elif kind == "rennala":
            events = float(K) * max(int(getattr(strategy, "batch", 1)), 1)
        else:           # malenia/ringleader: every worker >= 1 per round
            events = float(K) * n
        return S * events * C["heap_event"]
    if backend not in ("jax", "jax_sharded"):
        raise ValueError(f"no cost model for backend {backend!r}")
    shard = 1.0
    if backend == "jax_sharded":
        from ..launch.sweep import SHARDED_KINDS
        if kind in SHARDED_KINDS:
            D = _device_count() if devices is None else int(devices)
            shard = float(max(min(D, S), 1))
    accel = C["accel_speedup"] if accelerator else 1.0
    if kind in ("async", "ringmaster", "optimal_asgd"):
        from .batch_jax import arrival_scan_work
        ring = kind in ("ringmaster", "optimal_asgd")
        md = int(getattr(strategy, "max_delay", 0)) if ring else 0
        pool, window = arrival_scan_work(model, n, K, ringmaster=ring,
                                         max_delay=md)
        cost = S * pool * C["pool_elem"]
        if ring:
            cost += window * C["scan_step"] * (S / 32.0)
        return cost / accel / shard  # jit-cached: no compile term
    if kind == "rennala":
        elems = work * max(int(getattr(strategy, "batch", 1)), 1)
    elif kind == "malenia":
        elems = work * 2.0 * max(float(getattr(strategy, "S", 1.0)), 1.0)
    elif kind == "ringleader":      # one global chain, round scan over it
        elems = work * 2.0
    else:
        elems = work
    elem_c = C["jax_elem"] if kind == "msync" else C["round_elem"]
    cost = elems * elem_c / accel / shard
    fixed_timing_cached = kind == "msync" and isinstance(model, FixedTimes)
    if backend == "jax_sharded" or not fixed_timing_cached:
        cost += C["jit_compile"]    # closure-/AOT-compiled per call
    return cost


def _engine_kind(strategy) -> Optional[str]:
    """Which jax engine family would run ``strategy`` (None: event-loop
    only). Pure classification — no jax import."""
    from .batch_jax import _classify
    return _classify(strategy)

StrategySpec = Union[str, AggregationStrategy,
                     "tuple[str, Dict[str, Any]]", Callable[..., Any]]


@dataclasses.dataclass
class TraceBatch:
    """Traces of ``G`` grid points × ``S`` seeds plus cross-seed reducers.

    ``traces[g][s]`` is the full per-run :class:`Trace` (timing-only
    backends leave the recorded arrays empty, exactly like the scalar fast
    path). Scalar per-run fields are exposed as ``(G, S)`` arrays through
    :meth:`stat`, and :meth:`summary` produces the mean ± std rows the
    benchmark layer reports.
    """

    strategy: str                      # display name of the swept strategy
    grid: List[Dict[str, Any]]         # one kwargs dict per grid point
    seeds: np.ndarray                  # (S,) seeds, in run order
    traces: List[List[Trace]]          # [G][S]
    backend: str                       # backend that actually ran
    rng_scheme: str = "counter"        # EFFECTIVE draw contract of the
    #                                    run: the requested scheme for
    #                                    the vectorized engine, "stream"
    #                                    for serial (per-seed parity by
    #                                    construction), "jax.random" for
    #                                    the jax backend
    routing: Optional[List[Dict[str, Any]]] = None
    #                                    one record per grid point: the
    #                                    chosen backend plus, for
    #                                    backend="fastest", the cost-model
    #                                    estimates/reason (see
    #                                    _route_fastest); explicit backends
    #                                    record {"chosen": ..., "forced":
    #                                    True}. Surfaced in run_experiment
    #                                    JSON meta.

    # ------------------------------------------------------------ arrays
    def stat(self, field: str) -> np.ndarray:
        """``(G, S)`` array of a scalar Trace field/property."""
        return np.array([[getattr(tr, field) for tr in row]
                         for row in self.traces], dtype=float)

    @property
    def total_time(self) -> np.ndarray:
        return self.stat("total_time")

    def time_to_target(self, frac: float = 0.25) -> np.ndarray:
        """``(G, S)`` wall-clock time at which ``||∇f||²`` first drops to
        ``frac`` × its initial recorded value (``inf`` if never; ``nan``
        for timing-only traces)."""
        out = np.full((len(self.traces), len(self.seeds)), np.nan)
        for g, row in enumerate(self.traces):
            for s, tr in enumerate(row):
                if len(tr.grad_norms) == 0:
                    continue
                tgt = frac * tr.grad_norms[0]
                hit = np.flatnonzero(tr.grad_norms <= tgt)
                out[g, s] = tr.times[hit[0]] if hit.size else np.inf
        return out

    # ----------------------------------------------------------- summary
    def summary(self, target_frac: Optional[float] = None,
                quantiles: Sequence[float] = (0.1, 0.5, 0.9)) -> List[dict]:
        """One dict per grid point: mean ± std across seeds of total time,
        seconds per useful gradient and discard fraction, plus
        time-to-target quantiles when ``target_frac`` is given."""
        tt = self.total_time
        used = np.maximum(self.stat("gradients_used"), 1.0)
        per_grad = tt / used
        disc = self.stat("discard_fraction")
        rows = []
        for g, params in enumerate(self.grid):
            row = {
                "strategy": self.strategy,
                "params": dict(params),
                "seeds": len(self.seeds),
                "backend": self.backend,
                "rng_scheme": self.rng_scheme,
                "total_time_mean": float(tt[g].mean()),
                "total_time_std": float(tt[g].std()),
                "s_per_useful_grad_mean": float(per_grad[g].mean()),
                "s_per_useful_grad_std": float(per_grad[g].std()),
                "discard_fraction_mean": float(disc[g].mean()),
                "iterations_mean": float(self.stat("iterations")[g].mean()),
            }
            if target_frac is not None:
                t2t = self.time_to_target(target_frac)[g]
                finite = t2t[np.isfinite(t2t)]
                row["time_to_target_frac"] = target_frac
                row["time_to_target_hit_rate"] = (
                    float(np.mean(np.isfinite(t2t))) if len(t2t) else 0.0)
                for q in quantiles:
                    row[f"time_to_target_q{int(round(q * 100))}"] = (
                        float(np.quantile(finite, q)) if finite.size
                        else float("inf"))
            rows.append(row)
        return rows


# ---------------------------------------------------------------------------
# strategy specs and grids
# ---------------------------------------------------------------------------

def _as_spec(strategy: StrategySpec):
    """Normalize to ``(display_name, factory(**kw), base_kwargs)``."""
    if isinstance(strategy, str):
        if strategy not in STRATEGIES:
            make_strategy(strategy)    # raises KeyError with known names
        return strategy, STRATEGIES[strategy], {}
    if isinstance(strategy, tuple):
        name, kw = strategy
        make_strategy(name, **kw)      # validate early, with a clear error
        return name, STRATEGIES[name], dict(kw)
    if isinstance(strategy, AggregationStrategy):
        inst = strategy

        def factory(**kw):
            if kw:
                raise ValueError(
                    "grid sweeps over strategy parameters need a re-"
                    "instantiable spec — pass a name or (name, kwargs), "
                    f"not the instance {inst.name!r}")
            return inst
        return inst.name, factory, {}
    if callable(strategy):
        return getattr(strategy, "name", getattr(strategy, "__name__",
                                                 "strategy")), strategy, {}
    raise TypeError(f"bad strategy spec: {strategy!r}")


def _grid_points(grid: Optional[Mapping[str, Sequence]]) -> List[Dict]:
    if not grid:
        return [{}]
    keys = list(grid)
    return [dict(zip(keys, combo))
            for combo in itertools.product(*(grid[k] for k in keys))]


def _vectorized_eligible(strategy: AggregationStrategy, model,
                         problem, K: int, tol_grad_sq) -> bool:
    """Mirror of the scalar fast-path guard in :func:`simulate`."""
    return (problem is None and tol_grad_sq is None
            and not strategy.uses_alarm
            and isinstance(strategy, MSync)
            and type(strategy).on_arrival is MSync.on_arrival
            and type(strategy).on_step is AggregationStrategy.on_step
            and K > 0)


def _is_jax_problem(problem) -> bool:
    if problem is None:
        return False
    from .batch_jax import JaxProblem        # deferred import
    return isinstance(problem, JaxProblem)


def _route_fastest(strat: AggregationStrategy, model, problem, K_pt: int,
                   S: int, rng_scheme: str, tol_pt) -> "tuple[str, Dict]":
    """The ``backend="fastest"`` router: pick the cheapest *eligible*
    engine for one grid point and say why.

    Hard rules first (executability and contracts beat estimates):
    a :class:`~repro.core.batch_jax.JaxProblem` runs on jax or raises;
    deterministic universal m-sync timing replicates one scalar run on
    ``vectorized`` (nothing for a device sweep to win); an explicit
    ``rng_scheme="stream"`` request on a sampled model is a parity
    contract jax cannot honor; ``tol_grad_sq`` early exit needs the
    event loop. Sweeps below the :data:`JAX_MIN_WORK` probe floor stay
    on the host engines with no probe or estimate (jit compilation
    dominates any jax engine there). Everything else is decided by
    comparing :func:`estimate_backend_seconds` for the host engine vs
    the jax engine, with the accelerator probe folded in.

    Returns ``(chosen, info)`` where ``info`` is the routing record
    stored per grid point in :class:`TraceBatch.routing`.
    """
    n = model.n
    kind = _engine_kind(strat)
    vec_ok = _vectorized_eligible(strat, model, problem, K_pt, tol_pt)
    host = "vectorized" if vec_ok else "serial"
    info: Dict[str, Any] = {"engine": kind or "event-loop",
                            "work": int(S) * int(K_pt) * int(n)}

    def pick(chosen, reason):
        info.update(chosen=chosen, reason=reason)
        return chosen, info

    if _is_jax_problem(problem):
        from .batch_jax import _check_supported, jax_supported
        if tol_pt is None and K_pt > 0 and jax_supported(strat, model,
                                                         problem):
            devices = _device_count()
            from ..launch.sweep import SHARDED_KINDS
            if (devices > 1 and kind in SHARDED_KINDS
                    and info["work"] / devices >= JAX_MIN_WORK):
                accel = _accelerator_present()
                est = {"jax": estimate_backend_seconds(
                           "jax", strat, model, S, K_pt, n,
                           accelerator=accel),
                       "jax_sharded": estimate_backend_seconds(
                           "jax_sharded", strat, model, S, K_pt, n,
                           accelerator=accel, devices=devices)}
                info["est_seconds"] = {k: round(v, 6)
                                       for k, v in est.items()}
                info["devices"] = devices
                info["accelerator"] = accel
                return pick(min(est, key=est.get),
                            "jax-problem: only a jax engine can run it")
            return pick("jax", "jax-problem: only jax can execute it")
        # raise the precise unsupported-combination error instead of
        # letting the serial engine crash inside the jax oracle
        _check_supported(strat, model, problem)
        raise NotImplementedError(
            "JaxProblem sweeps run on the jax backend only, which does "
            "not support tol_grad_sq early exit or K <= 0; use a NumPy "
            "Problem with backend='serial'")
    if isinstance(model, UniversalModel) and vec_ok:
        # deterministic universal m-sync timing replicates ONE scalar
        # run across seeds — no sweep for a device engine to win
        return pick("vectorized", "deterministic-replication")
    if tol_pt is not None or K_pt <= 0:
        return pick(host, "tol-early-exit needs the event loop")
    if kind is None:
        return pick(host, "no jax engine for this strategy")
    if (rng_scheme == "stream"
            and not isinstance(model, (FixedTimes, UniversalModel))):
        return pick(host, "stream-parity contract excludes jax")
    if info["work"] < JAX_MIN_WORK:
        return pick(host, "below the JAX_MIN_WORK probe floor")
    from .batch_jax import jax_supported
    if not jax_supported(strat, model, problem):
        return pick(host, "model/oracle unsupported by the jax engines")
    accel = _accelerator_present()
    est = {host: estimate_backend_seconds(host, strat, model, S, K_pt, n),
           "jax": estimate_backend_seconds("jax", strat, model, S, K_pt, n,
                                           accelerator=accel)}
    devices = _device_count()
    from ..launch.sweep import SHARDED_KINDS
    if (devices > 1 and kind in SHARDED_KINDS
            and info["work"] / devices >= JAX_MIN_WORK):
        # sharded sweep: only with real devices to spread over AND
        # enough per-device work to clear the same probe floor
        est["jax_sharded"] = estimate_backend_seconds(
            "jax_sharded", strat, model, S, K_pt, n, accelerator=accel,
            devices=devices)
        info["devices"] = devices
    info["est_seconds"] = {k: round(v, 6) for k, v in est.items()}
    info["accelerator"] = accel
    chosen = min(est, key=est.get)
    return pick(chosen, "cost-model")


def _jax_eligible(strategy: AggregationStrategy, model, problem,
                  tol_grad_sq, K: int, S: int) -> bool:
    """DEPRECATED shim (PR 3/4 signature): True when ``fastest`` would
    route this combination to jax. Routing decisions now come from
    :func:`_route_fastest` / :func:`estimate_backend_seconds`."""
    try:
        chosen, _ = _route_fastest(strategy, model, problem, K, S,
                                   "counter", tol_grad_sq)
    except NotImplementedError:
        return False
    return chosen == "jax"


# ---------------------------------------------------------------------------
# the degradation ladder: engine execution failures downgrade, not raise
# ---------------------------------------------------------------------------

#: Downgrade order for engine *execution* failures (contract errors —
#: unsupported strategy/model combos on a forced backend — still raise
#: at validation time, before any engine runs). A failing engine is
#: retried once, then the point falls to the next rung that can run it;
#: every hop is recorded in the point's routing entry
#: (``routing[g]["downgrades"]``). Only when the last rung fails does
#: the exception propagate.
ENGINE_LADDER = ("jax_sharded", "jax", "vectorized", "serial")


def _ladder_below(chosen: str, strat, model, problem, K_pt: int,
                  tol_pt) -> List[str]:
    """Engines below ``chosen`` on the ladder able to run this point."""
    if chosen not in ENGINE_LADDER:
        return []
    out = []
    for eng in ENGINE_LADDER[ENGINE_LADDER.index(chosen) + 1:]:
        if eng == "jax":
            from .batch_jax import jax_supported
            if tol_pt is not None \
                    or not jax_supported(strat, model, problem):
                continue
        elif eng == "vectorized":
            if not _vectorized_eligible(strat, model, problem, K_pt,
                                        tol_pt):
                continue
        out.append(eng)
    return out


def _run_point_laddered(chosen: str, run_engine: Callable[[str], Any],
                        downgrade_to: Sequence[str],
                        route_info: Dict[str, Any]):
    """Run one grid point with retry-once-then-downgrade semantics.

    Returns ``(engine_that_ran, row)``. ``run_engine`` must be
    stateless per call (every engine rebuilds its RNG state from the
    seed list), so a retry reproduces the attempt exactly.
    """
    rungs = [chosen] + [e for e in downgrade_to if e != chosen]
    for pos, engine in enumerate(rungs):
        try:
            return engine, run_engine(engine)
        except Exception:
            try:
                return engine, run_engine(engine)      # retry once
            except Exception as exc:
                nxt = rungs[pos + 1] if pos + 1 < len(rungs) else None
                route_info.setdefault("downgrades", []).append({
                    "from": engine, "to": nxt,
                    "error": type(exc).__name__,
                    "reason": str(exc)[:300], "retried": True})
                if nxt is None:
                    raise
    raise AssertionError("unreachable")    # pragma: no cover


# ---------------------------------------------------------------------------
# the batched driver
# ---------------------------------------------------------------------------

def simulate_batch(strategy: StrategySpec,
                   model: Union[TimeModel, UniversalModel],
                   K: int,
                   problem=None,
                   gamma: float = 0.0,
                   seeds: Union[int, Sequence[int]] = 8,
                   grid: Optional[Mapping[str, Sequence]] = None,
                   record_every: int = 1,
                   tol_grad_sq: Optional[float] = None,
                   backend: str = "auto",
                   rng_scheme: str = "counter",
                   use_pallas: bool = False,
                   x64: bool = False) -> TraceBatch:
    """Run ``strategy`` under ``model`` across ``seeds`` × ``grid``.

    ``seeds`` is an int (→ ``range(seeds)``) or an explicit sequence.
    With ``seeds=[s]``, the default backends and ``rng_scheme="stream"``
    the result reproduces scalar ``simulate(..., seed=s)``
    trace-for-trace; the default ``rng_scheme="counter"`` draws random
    models from per-seed Philox counter streams instead — equal in
    distribution, much faster for sweeps, and independent of which other
    seeds are in the sweep. ``rng_scheme`` only affects the
    ``vectorized`` backend (``serial`` always consumes the scalar
    streams; ``jax`` always draws with ``jax.random`` — per-seed
    reproducible and sweep-independent like ``counter``, stream-equal
    to nothing). ``backend="jax"`` covers every registered paper
    strategy (m-sync family, rennala, malenia, async, ringmaster) under
    every time-model class, timing-only or with a
    :class:`~repro.core.batch_jax.JaxProblem`; ``deadline``/``dropout``
    and NumPy oracles stay on the host engines. ``x64=True`` runs the
    jax backend in float64 — slower, but gives per-run tie parity with
    the float64 NumPy event heap on adversarially tie-heavy instances
    (flat-power partial participation) where float32 tie-breaking
    diverges by whole events; the NumPy engines are always float64, so
    the flag only affects grid points that run on jax. See the module
    docstring for backend and grid semantics.
    """
    seed_list = list(range(seeds)) if isinstance(seeds, (int, np.integer)) \
        else [int(s) for s in seeds]
    if not seed_list:
        raise ValueError("need at least one seed")
    if backend not in ("auto", "fastest", "serial", "vectorized", "jax",
                       "jax_sharded"):
        raise ValueError(f"unknown backend {backend!r}")
    if rng_scheme not in ("counter", "stream"):
        raise ValueError(f"unknown rng_scheme {rng_scheme!r}; "
                         "use 'counter' or 'stream'")
    name, factory, base_kw = _as_spec(strategy)
    points = _grid_points(grid)

    traces: List[List[Trace]] = []
    used_backends = []
    used_schemes = []
    used_routing: List[Dict[str, Any]] = []
    sharded_points = []        # (grid index, SweepPoint) → one fused sweep
    for pt in points:
        sim_kw = {k: pt[k] for k in pt if k in SIM_GRID_KEYS}
        strat_kw = {**base_kw, **{k: v for k, v in pt.items()
                                  if k not in SIM_GRID_KEYS}}
        K_pt = int(sim_kw.pop("K", K))
        gamma_pt = float(sim_kw.pop("gamma", gamma))
        re_pt = int(sim_kw.pop("record_every", record_every))
        tol_pt = sim_kw.pop("tol_grad_sq", tol_grad_sq)

        strat = factory(**strat_kw)
        if isinstance(strat, str):     # factory returned a registry name
            strat = make_strategy(strat)
        strat.bind(model.n)

        if backend == "auto":
            chosen = "vectorized" if _vectorized_eligible(
                strat, model, problem, K_pt, tol_pt) else "serial"
            route_info = {"chosen": chosen, "forced": False,
                          "reason": "auto: vectorized when eligible",
                          "engine": _engine_kind(strat) or "event-loop"}
        elif backend == "fastest":
            chosen, route_info = _route_fastest(strat, model, problem,
                                                K_pt, len(seed_list),
                                                rng_scheme, tol_pt)
        else:
            chosen = backend
            route_info = {"chosen": chosen, "forced": True,
                          "engine": _engine_kind(strat) or "event-loop"}
        # contract errors on a forced/chosen backend raise up front, so
        # the ladder below only ever sees *execution* failures
        if chosen == "vectorized" and not _vectorized_eligible(
                strat, model, problem, K_pt, tol_pt):
            raise ValueError(
                "vectorized backend needs timing-only m-sync arrival "
                "semantics")
        if chosen in ("jax", "jax_sharded"):
            if tol_pt is not None:
                raise NotImplementedError(
                    "tol_grad_sq early exit is not supported by the jax "
                    "backends (fixed-length scan); use backend='serial'")
            from .batch_jax import _check_supported
            _check_supported(strat, model, problem)

        def run_engine(engine, strat=strat, strat_kw=dict(strat_kw),
                       K_pt=K_pt, gamma_pt=gamma_pt, re_pt=re_pt,
                       tol_pt=tol_pt):
            if engine == "vectorized":
                if rng_scheme == "counter" \
                        and not isinstance(model, UniversalModel):
                    rngs = philox_rngs(seed_list)
                else:
                    rngs = [np.random.default_rng(s) for s in seed_list]
                return _fast_msync_timing_batch(strat._m, model, K_pt,
                                                rngs,
                                                rng_scheme=rng_scheme)
            if engine == "jax":
                from .batch_jax import simulate_batch_jax
                return simulate_batch_jax(strat, model, K_pt,
                                          problem=problem, gamma=gamma_pt,
                                          seeds=seed_list,
                                          record_every=re_pt,
                                          use_pallas=use_pallas, x64=x64)
            return [simulate(factory(**strat_kw), model, K_pt,
                             problem=problem, gamma=gamma_pt, seed=s,
                             record_every=re_pt, tol_grad_sq=tol_pt)
                    for s in seed_list]

        if chosen == "jax_sharded":
            from ..launch.sweep import SweepPoint
            sharded_points.append(
                (len(traces), SweepPoint(index=len(traces), strategy=strat,
                                         K=K_pt, gamma=gamma_pt,
                                         record_every=re_pt),
                 run_engine, strat, K_pt, tol_pt))
            row = None             # filled by the fused sweep below
            actual = chosen
        else:
            downs = _ladder_below(chosen, strat, model, problem, K_pt,
                                  tol_pt)
            actual, row = _run_point_laddered(chosen, run_engine, downs,
                                              route_info)
        traces.append(row)
        used_backends.append(actual)
        used_schemes.append({"serial": "stream", "jax": "jax.random",
                             "jax_sharded": "jax.random"
                             }.get(actual, rng_scheme))
        used_routing.append(route_info)

    if sharded_points:
        # ONE fused, shape-bucketed, shard_mapped launch for every grid
        # point routed to the sharded sweep backend (retry-once, then
        # each deferred point falls down the ladder from "jax")
        from ..launch.sweep import run_sharded_sweep
        results = fused_exc = None
        for _attempt in range(2):
            try:
                results = run_sharded_sweep(
                    [sp for _, sp, *_ in sharded_points], model, problem,
                    seed_list, use_pallas=use_pallas, x64=x64)
                break
            except Exception as exc:
                fused_exc = exc
        if results is not None:
            for g, *_ in sharded_points:
                row, shard_rec = results[g]
                traces[g] = row
                used_routing[g] = {**used_routing[g], "shard": shard_rec}
        else:
            for g, _sp, run_engine, strat, K_pt, tol_pt in sharded_points:
                route_info = used_routing[g]
                route_info.setdefault("downgrades", []).append({
                    "from": "jax_sharded", "to": "jax",
                    "error": type(fused_exc).__name__,
                    "reason": str(fused_exc)[:300], "retried": True})
                downs = _ladder_below("jax", strat, model, problem, K_pt,
                                      tol_pt)
                actual, row = _run_point_laddered("jax", run_engine,
                                                  downs, route_info)
                traces[g] = row
                used_backends[g] = actual
                used_schemes[g] = {"serial": "stream",
                                   "jax": "jax.random"}.get(actual,
                                                            rng_scheme)

    # auto can pick different backends per grid point; report faithfully
    backend_label = used_backends[0] if len(set(used_backends)) == 1 \
        else "+".join(sorted(set(used_backends)))
    scheme_label = used_schemes[0] if len(set(used_schemes)) == 1 \
        else "+".join(sorted(set(used_schemes)))
    return TraceBatch(strategy=name, grid=points,
                      seeds=np.asarray(seed_list), traces=traces,
                      backend=backend_label, rng_scheme=scheme_label,
                      routing=used_routing)
