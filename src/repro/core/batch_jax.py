"""JAX backend for :func:`repro.core.batch.simulate_batch`.

Runs device-resident simulation as ONE array program over a
``(seeds, workers)`` state batch, one jitted recursion per strategy
family:

* **m-sync family** — a ``lax.scan`` over rounds whose body is pure
  elementwise work plus the per-round m-th order statistic from
  :mod:`repro.kernels.order_stats` (iterative tie-class extraction for
  small ``m``, counting-bisection selection for large ``m``, optionally
  the Pallas top-m partial-sort kernel via ``use_pallas=True``).
* **Rennala** — the same renewal structure, per round accumulating
  ``batch`` arrivals: each worker's within-round arrivals form a renewal
  chain (successive finish times), the round ends at the ``batch``-th
  smallest chain entry, and every worker's next pending computation is
  its first chain entry past the round end.
* **Malenia** — the renewal-chain scan generalized to a *per-worker
  count predicate*: the round ends at the first arrival time ``T`` at
  which every worker has delivered at least one fresh gradient AND the
  harmonic mean ``n / sum_i 1/B_i(T)`` of the per-worker counts reaches
  the strategy's ``S`` (the paper's §6 heterogeneous batching rule,
  preserved exactly). ``T`` is found by a monotone counting bisection
  over the chain pool plus an exact snap-to-arrival step; boundary ties
  are consumed one arrival at a time (worker-major) so the predicate
  first becomes true exactly as in the event engine.
* **Async / Ringmaster** — a renewal-chain **arrival scan**: because a
  popped worker always restarts immediately (accept or discard), its
  arrival times form a renewal chain independent of the server state, so
  the engine pre-draws every worker's chain in bulk
  (:func:`~repro.core.time_models.jax_chain_draws` — prefix-stable
  ``fold_in``-keyed duration rows, auto-sized ``L`` with doubling
  retries), merges the ``(S, n*L)`` pool into global arrival order ONCE
  (:func:`~repro.kernels.order_stats.smallest_k` — host stable argsort
  on CPU, device sort on accelerators), and runs ONE ``lax.scan`` over
  the ordered arrival window with O(1) per-arrival state transitions
  (worker id gather, Ringmaster delay test, version/snapshot scatter).
  Timing-only Async needs no scan at all: the first ``K`` merged
  arrivals ARE the steps. This replaces the PR 4 arrival-indexed
  ``lax.while_loop`` (kept as :func:`_arrival_while_run`, a
  benchmark/cross-check reference only), whose O(S·n) argmin per arrival
  and K serialized iterations made async the slowest device path.
  Per-worker start-iterate snapshots make the delayed-gradient math path
  exact. **OptimalASGD** (the Maranjyan bounded-staleness rule with the
  ``n``-scaled delay threshold and delay-adaptive stepsize) is the same
  recursion with its own ``max_delay`` and the adaptive multiplier — no
  new program, just routing.
* **Ringleader** — a round-indexed ``lax.scan`` over ONE global renewal
  chain per worker: Ringleader never idles and never discards, so each
  worker's arrival times are a pure renewal process from ``t = 0`` and
  the whole run consumes a single prefix-stable ``(S, n, L)`` chain
  tensor. Round ``k`` ends at ``T_k = max_i`` (worker ``i``'s first
  chain entry past ``T_{k-1}``) — the waste-free "everyone contributed"
  predicate — and the serial engine's version bookkeeping bounds
  staleness by one round, so the math path carries only
  ``x^{k-1}``/``x^k`` plus the previous round's triggering worker.

Time models: :class:`FixedTimes` (no RNG), any
:class:`~repro.core.time_models.SubExponentialTimes` carrying a
``jax_sampler`` (every in-tree factory does; the keyed Async path also
prefers ``jax_sampler_item``), and :class:`UniversalModel` /
:class:`PartialParticipationModel` via the deterministic
``finish_times_jax`` inversion (batched ``searchsorted`` on the
cumulative-power grid + closed-form quadratic segment solve) — every
strategy family above accepts all three classes, so the full paper
coverage matrix (DESIGN.md §3b) runs device-resident. Fault-wrapped
models (:class:`repro.core.faults.FaultyTimes`, DESIGN §3c) ARE
``SubExponentialTimes`` whose samplers compose the base draw with
fault noise on disjoint ``fold_in`` streams, so they ride this whole
sampled-model path — including ``jax_chain_draws`` renewal rows and
the sharded sweep — with no engine changes; an identity wrapper passes
the base samplers through by object identity and shares their jit
caches (bitwise no-op).

The math-carrying paths evaluate a :class:`JaxProblem` oracle under
``jax.vmap`` over seeds — n=1000 × 32-seed sweeps execute as a single
jitted program instead of 32 serial event loops (~6x over the serial
fast path on CPU here, far more on real accelerators).

Exactness contract (documented in DESIGN.md): the NumPy engines break
wall-clock ties by exact event-heap sequence numbers; this backend breaks
them by worker index (and within-worker arrival index for the renewal
chains) and draws with ``jax.random`` instead of NumPy ``Generator``
streams. For deterministic models in generic position the recursions are
identical and results match the NumPy backends to float tolerance; for
random models the results are equal in distribution, not per-seed.
"""

from __future__ import annotations

import dataclasses
import math as _math
from typing import Callable, List, Optional, Sequence

import numpy as np

from .strategies import (AggregationStrategy, Async, Malenia, MSync,
                         OptimalASGD, Rennala, Ringleader, Ringmaster,
                         Trace)
from .time_models import FixedTimes, SubExponentialTimes, UniversalModel

__all__ = ["JaxProblem", "quadratic_worst_case_jax", "simulate_batch_jax",
           "jax_supported", "arrival_scan_work"]

# Malenia round-end search: value-bisection passes over the chain pool,
# then snap-to-arrival passes (each consumes >= 1 tie class; more than a
# couple after the bisection is pathological and flags the run)
_MAL_BISECT_ITERS = 48
_MAL_SNAP_ITERS = 32


@dataclasses.dataclass
class JaxProblem:
    """A :class:`~repro.core.strategies.Problem` twin with JAX callables.

    ``stoch_grad(x, key)`` replaces the NumPy oracle's
    ``stoch_grad(x, rng)`` so gradient noise comes from ``jax.random``
    and the whole seed sweep stays inside one jitted program. Backend
    contract: a ``JaxProblem`` runs on ``backend="jax"`` ONLY (the NumPy
    engines cannot execute it, and ``backend="fastest"`` therefore
    always routes it to jax). RNG contract: oracle noise keys derive
    from ``jax.random.PRNGKey(seed)`` splits — reproducible per seed
    value, never stream-equal to any NumPy ``Generator`` path. All three
    callables must be jit-traceable; ``f``/``grad`` are the recording
    oracle only (never differentiated through by the engine).
    """

    x0: "np.ndarray"
    f: Callable
    grad: Callable
    stoch_grad: Callable


def quadratic_worst_case_jax(d: int = 1000, p: float = 0.1,
                             scale: float = 0.25) -> JaxProblem:
    """JAX twin of :func:`repro.core.oracle.quadratic_worst_case` —
    same tridiagonal quadratic, same eq. (27) progress-gated Bernoulli
    oracle, with ``jax.random`` noise."""
    import jax
    import jax.numpy as jnp

    main = 2.0 * scale * np.ones(d)
    off = -scale * np.ones(d - 1)
    b_np = np.zeros(d)
    b_np[0] = -scale
    A = np.diag(main) + np.diag(off, 1) + np.diag(off, -1)
    x_star = np.linalg.solve(A, b_np)
    f_star = float(0.5 * x_star @ (A @ x_star) - b_np @ x_star)

    b = jnp.asarray(b_np)
    sc = scale

    def matvec(x):
        y = 2.0 * sc * x
        y = y.at[:-1].add(-sc * x[1:])
        y = y.at[1:].add(-sc * x[:-1])
        return y

    def f(x):
        return 0.5 * x @ matvec(x) - b @ x - f_star

    def grad(x):
        return matvec(x) - b

    def stoch_grad(x, key):
        g = grad(x)
        nz = x != 0
        # prog(x) = max{i >= 1 : x_i != 0} (1-indexed), 0 if x == 0
        pr = jnp.max(jnp.where(nz, jnp.arange(1, d + 1), 0))
        xi = jax.random.bernoulli(key, p).astype(x.dtype)
        gate = jnp.where(jnp.arange(d) < pr, 1.0, xi / p)
        return g * gate

    x0 = np.zeros(d)
    x0[0] = np.sqrt(d)
    return JaxProblem(x0=x0, f=f, grad=grad, stoch_grad=stoch_grad)


def _classify(strategy: AggregationStrategy) -> Optional[str]:
    """Which jitted recursion runs ``strategy`` (None => unsupported)."""
    if (isinstance(strategy, MSync)
            and type(strategy).on_arrival is MSync.on_arrival
            and type(strategy).on_step is AggregationStrategy.on_step
            and not strategy.uses_alarm
            and strategy.grads_by_worker is None):
        return "msync"
    # exact types: subclasses may override semantics the scans hard-code
    if type(strategy) is Rennala:
        return "rennala"
    if type(strategy) is Malenia and strategy.grads_by_worker is None:
        return "malenia"
    if type(strategy) is Async:
        return "async"
    if type(strategy) is Ringmaster:
        return "ringmaster"
    if type(strategy) is OptimalASGD:
        return "optimal_asgd"
    if type(strategy) is Ringleader:
        return "ringleader"
    return None


def _model_supported(model) -> bool:
    return (isinstance(model, (FixedTimes, UniversalModel))
            or (isinstance(model, SubExponentialTimes)
                and getattr(model, "jax_sampler", None) is not None))


def jax_supported(strategy: AggregationStrategy, model, problem) -> bool:
    """Non-raising eligibility probe (``backend="fastest"`` uses this)."""
    return (_classify(strategy) is not None and _model_supported(model)
            and (problem is None or isinstance(problem, JaxProblem)))


def _check_supported(strategy: AggregationStrategy, model, problem) -> str:
    kind = _classify(strategy)
    if kind is None:
        raise NotImplementedError(
            f"jax backend supports the unmodified m-sync family, Rennala, "
            f"Malenia (homogeneous oracle), Async/Ringmaster and "
            f"Ringleader/OptimalASGD, not "
            f"{strategy.name!r}; use backend='serial'")
    if not _model_supported(model):
        raise NotImplementedError(
            f"jax backend needs FixedTimes, a UniversalModel, or a "
            f"SubExponentialTimes with a jax_sampler (got "
            f"{type(model).__name__}); use backend='serial' or "
            f"'vectorized'")
    if problem is not None and not isinstance(problem, JaxProblem):
        raise NotImplementedError(
            "jax backend takes a JaxProblem (jax.random oracle), not the "
            "NumPy Problem; use backend='serial' for NumPy oracles")
    return kind


def _timing_round(ft, ver, comp, k, cand, m, use_pallas):
    """Shared m-sync round update on ``(S, n)`` state (see module doc)."""
    import jax.numpy as jnp
    from jax import lax

    from ..kernels.order_stats import mth_smallest

    stale = ver < k
    T = mth_smallest(cand, m, use_pallas=use_pallas)
    leq = cand <= T[:, None]

    def exact_acc(_):
        # ties straddle the m-boundary somewhere: rank tied candidates by
        # worker index and accept only up to the per-row quota (cumsum is
        # ~40% of the round cost, so it only runs on tie rounds)
        c_lt = (cand < T[:, None]).sum(axis=1)
        tie = cand == T[:, None]
        tie_rank = jnp.cumsum(tie, axis=1) - 1
        return (cand < T[:, None]) | (tie
                                      & (tie_rank < (m - c_lt)[:, None]))

    acc = lax.cond(jnp.all(leq.sum(axis=1) == m),
                   lambda _: leq, exact_acc, operand=None)
    popped = stale & (ft < T[:, None])
    # int32 sums: under x64 bool sums default to int64 and would promote
    # the carried counters out of their scan-carry dtype
    comp = comp + m + popped.sum(axis=1, dtype=jnp.int32)
    ft = jnp.where(popped, cand, ft)
    ver = jnp.where(popped, k, ver)
    return ft, ver, comp, T, acc


def _timing_round_rowwise(ft, ver, comp, k, cand, m_vec):
    """:func:`_timing_round` with a TRACED per-row ``m`` — the sharded
    sweep backend fuses grid points with different ``m`` into one
    compiled program, so ``m`` arrives as a ``(rows,)`` int32 tensor.

    Bitwise parity with the static-``m`` round: the row-wise selection
    returns the same element value as :func:`mth_smallest`, and the
    tie fast path is output-equivalent by construction — when every
    row's ``<= T`` count equals its ``m``, the quota acceptance accepts
    exactly the ``leq`` mask, so whichever branch the (per-shard local)
    ``lax.cond`` takes, the accept mask is identical.
    """
    import jax.numpy as jnp
    from jax import lax

    from ..kernels.order_stats import mth_smallest_rowwise

    stale = ver < k
    T = mth_smallest_rowwise(cand, m_vec)
    leq = cand <= T[:, None]

    def exact_acc(_):
        c_lt = (cand < T[:, None]).sum(axis=1)
        tie = cand == T[:, None]
        tie_rank = jnp.cumsum(tie, axis=1) - 1
        return (cand < T[:, None]) | (tie
                                      & (tie_rank < (m_vec - c_lt)[:, None]))

    acc = lax.cond(jnp.all(leq.sum(axis=1) == m_vec),
                   lambda _: leq, exact_acc, operand=None)
    popped = stale & (ft < T[:, None])
    comp = comp + m_vec + popped.sum(axis=1, dtype=jnp.int32)
    ft = jnp.where(popped, cand, ft)
    ver = jnp.where(popped, k, ver)
    return ft, ver, comp, T, acc


def _fixed_timing_run(taus, S: int, m: int, K: int, use_pallas: bool):
    """Timing-only m-sync under FixedTimes: module-level jit, cached
    across calls (the benchmark-smoke hot path — no RNG at all)."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    n = taus.shape[0]

    def step(carry, k):
        ft, ver, comp = carry
        stale = ver < k
        cand = jnp.where(stale, ft + taus, ft)
        ft, ver, comp, T, acc = _timing_round(ft, ver, comp, k, cand, m,
                                              use_pallas)
        ft = jnp.where(acc, T[:, None] + taus, ft)
        ver = jnp.where(acc, k + 1, ver)
        return (ft, ver, comp), T

    init = (jnp.broadcast_to(taus, (S, n)), jnp.zeros((S, n), jnp.int32),
            jnp.zeros(S, jnp.int32))
    (_, _, comp), T = lax.scan(step, init, jnp.arange(K, dtype=jnp.int32))
    return comp, T


_fixed_timing_jit = None


def _engine_dtype():
    """float64 under the ``x64=True`` engine mode, float32 otherwise."""
    import jax
    import jax.numpy as jnp

    # The one traced-reachable site allowed to name both dtypes: this IS
    # the selector every engine derives its dtype from, and it reads the
    # x64 flag — so it cannot pin the wrong precision.
    return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32  # repcheck: ignore[JIT005]


def _keys_and_x(problem, S, n, seeds):
    """Per-seed PRNG keys and the broadcast initial iterate (``(S, 1)``
    zeros for timing-only runs)."""
    import jax
    import jax.numpy as jnp

    keys0 = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    if problem is not None:
        dt = _engine_dtype()
        x_init = jnp.broadcast_to(
            jnp.asarray(problem.x0, dtype=dt),
            (S,) + np.shape(problem.x0)).astype(dt)
    else:
        x_init = jnp.zeros((S, 1))
    return keys0, x_init


def _finish_factory(model, S, n):
    """``finish_all(round_keys, t0) -> (S, n)`` ABSOLUTE finish times of
    computations started at ``t0`` (scalar/broadcastable): duration draw
    plus start for sampled models, ``t0 + tau`` for FixedTimes, the
    deterministic ``finish_times_jax`` inversion for universal models
    (``round_keys`` unused by the draw-free cases)."""
    import jax
    import jax.numpy as jnp

    if isinstance(model, FixedTimes):
        taus = jnp.asarray(model.taus)

        def finish_all(round_keys, t0):           # no RNG consumed
            return jnp.broadcast_to(t0 + taus, (S, n))
    elif isinstance(model, UniversalModel):
        def finish_all(round_keys, t0):           # deterministic inversion
            return model.finish_times_jax(jnp.broadcast_to(t0, (S, n)))
    else:
        sampler = model.jax_sampler

        def finish_all(round_keys, t0):           # one (n,) draw per seed
            return t0 + jax.vmap(sampler)(round_keys)
    return finish_all


def _chain_factory(model, S, n):
    """``chain(round_keys, base, L) -> (S, n, L + 1)`` renewal chains:
    entry 0 is ``base`` (each worker's first fresh arrival), entry ``j``
    its ``j``-th subsequent arrival — cumulative duration draws for
    sampled models, ``base + j * tau`` for FixedTimes, iterated
    ``finish_times_jax`` for universal models."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    if isinstance(model, FixedTimes):
        taus = jnp.asarray(model.taus)

        def chain(round_keys, base, L):
            steps = taus[None, :, None] * jnp.arange(1, L + 1)
            return jnp.concatenate(
                [base[..., None], base[..., None] + steps], axis=-1)
    elif isinstance(model, UniversalModel):
        def chain(round_keys, base, L):
            def body(c, _):
                nxt = model.finish_times_jax(c)
                return nxt, nxt

            _, out = lax.scan(body, base, None, length=L)  # (L, S, n)
            return jnp.concatenate(
                [base[..., None], jnp.moveaxis(out, 0, -1)], axis=-1)
    else:
        sampler = model.jax_sampler

        def chain(round_keys, base, L):
            ks = jax.vmap(lambda k: jax.random.split(k, L))(round_keys)
            d = jax.vmap(jax.vmap(sampler))(ks)            # (S, L, n)
            return jnp.concatenate(
                [base[..., None],
                 base[..., None] + jnp.cumsum(jnp.moveaxis(d, 1, 2),
                                              axis=-1)], axis=-1)
    return chain


def _grad_mean_fn(problem, B):
    """vmap-over-seeds mean of ``B`` stochastic gradients at ``x``."""
    import jax

    def grad_mean(x, round_keys):
        gkeys = jax.vmap(lambda k: jax.random.split(k, B))(round_keys)
        per_seed = jax.vmap(jax.vmap(problem.stoch_grad, (None, 0)),
                            (0, 0))
        return per_seed(x, gkeys).mean(axis=1)

    return grad_mean


def _general_run(model, problem, m, n, S, K, gamma, use_pallas, seeds):
    """RNG-threading m-sync scan: random/universal time models and/or a
    JaxProblem oracle.

    Every seed's draw stream is a pure function of its ``PRNGKey(seed)``
    (a 4-way split of its own carried key per round). Closes over the
    sampler/oracle, so jit caching is per call.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax

    math = problem is not None
    keys0, x_init = _keys_and_x(problem, S, n, seeds)
    finish_all = _finish_factory(model, S, n)
    if math:
        grad_mean = _grad_mean_fn(problem, m)

    def step(carry, k):
        ft, ver, comp, x, keys = carry
        sub = jax.vmap(lambda kk: jax.random.split(kk, 4))(keys)
        keys = sub[:, 0]
        stale = ver < k
        cand = jnp.where(stale, finish_all(sub[:, 1], ft), ft)
        ft, ver, comp, T, acc = _timing_round(ft, ver, comp, k, cand, m,
                                              use_pallas)
        ft = jnp.where(acc, finish_all(sub[:, 2],
                                       jnp.broadcast_to(T[:, None],
                                                        (S, n))), ft)
        ver = jnp.where(acc, k + 1, ver)
        if math:
            x = x - gamma * grad_mean(x, sub[:, 3])
            val = jax.vmap(problem.f)(x)
            gn = jax.vmap(lambda xx: jnp.sum(problem.grad(xx) ** 2))(x)
        else:
            val = gn = jnp.zeros(S)
        return (ft, ver, comp, x, keys), (T, val, gn)

    @jax.jit
    def run(keys):
        sub = jax.vmap(lambda kk: jax.random.split(kk, 2))(keys)
        ft0 = finish_all(sub[:, 1], jnp.zeros((S, n)))
        init = (ft0, jnp.zeros((S, n), jnp.int32), jnp.zeros(S, jnp.int32),
                x_init, sub[:, 0])
        (_, _, comp, x, _), (T, val, gn) = lax.scan(
            step, init, jnp.arange(K, dtype=jnp.int32))
        return comp, x, T, val, gn

    return jax.block_until_ready(run(keys0))


class _ById:
    """Identity-keyed hashable wrapper: models/problems (unhashable
    dataclasses, closures over arrays) key the sweep program cache by
    object identity; the strong reference pins the id for the cache
    entry's lifetime."""

    __slots__ = ("obj",)

    def __init__(self, obj):
        self.obj = obj

    def __hash__(self):
        return id(self.obj)

    def __eq__(self, other):
        return isinstance(other, _ById) and other.obj is self.obj


#: AOT-compiled sharded sweep programs, FIFO like _CHAIN_PROGS/_SCAN_PROGS:
#: key = (family, static shape/params, mesh devices, model/problem ids).
_SWEEP_PROGS: dict = {}


def _mesh_cache_key(mesh):
    return tuple(d.id for d in mesh.devices.flat)


def sharded_msync_run(model, problem, n, S, K, seeds, m_list, gamma_list,
                      use_pallas, mesh, meta=None):
    """Fused + sharded m-sync family run over ``S = len(seeds)`` work
    units (one unit = one (grid point, seed) pair; the caller has
    already flattened and padded to a multiple of the mesh size).

    One compiled program covers every unit: timing-only units fuse
    heterogeneous ``m`` through the traced row-wise selection
    (:func:`_timing_round_rowwise`), math units fuse heterogeneous
    ``gamma`` as a traced per-unit stepsize vector (``m`` stays static
    for math — the oracle batch splits ``m`` ways). Per-unit draw
    streams are byte-for-byte the :func:`_general_run` streams (the
    same 4-way per-round key split of ``PRNGKey(seed)``), so each
    unit's outputs are bitwise identical to the unsharded
    ``backend="jax"`` run of its grid point. The program is
    ``shard_map``ped over the mesh's 1-D ``data`` axis and AOT-compiled
    (``lower().compile()``) so compile vs execute wall time and cache
    hits are observable; ``meta`` (if given) receives
    ``compile_s``/``exec_s``/``cache_hit``.

    ``use_pallas`` is accepted for signature symmetry but the row-wise
    counting selection always runs the fused elementwise path — the
    selected value is the same element either way.
    """
    import time

    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    math = problem is not None
    keys0, x_init = _keys_and_x(problem, S, n, seeds)
    m_static = int(m_list[0]) if math else None
    if math:
        grad_mean = _grad_mean_fn(problem, m_static)
    dt = _engine_dtype()
    m_in = jnp.asarray(m_list, jnp.int32)
    g_in = jnp.asarray(gamma_list, dt)

    def unit_prog(keys, m_vec, gamma_vec, x0):
        U = keys.shape[0]                     # local block under shard_map
        finish_all = _finish_factory(model, U, n)

        def step(carry, k):
            ft, ver, comp, x, kk = carry
            sub = jax.vmap(lambda q: jax.random.split(q, 4))(kk)
            kk = sub[:, 0]
            stale = ver < k
            cand = jnp.where(stale, finish_all(sub[:, 1], ft), ft)
            ft, ver, comp, T, acc = _timing_round_rowwise(ft, ver, comp, k,
                                                          cand, m_vec)
            ft = jnp.where(acc, finish_all(sub[:, 2],
                                           jnp.broadcast_to(T[:, None],
                                                            (U, n))), ft)
            ver = jnp.where(acc, k + 1, ver)
            if math:
                x = x - gamma_vec[:, None] * grad_mean(x, sub[:, 3])
                val = jax.vmap(problem.f)(x)
                gn = jax.vmap(lambda xx: jnp.sum(problem.grad(xx) ** 2))(x)
            else:
                val = gn = jnp.zeros(U)
            return (ft, ver, comp, x, kk), (T, val, gn)

        sub = jax.vmap(lambda q: jax.random.split(q, 2))(keys)
        ft0 = finish_all(sub[:, 1], jnp.zeros((U, n)))
        init = (ft0, jnp.zeros((U, n), jnp.int32), jnp.zeros(U, jnp.int32),
                x0, sub[:, 0])
        (_, _, comp, x, _), (T, val, gn) = lax.scan(
            step, init, jnp.arange(K, dtype=jnp.int32))
        return comp, x, T, val, gn

    P = PartitionSpec
    # check_rep=False: no collectives anywhere in the program, and jax
    # 0.4.x has no replication rule for the selection's while_loop
    wrapped = shard_map(
        unit_prog, mesh=mesh,
        in_specs=(P("data"), P("data"), P("data"), P("data")),
        out_specs=(P("data"), P("data"), P(None, "data"), P(None, "data"),
                   P(None, "data")),
        check_rep=False)

    key = ("msync", math, m_static, n, S, K,
           bool(jax.config.jax_enable_x64), _mesh_cache_key(mesh),
           _ById(model), _ById(problem))
    hit = key in _SWEEP_PROGS
    args = (keys0, m_in, g_in, x_init)
    compile_s = 0.0
    if not hit:
        t0 = time.perf_counter()
        compiled = jax.jit(wrapped).lower(*args).compile()
        compile_s = time.perf_counter() - t0
        _prog_cache_put(_SWEEP_PROGS, key, compiled)
    t0 = time.perf_counter()
    out = jax.block_until_ready(_SWEEP_PROGS[key](*args))
    if meta is not None:
        meta.update(cache_hit=hit, compile_s=round(compile_s, 4),
                    exec_s=round(time.perf_counter() - t0, 4))
    return out


def _rennala_run(model, problem, B, n, S, K, gamma, use_pallas, seeds,
                 mesh=None, meta=None):
    """Rennala as a renewal-batched ``lax.scan``: per round, each worker's
    fresh arrivals form a renewal chain, the round ends at the ``B``-th
    smallest chain entry, every worker's next pending computation is its
    first chain entry past the round end, and the stepping worker alone
    restarts at the new iterate. Ties are broken by (worker,
    within-round arrival index). For ``B`` beyond the iterative-kernel
    range the pool selection runs the counting-bisection path of
    :func:`~repro.kernels.order_stats.mth_smallest` — no ``top_k``
    lowering inside the scan.

    With a ``mesh`` the per-unit program is ``shard_map``ped over the
    1-D ``data`` axis and AOT-compiled into :data:`_SWEEP_PROGS` (the
    :func:`sharded_msync_run` treatment): every unit row is a pure
    function of its own ``PRNGKey``, so sharded outputs are bitwise the
    unsharded ``backend="jax"`` outputs. ``meta`` (if given) receives
    ``cache_hit``/``compile_s``/``exec_s``."""
    import time

    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec

    from ..kernels.order_stats import mth_smallest

    math = problem is not None
    keys0, x_init = _keys_and_x(problem, S, n, seeds)

    def unit_prog(keys, x0):
        U = keys.shape[0]                     # local block under shard_map
        finish_all = _finish_factory(model, U, n)
        chain_fn = _chain_factory(model, U, n)
        grad_mean = _grad_mean_fn(problem, B) if math else None
        widx = jnp.arange(n)
        flat_idx = jnp.arange(n * B)

        def step(carry, k):
            ft, ver, comp, x, keys = carry
            sub = jax.vmap(lambda kk: jax.random.split(kk, 4))(keys)
            keys = sub[:, 0]
            stale = ver < k
            # first fresh arrival: a stale pending pops at ft and restarts
            base = jnp.where(stale, finish_all(sub[:, 1], ft), ft)
            chain = chain_fn(sub[:, 2], base, B)      # (U, n, B+1)
            pool = chain[..., :B].reshape(U, n * B)
            T = mth_smallest(pool, B, use_pallas=use_pallas)
            lt = pool < T[:, None]
            eq = pool == T[:, None]
            quota = (B - lt.sum(axis=1))[:, None]
            acc = lt | (eq & ((jnp.cumsum(eq, axis=1) - 1) < quota))
            cnt = acc.reshape(U, n, B).sum(axis=2)    # accepted per worker
            popped = stale & (ft < T[:, None])        # discarded stale pops
            comp = comp + B + popped.sum(axis=1, dtype=jnp.int32)
            # the B-th (stepping) arrival: last accepted entry at exactly
            # T; its worker restarts at the new iterate (version k + 1)
            stepper = jnp.argmax(jnp.where(acc & eq, flat_idx[None, :], -1),
                                 axis=1) // B
            live = (~stale) | popped                  # chain materialized
            nxt = jnp.take_along_axis(chain, cnt[..., None], axis=2)[..., 0]
            ft = jnp.where(live, nxt, ft)
            ver = jnp.where(live, k, ver)
            ver = jnp.where(widx[None, :] == stepper[:, None], k + 1, ver)
            if math:
                x = x - gamma * grad_mean(x, sub[:, 3])
                val = jax.vmap(problem.f)(x)
                gn = jax.vmap(lambda xx: jnp.sum(problem.grad(xx) ** 2))(x)
            else:
                val = gn = jnp.zeros(U)
            return (ft, ver, comp, x, keys), (T, val, gn)

        sub = jax.vmap(lambda kk: jax.random.split(kk, 2))(keys)
        init = (finish_all(sub[:, 1], jnp.zeros((U, n))),
                jnp.zeros((U, n), jnp.int32),
                jnp.zeros(U, jnp.int32), x0, sub[:, 0])
        (_, _, comp, x, _), (T, val, gn) = lax.scan(
            step, init, jnp.arange(K, dtype=jnp.int32))
        return comp, x, T, val, gn

    if mesh is None:
        return jax.block_until_ready(jax.jit(unit_prog)(keys0, x_init))

    from jax.experimental.shard_map import shard_map
    P = PartitionSpec
    wrapped = shard_map(
        unit_prog, mesh=mesh,
        in_specs=(P("data"), P("data")),
        out_specs=(P("data"), P("data"), P(None, "data"), P(None, "data"),
                   P(None, "data")),
        check_rep=False)
    key = ("rennala", math, B, n, S, K, float(gamma), bool(use_pallas),
           bool(jax.config.jax_enable_x64), _mesh_cache_key(mesh),
           _ById(model), _ById(problem))
    hit = key in _SWEEP_PROGS
    args = (keys0, x_init)
    compile_s = 0.0
    if not hit:
        t0 = time.perf_counter()
        compiled = jax.jit(wrapped).lower(*args).compile()
        compile_s = time.perf_counter() - t0
        _prog_cache_put(_SWEEP_PROGS, key, compiled)
    t0 = time.perf_counter()
    out = jax.block_until_ready(_SWEEP_PROGS[key](*args))
    if meta is not None:
        meta.update(cache_hit=hit, compile_s=round(compile_s, 4),
                    exec_s=round(time.perf_counter() - t0, 4))
    return out


def _malenia_grad_fn(problem, n, L):
    """Malenia math update: ``(1/n) sum_i (1/B_i) sum_{j<B_i} g_ij`` at
    ``x^k`` — a **count-compacted** slot loop: slot ``j`` draws only
    while some worker still has an accepted arrival there
    (``j < max_i B_i``), so the per-round oracle volume is
    ``n * max(B)`` instead of the full masked ``n * L`` pool. ``L`` is
    sized for the model's speed *spread* (a fast worker's chain must
    cover the slowest worker's first delivery), so on sparse rounds —
    near-homogeneous speeds, ``B_i ~ ceil(S)`` — ``max(B) << L`` and the
    compaction cuts most of the draw volume. Slot keys are still split
    ``L`` ways up front, so the drawn values per occupied slot are
    bitwise-identical to the uncompacted loop (zero-weight slots are
    skipped, never re-keyed); memory stays ``(S, n, d)`` per slot."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    def upd(x, B, round_keys):
        slot_keys = jax.vmap(lambda k: jax.random.split(k, L))(round_keys)
        w = 1.0 / (jnp.maximum(B, 1).astype(x.dtype) * n)  # (S, n)
        Bmax = jnp.max(B)

        def cond(c):
            return c[0] < Bmax

        def body(c):
            j, acc = c
            kcol = slot_keys[:, j]                         # (S, 2)
            gk = jax.vmap(lambda k: jax.random.split(k, n))(kcol)
            g = jax.vmap(jax.vmap(problem.stoch_grad, (None, 0)),
                         (0, 0))(x, gk)                    # (S, n, d)
            wj = jnp.where(j < B, w, 0.0)
            return j + 1, acc + (g * wj[..., None]).sum(axis=1)

        _, out = lax.while_loop(cond, body,
                                (jnp.zeros((), jnp.int32),
                                 jnp.zeros_like(x)))
        return out

    return upd


def _malenia_run(model, problem, S_target, n, S, K, gamma, seeds,
                 chain_len=None, mesh=None, meta=None):
    """Malenia as the Rennala renewal scan generalized to the per-worker
    count predicate (see module doc): per round, each worker's fresh
    arrivals form an ``L``-slot renewal chain, and the round ends at the
    first arrival time ``T`` with ``min_i B_i(T) >= 1`` and harmonic
    mean ``n / sum_i 1/B_i(T) >= S_target``. The predicate is monotone
    in ``T``, so ``T`` comes from a value bisection over the pool, an
    exact snap onto the triggering arrival, and a worker-major
    tie-consumption search that reproduces the event engine's
    one-arrival-at-a-time predicate check (ties broken by worker index —
    the backend's documented contract).

    ``L`` must cover every worker's in-round arrival count: a fast
    worker keeps accumulating arrivals while the slowest delivers its
    first, so the default scales with both ``ceil(S)`` and the
    mean-speed spread. Rounds where a chain is exhausted anyway (a
    worker's ``L+1``-th arrival lands before the round end — e.g. a
    heavy-tailed slow draw) are flagged, and the engine retries with
    doubled chains a few times before raising — never silently
    mis-batched.

    With a ``mesh`` the per-unit program is ``shard_map``ped over the
    1-D ``data`` axis and AOT-compiled into :data:`_SWEEP_PROGS` (every
    unit row is a pure function of its own key — sharded outputs are
    bitwise the unsharded ones); ``meta`` (if given) receives
    ``cache_hit``/``compile_s``/``exec_s``.
    """
    import time

    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec

    math = problem is not None
    ceilS = int(_math.ceil(S_target))
    if chain_len:
        L = int(chain_len)
    else:
        taus = np.asarray(model.mean_times(), dtype=float) \
            if not isinstance(model, UniversalModel) else None
        spread = (float(np.max(taus) / max(np.min(taus), 1e-12))
                  if taus is not None and len(taus) else 1.0)
        L = max(2 * ceilS, int(np.ceil(3.0 * spread)) + ceilS, 8)
    if L < ceilS:
        raise ValueError(f"chain_len={L} cannot certify harmonic mean "
                         f"S={S_target} (need >= {ceilS})")
    keys0, x_init = _keys_and_x(problem, S, n, seeds)

    def P_of_counts(B):
        ok1 = jnp.all(B >= 1, axis=-1)
        # engine dtype, not hard-coded f32: the x64 tie-parity mode needs
        # the harmonic-mean threshold test at float64 like the NumPy heap
        hm = n / jnp.sum(1.0 / jnp.maximum(B, 1).astype(_engine_dtype()),
                         axis=-1)
        return ok1 & (hm >= S_target)

    def attempt(L):
        upd_fn = _malenia_grad_fn(problem, n, L) if math else None
        tie_iters = int(np.ceil(np.log2(n * L + 2))) + 2

        def unit_prog(keys, x0):
            U = keys.shape[0]                 # local block under shard_map
            finish_all = _finish_factory(model, U, n)
            chain_fn = _chain_factory(model, U, n)
            widx = jnp.arange(n)

            def step(carry, k):
                ft, ver, comp, used, x, keys, bad = carry
                sub = jax.vmap(lambda kk: jax.random.split(kk, 4))(keys)
                keys = sub[:, 0]
                stale = ver < k
                base = jnp.where(stale, finish_all(sub[:, 1], ft), ft)
                ch = chain_fn(sub[:, 2], base, L)     # (U, n, L+1)
                cand = ch[..., :L]

                def Pt(T):
                    return P_of_counts(
                        (cand <= T[:, None, None]).sum(axis=-1))

                # bisection invariants: no arrival at or before t_lo (B = 0,
                # false); every worker has >= ceil(S) arrivals by t_hi (true)
                t_lo = base.min(axis=1) - 1.0
                t_hi = cand[..., ceilS - 1].max(axis=1)

                def bisect(_, lh):
                    lo, hi = lh
                    mid = 0.5 * (lo + hi)
                    ok = Pt(mid)
                    return jnp.where(ok, lo, mid), jnp.where(ok, mid, hi)

                lo, _ = lax.fori_loop(0, _MAL_BISECT_ITERS, bisect,
                                      (t_lo, t_hi))

                # snap onto the exact triggering arrival: smallest pool
                # entry above lo; sub-threshold entries can survive a wide
                # interval, so advance past them (bounded; non-convergence
                # flags the run)
                def cond(c):
                    _, _, done, it = c
                    return jnp.any(~done) & (it < _MAL_SNAP_ITERS)

                def snap(c):
                    lo, T, done, it = c
                    cnd = jnp.where(cand > lo[:, None, None], cand,
                                    jnp.inf).min(axis=(1, 2))
                    ok = Pt(cnd)
                    T = jnp.where(done, T, cnd)
                    lo = jnp.where(done | ok, lo, cnd)
                    return lo, T, done | ok, it + 1

                _, T, done, _ = lax.while_loop(
                    cond, snap, (lo, jnp.zeros(U), jnp.zeros(U, bool),
                                 jnp.zeros((), jnp.int32)))
                bad_k = ~done

                # per-worker counts at T, consuming boundary ties one
                # arrival at a time in worker-major order until the
                # predicate first holds
                Tb = T[:, None, None]
                lt = (cand < Tb).sum(axis=-1)         # (U, n)
                tie = (cand == Tb).sum(axis=-1)
                prev = jnp.cumsum(tie, axis=1) - tie

                def consumed(tc):
                    return jnp.clip(tc[:, None] - prev, 0, tie)

                def cbisect(_, lh):                   # minimal tc, P true
                    lo_c, hi_c = lh
                    mid = (lo_c + hi_c) // 2
                    ok = P_of_counts(lt + consumed(mid))
                    return (jnp.where(ok, lo_c, mid),
                            jnp.where(ok, mid, hi_c))

                # U, not S: under shard_map the local block is smaller
                # than the global unit count (S would break the carry)
                _, tc = lax.fori_loop(0, tie_iters, cbisect,
                                      (jnp.zeros(U, jnp.int32),
                                       tie.sum(axis=1).astype(jnp.int32)))
                cons = consumed(tc)
                B = lt + cons                         # accepted per worker
                stepper = jnp.max(jnp.where(cons > 0, widx[None, :], -1),
                                  axis=1)

                popped = stale & (ft < T[:, None])    # discarded stale pops
                comp = (comp + B.sum(axis=1, dtype=jnp.int32)
                        + popped.sum(axis=1, dtype=jnp.int32))
                used = used + B.sum(axis=1, dtype=jnp.int32)
                # chain exhausted: an (L+1)-th arrival before the round end
                bad = bad | bad_k | (ch[..., L] <= T[:, None]).any(axis=1)

                live = (~stale) | popped              # chain materialized
                nxt = jnp.take_along_axis(ch, B[..., None], axis=2)[..., 0]
                ft = jnp.where(live, nxt, ft)
                ver = jnp.where(live, k, ver)
                ver = jnp.where(widx[None, :] == stepper[:, None], k + 1, ver)
                if math:
                    x = x - gamma * upd_fn(x, B, sub[:, 3])
                    val = jax.vmap(problem.f)(x)
                    gn = jax.vmap(lambda xx: jnp.sum(problem.grad(xx) ** 2))(x)
                else:
                    val = gn = jnp.zeros(U)
                return (ft, ver, comp, used, x, keys, bad), (T, val, gn)

            sub = jax.vmap(lambda kk: jax.random.split(kk, 2))(keys)
            init = (finish_all(sub[:, 1], jnp.zeros((U, n))),
                    jnp.zeros((U, n), jnp.int32), jnp.zeros(U, jnp.int32),
                    jnp.zeros(U, jnp.int32), x0, sub[:, 0],
                    jnp.zeros(U, bool))
            (_, _, comp, used, x, _, bad), (T, val, gn) = lax.scan(
                step, init, jnp.arange(K, dtype=jnp.int32))
            return comp, used, x, T, val, gn, bad

        if mesh is None:
            return jax.block_until_ready(jax.jit(unit_prog)(keys0, x_init))

        from jax.experimental.shard_map import shard_map
        P = PartitionSpec
        wrapped = shard_map(
            unit_prog, mesh=mesh,
            in_specs=(P("data"), P("data")),
            out_specs=(P("data"), P("data"), P("data"), P(None, "data"),
                       P(None, "data"), P(None, "data"), P("data")),
            check_rep=False)
        key = ("malenia", math, float(S_target), L, n, S, K, float(gamma),
               bool(jax.config.jax_enable_x64), _mesh_cache_key(mesh),
               _ById(model), _ById(problem))
        hit = key in _SWEEP_PROGS
        args = (keys0, x_init)
        compile_s = 0.0
        if not hit:
            t0 = time.perf_counter()
            compiled = jax.jit(wrapped).lower(*args).compile()
            compile_s = time.perf_counter() - t0
            _prog_cache_put(_SWEEP_PROGS, key, compiled)
        t0 = time.perf_counter()
        out = jax.block_until_ready(_SWEEP_PROGS[key](*args))
        if meta is not None:
            meta.update(cache_hit=hit, compile_s=round(compile_s, 4),
                        exec_s=round(time.perf_counter() - t0, 4))
        return out

    for _ in range(4):
        comp, used, x, T, val, gn, bad = attempt(L)
        if not bool(np.any(np.asarray(bad))):
            return comp, x, T, val, gn, used
        L *= 2                                    # outran the chains: retry
    raise RuntimeError(
        f"malenia jax engine could not certify a round within its "
        f"{L // 2}-slot renewal chains even after doubling retries "
        f"(extreme speed heterogeneity?); pass a larger chain_len to "
        f"simulate_batch_jax or use backend='serial'")


def _ringleader_grad_fn(problem, n):
    """Ringleader math update: ``(1/n) sum_i (1/B_i) sum_{j<B_i} g_ij``
    — the Malenia count-compacted slot loop with one twist: slot 0 (each
    worker's FIRST in-round arrival) evaluates at the previous iterate
    ``x^{k-1}`` (``x^k`` for the worker that triggered the previous
    round's step — it alone restarted at the fresh iterate), all later
    slots at ``x^k``. That two-point rule is exact, not an
    approximation: the serial engine restarts every worker at the
    current iterate on every (always-accepted) arrival, and every worker
    delivers at least once per round, so staleness never exceeds one
    round. Slot ``j``'s key is ``fold_in(round_key, j)`` — independent
    of any chain budget, so window growth and chunk re-runs leave
    completed rounds' draws bitwise unchanged."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    widx = jnp.arange(n)

    def upd(x_prev, x_cur, trig_prev, B, round_keys):
        w = 1.0 / (jnp.maximum(B, 1).astype(x_cur.dtype) * n)  # (S, n)
        Bmax = jnp.max(B)
        first_pt = jnp.where(
            (widx[None, :] == trig_prev[:, None])[..., None],
            x_cur[:, None, :], x_prev[:, None, :])             # (S, n, d)
        later_pt = jnp.broadcast_to(x_cur[:, None, :], first_pt.shape)

        def cond(c):
            return c[0] < Bmax

        def body(c):
            j, acc = c
            kcol = jax.vmap(
                lambda k: jax.random.fold_in(k, j))(round_keys)  # (S, 2)
            gk = jax.vmap(lambda k: jax.random.split(k, n))(kcol)
            pts = jnp.where(j == 0, first_pt, later_pt)
            g = jax.vmap(jax.vmap(problem.stoch_grad, (0, 0)),
                         (0, 0))(pts, gk)                      # (S, n, d)
            wj = jnp.where(j < B, w, 0.0)
            return j + 1, acc + (g * wj[..., None]).sum(axis=1)

        _, out = lax.while_loop(cond, body,
                                (jnp.zeros((), jnp.int32),
                                 jnp.zeros_like(x_cur)))
        return out

    return upd


def _ringleader_run(model, problem, n, S, K, gamma, seeds, chain_len=None,
                    mesh=None, meta=None):
    """Ringleader as a chunked round scan over ONE ragged global renewal
    chain per worker (see module doc): workers never idle and never
    discard, so their arrival times are pure renewal processes from
    ``t = 0`` and the whole run consumes a single prefix-stable
    worker-major flat pool from :func:`_chain_builder` with per-worker
    budgets from :func:`_chain_plan_ragged` — no per-round redraw, no
    rectangular ``n x max(L_i)`` tax under skewed rates. Round ``k``
    ends at ``T_k = max_i`` (worker ``i``'s first chain entry past
    ``T_{k-1}``); worker ``i`` contributes the ``B_i >= 1`` entries in
    ``(T_{k-1}, T_k]`` and the pointer update is pure counting
    (``newp = #{entries <= T_k}`` — a layout-independent per-worker
    count). Ties at the round end break by worker index (the backend's
    documented contract).

    The ``K`` rounds run in chunks of at most 64; the scan carry
    ``(p, comp, x_prev, x_cur, trig, keys)`` is saved at every chunk
    boundary. A pointer reaching its budget means the pool may hide
    arrivals inside the round: the failed chunk's outputs are
    discarded, the budgets double, :func:`_chain_builder` draws ONLY
    the extension slots (anchored, prefix-stable), and the SAME chunk
    re-runs from the saved carry — completed chunks are never re-drawn
    or re-scanned, and the re-run's completed rounds are bitwise
    unchanged (round keys are carried, slot keys are
    ``fold_in(round_key, j)``). With a ``mesh`` the chunk program is
    ``shard_map``ped over the seed rows; ``meta`` (if given) collects
    chain/window/chunk accounting and program-cache hits."""
    import time

    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec

    from .time_models import ragged_layout

    math = problem is not None
    if chain_len:
        budgets = np.full(n, int(chain_len), np.int64)
    else:
        # expected global arrivals per round: every worker delivers ~
        # rate_i / min(rate) times while the slowest delivers once
        rates = _model_rates(model)
        per_round = float(rates.sum() / max(rates.min(), 1e-12))
        fluct = (1.0 if isinstance(model, (FixedTimes, UniversalModel))
                 else 1.0 + float(np.log(max(n, 1))))
        budgets = _chain_plan_ragged(
            model, n, int(np.ceil(K * per_round * fluct)))
    keys0, x_init = _keys_and_x(problem, S, n, seeds)
    sub0 = jax.vmap(lambda kk: jax.random.split(kk, 2))(keys0)
    round_root, chain_root = sub0[:, 0], sub0[:, 1]
    upd_fn = _ringleader_grad_fn(problem, n) if math else None
    dt = _engine_dtype()

    # windowed ragged chain state (host): canonical per-worker pool
    # segments, drawn slot counts, carried last-absolute-time anchors
    drawn = np.zeros(n, np.int64)
    segs = [np.zeros((S, 0)) for _ in range(n)]
    anchors = jnp.zeros((S, n), dt)
    if meta is not None:
        meta.setdefault("chain_s", 0.0)
        meta.update(windows=0, drawn_slots=[], chunk_runs=0)

    def draw_to(budgets_new):
        nonlocal drawn, anchors
        ext = budgets_new - drawn
        builder = _chain_builder(model, S, n, ext, starts=drawn, mesh=mesh)
        t0 = time.perf_counter()
        flat_ext, anchors = builder(chain_root, anchors)
        flat_ext = jax.block_until_ready(flat_ext)
        if meta is not None:
            meta["chain_s"] = round(
                meta["chain_s"] + time.perf_counter() - t0, 4)
            meta["windows"] += 1
            meta["drawn_slots"].append(int(ext.sum()))
        ext_np = np.asarray(flat_ext)
        eoff, _, _, _ = ragged_layout(ext, drawn)
        for i in range(n):
            segs[i] = np.concatenate(
                [segs[i], ext_np[:, eoff[i]:eoff[i] + ext[i]]], axis=1)
        drawn = budgets_new.copy()
        return jnp.asarray(np.concatenate(segs, axis=1))

    def chunk_prog(buds, Kc):
        offs, widx_flat, _, _ = ragged_layout(buds)
        offs_c = offs.astype(np.int32)
        buds_c = buds.astype(np.int32)
        widx_c = widx_flat.astype(np.int32)

        key = ("ringleader", math, n, S, K, Kc, float(gamma),
               buds.tobytes(), bool(jax.config.jax_enable_x64),
               None if mesh is None else _mesh_cache_key(mesh),
               _ById(model), _ById(problem))
        hit = key in _SWEEP_PROGS
        if meta is not None:
            meta["cache_hit"] = hit
        if hit:
            return _SWEEP_PROGS[key]

        def unit_prog(ch_flat, p, comp, x_prev, x_cur, trig, keys):
            U = keys.shape[0]                 # local block under shard_map
            offs_d = jnp.asarray(offs_c)
            buds_d = jnp.asarray(buds_c)
            widx_d = jnp.asarray(widx_c)

            def step(carry, _):
                p, comp, x_prev, x_cur, trig, keys, bad = carry
                sub = jax.vmap(lambda kk: jax.random.split(kk, 2))(keys)
                keys = sub[:, 0]
                # flat slot offs_i + p_i is worker i's first arrival
                # past T_{k-1} (p_i is a layout-independent count)
                gidx = offs_d[None, :] + jnp.minimum(p, buds_d[None, :] - 1)
                nxt = jnp.take_along_axis(ch_flat, gidx, axis=1)   # (U, n)
                T = nxt.max(axis=1)
                trig_new = nxt.argmax(axis=1).astype(jnp.int32)
                le = (ch_flat <= T[:, None]).astype(jnp.int32)
                newp = jax.vmap(lambda m: jax.ops.segment_sum(
                    m, widx_d, num_segments=n))(le)                # (U, n)
                B = newp - p
                bad = bad | (newp >= buds_d[None, :]).any(axis=1)
                comp = comp + B.sum(axis=1, dtype=jnp.int32)
                if math:
                    g = upd_fn(x_prev, x_cur, trig, B, sub[:, 1])
                    x_new = x_cur - gamma * g
                    val = jax.vmap(problem.f)(x_new)
                    gn = jax.vmap(
                        lambda xx: jnp.sum(problem.grad(xx) ** 2))(x_new)
                else:
                    x_new = x_cur
                    val = gn = jnp.zeros(U)
                return (newp, comp, x_cur, x_new, trig_new, keys, bad), \
                    (T, val, gn)

            init = (p, comp, x_prev, x_cur, trig, keys,
                    jnp.zeros(U, bool))
            (p, comp, x_prev, x_cur, trig, keys, bad), (T, val, gn) = \
                lax.scan(step, init, None, length=Kc)
            return p, comp, x_prev, x_cur, trig, keys, bad, T, val, gn

        if mesh is None:
            return _prog_cache_put(_SWEEP_PROGS, key, jax.jit(unit_prog))
        from jax.experimental.shard_map import shard_map
        P = PartitionSpec
        wrapped = shard_map(
            unit_prog, mesh=mesh,
            in_specs=(P("data"),) * 7,
            out_specs=(P("data"),) * 7 + (P(None, "data"),) * 3,
            check_rep=False)
        return _prog_cache_put(_SWEEP_PROGS, key, jax.jit(wrapped))

    ch_flat = draw_to(budgets)
    Kc = min(K, 64)
    T_all = np.zeros((K, S))
    vals = np.zeros((K, S))
    gns = np.zeros((K, S))
    # trig = -1: round 0 has no previous trigger and x_prev == x_cur ==
    # x0, so the first-slot rule is vacuous
    carry = (jnp.zeros((S, n), jnp.int32), jnp.zeros(S, jnp.int32),
             x_init, x_init, jnp.full(S, -1, jnp.int32), round_root)
    done = 0
    grows = 0
    while done < K:
        kc = min(Kc, K - done)
        out = jax.block_until_ready(chunk_prog(drawn, kc)(ch_flat, *carry))
        if meta is not None:
            meta["chunk_runs"] += 1
        p, comp, x_prev, x_cur, trig, rkeys, bad, T, val, gn = out
        if bool(np.any(np.asarray(bad))):
            # pool may hide arrivals inside this chunk: discard its
            # outputs, double the budgets, draw ONLY the extension and
            # re-run the SAME chunk from the saved chunk-start carry
            if grows >= 4:
                raise RuntimeError(
                    f"ringleader jax engine outran its per-worker renewal "
                    f"chains (max {int(drawn.max())} slots) even after "
                    f"doubling windows (extreme speed heterogeneity?); "
                    f"pass a larger async_chain to simulate_batch_jax or "
                    f"use backend='serial'")
            grows += 1
            ch_flat = draw_to(drawn * 2)
            continue
        T_all[done:done + kc] = np.asarray(T)
        if math:
            vals[done:done + kc] = np.asarray(val)
            gns[done:done + kc] = np.asarray(gn)
        carry = (p, comp, x_prev, x_cur, trig, rkeys)
        done += kc
    comp_np = np.asarray(carry[1])
    x = carry[3]
    return comp_np, x, T_all, vals, gns, comp_np  # waste-free: used == comp


# --------------------------------------------------------------------------
# Async / Ringmaster: the renewal-chain arrival-scan engine
# --------------------------------------------------------------------------

# timing-only chain/scan programs are cached here so repeated same-shape
# sweeps (grid points, benchmark loops) skip recompilation; math programs
# close over the oracle and recompile per call like the other engines.
# Keys are (hashable sampler/model handle, static shape ints, x64 flag).
# Bounded FIFO: long sessions sweeping many model instances would
# otherwise retain one compiled program (plus its captured closure) per
# instance forever.
_CHAIN_PROGS: dict = {}
_SCAN_PROGS: dict = {}
_PROG_CACHE_CAP = 64


def _prog_cache_put(cache: dict, key, value):
    if len(cache) >= _PROG_CACHE_CAP:
        cache.pop(next(iter(cache)))          # FIFO: dicts keep insert order
    cache[key] = value
    return value

# arrival-scan sizing: chain-length safety factors and retry budget
_CHAIN_GROWTH = 1.25
_CHAIN_SLACK = 8.0
_CHAIN_RETRIES = 5


def _model_rates(model) -> np.ndarray:
    """Per-worker mean arrival rates (host), the sizing input for both
    chain plans: inverse mean times for fixed/sampled models, mean
    cumulative power for universal models."""
    if isinstance(model, UniversalModel):
        span = float(model.grid[-1] - model.grid[0]) or 1.0
        return np.maximum(np.asarray(model.cum[:, -1], dtype=float) / span,
                          1e-9)
    taus = np.asarray(model.mean_times(), dtype=float)
    return 1.0 / np.maximum(taus, 1e-12)


def _chain_plan(model, n: int, arrivals: int) -> int:
    """Rectangular per-worker chain length ``L`` for a window of
    ``arrivals`` global pops: expected max per-worker share of the
    window from the model's mean rates, a fluctuation cushion, capped at
    ``arrivals + 1`` (one worker can own at most the whole window). This
    sizes every worker to the *fastest* worker's share — the
    ``layout="rect"`` mode and the baseline the ragged plan is gated
    against; the engine itself defaults to :func:`_chain_plan_ragged`."""
    rates = _model_rates(model)
    share = float(rates.max() / max(rates.sum(), 1e-12))
    exp_max = arrivals * share
    L = int(np.ceil(_CHAIN_GROWTH * exp_max
                    + 4.0 * np.sqrt(max(exp_max, 1.0)) + _CHAIN_SLACK))
    return max(min(L, arrivals + 1), int(np.ceil(arrivals / n)) + 1, 4)


def _chain_plan_ragged(model, n: int, arrivals: int) -> np.ndarray:
    """Per-worker slot budgets ``L_i`` for a window of ``arrivals``
    global pops: each worker gets its own expected share
    ``arrivals * rate_i / sum(rates)`` with the same growth factor,
    sqrt fluctuation cushion and additive slack as the rectangular
    plan. Under skewed rates the flat pool ``sum(L_i)`` stays
    ``O(arrivals)`` where the rectangle pays ``n * max(L_i)``; at
    uniform rates every budget equals the rectangular share. Budgets
    are clamped to ``[4, arrivals + 1]`` per worker; the windowed
    engine doubles them (drawing only the extension) when a chain is
    outrun anyway."""
    rates = _model_rates(model)
    share = rates / max(float(rates.sum()), 1e-12)
    exp = arrivals * share
    L = np.ceil(_CHAIN_GROWTH * exp + 4.0 * np.sqrt(np.maximum(exp, 1.0))
                + _CHAIN_SLACK).astype(np.int64)
    return np.maximum(np.minimum(L, arrivals + 1), 4)


def _ring_pop_budget(n: int, K: int, max_delay: int) -> int:
    """Extra-arrival budget for the Ringmaster window: the engine pops
    ~``1 + sqrt(n / (max_delay + 1))`` arrivals per accept (empirical fit
    on the exponential model — the discard rate self-limits because a
    stalled server drives delays back to zero), plus slack; exhaustion
    retries quadruple it."""
    pops = 1.0 + float(np.sqrt(n / (max_delay + 1.0)))
    return int(K * min(float(n), pops - 1.0)) + 2 * n


def arrival_scan_work(model, n: int, K: int, ringmaster: bool = False,
                      max_delay: int = 0) -> "tuple[int, int]":
    """``(pool_elements, window_arrivals)`` the arrival-scan engine would
    process for this shape — the same sizing the engine itself uses
    (:func:`_chain_plan_ragged` budgets, :func:`_ring_pop_budget`
    window). The cost-model router in :mod:`repro.core.batch` consumes
    this; pure host arithmetic, no jax import."""
    budget = _ring_pop_budget(n, K, max_delay) if ringmaster else 0
    total = int(_chain_plan_ragged(model, n, K + budget).sum())
    return total, min(K + budget, total)


def _shard_wrap(fn, mesh, in_specs, out_specs):
    """``shard_map`` + jit a per-row program over the 1-D ``data`` axis
    (None mesh: plain jit — the unsharded path is the same program)."""
    import jax

    if mesh is None:
        return jax.jit(fn)
    from jax.experimental.shard_map import shard_map

    # check_rep=False: these programs have no collectives, and jax 0.4.x
    # lacks replication rules for some of their primitives (while_loop)
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False))


def _mesh_rows(S: int, mesh) -> int:
    """Per-device row block for a ``(S, ...)`` batch on a 1-D mesh."""
    if mesh is None:
        return S
    D = mesh.devices.size
    if S % D:
        raise ValueError(
            f"sharded arrival scan needs rows % devices == 0 (got "
            f"S={S}, D={D}); the sweep layer pads units before calling")
    return S // D


def _chain_builder(model, S: int, n: int, budgets, starts=None, mesh=None):
    """``chains(chain_keys, anchors) -> (flat, anchors_out)`` — ragged
    per-worker renewal chains over ONE worker-major flat buffer.

    ``budgets[i]`` slots are drawn for worker ``i`` starting at global
    slot ``starts[i]`` (0 for a fresh window); ``flat`` is ``(S,
    sum(budgets))`` ABSOLUTE arrival times laid out by
    :func:`~repro.core.time_models.ragged_layout`, and ``anchors_out``
    is each worker's last absolute time — the carry a window extension
    feeds back as ``anchors`` so accumulation continues the exact float
    recurrence (sequential adds, bitwise split-invariant; ``jnp.cumsum``
    would not be). Slot ``(i, g)``'s duration is the fold-in keyed
    :func:`~repro.core.time_models.jax_chain_draws_ragged` contract
    draw, so growing budgets or extending windows appends slots and
    leaves certified prefixes bitwise unchanged. FixedTimes is the
    closed form ``(g + 1) * tau`` (no RNG); universal models iterate
    the deterministic ``finish_times_jax`` inversion from ``anchors``.
    Programs are jit-cached (keyed by sampler/model identity, the
    budget/start layout bytes, x64 and the mesh); with a ``mesh`` the
    program is ``shard_map``ped over the seed/unit axis — every chain
    row is a pure function of its own key and anchor row, so sharded
    rows are bitwise the unsharded rows."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from .time_models import ragged_layout

    b = np.asarray(budgets, dtype=np.int64)
    s0 = (np.zeros(n, np.int64) if starts is None
          else np.asarray(starts, dtype=np.int64))
    offsets, widx, gslot, total = ragged_layout(b, s0)
    jmin = int(s0.min()) if n else 0
    jmax = int((s0 + b).max()) if n else 0
    steps = max(jmax - jmin, 0)

    x64 = bool(jax.config.jax_enable_x64)
    rows = _mesh_rows(S, mesh)
    mk = None if mesh is None else _mesh_cache_key(mesh)
    layout_key = (b.tobytes(), s0.tobytes())
    dt = _engine_dtype()

    if isinstance(model, FixedTimes):
        key = ("fixed", S, n, layout_key, x64, mk)
        if key not in _CHAIN_PROGS:
            gs = jnp.asarray(gslot)
            wi = jnp.asarray(widx)
            bd = jnp.asarray(b)
            sd = jnp.asarray(s0)

            def fixed_chain(taus, chain_keys, anchors):  # keys/anchors: no RNG
                flat = jnp.broadcast_to(taus[wi] * (gs + 1), (rows, total))
                out_anchor = jnp.broadcast_to(taus * (sd + bd).astype(taus.dtype),
                                              (rows, n))
                return flat, out_anchor

            _prog_cache_put(_CHAIN_PROGS, key,
                            _shard_wrap(fixed_chain, mesh,
                                        (P(), P("data"), P("data")),
                                        (P("data"), P("data"))))
        prog = _CHAIN_PROGS[key]
        taus = model.taus
        return lambda chain_keys, anchors: prog(jnp.asarray(taus, dt),
                                                chain_keys, anchors)

    # in-budget mask and flat destination per global slot (host consts);
    # out-of-budget entries scatter to index `total` and drop
    jg = np.arange(jmin, jmax, dtype=np.int64)[:, None]
    rel = jg - s0[None, :]
    in_b = (rel >= 0) & (rel < b[None, :])
    dest_np = np.where(in_b, offsets[None, :] + rel, total).astype(np.int32)

    if isinstance(model, UniversalModel):
        key = (model, S, n, layout_key, x64, mk)    # identity-hashed
        if key not in _CHAIN_PROGS:
            mask = jnp.asarray(in_b)
            dest = jnp.asarray(dest_np)

            def universal_chain(chain_keys, anchors):    # keys unused
                def body(carry, inp):
                    c, buf = carry
                    m, d = inp
                    nxt = model.finish_times_jax(c)
                    c = jnp.where(m[None, :], nxt, c)
                    buf = buf.at[:, d].set(c, mode="drop")
                    return (c, buf), None

                buf0 = jnp.zeros((rows, total), dt)
                (c, buf), _ = lax.scan(body, (anchors, buf0), (mask, dest))
                return buf, c

            _prog_cache_put(_CHAIN_PROGS, key,
                            _shard_wrap(universal_chain, mesh,
                                        (P("data"), P("data")),
                                        (P("data"), P("data"))))
        return _CHAIN_PROGS[key]

    sampler = model.jax_sampler
    key = (sampler, S, n, layout_key, x64, mk)
    if key not in _CHAIN_PROGS:
        mask = jnp.asarray(in_b)
        dest = jnp.asarray(dest_np)
        jgd = jnp.arange(jmin, jmax)

        def sampled_chain(chain_keys, anchors):
            def per_seed(ck, anchor):
                def body(carry, inp):
                    tot, buf = carry
                    j, m, d = inp
                    row = sampler(jax.random.fold_in(ck, j))
                    tot = jnp.where(m, tot + row, tot)
                    buf = buf.at[d].set(tot, mode="drop")
                    return (tot, buf), None

                buf0 = jnp.zeros((total,), dt)
                (tot, buf), _ = lax.scan(body, (anchor, buf0),
                                         (jgd, mask, dest))
                return buf, tot

            return jax.vmap(per_seed)(chain_keys, anchors)

        _prog_cache_put(_CHAIN_PROGS, key,
                        _shard_wrap(sampled_chain, mesh,
                                    (P("data"), P("data")),
                                    (P("data"), P("data"))))
    return _CHAIN_PROGS[key]


def _ring_timing_prog(S: int, n: int, K: int, max_delay: int, A: int,
                      mesh=None):
    """Cached timing-only Ringmaster arrival-scan *window*: O(1)
    per-arrival work (version gather, delay test, version scatter) over
    ``A`` pre-merged arrivals, gated by a per-(arrival, seed) ``valid``
    mask and resumed from a carried ``(k, ver, comp)`` state — window
    extensions scan only newly certified arrivals, never the certified
    prefix. Returns ``(k, ver, comp, accept)``; wall-clock times stay
    host-side (the merged order already carries them). With a ``mesh``
    the scan is ``shard_map``ped over the seed/unit columns — the
    recursion is column-independent, so sharding is bitwise-free."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P

    key = (S, n, K, max_delay, A, bool(jax.config.jax_enable_x64),
           None if mesh is None else _mesh_cache_key(mesh))
    if key in _SCAN_PROGS:
        return _SCAN_PROGS[key]

    R = _mesh_rows(S, mesh)
    rows = jnp.arange(R)

    def run(w_seq, valid, k0, ver0, comp0):         # (A, R) x2, carry-in
        def body(carry, inp):
            k, ver, comp = carry
            w, v = inp
            vw = ver[rows, w]
            live = v & (k < K)
            acc = live & ((k - vw) <= max_delay)
            k = k + acc
            ver = ver.at[rows, w].set(jnp.where(live, k, vw))
            comp = comp + live
            return (k, ver, comp), acc

        (kf, ver, comp), acc = lax.scan(body, (k0, ver0, comp0),
                                        (w_seq, valid))
        return kf, ver, comp, acc                   # acc: (A, R)

    return _prog_cache_put(
        _SCAN_PROGS, key,
        _shard_wrap(run, mesh,
                    (P(None, "data"), P(None, "data"), P("data"),
                     P("data"), P("data")),
                    (P("data"), P("data"), P("data"), P(None, "data"))))


def _arrival_math_prog(problem, gamma, delay_adaptive, S, n, K, max_delay,
                       mesh=None):
    """Math-path arrival-scan *window* (Async and Ringmaster): per
    arrival, one oracle draw at the popped worker's start-iterate
    snapshot, a masked step, and version/snapshot scatters — gated by a
    per-(arrival, seed) ``valid`` mask and resumed from a carried
    ``(k, ver, comp, x, xs)`` state, so window extensions scan only the
    newly certified arrivals. Gradient keys are ``fold_in(seed key,
    global arrival index)`` (the ``pos`` input) — prefix-stable, so
    extensions and chain growth leave already-certified arrivals
    bitwise unchanged. Closes over the oracle: compiles per call, like
    :func:`_general_run`. With a ``mesh`` the seed/unit axis is
    ``shard_map``ped (every column's recursion is independent)."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import PartitionSpec as P

    R = _mesh_rows(S, mesh)
    rows = jnp.arange(R)

    def run(w_seq, valid, pos, gkey_root, k0, ver0, comp0, x0, xs0):
        def body(carry, inp):
            k, ver, comp, x, xs = carry
            w, v, a = inp
            gk = jax.vmap(lambda kk: jax.random.fold_in(kk, a))(gkey_root)
            vw = ver[rows, w]
            live = v & (k < K)
            acc = live & ((k - vw) <= max_delay)
            g = jax.vmap(problem.stoch_grad)(xs[rows, w], gk)
            mult = (1.0 / (1.0 + (k - vw).astype(g.dtype) / n)
                    if delay_adaptive else jnp.ones(R, g.dtype))
            x = jnp.where(acc[:, None], x - gamma * mult[:, None] * g, x)
            val = jax.vmap(problem.f)(x)
            gn = jax.vmap(lambda xx: jnp.sum(problem.grad(xx) ** 2))(x)
            k = k + acc
            ver = ver.at[rows, w].set(jnp.where(live, k, vw))
            xs = xs.at[rows, w].set(
                jnp.where(live[:, None], x, xs[rows, w]))
            comp = comp + live
            return (k, ver, comp, x, xs), (acc, val, gn)

        (kf, ver, comp, x, xs), (acc, val, gn) = lax.scan(
            body, (k0, ver0, comp0, x0, xs0), (w_seq, valid, pos))
        return kf, ver, comp, x, xs, acc, val, gn

    return _shard_wrap(
        run, mesh,
        (P(None, "data"), P(None, "data"), P(None), P("data"), P("data"),
         P("data"), P("data"), P("data"), P("data")),
        (P("data"), P("data"), P("data"), P("data"), P("data"),
         P(None, "data"), P(None, "data"), P(None, "data")))


def _chain_scan_run(model, problem, ringmaster, max_delay, delay_adaptive,
                    n, S, K, gamma, seeds, chain_len=None, mesh=None,
                    meta=None, layout="ragged"):
    """Async/Ringmaster as the ragged, windowed renewal-chain arrival
    scan (module doc): a popped worker restarts immediately whether its
    gradient is used or discarded, so every worker's arrival times form
    a renewal chain that is INDEPENDENT of the server recursion. The
    engine pre-draws per-worker-budgeted chains
    (:func:`_chain_plan_ragged` — the flat worker-major pool is
    ``sum(L_i)`` instead of the rectangle's ``n * max(L_i)``), merges
    the pool into global arrival order (ties by (worker, arrival
    index) — the backend's documented contract, preserved by the
    worker-major ragged layout), and replays the server recursion over
    the *certified* prefix — the arrivals strictly before the seed's
    certified horizon ``h_s = min_i`` (worker ``i``'s last drawn
    time), which provably contains no unmodeled arrival:

    * timing-only Async — no recursion at all: every certified arrival
      is a step, so the first ``K`` merged arrivals ARE the step times;
    * Ringmaster / any math path — a ``lax.scan`` whose body is O(1)
      per arrival (gather the popped worker's version, delay-test,
      masked step, scatter version/snapshot), vs the while_loop's
      O(S·n) argmin per arrival and K serialized pops.

    On chain exhaustion (a seed needs arrivals at or past its horizon)
    the engine does NOT cold-restart: it doubles the budgets, draws
    ONLY the extension slots (fold-in keyed prefix-stable draws,
    anchored sequential accumulation), re-merges, and resumes the scan
    from the carried ``(k, versions, snapshots, x)`` state over only
    the newly certified arrivals — the certified prefix is never
    re-drawn or re-scanned (``meta['scan_ranges']`` records the
    disjoint per-window position ranges). ``layout="rect"`` forces
    uniform rectangular budgets (:func:`_chain_plan`) for benchmarking;
    results are bitwise ``layout="ragged"`` under x64 (resume parity).

    Exactness: identical event order to the serial heap for
    deterministic models in generic position (delayed-gradient math via
    the same per-worker snapshots); distribution-equal for sampled
    models. After :data:`_CHAIN_RETRIES` windows the engine raises
    rather than silently dropping arrivals.

    ``mesh`` shards the chain build and the arrival scan over the
    seed/unit rows (``shard_map`` on the 1-D ``data`` axis; rows must be
    a multiple of the mesh size — the sweep layer pads). The merged pool
    sort and the per-seed compaction stay host-side exactly as in the
    unsharded path, and every device-side row is a pure function of its
    own key, so sharded results are bitwise the unsharded results.
    ``meta`` (if given) collects chain/scan wall times, program-cache
    hits, window count and draw/scan accounting for the routing
    record."""
    import time

    import jax
    import jax.numpy as jnp

    from ..kernels.order_stats import smallest_k
    from .time_models import ragged_layout

    math = problem is not None
    keys0, x_init = _keys_and_x(problem, S, n, seeds)
    sub = jax.vmap(lambda kk: jax.random.split(kk, 2))(keys0)
    gkey_root, chain_root = sub[:, 0], sub[:, 1]
    if math:
        xs_init = jnp.broadcast_to(x_init[:, None, :],
                                   (S, n) + x_init.shape[1:])

    # Async never discards: the window is exactly K. Ringmaster gets the
    # empirical discard budget (see _ring_pop_budget).
    budget = _ring_pop_budget(n, K, max_delay) if ringmaster else 0
    if chain_len:
        budgets = np.full(n, int(chain_len), np.int64)
    elif layout == "rect":
        budgets = np.full(n, _chain_plan(model, n, K + budget), np.int64)
    elif layout == "ragged":
        budgets = _chain_plan_ragged(model, n, K + budget)
    else:
        raise ValueError(f"unknown chain layout {layout!r}; "
                         "use 'ragged' or 'rect'")
    scan_needed = math or ringmaster
    dt = _engine_dtype()

    # host window state: per-worker drawn slot counts, the canonical
    # worker-major pool segments, and the per-seed progress counters
    drawn = np.zeros(n, np.int64)
    segs = [np.zeros((S, 0)) for _ in range(n)]
    anchors = jnp.zeros((S, n), dt)
    carry = None                        # device scan carry across windows
    c_prev = np.zeros(S, np.int64)      # certified arrivals consumed
    kfin = np.zeros(S, np.int64)
    comp = np.zeros(S, np.int64)
    filled = np.zeros(S, np.int64)      # accepted steps committed
    T = np.zeros((K, S))
    vK = np.zeros((K, S)) if math else None
    gK = np.zeros((K, S)) if math else None
    x = val = gn = None
    if meta is not None:
        meta.setdefault("chain_s", 0.0)
        meta.setdefault("scan_s", 0.0)
        meta.update(layout=layout, windows=0, drawn_slots=[],
                    scan_ranges=[])

    for _ in range(_CHAIN_RETRIES):
        # draw ONLY the extension slots, anchored at the carried last
        # absolute times (window 0: everything, anchored at t = 0)
        ext = budgets - drawn
        builder = _chain_builder(model, S, n, ext, starts=drawn, mesh=mesh)
        t0 = time.perf_counter()
        flat_ext, anchors = builder(chain_root, anchors)
        flat_ext = jax.block_until_ready(flat_ext)
        if meta is not None:
            meta["chain_s"] = round(
                meta["chain_s"] + time.perf_counter() - t0, 4)
            meta["windows"] += 1
            meta["drawn_slots"].append(int(ext.sum()))
        ext_np = np.asarray(flat_ext)
        eoff, _, _, _ = ragged_layout(ext, drawn)
        for i in range(n):
            segs[i] = np.concatenate(
                [segs[i], ext_np[:, eoff[i]:eoff[i] + ext[i]]], axis=1)
        drawn = budgets.copy()
        pool = np.concatenate(segs, axis=1)         # canonical (S, total)
        _, widx_flat, _, total = ragged_layout(drawn)
        if meta is not None:
            meta["pool_elems"] = total

        # merged global arrival order + certified horizon per seed
        h = np.asarray(anchors).min(axis=1)         # (S,)
        A_cap = int(min(K + budget, total))
        t_seq, idx = smallest_k(jnp.asarray(pool), A_cap)
        t_host = np.asarray(t_seq)                  # (S, A_cap) ascending
        w_all = widx_flat[np.asarray(idx)]          # (S, A_cap) worker ids
        done = kfin >= K
        # certified: strictly before the horizon (an arrival AT the
        # horizon could tie with an undrawn slot of the slowest worker)
        c_new = np.array([np.searchsorted(t_host[s], h[s], side="left")
                          for s in range(S)], dtype=np.int64)
        c_new = np.where(done, c_prev,
                         np.clip(c_new, c_prev, A_cap))

        live_seeds = np.flatnonzero(~done)
        p0 = int(c_prev[live_seeds].min()) if live_seeds.size else 0
        p1 = int(c_new.max()) if live_seeds.size else 0

        if not scan_needed:
            # timing-only Async: every certified arrival is a step
            for s in live_seeds:
                take = min(int(c_new[s] - c_prev[s]), K - int(kfin[s]))
                if take > 0:
                    lo = int(c_prev[s])
                    T[int(filled[s]):int(filled[s]) + take, s] = \
                        t_host[s, lo:lo + take]
                    filled[s] += take
                    kfin[s] += take
                    comp[s] += take
            if meta is not None:
                meta["scan_ranges"].append((p0, p1))
        elif p1 > p0:
            W = p1 - p0
            pos_idx = np.arange(p0, p1, dtype=np.int64)
            w_win = jnp.asarray(
                w_all[:, p0:p1].T.astype(np.int32))          # (W, S)
            valid = jnp.asarray(
                (pos_idx[:, None] >= c_prev[None, :])
                & (pos_idx[:, None] < c_new[None, :]))       # (W, S)
            if carry is None:
                k0 = jnp.zeros(S, jnp.int32)
                ver0 = jnp.zeros((S, n), jnp.int32)
                comp0 = jnp.zeros(S, jnp.int32)
                carry = ((k0, ver0, comp0, x_init, xs_init) if math
                         else (k0, ver0, comp0))
            t0 = time.perf_counter()
            if math:
                prog = _arrival_math_prog(problem, gamma, delay_adaptive,
                                          S, n, K, max_delay, mesh=mesh)
                pos = jnp.asarray(pos_idx.astype(np.int32))
                kf, ver, cmp_, x_c, xs_c, acc, v_w, g_w = \
                    jax.block_until_ready(prog(
                        w_win, valid, pos, gkey_root, *carry))
                carry = (kf, ver, cmp_, x_c, xs_c)
                v_w = np.asarray(v_w)
                g_w = np.asarray(g_w)
            else:
                scan_key_known = (
                    S, n, K, max_delay, W,
                    bool(jax.config.jax_enable_x64),
                    None if mesh is None else _mesh_cache_key(mesh)
                ) in _SCAN_PROGS
                if meta is not None:
                    meta["scan_cache_hit"] = scan_key_known
                kf, ver, cmp_, acc = jax.block_until_ready(
                    _ring_timing_prog(S, n, K, max_delay, W,
                                      mesh=mesh)(w_win, valid, *carry))
                carry = (kf, ver, cmp_)
            if meta is not None:
                meta["scan_s"] = round(
                    meta["scan_s"] + time.perf_counter() - t0, 4)
                meta["scan_ranges"].append((p0, p1))
            kfin = np.asarray(kf).astype(np.int64)
            comp = np.asarray(cmp_).astype(np.int64)
            acc = np.asarray(acc)                    # (W, S), valid-gated
            for s in live_seeds:
                sel = np.flatnonzero(acc[:, s])
                sel = sel[:K - int(filled[s])]
                got = sel.size
                lo = int(filled[s])
                T[lo:lo + got, s] = t_host[s, p0 + sel]
                if math:
                    vK[lo:lo + got, s] = v_w[sel, s]
                    gK[lo:lo + got, s] = g_w[sel, s]
                filled[s] += got

        c_prev = c_new
        if (kfin >= K).all():
            if math:
                x = carry[3]
                val, gn = vK, gK
            return comp.astype(np.int64), x, T, val, gn
        # exhaustion: double every budget (the extension draws and
        # scans only the new slots/arrivals); Ringmaster's discard
        # budget grows with the pool so the window can absorb storms
        budgets = budgets * 2
        if ringmaster:
            budget = min(budget * 4, int(budgets.sum()) - K)
    raise RuntimeError(
        f"arrival-scan jax engine could not certify chain coverage "
        f"within its per-worker renewal-chain budgets (max "
        f"{int(budgets.max()) // 2} slots) even after doubling windows "
        f"(extreme speed heterogeneity or a discard storm — max_delay "
        f"far below the typical delay?); pass a larger chain_len to "
        f"simulate_batch_jax or use backend='serial'")


def _arrival_while_run(model, problem, max_delay, delay_adaptive, n, S, K,
                       gamma, seeds):
    """PR 4 reference engine — Async/Ringmaster as an arrival-indexed
    ``lax.while_loop``. NOT routed by :func:`simulate_batch_jax` anymore
    (the renewal-chain arrival scan replaced it); kept callable via
    ``async_engine="while"`` as the benchmark baseline
    (``benchmarks/simbatch_speed.py`` gates the scan's speedup against
    it) and as an independent cross-check of the scan's recursion.

    Each
    iteration pops the earliest pending finish per seed (ties by worker
    index), steps unless the gradient's delay exceeds ``max_delay``
    (discard => recompute at the current iterate), and restarts the
    popped worker. The restart costs ONE keyed draw from the pre-split
    per-(seed, worker) key grid — worker streams are pure functions of
    ``(seed value, worker index)``, independent of arrival order (the
    keyed-draw contract, DESIGN.md §3b) — instead of a full ``(S, n)``
    row per arrival. Per-worker start-iterate snapshots (``xs``)
    evaluate delayed gradients at the iterate they started from, exactly
    like the event engine's snapshot dict. Returns per-step time/value
    buffers."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    from .time_models import jax_worker_key_grid

    math = problem is not None
    keys0, x_init = _keys_and_x(problem, S, n, seeds)
    xs_init = jnp.broadcast_to(x_init[:, None, :],
                               (S, n) + x_init.shape[1:])

    fixed = isinstance(model, FixedTimes)
    universal = isinstance(model, UniversalModel)
    sampled = not fixed and not universal
    if fixed:
        taus = jnp.asarray(model.taus)
    elif sampled:
        item = model.jax_sampler_item
        if item is None:
            # correct fallback for user models without a single-draw
            # sampler: draw the row, keep one column (~n× draw volume)
            row_sampler = model.jax_sampler

            def item(key, i):
                return row_sampler(key)[i]

    rows = jnp.arange(S)
    widx = jnp.arange(n)
    # Async pops exactly K arrivals. Ringmaster also pays discards, but
    # a worker can only be re-discarded after another step lands, so
    # each worker is discarded at most K+1 times: arrivals are bounded
    # by K accepts + n*(K+1) discards. The cap is that bound plus slack
    # and only guards against a broken recursion — the caller verifies
    # every seed reached K and raises otherwise.
    cap = (K + 1) * (n + 2) + 64

    def cond(carry):
        it, ft, ver, k = carry[0], carry[1], carry[2], carry[3]
        return jnp.any(k < K) & (it < cap)

    def body(carry):
        it, ft, ver, k, comp, x, xs, keys, grid, Tb, vb, gb = carry
        sub = jax.vmap(lambda kk: jax.random.split(kk, 2))(keys)
        keys = sub[:, 0]
        w = jnp.argmin(ft, axis=1)                # earliest pending pop
        t = ft[rows, w]
        delay = k - ver[rows, w]
        active = k < K
        accept = active & (delay <= max_delay)
        kc = jnp.clip(k, 0, K - 1)
        if math:
            g = jax.vmap(problem.stoch_grad)(xs[rows, w], sub[:, 1])
            # g.dtype, not a hard-coded float32: under x64=True the
            # carry is float64 and a float32 mult would silently down-
            # cast the step (the scan engine already derives its dtype).
            mult = (1.0 / (1.0 + delay.astype(g.dtype) / n)
                    if delay_adaptive else jnp.ones(S, g.dtype))
            x = jnp.where(accept[:, None],
                          x - gamma * mult[:, None] * g, x)
            val = jax.vmap(problem.f)(x)
            gn = jax.vmap(lambda xx: jnp.sum(problem.grad(xx) ** 2))(x)
            vb = vb.at[rows, kc].set(jnp.where(accept, val, vb[rows, kc]))
            gb = gb.at[rows, kc].set(jnp.where(accept, gn, gb[rows, kc]))
        Tb = Tb.at[rows, kc].set(jnp.where(accept, t, Tb[rows, kc]))
        k = k + accept.astype(k.dtype)
        # restart the popped worker: one keyed draw (or inversion)
        if fixed:
            ftw = t + taus[w]
        elif universal:
            ftw = model.finish_times_jax(t, workers=w)
        else:
            kw = jax.vmap(jax.random.split)(grid[rows, w])  # (S, 2, 2)
            grid = grid.at[rows, w].set(kw[:, 0])
            ftw = t + jax.vmap(item)(kw[:, 1], w)
        ft = ft.at[rows, w].set(jnp.where(active, ftw, ft[rows, w]))
        ver = ver.at[rows, w].set(jnp.where(active, k, ver[rows, w]))
        xs = xs.at[rows, w].set(jnp.where(active[:, None], x, xs[rows, w]))
        comp = comp + active.astype(comp.dtype)
        return (it + 1, ft, ver, k, comp, x, xs, keys, grid, Tb, vb, gb)

    @jax.jit
    def run(keys):
        sub = jax.vmap(lambda kk: jax.random.split(kk, 2))(keys)
        if fixed:
            grid = jnp.zeros((1, 1, 2), jnp.uint32)        # unused
            ft0 = jnp.broadcast_to(taus, (S, n))
        elif universal:
            grid = jnp.zeros((1, 1, 2), jnp.uint32)        # unused
            ft0 = model.finish_times_jax(jnp.zeros((S, n)))
        else:
            grid = jax_worker_key_grid(sub[:, 1], n)       # (S, n, 2)
            kk = jax.vmap(jax.vmap(jax.random.split))(grid)
            grid = kk[:, :, 0]
            ft0 = jax.vmap(jax.vmap(item))(
                kk[:, :, 1], jnp.broadcast_to(widx, (S, n)))
        init = (jnp.zeros((), jnp.int32), ft0,
                jnp.zeros((S, n), jnp.int32), jnp.zeros(S, jnp.int32),
                jnp.zeros(S, jnp.int32), x_init, xs_init, sub[:, 0], grid,
                jnp.zeros((S, K)), jnp.zeros((S, K)), jnp.zeros((S, K)))
        out = lax.while_loop(cond, body, init)
        _, _, _, k, comp, x, _, _, _, Tb, vb, gb = out
        return k, comp, x, Tb.T, vb.T, gb.T      # (K, S) like the scans

    kfin, comp, x, T, val, gn = jax.block_until_ready(run(keys0))
    if int(np.min(np.asarray(kfin))) < K:
        raise RuntimeError(
            f"arrival-indexed jax backend hit its {cap}-arrival cap "
            f"before finishing K={K} iterations (max_delay too tight?); "
            f"use backend='serial'")
    return comp, x, T, val, gn


def simulate_batch_jax(strategy: AggregationStrategy,
                       model,
                       K: int,
                       problem: Optional[JaxProblem] = None,
                       gamma: float = 0.0,
                       seeds: Sequence[int] = (0,),
                       record_every: int = 1,
                       use_pallas: bool = False,
                       malenia_chain: Optional[int] = None,
                       async_chain: Optional[int] = None,
                       async_engine: str = "scan",
                       async_layout: str = "ragged",
                       x64: bool = False) -> List[Trace]:
    """One jitted ``(seeds, ...)`` array program per strategy family
    (m-sync round scan, Rennala/Malenia renewal scans, Async/Ringmaster
    arrival scan); returns the per-seed :class:`Trace` list
    (timing-only traces have empty arrays, like the scalar fast path).

    RNG/backend guarantees: every draw comes from ``jax.random`` keys
    derived from ``PRNGKey(seed)`` — per-seed reproducible, sweep-
    independent, equal in distribution to (never stream-equal with) the
    NumPy engines; deterministic models (FixedTimes, universal) match
    the NumPy engines to float tolerance in generic position, with ties
    broken by worker index. ``malenia_chain`` overrides the Malenia
    engine's per-round renewal-chain length — the default
    ``max(2*ceil(S), ceil(3*spread) + ceil(S), 8)`` scales with the
    model's mean-speed spread ``max(tau)/min(tau)`` (fast workers keep
    arriving while the slowest delivers its first), so strongly
    heterogeneous models allocate ``(seeds, n, L+1)`` chains with large
    ``L``; the engine retries with doubled chains, then raises, if a
    round outruns them. ``async_chain`` is the analogous override for
    the Async/Ringmaster arrival-scan chains (default from
    :func:`_chain_plan_ragged`); ``async_engine="while"`` falls back to
    the PR 4 ``lax.while_loop`` reference engine (benchmarking/
    cross-checks only). ``async_layout`` picks the arrival-scan chain
    layout: ``"ragged"`` (default — per-worker budgets proportional to
    mean rates) or ``"rect"`` (uniform rectangular budgets, the
    pre-windowed baseline); both produce identical results (bitwise
    under x64) since certified arrivals are layout-independent.

    ``x64=True`` runs the whole program in float64 (via
    ``jax.experimental.enable_x64``): slower, but gives per-run tie
    parity with the float64 NumPy event heap on adversarially tie-heavy
    instances (flat-power partial participation) where float32
    tie-breaking diverges by whole events.

    The FixedTimes timing-only m-sync case and the timing-only
    arrival-scan programs hit module-level jit caches (no recompile
    across calls of the same shape); the other programs close over the
    oracle and sampler, so they recompile per call — fine for
    sweep-sized S × K, not for tight loops of tiny calls.
    """
    import jax
    import jax.numpy as jnp

    if x64 and not jax.config.jax_enable_x64:
        from jax.experimental import enable_x64
        with enable_x64():
            return simulate_batch_jax(
                strategy, model, K, problem=problem, gamma=gamma,
                seeds=seeds, record_every=record_every,
                use_pallas=use_pallas, malenia_chain=malenia_chain,
                async_chain=async_chain, async_engine=async_engine,
                async_layout=async_layout, x64=False)

    strategy.bind(model.n)
    kind = _check_supported(strategy, model, problem)
    n = model.n
    S = len(seeds)
    K = int(K)
    if K <= 0:
        raise ValueError(f"K={K} must be positive for the jax backend")

    if isinstance(model, UniversalModel) and problem is None and S > 1:
        # universal timing-only runs are deterministic (finish-time
        # inversions, no draws): compute one seed, replicate the Trace
        row = simulate_batch_jax(strategy, model, K, problem=None,
                                 gamma=gamma, seeds=[seeds[0]],
                                 record_every=record_every,
                                 use_pallas=use_pallas,
                                 malenia_chain=malenia_chain,
                                 async_chain=async_chain,
                                 async_engine=async_engine,
                                 async_layout=async_layout)
        return [dataclasses.replace(row[0]) for _ in range(S)]

    fixed = isinstance(model, FixedTimes)
    math = problem is not None

    if kind == "msync":
        m = strategy._m
        used = m * K
        if fixed and not math:
            global _fixed_timing_jit
            if _fixed_timing_jit is None:
                _fixed_timing_jit = jax.jit(
                    _fixed_timing_run,
                    static_argnames=("S", "m", "K", "use_pallas"))
            comp, T = jax.block_until_ready(_fixed_timing_jit(
                jnp.asarray(model.taus), S=S, m=m, K=K,
                use_pallas=use_pallas))
            x = val = gn = None
        else:
            comp, x, T, val, gn = _general_run(model, problem, m, n, S, K,
                                               gamma, use_pallas, seeds)
    elif kind == "rennala":
        used = int(strategy.batch) * K
        comp, x, T, val, gn = _rennala_run(model, problem,
                                           int(strategy.batch), n, S, K,
                                           gamma, use_pallas, seeds)
    elif kind == "malenia":
        comp, x, T, val, gn, used = _malenia_run(
            model, problem, float(strategy.S), n, S, K, gamma, seeds,
            chain_len=malenia_chain)
    elif kind == "ringleader":
        comp, x, T, val, gn, used = _ringleader_run(
            model, problem, n, S, K, gamma, seeds, chain_len=async_chain)
    else:
        used = K          # every server step consumes exactly one gradient
        md = (int(strategy.max_delay)
              if kind in ("ringmaster", "optimal_asgd") else K + 1)
        adaptive = bool(getattr(strategy, "delay_adaptive", False))
        if async_engine == "while":               # PR 4 reference engine
            comp, x, T, val, gn = _arrival_while_run(
                model, problem, md, adaptive, n, S, K, gamma, seeds)
        elif async_engine == "scan":
            comp, x, T, val, gn = _chain_scan_run(
                model, problem, kind in ("ringmaster", "optimal_asgd"),
                md, adaptive, n, S, K, gamma, seeds, chain_len=async_chain,
                layout=async_layout)
        else:
            raise ValueError(f"unknown async_engine {async_engine!r}; "
                             "use 'scan' or 'while'")

    return assemble_traces(comp, x, T, val, gn, used, S, K, record_every,
                           problem)


def assemble_traces(comp, x, T, val, gn, used, S, K, record_every,
                    problem) -> List[Trace]:
    """Package raw engine outputs (``comp (S,)``, ``T/val/gn (K, S)``,
    ``x (S, d)``) into the per-seed :class:`Trace` list — shared by
    :func:`simulate_batch_jax` and the sharded sweep backend, so both
    paths produce structurally identical traces from identical arrays."""
    import jax.numpy as jnp

    math = problem is not None
    comp = np.asarray(comp)
    T = np.asarray(T)                             # (K, S)
    used = np.broadcast_to(np.asarray(used), (S,))  # malenia: per seed
    total = T[-1]
    traces: List[Trace] = []
    if math:
        val = np.asarray(val)
        gn = np.asarray(gn)
        x_np = np.asarray(x)
        rec = np.arange(record_every, K + 1, record_every)     # steps k
        x0j = jnp.asarray(problem.x0, dtype=_engine_dtype())
        f0 = float(problem.f(x0j))
        g0 = np.asarray(problem.grad(x0j))
        gn0 = float(np.dot(g0, g0))
        for s in range(S):
            times = np.concatenate([[0.0], T[rec - 1, s]])
            vals = np.concatenate([[f0], val[rec - 1, s]])
            gns = np.concatenate([[gn0], gn[rec - 1, s]])
            traces.append(Trace(times, vals, gns, iterations=K,
                                total_time=float(total[s]),
                                gradients_used=int(used[s]),
                                gradients_computed=int(comp[s]),
                                x_final=x_np[s]))
    else:
        e = np.array([])
        for s in range(S):
            traces.append(Trace(e, e, e, iterations=K,
                                total_time=float(total[s]),
                                gradients_used=int(used[s]),
                                gradients_computed=int(comp[s])))
    return traces
