"""JAX backend for :func:`repro.core.batch.simulate_batch`.

Runs the m-sync round recursion as ONE array program over a
``(seeds, workers)`` state batch: a ``lax.scan`` over rounds whose body is
pure elementwise work plus the per-round m-th order statistic from
:mod:`repro.kernels.order_stats` (iterative tie-class extraction by
default; optionally the Pallas top-m partial-sort kernel via
``use_pallas=True``). The math-carrying path evaluates a
:class:`JaxProblem` oracle under ``jax.vmap`` over seeds — n=1000 ×
32-seed sweeps execute as a single jitted program instead of 32 serial
event loops (~6x over the serial fast path on CPU here, far more on real
accelerators).

Exactness contract (documented in DESIGN.md): the NumPy engines break
wall-clock ties by exact event-heap sequence numbers; this backend breaks
them by worker index and draws with ``jax.random`` instead of NumPy
``Generator`` streams. For deterministic models in generic position the
round recursion is identical and results match the NumPy backends to
float tolerance; for random models the results are equal in distribution,
not per-seed. Supported: the m-sync family (unmodified arrival
semantics) under :class:`FixedTimes`, or a
:class:`~repro.core.time_models.SubExponentialTimes` carrying a
``jax_sampler``; timing-only or with a :class:`JaxProblem`.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from .strategies import AggregationStrategy, MSync, Trace
from .time_models import FixedTimes, SubExponentialTimes

__all__ = ["JaxProblem", "quadratic_worst_case_jax", "simulate_batch_jax"]


@dataclasses.dataclass
class JaxProblem:
    """A :class:`~repro.core.strategies.Problem` twin with JAX callables.

    ``stoch_grad(x, key)`` replaces the NumPy oracle's
    ``stoch_grad(x, rng)`` so gradient noise comes from ``jax.random``
    and the whole seed sweep stays inside one jitted program.
    """

    x0: "np.ndarray"
    f: Callable
    grad: Callable
    stoch_grad: Callable


def quadratic_worst_case_jax(d: int = 1000, p: float = 0.1,
                             scale: float = 0.25) -> JaxProblem:
    """JAX twin of :func:`repro.core.oracle.quadratic_worst_case` —
    same tridiagonal quadratic, same eq. (27) progress-gated Bernoulli
    oracle, with ``jax.random`` noise."""
    import jax
    import jax.numpy as jnp

    main = 2.0 * scale * np.ones(d)
    off = -scale * np.ones(d - 1)
    b_np = np.zeros(d)
    b_np[0] = -scale
    A = np.diag(main) + np.diag(off, 1) + np.diag(off, -1)
    x_star = np.linalg.solve(A, b_np)
    f_star = float(0.5 * x_star @ (A @ x_star) - b_np @ x_star)

    b = jnp.asarray(b_np)
    sc = scale

    def matvec(x):
        y = 2.0 * sc * x
        y = y.at[:-1].add(-sc * x[1:])
        y = y.at[1:].add(-sc * x[:-1])
        return y

    def f(x):
        return 0.5 * x @ matvec(x) - b @ x - f_star

    def grad(x):
        return matvec(x) - b

    def stoch_grad(x, key):
        g = grad(x)
        nz = x != 0
        # prog(x) = max{i >= 1 : x_i != 0} (1-indexed), 0 if x == 0
        pr = jnp.max(jnp.where(nz, jnp.arange(1, d + 1), 0))
        xi = jax.random.bernoulli(key, p).astype(x.dtype)
        gate = jnp.where(jnp.arange(d) < pr, 1.0, xi / p)
        return g * gate

    x0 = np.zeros(d)
    x0[0] = np.sqrt(d)
    return JaxProblem(x0=x0, f=f, grad=grad, stoch_grad=stoch_grad)


def _check_supported(strategy: AggregationStrategy, model, problem) -> None:
    ok = (isinstance(strategy, MSync)
          and type(strategy).on_arrival is MSync.on_arrival
          and type(strategy).on_step is AggregationStrategy.on_step
          and not strategy.uses_alarm
          and strategy.grads_by_worker is None)
    if not ok:
        raise NotImplementedError(
            f"jax backend supports the unmodified m-sync family only, "
            f"not {strategy.name!r}; use backend='serial'")
    if isinstance(model, FixedTimes):
        pass
    elif isinstance(model, SubExponentialTimes) \
            and getattr(model, "jax_sampler", None) is not None:
        pass
    else:
        raise NotImplementedError(
            f"jax backend needs FixedTimes or a SubExponentialTimes with "
            f"a jax_sampler (got {type(model).__name__}); "
            f"use backend='serial' or 'vectorized'")
    if problem is not None and not isinstance(problem, JaxProblem):
        raise NotImplementedError(
            "jax backend takes a JaxProblem (jax.random oracle), not the "
            "NumPy Problem; use backend='serial' for NumPy oracles")


def _timing_round(ft, ver, comp, k, cand, m, use_pallas):
    """Shared m-sync round update on ``(S, n)`` state (see module doc)."""
    import jax.numpy as jnp
    from jax import lax

    from ..kernels.order_stats import mth_smallest

    stale = ver < k
    T = mth_smallest(cand, m, use_pallas=use_pallas)
    leq = cand <= T[:, None]

    def exact_acc(_):
        # ties straddle the m-boundary somewhere: rank tied candidates by
        # worker index and accept only up to the per-row quota (cumsum is
        # ~40% of the round cost, so it only runs on tie rounds)
        c_lt = (cand < T[:, None]).sum(axis=1)
        tie = cand == T[:, None]
        tie_rank = jnp.cumsum(tie, axis=1) - 1
        return (cand < T[:, None]) | (tie
                                      & (tie_rank < (m - c_lt)[:, None]))

    acc = lax.cond(jnp.all(leq.sum(axis=1) == m),
                   lambda _: leq, exact_acc, operand=None)
    popped = stale & (ft < T[:, None])
    comp = comp + m + popped.sum(axis=1)
    ft = jnp.where(popped, cand, ft)
    ver = jnp.where(popped, k, ver)
    return ft, ver, comp, T, acc


def _fixed_timing_run(taus, S: int, m: int, K: int, use_pallas: bool):
    """Timing-only m-sync under FixedTimes: module-level jit, cached
    across calls (the benchmark-smoke hot path — no RNG at all)."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    n = taus.shape[0]

    def step(carry, k):
        ft, ver, comp = carry
        stale = ver < k
        cand = jnp.where(stale, ft + taus, ft)
        ft, ver, comp, T, acc = _timing_round(ft, ver, comp, k, cand, m,
                                              use_pallas)
        ft = jnp.where(acc, T[:, None] + taus, ft)
        ver = jnp.where(acc, k + 1, ver)
        return (ft, ver, comp), T

    init = (jnp.broadcast_to(taus, (S, n)), jnp.zeros((S, n), jnp.int32),
            jnp.zeros(S, jnp.int32))
    (_, _, comp), T = lax.scan(step, init, jnp.arange(K, dtype=jnp.int32))
    return comp, T


_fixed_timing_jit = None


def _general_run(model, problem, m, n, S, K, gamma, use_pallas, seeds):
    """RNG-threading scan: random time models and/or a JaxProblem oracle.

    Every seed's draw stream is a pure function of its ``PRNGKey(seed)``
    (a 4-way split of its own carried key per round). Closes over the
    sampler/oracle, so jit caching is per call.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax

    fixed = isinstance(model, FixedTimes)
    math = problem is not None
    keys0 = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    if fixed:
        taus = jnp.asarray(model.taus)

        def draw(round_keys):                     # no RNG consumed
            return jnp.broadcast_to(taus, (S, n))
    else:
        sampler = model.jax_sampler

        def draw(round_keys):
            return jax.vmap(sampler)(round_keys)  # one (n,) draw per seed

    if math:
        x_init = jnp.broadcast_to(
            jnp.asarray(problem.x0, dtype=jnp.float32),
            (S,) + np.shape(problem.x0)).astype(jnp.float32)

        def grad_mean(x, round_keys):             # mean of m stoch grads
            gkeys = jax.vmap(lambda k: jax.random.split(k, m))(round_keys)
            per_seed = jax.vmap(jax.vmap(problem.stoch_grad, (None, 0)),
                                (0, 0))
            return per_seed(x, gkeys).mean(axis=1)
    else:
        x_init = jnp.zeros((S, 1))

    def step(carry, k):
        ft, ver, comp, x, keys = carry
        sub = jax.vmap(lambda kk: jax.random.split(kk, 4))(keys)
        keys = sub[:, 0]
        stale = ver < k
        cand = jnp.where(stale, ft + draw(sub[:, 1]), ft)
        ft, ver, comp, T, acc = _timing_round(ft, ver, comp, k, cand, m,
                                              use_pallas)
        ft = jnp.where(acc, T[:, None] + draw(sub[:, 2]), ft)
        ver = jnp.where(acc, k + 1, ver)
        if math:
            x = x - gamma * grad_mean(x, sub[:, 3])
            val = jax.vmap(problem.f)(x)
            gn = jax.vmap(lambda xx: jnp.sum(problem.grad(xx) ** 2))(x)
        else:
            val = gn = jnp.zeros(S)
        return (ft, ver, comp, x, keys), (T, val, gn)

    @jax.jit
    def run(keys):
        sub = jax.vmap(lambda kk: jax.random.split(kk, 2))(keys)
        ft0 = draw(sub[:, 1])
        init = (ft0, jnp.zeros((S, n), jnp.int32), jnp.zeros(S, jnp.int32),
                x_init, sub[:, 0])
        (_, _, comp, x, _), (T, val, gn) = lax.scan(
            step, init, jnp.arange(K, dtype=jnp.int32))
        return comp, x, T, val, gn

    return jax.block_until_ready(run(keys0))


def simulate_batch_jax(strategy: AggregationStrategy,
                       model,
                       K: int,
                       problem: Optional[JaxProblem] = None,
                       gamma: float = 0.0,
                       seeds: Sequence[int] = (0,),
                       record_every: int = 1,
                       use_pallas: bool = False) -> List[Trace]:
    """One jitted ``(seeds, rounds, workers)`` m-sync program; returns the
    per-seed :class:`Trace` list (timing-only traces have empty arrays,
    like the scalar fast path).

    The FixedTimes timing-only case hits a module-level jit cache (no
    recompile across calls of the same shape); math/random-model programs
    close over the oracle and sampler, so they recompile per call — fine
    for sweep-sized S × K, not for tight loops of tiny calls.
    """
    import jax
    import jax.numpy as jnp

    strategy.bind(model.n)
    _check_supported(strategy, model, problem)
    m = strategy._m
    n = model.n
    S = len(seeds)
    K = int(K)
    if K <= 0:
        raise ValueError(f"K={K} must be positive for the jax backend")

    fixed = isinstance(model, FixedTimes)
    math = problem is not None

    if fixed and not math:
        global _fixed_timing_jit
        if _fixed_timing_jit is None:
            _fixed_timing_jit = jax.jit(
                _fixed_timing_run,
                static_argnames=("S", "m", "K", "use_pallas"))
        comp, T = jax.block_until_ready(_fixed_timing_jit(
            jnp.asarray(model.taus), S=S, m=m, K=K, use_pallas=use_pallas))
        x = val = gn = None
    else:
        comp, x, T, val, gn = _general_run(model, problem, m, n, S, K,
                                           gamma, use_pallas, seeds)

    comp = np.asarray(comp)
    T = np.asarray(T)                             # (K, S)
    total = T[-1]
    traces: List[Trace] = []
    if math:
        val = np.asarray(val)
        gn = np.asarray(gn)
        x_np = np.asarray(x)
        rec = np.arange(record_every, K + 1, record_every)     # steps k
        x0j = jnp.asarray(problem.x0, dtype=jnp.float32)
        f0 = float(problem.f(x0j))
        g0 = np.asarray(problem.grad(x0j))
        gn0 = float(np.dot(g0, g0))
        for s in range(S):
            times = np.concatenate([[0.0], T[rec - 1, s]])
            vals = np.concatenate([[f0], val[rec - 1, s]])
            gns = np.concatenate([[gn0], gn[rec - 1, s]])
            traces.append(Trace(times, vals, gns, iterations=K,
                                total_time=float(total[s]),
                                gradients_used=m * K,
                                gradients_computed=int(comp[s]),
                                x_final=x_np[s]))
    else:
        e = np.array([])
        for s in range(S):
            traces.append(Trace(e, e, e, iterations=K,
                                total_time=float(total[s]),
                                gradients_used=m * K,
                                gradients_computed=int(comp[s])))
    return traces
