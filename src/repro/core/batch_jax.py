"""JAX backend for :func:`repro.core.batch.simulate_batch`.

Runs device-resident simulation as ONE array program over a
``(seeds, workers)`` state batch, one jitted recursion per strategy
family:

* **m-sync family** — a ``lax.scan`` over rounds whose body is pure
  elementwise work plus the per-round m-th order statistic from
  :mod:`repro.kernels.order_stats` (iterative tie-class extraction by
  default; optionally the Pallas top-m partial-sort kernel via
  ``use_pallas=True``).
* **Rennala** — the same renewal structure, per round accumulating
  ``batch`` arrivals: each worker's within-round arrivals form a renewal
  chain (cumulative sums of fresh draws), the round ends at the
  ``batch``-th smallest chain entry, and every worker's next pending
  computation is its first chain entry past the round end.
* **Async / Ringmaster** — an arrival-indexed ``lax.while_loop``: each
  iteration pops the earliest pending finish per seed, steps (or, for
  Ringmaster, discards over-delayed gradients), and restarts that worker;
  per-worker start-iterate snapshots make the delayed-gradient math path
  exact.

The math-carrying paths evaluate a :class:`JaxProblem` oracle under
``jax.vmap`` over seeds — n=1000 × 32-seed sweeps execute as a single
jitted program instead of 32 serial event loops (~6x over the serial
fast path on CPU here, far more on real accelerators).

Exactness contract (documented in DESIGN.md): the NumPy engines break
wall-clock ties by exact event-heap sequence numbers; this backend breaks
them by worker index (and within-round arrival index for Rennala) and
draws with ``jax.random`` instead of NumPy ``Generator`` streams. For
deterministic models in generic position the recursions are identical
and results match the NumPy backends to float tolerance; for random
models the results are equal in distribution, not per-seed. Supported
models: :class:`FixedTimes`, or a
:class:`~repro.core.time_models.SubExponentialTimes` carrying a
``jax_sampler`` (every in-tree factory does); timing-only or with a
:class:`JaxProblem`.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from .strategies import (AggregationStrategy, Async, MSync, Rennala,
                         Ringmaster, Trace)
from .time_models import FixedTimes, SubExponentialTimes

__all__ = ["JaxProblem", "quadratic_worst_case_jax", "simulate_batch_jax",
           "jax_supported"]


@dataclasses.dataclass
class JaxProblem:
    """A :class:`~repro.core.strategies.Problem` twin with JAX callables.

    ``stoch_grad(x, key)`` replaces the NumPy oracle's
    ``stoch_grad(x, rng)`` so gradient noise comes from ``jax.random``
    and the whole seed sweep stays inside one jitted program.
    """

    x0: "np.ndarray"
    f: Callable
    grad: Callable
    stoch_grad: Callable


def quadratic_worst_case_jax(d: int = 1000, p: float = 0.1,
                             scale: float = 0.25) -> JaxProblem:
    """JAX twin of :func:`repro.core.oracle.quadratic_worst_case` —
    same tridiagonal quadratic, same eq. (27) progress-gated Bernoulli
    oracle, with ``jax.random`` noise."""
    import jax
    import jax.numpy as jnp

    main = 2.0 * scale * np.ones(d)
    off = -scale * np.ones(d - 1)
    b_np = np.zeros(d)
    b_np[0] = -scale
    A = np.diag(main) + np.diag(off, 1) + np.diag(off, -1)
    x_star = np.linalg.solve(A, b_np)
    f_star = float(0.5 * x_star @ (A @ x_star) - b_np @ x_star)

    b = jnp.asarray(b_np)
    sc = scale

    def matvec(x):
        y = 2.0 * sc * x
        y = y.at[:-1].add(-sc * x[1:])
        y = y.at[1:].add(-sc * x[:-1])
        return y

    def f(x):
        return 0.5 * x @ matvec(x) - b @ x - f_star

    def grad(x):
        return matvec(x) - b

    def stoch_grad(x, key):
        g = grad(x)
        nz = x != 0
        # prog(x) = max{i >= 1 : x_i != 0} (1-indexed), 0 if x == 0
        pr = jnp.max(jnp.where(nz, jnp.arange(1, d + 1), 0))
        xi = jax.random.bernoulli(key, p).astype(x.dtype)
        gate = jnp.where(jnp.arange(d) < pr, 1.0, xi / p)
        return g * gate

    x0 = np.zeros(d)
    x0[0] = np.sqrt(d)
    return JaxProblem(x0=x0, f=f, grad=grad, stoch_grad=stoch_grad)


def _classify(strategy: AggregationStrategy) -> Optional[str]:
    """Which jitted recursion runs ``strategy`` (None => unsupported)."""
    if (isinstance(strategy, MSync)
            and type(strategy).on_arrival is MSync.on_arrival
            and type(strategy).on_step is AggregationStrategy.on_step
            and not strategy.uses_alarm
            and strategy.grads_by_worker is None):
        return "msync"
    # exact types: subclasses may override semantics the scans hard-code
    if type(strategy) is Rennala:
        return "rennala"
    if type(strategy) is Async:
        return "async"
    if type(strategy) is Ringmaster:
        return "ringmaster"
    return None


def _model_supported(model) -> bool:
    return (isinstance(model, FixedTimes)
            or (isinstance(model, SubExponentialTimes)
                and getattr(model, "jax_sampler", None) is not None))


def jax_supported(strategy: AggregationStrategy, model, problem) -> bool:
    """Non-raising eligibility probe (``backend="fastest"`` uses this)."""
    return (_classify(strategy) is not None and _model_supported(model)
            and (problem is None or isinstance(problem, JaxProblem)))


def _check_supported(strategy: AggregationStrategy, model, problem) -> str:
    kind = _classify(strategy)
    if kind is None:
        raise NotImplementedError(
            f"jax backend supports the unmodified m-sync family, Rennala "
            f"and Async/Ringmaster, not {strategy.name!r}; use "
            f"backend='serial'")
    if not _model_supported(model):
        raise NotImplementedError(
            f"jax backend needs FixedTimes or a SubExponentialTimes with "
            f"a jax_sampler (got {type(model).__name__}); "
            f"use backend='serial' or 'vectorized'")
    if problem is not None and not isinstance(problem, JaxProblem):
        raise NotImplementedError(
            "jax backend takes a JaxProblem (jax.random oracle), not the "
            "NumPy Problem; use backend='serial' for NumPy oracles")
    return kind


def _timing_round(ft, ver, comp, k, cand, m, use_pallas):
    """Shared m-sync round update on ``(S, n)`` state (see module doc)."""
    import jax.numpy as jnp
    from jax import lax

    from ..kernels.order_stats import mth_smallest

    stale = ver < k
    T = mth_smallest(cand, m, use_pallas=use_pallas)
    leq = cand <= T[:, None]

    def exact_acc(_):
        # ties straddle the m-boundary somewhere: rank tied candidates by
        # worker index and accept only up to the per-row quota (cumsum is
        # ~40% of the round cost, so it only runs on tie rounds)
        c_lt = (cand < T[:, None]).sum(axis=1)
        tie = cand == T[:, None]
        tie_rank = jnp.cumsum(tie, axis=1) - 1
        return (cand < T[:, None]) | (tie
                                      & (tie_rank < (m - c_lt)[:, None]))

    acc = lax.cond(jnp.all(leq.sum(axis=1) == m),
                   lambda _: leq, exact_acc, operand=None)
    popped = stale & (ft < T[:, None])
    comp = comp + m + popped.sum(axis=1)
    ft = jnp.where(popped, cand, ft)
    ver = jnp.where(popped, k, ver)
    return ft, ver, comp, T, acc


def _fixed_timing_run(taus, S: int, m: int, K: int, use_pallas: bool):
    """Timing-only m-sync under FixedTimes: module-level jit, cached
    across calls (the benchmark-smoke hot path — no RNG at all)."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    n = taus.shape[0]

    def step(carry, k):
        ft, ver, comp = carry
        stale = ver < k
        cand = jnp.where(stale, ft + taus, ft)
        ft, ver, comp, T, acc = _timing_round(ft, ver, comp, k, cand, m,
                                              use_pallas)
        ft = jnp.where(acc, T[:, None] + taus, ft)
        ver = jnp.where(acc, k + 1, ver)
        return (ft, ver, comp), T

    init = (jnp.broadcast_to(taus, (S, n)), jnp.zeros((S, n), jnp.int32),
            jnp.zeros(S, jnp.int32))
    (_, _, comp), T = lax.scan(step, init, jnp.arange(K, dtype=jnp.int32))
    return comp, T


_fixed_timing_jit = None


def _sweep_setup(model, problem, S, n, seeds):
    """Shared per-run scaffolding for every jitted recursion: per-seed
    PRNG keys, the per-round ``(S, n)`` draw closure (FixedTimes
    broadcast vs vmapped ``jax_sampler``), and the broadcast initial
    iterate (``(S, 1)`` zeros for timing-only runs)."""
    import jax
    import jax.numpy as jnp

    keys0 = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    if isinstance(model, FixedTimes):
        taus = jnp.asarray(model.taus)

        def draw(round_keys):                     # no RNG consumed
            return jnp.broadcast_to(taus, (S, n))
    else:
        sampler = model.jax_sampler

        def draw(round_keys):
            return jax.vmap(sampler)(round_keys)  # one (n,) draw per seed
    if problem is not None:
        x_init = jnp.broadcast_to(
            jnp.asarray(problem.x0, dtype=jnp.float32),
            (S,) + np.shape(problem.x0)).astype(jnp.float32)
    else:
        x_init = jnp.zeros((S, 1))
    return keys0, draw, x_init


def _grad_mean_fn(problem, B):
    """vmap-over-seeds mean of ``B`` stochastic gradients at ``x``."""
    import jax

    def grad_mean(x, round_keys):
        gkeys = jax.vmap(lambda k: jax.random.split(k, B))(round_keys)
        per_seed = jax.vmap(jax.vmap(problem.stoch_grad, (None, 0)),
                            (0, 0))
        return per_seed(x, gkeys).mean(axis=1)

    return grad_mean


def _general_run(model, problem, m, n, S, K, gamma, use_pallas, seeds):
    """RNG-threading scan: random time models and/or a JaxProblem oracle.

    Every seed's draw stream is a pure function of its ``PRNGKey(seed)``
    (a 4-way split of its own carried key per round). Closes over the
    sampler/oracle, so jit caching is per call.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax

    math = problem is not None
    keys0, draw, x_init = _sweep_setup(model, problem, S, n, seeds)
    if math:
        grad_mean = _grad_mean_fn(problem, m)

    def step(carry, k):
        ft, ver, comp, x, keys = carry
        sub = jax.vmap(lambda kk: jax.random.split(kk, 4))(keys)
        keys = sub[:, 0]
        stale = ver < k
        cand = jnp.where(stale, ft + draw(sub[:, 1]), ft)
        ft, ver, comp, T, acc = _timing_round(ft, ver, comp, k, cand, m,
                                              use_pallas)
        ft = jnp.where(acc, T[:, None] + draw(sub[:, 2]), ft)
        ver = jnp.where(acc, k + 1, ver)
        if math:
            x = x - gamma * grad_mean(x, sub[:, 3])
            val = jax.vmap(problem.f)(x)
            gn = jax.vmap(lambda xx: jnp.sum(problem.grad(xx) ** 2))(x)
        else:
            val = gn = jnp.zeros(S)
        return (ft, ver, comp, x, keys), (T, val, gn)

    @jax.jit
    def run(keys):
        sub = jax.vmap(lambda kk: jax.random.split(kk, 2))(keys)
        ft0 = draw(sub[:, 1])
        init = (ft0, jnp.zeros((S, n), jnp.int32), jnp.zeros(S, jnp.int32),
                x_init, sub[:, 0])
        (_, _, comp, x, _), (T, val, gn) = lax.scan(
            step, init, jnp.arange(K, dtype=jnp.int32))
        return comp, x, T, val, gn

    return jax.block_until_ready(run(keys0))


def _rennala_run(model, problem, B, n, S, K, gamma, use_pallas, seeds):
    """Rennala as a renewal-batched ``lax.scan``: per round, each worker's
    fresh arrivals form a renewal chain (base + cumulative draws), the
    round ends at the ``B``-th smallest chain entry, every worker's next
    pending computation is its first chain entry past the round end, and
    the stepping worker alone restarts at the new iterate. Ties are
    broken by (worker, within-round arrival index)."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    from ..kernels.order_stats import mth_smallest

    math = problem is not None
    keys0, draw, x_init = _sweep_setup(model, problem, S, n, seeds)
    if isinstance(model, FixedTimes):
        taus = jnp.asarray(model.taus)

        def draw_chain(round_keys):               # (S, n, B)
            return jnp.broadcast_to(taus[None, :, None], (S, n, B))
    else:
        sampler = model.jax_sampler

        def draw_chain(round_keys):
            ks = jax.vmap(lambda k: jax.random.split(k, B))(round_keys)
            return jnp.moveaxis(jax.vmap(jax.vmap(sampler))(ks), 1, 2)

    if math:
        grad_mean = _grad_mean_fn(problem, B)

    widx = jnp.arange(n)
    flat_idx = jnp.arange(n * B)

    def step(carry, k):
        ft, ver, comp, x, keys = carry
        sub = jax.vmap(lambda kk: jax.random.split(kk, 4))(keys)
        keys = sub[:, 0]
        stale = ver < k
        # first fresh arrival: a stale pending pops at ft and restarts
        base = jnp.where(stale, ft + draw(sub[:, 1]), ft)
        chain = jnp.concatenate(
            [base[..., None],
             base[..., None] + jnp.cumsum(draw_chain(sub[:, 2]), axis=2)],
            axis=2)                               # (S, n, B+1)
        pool = chain[..., :B].reshape(S, n * B)
        T = mth_smallest(pool, B, use_pallas=use_pallas)
        lt = pool < T[:, None]
        eq = pool == T[:, None]
        quota = (B - lt.sum(axis=1))[:, None]
        acc = lt | (eq & ((jnp.cumsum(eq, axis=1) - 1) < quota))
        cnt = acc.reshape(S, n, B).sum(axis=2)    # accepted per worker
        popped = stale & (ft < T[:, None])        # discarded stale pops
        comp = comp + B + popped.sum(axis=1)
        # the B-th (stepping) arrival: last accepted entry at exactly T;
        # its worker restarts at the new iterate (version k + 1)
        stepper = jnp.argmax(jnp.where(acc & eq, flat_idx[None, :], -1),
                             axis=1) // B
        live = (~stale) | popped                  # chain materialized
        nxt = jnp.take_along_axis(chain, cnt[..., None], axis=2)[..., 0]
        ft = jnp.where(live, nxt, ft)
        ver = jnp.where(live, k, ver)
        ver = jnp.where(widx[None, :] == stepper[:, None], k + 1, ver)
        if math:
            x = x - gamma * grad_mean(x, sub[:, 3])
            val = jax.vmap(problem.f)(x)
            gn = jax.vmap(lambda xx: jnp.sum(problem.grad(xx) ** 2))(x)
        else:
            val = gn = jnp.zeros(S)
        return (ft, ver, comp, x, keys), (T, val, gn)

    @jax.jit
    def run(keys):
        sub = jax.vmap(lambda kk: jax.random.split(kk, 2))(keys)
        init = (draw(sub[:, 1]), jnp.zeros((S, n), jnp.int32),
                jnp.zeros(S, jnp.int32), x_init, sub[:, 0])
        (_, _, comp, x, _), (T, val, gn) = lax.scan(
            step, init, jnp.arange(K, dtype=jnp.int32))
        return comp, x, T, val, gn

    return jax.block_until_ready(run(keys0))


def _arrival_run(model, problem, max_delay, delay_adaptive, n, S, K,
                 gamma, seeds):
    """Async/Ringmaster as an arrival-indexed ``lax.while_loop``: each
    iteration pops the earliest pending finish per seed (ties by worker
    index), steps unless the gradient's delay exceeds ``max_delay``
    (discard => recompute at the current iterate), and restarts the
    popped worker. Per-worker start-iterate snapshots (``xs``) evaluate
    delayed gradients at the iterate they started from, exactly like the
    event engine's snapshot dict. Returns per-step time/value buffers."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    math = problem is not None
    keys0, draw, x_init = _sweep_setup(model, problem, S, n, seeds)
    xs_init = jnp.broadcast_to(x_init[:, None, :],
                               (S, n) + x_init.shape[1:])

    rows = jnp.arange(S)
    # Async pops exactly K arrivals. Ringmaster also pays discards, but
    # a worker can only be re-discarded after another step lands, so
    # each worker is discarded at most K+1 times: arrivals are bounded
    # by K accepts + n*(K+1) discards. The cap is that bound plus slack
    # and only guards against a broken recursion — the caller verifies
    # every seed reached K and raises otherwise.
    cap = (K + 1) * (n + 2) + 64

    def cond(carry):
        it, ft, ver, k = carry[0], carry[1], carry[2], carry[3]
        return jnp.any(k < K) & (it < cap)

    def body(carry):
        it, ft, ver, k, comp, x, xs, keys, Tb, vb, gb = carry
        sub = jax.vmap(lambda kk: jax.random.split(kk, 3))(keys)
        keys = sub[:, 0]
        w = jnp.argmin(ft, axis=1)                # earliest pending pop
        t = ft[rows, w]
        delay = k - ver[rows, w]
        active = k < K
        accept = active & (delay <= max_delay)
        kc = jnp.clip(k, 0, K - 1)
        if math:
            g = jax.vmap(problem.stoch_grad)(xs[rows, w], sub[:, 1])
            mult = (1.0 / (1.0 + delay.astype(jnp.float32) / n)
                    if delay_adaptive else jnp.ones(S, jnp.float32))
            x = jnp.where(accept[:, None],
                          x - gamma * mult[:, None] * g, x)
            val = jax.vmap(problem.f)(x)
            gn = jax.vmap(lambda xx: jnp.sum(problem.grad(xx) ** 2))(x)
            vb = vb.at[rows, kc].set(jnp.where(accept, val, vb[rows, kc]))
            gb = gb.at[rows, kc].set(jnp.where(accept, gn, gb[rows, kc]))
        Tb = Tb.at[rows, kc].set(jnp.where(accept, t, Tb[rows, kc]))
        k = k + accept.astype(k.dtype)
        dts = draw(sub[:, 2])                     # restart the popped worker
        ft = ft.at[rows, w].set(jnp.where(active, t + dts[rows, w],
                                          ft[rows, w]))
        ver = ver.at[rows, w].set(jnp.where(active, k, ver[rows, w]))
        xs = xs.at[rows, w].set(jnp.where(active[:, None], x, xs[rows, w]))
        comp = comp + active.astype(comp.dtype)
        return (it + 1, ft, ver, k, comp, x, xs, keys, Tb, vb, gb)

    @jax.jit
    def run(keys):
        sub = jax.vmap(lambda kk: jax.random.split(kk, 2))(keys)
        init = (jnp.zeros((), jnp.int32), draw(sub[:, 1]),
                jnp.zeros((S, n), jnp.int32), jnp.zeros(S, jnp.int32),
                jnp.zeros(S, jnp.int32), x_init, xs_init, sub[:, 0],
                jnp.zeros((S, K)), jnp.zeros((S, K)), jnp.zeros((S, K)))
        out = lax.while_loop(cond, body, init)
        _, _, _, k, comp, x, _, _, Tb, vb, gb = out
        return k, comp, x, Tb.T, vb.T, gb.T      # (K, S) like the scans

    kfin, comp, x, T, val, gn = jax.block_until_ready(run(keys0))
    if int(np.min(np.asarray(kfin))) < K:
        raise RuntimeError(
            f"arrival-indexed jax backend hit its {cap}-arrival cap "
            f"before finishing K={K} iterations (max_delay too tight?); "
            f"use backend='serial'")
    return comp, x, T, val, gn


def simulate_batch_jax(strategy: AggregationStrategy,
                       model,
                       K: int,
                       problem: Optional[JaxProblem] = None,
                       gamma: float = 0.0,
                       seeds: Sequence[int] = (0,),
                       record_every: int = 1,
                       use_pallas: bool = False) -> List[Trace]:
    """One jitted ``(seeds, ...)`` array program per strategy family
    (m-sync round scan, Rennala renewal scan, Async/Ringmaster arrival
    recursion); returns the per-seed :class:`Trace` list (timing-only
    traces have empty arrays, like the scalar fast path).

    The FixedTimes timing-only m-sync case hits a module-level jit cache
    (no recompile across calls of the same shape); the other programs
    close over the oracle and sampler, so they recompile per call — fine
    for sweep-sized S × K, not for tight loops of tiny calls.
    """
    import jax
    import jax.numpy as jnp

    strategy.bind(model.n)
    kind = _check_supported(strategy, model, problem)
    n = model.n
    S = len(seeds)
    K = int(K)
    if K <= 0:
        raise ValueError(f"K={K} must be positive for the jax backend")

    fixed = isinstance(model, FixedTimes)
    math = problem is not None

    if kind == "msync":
        m = strategy._m
        used = m * K
        if fixed and not math:
            global _fixed_timing_jit
            if _fixed_timing_jit is None:
                _fixed_timing_jit = jax.jit(
                    _fixed_timing_run,
                    static_argnames=("S", "m", "K", "use_pallas"))
            comp, T = jax.block_until_ready(_fixed_timing_jit(
                jnp.asarray(model.taus), S=S, m=m, K=K,
                use_pallas=use_pallas))
            x = val = gn = None
        else:
            comp, x, T, val, gn = _general_run(model, problem, m, n, S, K,
                                               gamma, use_pallas, seeds)
    elif kind == "rennala":
        used = int(strategy.batch) * K
        comp, x, T, val, gn = _rennala_run(model, problem,
                                           int(strategy.batch), n, S, K,
                                           gamma, use_pallas, seeds)
    else:
        used = K          # every server step consumes exactly one gradient
        md = int(strategy.max_delay) if kind == "ringmaster" else K + 1
        comp, x, T, val, gn = _arrival_run(
            model, problem, md, bool(getattr(strategy, "delay_adaptive",
                                             False)), n, S, K, gamma, seeds)

    comp = np.asarray(comp)
    T = np.asarray(T)                             # (K, S)
    total = T[-1]
    traces: List[Trace] = []
    if math:
        val = np.asarray(val)
        gn = np.asarray(gn)
        x_np = np.asarray(x)
        rec = np.arange(record_every, K + 1, record_every)     # steps k
        x0j = jnp.asarray(problem.x0, dtype=jnp.float32)
        f0 = float(problem.f(x0j))
        g0 = np.asarray(problem.grad(x0j))
        gn0 = float(np.dot(g0, g0))
        for s in range(S):
            times = np.concatenate([[0.0], T[rec - 1, s]])
            vals = np.concatenate([[f0], val[rec - 1, s]])
            gns = np.concatenate([[gn0], gn[rec - 1, s]])
            traces.append(Trace(times, vals, gns, iterations=K,
                                total_time=float(total[s]),
                                gradients_used=used,
                                gradients_computed=int(comp[s]),
                                x_final=x_np[s]))
    else:
        e = np.array([])
        for s in range(S):
            traces.append(Trace(e, e, e, iterations=K,
                                total_time=float(total[s]),
                                gradients_used=used,
                                gradients_computed=int(comp[s])))
    return traces
