"""Computation-time models from the paper.

Implements the paper's four worker-compute assumptions:

* Assumption 2.2 — Fixed computation model: worker ``i`` always takes
  ``tau_i`` seconds per stochastic gradient.
* Assumption 3.1 — Random computation model: worker ``i``'s time is a
  ``(tau_i, R)``-sub-exponential random variable (mean ``tau_i``,
  ``E[exp(|t - tau_i| / R)] <= 2``, nonnegative a.s.).
* Assumption 5.1 — Universal computation model: worker ``i`` has an
  integrable computation *power* ``v_i(t) >= 0`` and computes
  ``N_i(t0, t1) = floor(int_{t0}^{t1} v_i)`` gradients in ``[t0, t1]``.
* Assumption 5.4 — Partial participation: all powers equal ``v`` except an
  (arbitrary, possibly adversarial) set of at most ``p*n`` stragglers at any
  instant.

All models expose a unified event-simulator interface::

    sample_time(i, rng) -> float          # seconds for ONE gradient started now
    sample_times(workers, rng) -> array   # batched draw for many workers
    (Universal models instead expose ``time_for_integral`` /
    ``finish_times(workers, t_start)``.)

``sample_times`` is the engine's hot path: models with closed-form or
vectorizable distributions override it (``FixedTimes`` is a pure gather;
the distribution factories below install NumPy-vectorized samplers), so a
round that restarts many workers costs one vector op instead of ``n``
Python calls. The default falls back to per-worker ``sample_time`` calls
in worker order, which keeps the RNG stream identical to the scalar path.
``sample_times_tensor`` is the multi-seed sweep engine's bulk draw: the
entire ``(seeds, rounds, workers)`` time tensor in one call per model,
either from per-seed Philox counter streams (``rng_scheme="counter"``,
the fast sweep default) or replaying the scalar per-round stream order
(``"stream"``). Every ``SubExponentialTimes`` factory also carries a
``jax_sampler`` for the device-resident ``simulate_batch`` backend, and
``UniversalModel.finish_times`` is a batched closed-form inversion of
the cumulative-power grid (the event engine's universal hot path).

Every random model also reports its ``(tau_i, R)`` sub-exponential
certificate where known, so the theory in :mod:`repro.core.complexity` can be
evaluated against the exact constants used by the simulator.

Device-resident hooks (the ``backend="jax"`` engines in
:mod:`repro.core.batch_jax` consume these):

* ``SubExponentialTimes.jax_sampler(key) -> (n,)`` — one full round of
  per-worker times (every in-tree factory installs it);
* ``SubExponentialTimes.jax_sampler_item(key, i) -> scalar`` — ONE draw
  from worker ``i``'s marginal, for arrival-indexed recursions that
  restart a single worker per event (the keyed Async/Ringmaster path —
  one draw per arrival instead of a full ``(seeds, n)`` row);
* :func:`jax_worker_key_grid` — the pre-split ``(seeds, workers)``
  counter-key grid those keyed draws consume: worker ``i``'s stream
  under seed ``s`` is a pure function of ``(s, i)``, independent of
  arrival order and of which other seeds are in the sweep (the
  ``jax.random`` twin of the ``rng_scheme="counter"`` contract);
* ``UniversalModel.finish_times_jax`` — the jit-compatible twin of
  ``finish_times`` (batched ``searchsorted`` on the cumulative-power
  grid + the same closed-form quadratic segment inversion), which lets
  universal/partial-participation scenarios run inside jitted sweeps.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import numpy as np

__all__ = [
    "TimeModel",
    "FixedTimes",
    "SubExponentialTimes",
    "philox_rngs",
    "jax_worker_key_grid",
    "jax_chain_draws",
    "ragged_layout",
    "jax_chain_draws_ragged",
    "truncated_normal_times",
    "exponential_times",
    "shifted_exponential_times",
    "gamma_times",
    "uniform_times",
    "chi2_times",
    "UniversalModel",
    "PartialParticipationModel",
    "PiecewisePower",
    "powers_figure3",
    "powers_figure4",
]


def philox_rngs(seeds: Sequence[int]) -> list:
    """One counter-based generator per seed (Philox, 128-bit spawn key).

    ``philox_rngs([s])[0]`` depends only on the seed *value* ``s`` — not
    on the position of ``s`` in the sweep or on the other seeds — so any
    sweep that includes seed ``s`` draws the same row for it. These are
    the ``rng_scheme="counter"`` streams: independent of (and therefore
    NOT stream-equal to) the ``np.random.default_rng(s)`` streams the
    scalar ``simulate()`` path consumes.
    """
    return [np.random.Generator(np.random.Philox(
        key=np.random.SeedSequence(int(s)).generate_state(2, np.uint64)))
        for s in seeds]


def jax_worker_key_grid(seed_keys, n: int):
    """Pre-split ``(seeds, workers)`` ``jax.random`` key grid.

    ``grid[s, i]`` roots worker ``i``'s independent draw stream under
    seed ``s``: arrival-indexed engines split one fresh subkey off
    ``grid[s, i]`` per arrival of worker ``i``, so a worker's stream is
    a pure function of ``(seed value, worker index)`` — independent of
    the arrival order, of the other workers, and of which other seeds
    are in the sweep. This is the ``jax.random`` counter-key twin of the
    NumPy :func:`philox_rngs` contract (``rng_scheme="counter"``): NOT
    stream-equal to any NumPy path, reproducible per seed value.

    ``seed_keys`` is a sequence of seed ints or an already-built
    ``(seeds, 2)`` raw ``uint32`` key array (e.g. one branch of a
    ``jax.random.split``, to keep the grid disjoint from other streams
    derived from the same seed).
    """
    import jax
    import jax.numpy as jnp

    if getattr(seed_keys, "ndim", None) != 2:
        seed_keys = jnp.stack(
            [jax.random.PRNGKey(int(s)) for s in seed_keys])
    return jax.vmap(lambda k: jax.random.split(k, n))(seed_keys)


def jax_chain_draws(chain_keys, L: int, row_sampler):
    """``(seeds, L, workers)`` renewal-chain duration rows for the
    arrival-scan async engine.

    Row ``(s, j)`` is ``row_sampler(fold_in(chain_keys[s], j))`` — ONE
    vectorized draw of every worker's ``j``-th renewal duration (the
    model's ``jax_sampler``), so the whole chain pool costs ``S * L``
    key derivations instead of ``S * n * L`` per-item draws. Cumulative
    sums along ``j`` turn the rows into each worker's arrival chain.

    Contract (the arrival-scan twin of the :func:`philox_rngs` /
    :func:`jax_worker_key_grid` counter contracts): row ``(s, j)`` is a
    pure function of *(seed key, slot j)* via ``jax.random.fold_in`` —
    independent of ``L`` (**prefix-stable**: growing ``L`` appends rows
    and never reshuffles existing ones, which the engine's
    chain-doubling retries rely on to leave already-certified seeds
    bitwise unchanged), of the sweep composition, and of arrival order.
    Like every ``jax.random`` path it is equal in distribution to — and
    never stream-equal with — the NumPy engines.
    """
    import jax
    import jax.numpy as jnp

    def per_seed(key):
        return jax.vmap(
            lambda j: row_sampler(jax.random.fold_in(key, j)))(
                jnp.arange(L))

    return jax.vmap(per_seed)(chain_keys)


def ragged_layout(budgets, starts=None):
    """Host-side offset/slot-budget layout for ragged per-worker chains.

    ``budgets[i]`` is worker ``i``'s slot count; the flat buffer packs
    the workers' slot runs back to back (worker-major), so flat index
    ``offsets[i] + j`` holds worker ``i``'s ``j``-th slot. Returns
    ``(offsets, widx, gslot, total)``: per-worker start offsets
    ``(n,)``, the flat-index -> worker map ``(total,)``, the
    flat-index -> *global* slot index map ``(total,)`` (``starts[i] +
    j`` — window extensions pass the slots already drawn so the global
    slot index keeps counting across windows), and the flat length.
    Worker-major packing keeps the merged-pool tie contract intact:
    flat-index tie-breaking in :func:`~repro.kernels.order_stats.
    smallest_k` is (worker, global slot) lexicographic order, exactly
    the rectangular pool's documented contract."""
    b = np.asarray(budgets, dtype=np.int64)
    n = b.size
    s0 = (np.zeros(n, np.int64) if starts is None
          else np.asarray(starts, dtype=np.int64))
    if (b < 0).any() or (s0 < 0).any():
        raise ValueError("ragged_layout needs nonnegative budgets/starts")
    offsets = np.concatenate([[0], np.cumsum(b)[:-1]]).astype(np.int64)
    total = int(b.sum())
    widx = np.repeat(np.arange(n, dtype=np.int64), b)
    gslot = (np.arange(total, dtype=np.int64) - np.repeat(offsets, b)
             + np.repeat(s0, b))
    return offsets, widx, gslot, total


def jax_chain_draws_ragged(chain_keys, budgets, row_sampler, starts=None):
    """``(seeds, total)`` flat ragged renewal-duration buffer — the
    per-worker-budgeted twin of :func:`jax_chain_draws`.

    Entry ``(s, offsets[i] + j)`` is bitwise
    ``row_sampler(fold_in(chain_keys[s], starts[i] + j))[i]`` — i.e.
    worker ``i``'s slot at global index ``g = starts[i] + j`` equals
    column ``i`` of the rectangular contract's row ``g``. The fold-in
    keyed prefix-stability contract is therefore preserved exactly:
    growing any worker's budget (or drawing a window extension via
    ``starts``) appends slots and never reshuffles or re-keys existing
    ones, and with uniform budgets and ``starts=None`` the buffer is
    ``jax_chain_draws(chain_keys, L, row_sampler)`` transposed to
    worker-major and flattened, bitwise.

    The buffer is built by ONE short scan over the global slot range
    (``max(starts + budgets) - min(starts)`` steps, each one
    ``row_sampler`` row) that scatters each row's in-budget entries
    through a precomputed destination map (out-of-budget entries drop),
    so no ``(seeds, L_max, n)`` rectangle is ever materialized — under
    skewed rates the flat buffer is up to ``n`` times smaller."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    b = np.asarray(budgets, dtype=np.int64)
    n = b.size
    s0 = (np.zeros(n, np.int64) if starts is None
          else np.asarray(starts, dtype=np.int64))
    offsets, _, _, total = ragged_layout(b, s0)
    jmin = int(s0.min()) if n else 0
    jmax = int((s0 + b).max()) if n else 0
    steps = max(jmax - jmin, 0)
    # dest[j - jmin, i]: flat slot of worker i's draw at global slot j,
    # or `total` (out of range -> dropped by the scatter) outside
    # [starts[i], starts[i] + budgets[i])
    jg = np.arange(jmin, jmax, dtype=np.int64)[:, None]
    rel = jg - s0[None, :]
    dest = jnp.asarray(np.where((rel >= 0) & (rel < b[None, :]),
                                offsets[None, :] + rel,
                                total).astype(np.int32))
    probe = jax.eval_shape(row_sampler,
                           jax.ShapeDtypeStruct((2,), jnp.uint32))

    def per_seed(key):
        def body(buf, inp):
            j, d = inp
            row = row_sampler(jax.random.fold_in(key, j))
            return buf.at[d].set(row, mode="drop"), None

        buf0 = jnp.zeros((total,), probe.dtype)
        if steps == 0:
            return buf0
        buf, _ = lax.scan(body, buf0,
                          (jnp.arange(jmin, jmax), dest))
        return buf

    return jax.vmap(per_seed)(chain_keys)


def _as_rng(key, rng_scheme: str):
    if isinstance(key, np.random.Generator):
        return key
    if rng_scheme == "counter":
        return philox_rngs([key])[0]
    return np.random.default_rng(int(key))


class TimeModel:
    """Base class: per-gradient computation-time sampling for ``n`` workers."""

    n: int

    def sample_time(self, i: int, rng: np.random.Generator) -> float:
        raise NotImplementedError

    def sample_times(self, workers: Sequence[int],
                     rng: np.random.Generator) -> np.ndarray:
        """Batched per-gradient times for ``workers`` (engine hot path).

        The fallback draws per worker in order, so it consumes the RNG
        stream exactly like sequential ``sample_time`` calls; subclasses
        override with a single vectorized draw where possible.
        """
        return np.array([self.sample_time(int(i), rng) for i in workers],
                        dtype=float)

    def sample_times_seeds(self, workers: Sequence[int],
                           rngs: Sequence[np.random.Generator]) -> np.ndarray:
        """Multi-seed batched draw: one ``(seeds, workers)`` matrix.

        Row ``s`` consumes ``rngs[s]`` exactly as one :meth:`sample_times`
        call would, so per-seed RNG-stream parity with scalar runs is
        preserved (the seed-batched ``simulate_batch`` engine depends on
        this). Models whose draws are RNG-free (:class:`FixedTimes`)
        override with a pure broadcast.
        """
        return np.stack([np.asarray(self.sample_times(workers, rng),
                                    dtype=float) for rng in rngs])

    def sample_times_tensor(self, workers: Sequence[int], rounds: int,
                            seed_keys: Sequence,
                            rng_scheme: str = "counter") -> np.ndarray:
        """One ``(seeds, rounds, workers)`` tensor of per-gradient times.

        This is the sweep engine's bulk draw: the *entire* time tensor
        for a multi-seed run comes out of one call per model instead of
        ``seeds x rounds`` small draws. ``seed_keys`` are seed ints or
        already-constructed ``np.random.Generator`` instances (stateful —
        successive calls continue each seed's stream, which is how the
        batched engine chunks very long horizons).

        ``rng_scheme`` picks the documented reproducibility contract:

        * ``"counter"`` (default) — one tiled vectorized draw per seed
          from its Philox counter stream (:func:`philox_rngs`). Row ``s``
          is a pure function of the seed value; entry ``[s, r, j]`` is an
          independent draw from worker ``workers[j]``'s marginal.
          Distribution-equal to — but NOT stream-equal with — the scalar
          ``simulate()`` path.
        * ``"stream"`` — row ``[s, r]`` is the ``r``-th successive
          :meth:`sample_times` call on ``np.random.default_rng(s)``, i.e.
          exactly the values a per-round loop would consume.
        """
        if rng_scheme not in ("counter", "stream"):
            raise ValueError(f"unknown rng_scheme {rng_scheme!r}; "
                             "use 'counter' or 'stream'")
        workers = np.asarray(workers, dtype=int)
        W = len(workers)
        out = np.empty((len(seed_keys), int(rounds), W), dtype=float)
        tiled = np.tile(workers, int(rounds))
        for si, key in enumerate(seed_keys):
            rng = _as_rng(key, rng_scheme)
            if rng_scheme == "counter":
                out[si] = np.asarray(self.sample_times(tiled, rng),
                                     dtype=float).reshape(int(rounds), W)
            else:
                for r in range(int(rounds)):
                    out[si, r] = self.sample_times(workers, rng)
        return out

    def mean_times(self) -> np.ndarray:
        """``tau_i = E[time for worker i]``, sorted or not — as configured."""
        raise NotImplementedError

    # Sub-exponential certificate (Assumption 3.1); None => unknown/infinite.
    def sub_exponential_R(self) -> Optional[float]:
        return None

    def faulted(self, *faults) -> "SubExponentialTimes":
        """Wrap this model with fault transformations (``repro.core.faults``).

        ``model.faulted(CrashRestart(p=0.05, mean_downtime=2.0))`` is
        :func:`repro.core.faults.with_faults` as a method; with no
        active faults the wrapper is bitwise a no-op on every backend.
        """
        from .faults import FaultyTimes
        return FaultyTimes(self, faults)


@dataclasses.dataclass
class FixedTimes(TimeModel):
    """Assumption 2.2 — deterministic ``tau_i``."""

    taus: np.ndarray

    def __post_init__(self) -> None:
        self.taus = np.asarray(self.taus, dtype=float)
        if np.any(self.taus <= 0):
            raise ValueError("tau_i must be positive")
        self.n = len(self.taus)

    def sample_time(self, i: int, rng: np.random.Generator) -> float:
        return float(self.taus[i])

    def sample_times(self, workers: Sequence[int],
                     rng: np.random.Generator) -> np.ndarray:
        return self.taus[np.asarray(workers, dtype=int)]

    def sample_times_seeds(self, workers: Sequence[int],
                           rngs: Sequence[np.random.Generator]) -> np.ndarray:
        # deterministic: no RNG consumed, one broadcast for all seeds
        return np.broadcast_to(self.taus[np.asarray(workers, dtype=int)],
                               (len(rngs), len(workers))).copy()

    def sample_times_tensor(self, workers: Sequence[int], rounds: int,
                            seed_keys: Sequence,
                            rng_scheme: str = "counter") -> np.ndarray:
        if rng_scheme not in ("counter", "stream"):
            raise ValueError(f"unknown rng_scheme {rng_scheme!r}; "
                             "use 'counter' or 'stream'")
        return np.broadcast_to(
            self.taus[np.asarray(workers, dtype=int)],
            (len(seed_keys), int(rounds), len(workers))).copy()

    def mean_times(self) -> np.ndarray:
        return self.taus

    def sub_exponential_R(self) -> float:
        return 0.0

    @staticmethod
    def sqrt_law(n: int, tau1: float = 1.0) -> "FixedTimes":
        """tau_i = tau1 * sqrt(i) — the paper's Figure 5 / K.1 setup."""
        return FixedTimes(tau1 * np.sqrt(np.arange(1, n + 1)))

    @staticmethod
    def power_law(n: int, alpha: float, tau1: float = 1.0,
                  delta: Optional[np.ndarray] = None) -> "FixedTimes":
        """tau_m = tau1 * m**alpha + delta_m — eq. (10)."""
        taus = tau1 * np.arange(1, n + 1, dtype=float) ** alpha
        if delta is not None:
            taus = taus + np.asarray(delta, dtype=float)
        return FixedTimes(taus)

    @staticmethod
    def linear(n: int, tau1: float = 1.0) -> "FixedTimes":
        """tau_i = tau1 * i — the log-factor-tight case of Theorem 2.3."""
        return FixedTimes(tau1 * np.arange(1, n + 1, dtype=float))


@dataclasses.dataclass
class SubExponentialTimes(TimeModel):
    """Assumption 3.1 — random per-gradient times, independent across draws.

    ``sampler(i, rng)`` must return a nonnegative float with mean
    ``taus[i]``; ``R`` is the common sub-exponential parameter (may be a
    conservative upper bound). ``batch_sampler(workers, rng)``, when
    provided, draws one vectorized sample per listed worker — the engine
    prefers it for bulk restarts. ``jax_sampler(key) -> (n,)``, when
    provided, draws one full round of per-worker times with ``jax.random``
    — the ``simulate_batch`` JAX backend needs it (distribution-equal to
    the NumPy samplers, not stream-equal). ``jax_sampler_item(key, i)``
    draws ONE sample from worker ``i``'s marginal (``i`` may be traced):
    the keyed Async/Ringmaster arrival loop uses it with a
    :func:`jax_worker_key_grid` so each arrival costs one draw instead
    of a full ``(seeds, n)`` row; when absent, the engine falls back to
    row draws through ``jax_sampler`` (correct, ~n× more draw volume).
    """

    taus: np.ndarray
    sampler: Callable[[int, np.random.Generator], float]
    R: float
    name: str = "subexp"
    batch_sampler: Optional[Callable[[np.ndarray, np.random.Generator],
                                     np.ndarray]] = None
    jax_sampler: Optional[Callable] = None
    jax_sampler_item: Optional[Callable] = None

    def __post_init__(self) -> None:
        self.taus = np.asarray(self.taus, dtype=float)
        self.n = len(self.taus)

    def sample_time(self, i: int, rng: np.random.Generator) -> float:
        t = float(self.sampler(i, rng))
        return max(t, 0.0)

    def sample_times(self, workers: Sequence[int],
                     rng: np.random.Generator) -> np.ndarray:
        workers = np.asarray(workers, dtype=int)
        if self.batch_sampler is None:
            return np.array([max(float(self.sampler(int(i), rng)), 0.0)
                             for i in workers])
        return np.maximum(np.asarray(self.batch_sampler(workers, rng),
                                     dtype=float), 0.0)

    def mean_times(self) -> np.ndarray:
        return self.taus

    def sub_exponential_R(self) -> float:
        return self.R


def truncated_normal_times(mus: Sequence[float], sigma: float
                           ) -> SubExponentialTimes:
    """``tau_i ~ N(mu_i, sigma^2)`` truncated to ``[0, inf)``.

    Sub-exponential with ``R = O(sigma)`` (Barreto et al., 2025). The mean of
    the truncated variable is ``mu + sigma * phi(a)/Phi(-a)`` with
    ``a = -mu/sigma``; we report the exact truncated means.
    """
    mus = np.asarray(mus, dtype=float)

    def _truncated_mean(mu: float) -> float:
        if sigma == 0:
            return max(mu, 0.0)
        a = -mu / sigma
        phi = math.exp(-0.5 * a * a) / math.sqrt(2 * math.pi)
        Phi = 0.5 * math.erfc(a / math.sqrt(2))
        return mu + sigma * phi / max(Phi, 1e-300)

    taus = np.array([_truncated_mean(mu) for mu in mus])

    def sampler(i: int, rng: np.random.Generator) -> float:
        while True:
            t = rng.normal(mus[i], sigma)
            if t >= 0:
                return t

    def batch_sampler(workers: np.ndarray,
                      rng: np.random.Generator) -> np.ndarray:
        out = rng.normal(mus[workers], sigma)
        while True:
            bad = out < 0
            if not bad.any():
                return out
            out[bad] = rng.normal(mus[workers][bad], sigma)

    def jax_sampler(key):
        # exact bounded sampling (no rejection loop): truncate the
        # standard normal to [(0 - mu)/sigma, inf) and rescale —
        # distribution-equal to the NumPy rejection sampler
        import jax
        import jax.numpy as jnp
        if sigma == 0:
            return jnp.maximum(jnp.asarray(mus), 0.0)
        z = jax.random.truncated_normal(key, (0.0 - mus) / sigma, jnp.inf,
                                        mus.shape)
        return mus + sigma * z

    def jax_sampler_item(key, i):
        import jax
        import jax.numpy as jnp
        mu = jnp.asarray(mus)[i]
        if sigma == 0:
            return jnp.maximum(mu, 0.0)
        z = jax.random.truncated_normal(key, (0.0 - mu) / sigma, jnp.inf)
        return mu + sigma * z

    return SubExponentialTimes(taus, sampler, R=float(sigma),
                               name=f"truncnorm(sigma={sigma})",
                               batch_sampler=batch_sampler,
                               jax_sampler=jax_sampler,
                               jax_sampler_item=jax_sampler_item)


def exponential_times(lam: float, n: int) -> SubExponentialTimes:
    """``tau_i ~ Exp(lam)`` for all workers: ``tau_i = R = 1/lam`` (§3)."""
    taus = np.full(n, 1.0 / lam)

    def sampler(i: int, rng: np.random.Generator) -> float:
        return rng.exponential(1.0 / lam)

    def jax_sampler(key):
        import jax
        return jax.random.exponential(key, (n,)) / lam

    def jax_sampler_item(key, i):
        import jax
        return jax.random.exponential(key) / lam

    return SubExponentialTimes(
        taus, sampler, R=1.0 / lam, name=f"exp(lam={lam})",
        batch_sampler=lambda w, rng: rng.exponential(1.0 / lam, size=len(w)),
        jax_sampler=jax_sampler, jax_sampler_item=jax_sampler_item)


def shifted_exponential_times(mus: Sequence[float], lams: Sequence[float]
                              ) -> SubExponentialTimes:
    """``tau_i = mu_i + Exp(lam_i)`` (§D.1): R = max_i 1/lam_i."""
    mus = np.asarray(mus, dtype=float)
    lams = np.asarray(lams, dtype=float)
    taus = mus + 1.0 / lams

    def sampler(i: int, rng: np.random.Generator) -> float:
        return mus[i] + rng.exponential(1.0 / lams[i])

    def jax_sampler(key):
        import jax
        return mus + jax.random.exponential(key, mus.shape) / lams

    def jax_sampler_item(key, i):
        import jax
        import jax.numpy as jnp
        return (jnp.asarray(mus)[i]
                + jax.random.exponential(key) / jnp.asarray(lams)[i])

    return SubExponentialTimes(
        taus, sampler, R=float(np.max(1.0 / lams)), name="shifted-exp",
        batch_sampler=lambda w, rng: mus[w] + rng.exponential(1.0 / lams[w]),
        jax_sampler=jax_sampler, jax_sampler_item=jax_sampler_item)


def gamma_times(means: Sequence[float], var: float) -> SubExponentialTimes:
    """Gamma with per-worker mean ``tau_i`` and common variance (§K.3).

    shape k = tau^2/var, scale theta = var/tau; R = O(max sqrt(k)*theta).
    """
    means = np.asarray(means, dtype=float)
    ks = means ** 2 / var
    thetas = var / means
    R = float(np.max(np.maximum(np.sqrt(ks), 1.0) * thetas))

    def sampler(i: int, rng: np.random.Generator) -> float:
        return rng.gamma(ks[i], thetas[i])

    def jax_sampler(key):
        import jax
        return jax.random.gamma(key, ks) * thetas

    def jax_sampler_item(key, i):
        import jax
        import jax.numpy as jnp
        return (jax.random.gamma(key, jnp.asarray(ks)[i])
                * jnp.asarray(thetas)[i])

    return SubExponentialTimes(
        means, sampler, R=R, name="gamma",
        batch_sampler=lambda w, rng: rng.gamma(ks[w], thetas[w]),
        jax_sampler=jax_sampler, jax_sampler_item=jax_sampler_item)


def uniform_times(means: Sequence[float], half_width: float
                  ) -> SubExponentialTimes:
    """``tau_i ~ Unif(tau_i - w, tau_i + w)`` (§K.3/K.4). Bounded => R=O(w)."""
    means = np.asarray(means, dtype=float)

    def sampler(i: int, rng: np.random.Generator) -> float:
        return rng.uniform(means[i] - half_width, means[i] + half_width)

    def jax_sampler(key):
        import jax
        import jax.numpy as jnp
        u = jax.random.uniform(key, means.shape,
                               minval=-half_width, maxval=half_width)
        # same clamp the engine applies to every NumPy draw via
        # sample_time / sample_times (times are nonnegative a.s.)
        return jnp.maximum(means + u, 0.0)

    def jax_sampler_item(key, i):
        import jax
        import jax.numpy as jnp
        u = jax.random.uniform(key, minval=-half_width, maxval=half_width)
        return jnp.maximum(jnp.asarray(means)[i] + u, 0.0)

    return SubExponentialTimes(
        means, sampler, R=float(half_width), name=f"uniform(w={half_width})",
        batch_sampler=lambda w, rng: rng.uniform(means[w] - half_width,
                                                 means[w] + half_width),
        jax_sampler=jax_sampler, jax_sampler_item=jax_sampler_item)


def chi2_times(dofs: Sequence[int]) -> SubExponentialTimes:
    """``tau_i ~ chi^2_{k_i}`` (§D.1): tau_i = k_i, R = O(max sqrt(k_i))."""
    dofs = np.asarray(dofs, dtype=float)

    def sampler(i: int, rng: np.random.Generator) -> float:
        return rng.chisquare(dofs[i])

    def jax_sampler(key):
        # chi^2_k == Gamma(shape k/2, scale 2)
        import jax
        return 2.0 * jax.random.gamma(key, dofs / 2.0)

    def jax_sampler_item(key, i):
        import jax
        import jax.numpy as jnp
        return 2.0 * jax.random.gamma(key, jnp.asarray(dofs)[i] / 2.0)

    return SubExponentialTimes(dofs.copy(), sampler,
                               R=float(2.0 * np.sqrt(np.max(dofs))),
                               name="chi2",
                               batch_sampler=lambda w, rng:
                                   rng.chisquare(dofs[w]),
                               jax_sampler=jax_sampler,
                               jax_sampler_item=jax_sampler_item)


# ---------------------------------------------------------------------------
# Assumption 5.1 — Universal computation model.
# ---------------------------------------------------------------------------

class UniversalModel:
    """Computation powers ``v_i(t)`` on a uniform grid with linear interp.

    ``N_i(t0, t1) = floor(int_{t0}^{t1} v_i(s) ds)`` — eq. (11). The paper's
    Figures 3/4 define powers exactly this way (grid ``t_k = 0.1 k`` +
    linear interpolation), so a trapezoid cumulative integral on the grid is
    *exact* for these instances.
    """

    def __init__(self, grid: np.ndarray, powers: np.ndarray) -> None:
        # powers: (n, T) nonnegative samples on grid (T,)
        self.grid = np.asarray(grid, dtype=float)
        self.powers = np.maximum(np.asarray(powers, dtype=float), 0.0)
        self.n = self.powers.shape[0]
        dt = np.diff(self.grid)
        mids = 0.5 * (self.powers[:, 1:] + self.powers[:, :-1])
        self.cum = np.concatenate(
            [np.zeros((self.n, 1)), np.cumsum(mids * dt, axis=1)], axis=1)

    def integral(self, i: int, t0: float, t1: float) -> float:
        """``int_{t0}^{t1} v_i`` (exact for piecewise-linear powers)."""
        return self._cum_at(i, t1) - self._cum_at(i, t0)

    def _cum_at(self, i: int, t: float) -> float:
        g = self.grid
        if t <= g[0]:
            return 0.0
        if t >= g[-1]:
            # extrapolate with the final power value (constant tail)
            return float(self.cum[i, -1] + self.powers[i, -1] * (t - g[-1]))
        j = int(np.searchsorted(g, t) - 1)
        dt = t - g[j]
        h = g[j + 1] - g[j]
        v0 = self.powers[i, j]
        v1 = self.powers[i, j + 1]
        vt = v0 + (v1 - v0) * dt / h
        return float(self.cum[i, j] + 0.5 * (v0 + vt) * dt)

    def N(self, i: int, t0: float, t1: float) -> int:
        return int(math.floor(self.integral(i, t0, t1) + 1e-12))

    def time_for_integral(self, i: int, t0: float, target: float) -> float:
        """Smallest ``t >= t0`` with ``int_{t0}^{t} v_i >= target`` (inf if never)."""
        base = self._cum_at(i, t0)
        want = base + target
        if self.cum[i, -1] < want:
            tail_v = self.powers[i, -1]
            if tail_v <= 0:
                return math.inf
            return float(self.grid[-1]
                         + (want - self.cum[i, -1]) / tail_v)
        # binary search on [t0, grid[-1]]
        lo, hi = t0, float(self.grid[-1])
        for _ in range(80):
            mid = 0.5 * (lo + hi)
            if self._cum_at(i, mid) >= want:
                hi = mid
            else:
                lo = mid
        return hi

    def _cum_at_vec(self, idx: np.ndarray, t: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`_cum_at`: cumulative integral of ``v_i`` at
        per-worker times ``t`` (same segment convention as the scalar)."""
        g = self.grid
        t = np.asarray(t, dtype=float)
        tf = np.where(np.isfinite(t), t, g[-1])     # placeholder; masked below
        j = np.clip(np.searchsorted(g, tf, side="left") - 1, 0, len(g) - 2)
        dt = tf - g[j]
        h = g[j + 1] - g[j]
        v0 = self.powers[idx, j]
        v1 = self.powers[idx, j + 1]
        vt = v0 + (v1 - v0) * dt / h
        mid = self.cum[idx, j] + 0.5 * (v0 + vt) * dt
        tail = self.cum[idx, -1] + self.powers[idx, -1] * (tf - g[-1])
        out = np.where(tf <= g[0], 0.0, np.where(tf >= g[-1], tail, mid))
        # t = inf: infinite tail power integral (inf if tail v > 0 else
        # the finite grid total — the 0 * inf nan is never the answer)
        return np.where(np.isfinite(t), out,
                        np.where(self.powers[idx, -1] > 0, np.inf,
                                 self.cum[idx, -1]))

    def finish_times(self, workers: Sequence[int], t0,
                     target: float = 1.0) -> np.ndarray:
        """Batched :meth:`time_for_integral` (the event engine's hot path).

        ``t0`` is a scalar or a per-worker array. Replaces the per-worker
        80-iteration Python bisection with one vectorized inversion:
        a batched binary search over the per-worker cumulative-power grid
        rows finds the crossing segment, then the quadratic
        ``cum(t) = cum_j + v0*dt + 0.5*(v1-v0)/h*dt^2`` (exact for the
        piecewise-linear powers) is solved in closed form. Agrees with
        the scalar bisection to ~1e-12 relative (tested at 1e-9).
        """
        idx = np.asarray(workers, dtype=int)
        t0 = np.broadcast_to(np.asarray(t0, dtype=float), idx.shape).copy()
        g = self.grid
        T = len(g)
        base = self._cum_at_vec(idx, t0)
        want = base + target
        tail_v = self.powers[idx, -1]
        cum_end = self.cum[idx, -1]
        overflow = cum_end < want                    # crossing past the grid
        with np.errstate(divide="ignore", invalid="ignore"):
            t_tail = g[-1] + (want - cum_end) / tail_v
        t_tail = np.where(tail_v > 0, t_tail, np.inf)
        # first grid index with cum >= want (per-row binary search; rows
        # differ so np.searchsorted cannot batch this directly)
        want_in = np.where(overflow, cum_end, want)  # keep the search bounded
        lo = np.zeros(idx.shape, dtype=np.int64)
        hi = np.full(idx.shape, T - 1, dtype=np.int64)
        for _ in range(int(np.ceil(np.log2(max(T, 2)))) + 1):
            mid = (lo + hi) // 2
            ge = self.cum[idx, mid] >= want_in
            hi = np.where(ge, mid, hi)
            lo = np.where(ge, lo, np.minimum(mid + 1, T - 1))
        jj = np.maximum(hi, 1)                       # crossing in [jj-1, jj]
        rem = np.where(overflow, 0.0, want - self.cum[idx, jj - 1])
        v0 = self.powers[idx, jj - 1]
        v1 = self.powers[idx, jj]
        h = g[jj] - g[jj - 1]
        slope = (v1 - v0) / h
        # 0.5*slope*dt^2 + v0*dt = rem, stable root (exact in the linear
        # slope -> 0 limit): dt = 2*rem / (v0 + sqrt(v0^2 + 2*slope*rem))
        with np.errstate(divide="ignore", invalid="ignore"):
            disc = np.maximum(v0 * v0 + 2.0 * slope * rem, 0.0)
            den = v0 + np.sqrt(disc)
            dt = np.where(den > 0, 2.0 * rem / np.where(den > 0, den, 1.0),
                          0.0)
        t_in = g[jj - 1] + np.where(rem > 0, dt, 0.0)
        out = np.where(overflow, t_tail, np.maximum(t_in, t0))
        # never-started computations (t0 = inf) never finish
        return np.where(np.isfinite(t0), out, np.inf)

    # ------------------------------------------------ device-resident twin
    def _jax_arrays(self):
        """(grid, cum, powers) as jnp arrays, cached per x64 mode (the
        cache key matters: tests run the 1e-9 parity check under
        ``jax.experimental.enable_x64`` while the engines default to
        float32)."""
        import jax
        import jax.numpy as jnp

        key = bool(jax.config.jax_enable_x64)
        cache = getattr(self, "_jax_cache", None)
        if cache is None:
            cache = self._jax_cache = {}
        if key not in cache:
            # eager even when first touched inside a jit trace: cached
            # constants must not be tracers of the enclosing program
            with jax.ensure_compile_time_eval():
                cache[key] = (jnp.asarray(self.grid),
                              jnp.asarray(self.cum),
                              jnp.asarray(self.powers))
        return cache[key]

    def _cum_at_jax(self, t, idx):
        """jit-compatible :meth:`_cum_at_vec`: cumulative integral of
        ``v_{idx}`` at times ``t`` (``t`` and ``idx`` broadcast)."""
        import jax.numpy as jnp

        g, cum, powers = self._jax_arrays()
        t = jnp.asarray(t)
        tf = jnp.where(jnp.isfinite(t), t, g[-1])
        j = jnp.clip(jnp.searchsorted(g, tf, side="left") - 1, 0,
                     len(self.grid) - 2)
        dt = tf - g[j]
        h = g[j + 1] - g[j]
        v0 = powers[idx, j]
        v1 = powers[idx, j + 1]
        vt = v0 + (v1 - v0) * dt / h
        mid = cum[idx, j] + 0.5 * (v0 + vt) * dt
        tail = cum[idx, -1] + powers[idx, -1] * (tf - g[-1])
        out = jnp.where(tf <= g[0], 0.0,
                        jnp.where(tf >= g[-1], tail, mid))
        return jnp.where(jnp.isfinite(t), out,
                         jnp.where(powers[idx, -1] > 0, jnp.inf,
                                   cum[idx, -1]))

    def finish_times_jax(self, t0, workers=None, target: float = 1.0):
        """jit-compatible :meth:`finish_times` (the ``backend="jax"``
        hot path): smallest ``t >= t0`` with unit power integral.

        ``t0``'s last axis indexes workers ``0..n-1`` unless ``workers``
        (an integer array broadcastable against ``t0``) says otherwise —
        arrival-indexed engines pass the single popped worker per seed.
        A batched ``jnp.searchsorted`` (vmapped over the per-worker
        cumulative-power rows) finds the crossing segment and the same
        closed-form quadratic inversion as the NumPy path solves it —
        deterministic, no RNG. Matches the NumPy ``finish_times`` to
        ~1e-12 relative under x64 (tested at 1e-9 on the Fig 3/4 grids,
        including the constant-tail extrapolation and the ``v = 0``
        never-finishes inf branch); float32 precision under the engine
        default. Like every jax engine draw, NOT part of any NumPy RNG
        stream contract (the inversion is draw-free anyway).
        """
        import jax
        import jax.numpy as jnp

        g, cum, powers = self._jax_arrays()
        t0 = jnp.asarray(t0)
        if workers is None:
            workers = jnp.arange(self.n)
        idx = jnp.broadcast_to(workers, t0.shape)
        base = self._cum_at_jax(t0, idx)
        want = base + target
        tail_v = powers[idx, -1]
        cum_end = cum[idx, -1]
        overflow = cum_end < want                # crossing past the grid
        t_tail = jnp.where(tail_v > 0,
                           g[-1] + (want - cum_end) / jnp.where(
                               tail_v > 0, tail_v, 1.0), jnp.inf)
        want_in = jnp.where(overflow, cum_end, want)
        # first grid index with cum >= want, per (row = worker) pair
        flat_idx = idx.reshape(-1)
        flat_want = want_in.reshape(-1)
        jj = jax.vmap(lambda i, w: jnp.searchsorted(cum[i], w,
                                                    side="left"))(
            flat_idx, flat_want).reshape(idx.shape)
        jj = jnp.clip(jj, 1, len(self.grid) - 1)  # crossing in [jj-1, jj]
        rem = jnp.where(overflow, 0.0, want - cum[idx, jj - 1])
        v0 = powers[idx, jj - 1]
        v1 = powers[idx, jj]
        h = g[jj] - g[jj - 1]
        slope = (v1 - v0) / h
        # 0.5*slope*dt^2 + v0*dt = rem, stable root (exact in the linear
        # slope -> 0 limit): dt = 2*rem / (v0 + sqrt(v0^2 + 2*slope*rem))
        disc = jnp.maximum(v0 * v0 + 2.0 * slope * rem, 0.0)
        den = v0 + jnp.sqrt(disc)
        dt = jnp.where(den > 0, 2.0 * rem / jnp.where(den > 0, den, 1.0),
                       0.0)
        t_in = g[jj - 1] + jnp.where(rem > 0, dt, 0.0)
        out = jnp.where(overflow, t_tail, jnp.maximum(t_in, t0))
        return jnp.where(jnp.isfinite(t0), out, jnp.inf)


@dataclasses.dataclass
class PiecewisePower:
    """Analytic power: constant ``v`` until ``t_switch`` then ``v_after``.

    Used for the §6/§I "worker becomes infinitely fast" example
    (v_after = inf encoded as a huge float).
    """

    v: float
    t_switch: float = math.inf
    v_after: float = math.inf

    def integral(self, t0: float, t1: float) -> float:
        if t1 <= self.t_switch:
            return self.v * (t1 - t0)
        pre = self.v * (max(self.t_switch, t0) - t0) if t0 < self.t_switch else 0.0
        post = self.v_after * (t1 - max(self.t_switch, t0))
        return pre + post


def powers_figure3(n: int = 50, seed: int = 0, t_max: float = 400.0
                   ) -> UniversalModel:
    """Figure 3: ``v_i(t_k) = max(sin(a_i t_k + s_i) + eps, 0)``."""
    rng = np.random.default_rng(seed)
    grid = np.arange(0.0, t_max, 0.1)
    a = rng.uniform(0.5, 1.0, size=n)
    s = rng.uniform(0.0, 2 * np.pi, size=n)
    eps = rng.normal(0.0, 0.1, size=(n, len(grid)))
    powers = np.maximum(np.sin(a[:, None] * grid[None, :] + s[:, None]) + eps,
                        0.0)
    return UniversalModel(grid, powers)


def powers_figure4(n: int = 50, seed: int = 0, t_max: float = 400.0
                   ) -> UniversalModel:
    """Figure 4: ``v_i(t_k) = max(s_i + 3 sin(t_k + phi_i) + eps, 0.1)``."""
    rng = np.random.default_rng(seed)
    grid = np.arange(0.0, t_max, 0.1)
    s = rng.uniform(10.5, 11.0, size=n)
    phi = rng.uniform(0.0, 2 * np.pi, size=n)
    eps = rng.normal(0.0, 0.1, size=(n, len(grid)))
    powers = np.maximum(s[:, None] + 3 * np.sin(grid[None, :] + phi[:, None])
                        + eps, 0.1)
    return UniversalModel(grid, powers)


class PartialParticipationModel(UniversalModel):
    """Assumption 5.4 — equal power ``v`` except ≤ p·n stragglers at any time.

    ``straggler_fn(t) -> set of straggler indices`` may be adversarial; by
    default a rotating window (the worst *stationary* adversary for m-sync:
    it keeps rotating which workers are dead so no fixed subset works).
    """

    def __init__(self, n: int, v: float = 1.0, p: float = 0.1,
                 period: float = 1.0, t_max: float = 400.0,
                 straggler_fn: Optional[Callable[[float], set]] = None,
                 dt: float = 0.05) -> None:
        self.v0 = v
        self.p = p
        k = int(math.floor(p * n))
        grid = np.arange(0.0, t_max, dt)
        powers = np.full((n, len(grid)), float(v))
        if straggler_fn is None:
            def straggler_fn(t: float) -> set:
                start = int(t / period) * k % max(n, 1)
                return {(start + j) % n for j in range(k)}
        for ti, t in enumerate(grid):
            for i in straggler_fn(float(t)):
                powers[i, ti] = 0.0
        super().__init__(grid, powers)
