"""Computation-time models from the paper.

Implements the paper's four worker-compute assumptions:

* Assumption 2.2 — Fixed computation model: worker ``i`` always takes
  ``tau_i`` seconds per stochastic gradient.
* Assumption 3.1 — Random computation model: worker ``i``'s time is a
  ``(tau_i, R)``-sub-exponential random variable (mean ``tau_i``,
  ``E[exp(|t - tau_i| / R)] <= 2``, nonnegative a.s.).
* Assumption 5.1 — Universal computation model: worker ``i`` has an
  integrable computation *power* ``v_i(t) >= 0`` and computes
  ``N_i(t0, t1) = floor(int_{t0}^{t1} v_i)`` gradients in ``[t0, t1]``.
* Assumption 5.4 — Partial participation: all powers equal ``v`` except an
  (arbitrary, possibly adversarial) set of at most ``p*n`` stragglers at any
  instant.

All models expose a unified event-simulator interface::

    sample_time(i, rng) -> float          # seconds for ONE gradient started now
    sample_times(workers, rng) -> array   # batched draw for many workers
    (Universal models instead expose ``time_for_integral`` /
    ``finish_times(workers, t_start)``.)

``sample_times`` is the engine's hot path: models with closed-form or
vectorizable distributions override it (``FixedTimes`` is a pure gather;
the distribution factories below install NumPy-vectorized samplers), so a
round that restarts many workers costs one vector op instead of ``n``
Python calls. The default falls back to per-worker ``sample_time`` calls
in worker order, which keeps the RNG stream identical to the scalar path.

Every random model also reports its ``(tau_i, R)`` sub-exponential
certificate where known, so the theory in :mod:`repro.core.complexity` can be
evaluated against the exact constants used by the simulator.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence

import numpy as np

__all__ = [
    "TimeModel",
    "FixedTimes",
    "SubExponentialTimes",
    "truncated_normal_times",
    "exponential_times",
    "shifted_exponential_times",
    "gamma_times",
    "uniform_times",
    "chi2_times",
    "UniversalModel",
    "PartialParticipationModel",
    "PiecewisePower",
    "powers_figure3",
    "powers_figure4",
]


class TimeModel:
    """Base class: per-gradient computation-time sampling for ``n`` workers."""

    n: int

    def sample_time(self, i: int, rng: np.random.Generator) -> float:
        raise NotImplementedError

    def sample_times(self, workers: Sequence[int],
                     rng: np.random.Generator) -> np.ndarray:
        """Batched per-gradient times for ``workers`` (engine hot path).

        The fallback draws per worker in order, so it consumes the RNG
        stream exactly like sequential ``sample_time`` calls; subclasses
        override with a single vectorized draw where possible.
        """
        return np.array([self.sample_time(int(i), rng) for i in workers],
                        dtype=float)

    def sample_times_seeds(self, workers: Sequence[int],
                           rngs: Sequence[np.random.Generator]) -> np.ndarray:
        """Multi-seed batched draw: one ``(seeds, workers)`` matrix.

        Row ``s`` consumes ``rngs[s]`` exactly as one :meth:`sample_times`
        call would, so per-seed RNG-stream parity with scalar runs is
        preserved (the seed-batched ``simulate_batch`` engine depends on
        this). Models whose draws are RNG-free (:class:`FixedTimes`)
        override with a pure broadcast.
        """
        return np.stack([np.asarray(self.sample_times(workers, rng),
                                    dtype=float) for rng in rngs])

    def mean_times(self) -> np.ndarray:
        """``tau_i = E[time for worker i]``, sorted or not — as configured."""
        raise NotImplementedError

    # Sub-exponential certificate (Assumption 3.1); None => unknown/infinite.
    def sub_exponential_R(self) -> Optional[float]:
        return None


@dataclasses.dataclass
class FixedTimes(TimeModel):
    """Assumption 2.2 — deterministic ``tau_i``."""

    taus: np.ndarray

    def __post_init__(self) -> None:
        self.taus = np.asarray(self.taus, dtype=float)
        if np.any(self.taus <= 0):
            raise ValueError("tau_i must be positive")
        self.n = len(self.taus)

    def sample_time(self, i: int, rng: np.random.Generator) -> float:
        return float(self.taus[i])

    def sample_times(self, workers: Sequence[int],
                     rng: np.random.Generator) -> np.ndarray:
        return self.taus[np.asarray(workers, dtype=int)]

    def sample_times_seeds(self, workers: Sequence[int],
                           rngs: Sequence[np.random.Generator]) -> np.ndarray:
        # deterministic: no RNG consumed, one broadcast for all seeds
        return np.broadcast_to(self.taus[np.asarray(workers, dtype=int)],
                               (len(rngs), len(workers))).copy()

    def mean_times(self) -> np.ndarray:
        return self.taus

    def sub_exponential_R(self) -> float:
        return 0.0

    @staticmethod
    def sqrt_law(n: int, tau1: float = 1.0) -> "FixedTimes":
        """tau_i = tau1 * sqrt(i) — the paper's Figure 5 / K.1 setup."""
        return FixedTimes(tau1 * np.sqrt(np.arange(1, n + 1)))

    @staticmethod
    def power_law(n: int, alpha: float, tau1: float = 1.0,
                  delta: Optional[np.ndarray] = None) -> "FixedTimes":
        """tau_m = tau1 * m**alpha + delta_m — eq. (10)."""
        taus = tau1 * np.arange(1, n + 1, dtype=float) ** alpha
        if delta is not None:
            taus = taus + np.asarray(delta, dtype=float)
        return FixedTimes(taus)

    @staticmethod
    def linear(n: int, tau1: float = 1.0) -> "FixedTimes":
        """tau_i = tau1 * i — the log-factor-tight case of Theorem 2.3."""
        return FixedTimes(tau1 * np.arange(1, n + 1, dtype=float))


@dataclasses.dataclass
class SubExponentialTimes(TimeModel):
    """Assumption 3.1 — random per-gradient times, independent across draws.

    ``sampler(i, rng)`` must return a nonnegative float with mean
    ``taus[i]``; ``R`` is the common sub-exponential parameter (may be a
    conservative upper bound). ``batch_sampler(workers, rng)``, when
    provided, draws one vectorized sample per listed worker — the engine
    prefers it for bulk restarts. ``jax_sampler(key) -> (n,)``, when
    provided, draws one full round of per-worker times with ``jax.random``
    — the ``simulate_batch`` JAX backend needs it (distribution-equal to
    the NumPy samplers, not stream-equal).
    """

    taus: np.ndarray
    sampler: Callable[[int, np.random.Generator], float]
    R: float
    name: str = "subexp"
    batch_sampler: Optional[Callable[[np.ndarray, np.random.Generator],
                                     np.ndarray]] = None
    jax_sampler: Optional[Callable] = None

    def __post_init__(self) -> None:
        self.taus = np.asarray(self.taus, dtype=float)
        self.n = len(self.taus)

    def sample_time(self, i: int, rng: np.random.Generator) -> float:
        t = float(self.sampler(i, rng))
        return max(t, 0.0)

    def sample_times(self, workers: Sequence[int],
                     rng: np.random.Generator) -> np.ndarray:
        workers = np.asarray(workers, dtype=int)
        if self.batch_sampler is None:
            return np.array([max(float(self.sampler(int(i), rng)), 0.0)
                             for i in workers])
        return np.maximum(np.asarray(self.batch_sampler(workers, rng),
                                     dtype=float), 0.0)

    def mean_times(self) -> np.ndarray:
        return self.taus

    def sub_exponential_R(self) -> float:
        return self.R


def truncated_normal_times(mus: Sequence[float], sigma: float
                           ) -> SubExponentialTimes:
    """``tau_i ~ N(mu_i, sigma^2)`` truncated to ``[0, inf)``.

    Sub-exponential with ``R = O(sigma)`` (Barreto et al., 2025). The mean of
    the truncated variable is ``mu + sigma * phi(a)/Phi(-a)`` with
    ``a = -mu/sigma``; we report the exact truncated means.
    """
    mus = np.asarray(mus, dtype=float)

    def _truncated_mean(mu: float) -> float:
        if sigma == 0:
            return max(mu, 0.0)
        a = -mu / sigma
        phi = math.exp(-0.5 * a * a) / math.sqrt(2 * math.pi)
        Phi = 0.5 * math.erfc(a / math.sqrt(2))
        return mu + sigma * phi / max(Phi, 1e-300)

    taus = np.array([_truncated_mean(mu) for mu in mus])

    def sampler(i: int, rng: np.random.Generator) -> float:
        while True:
            t = rng.normal(mus[i], sigma)
            if t >= 0:
                return t

    def batch_sampler(workers: np.ndarray,
                      rng: np.random.Generator) -> np.ndarray:
        out = rng.normal(mus[workers], sigma)
        while True:
            bad = out < 0
            if not bad.any():
                return out
            out[bad] = rng.normal(mus[workers][bad], sigma)

    return SubExponentialTimes(taus, sampler, R=float(sigma),
                               name=f"truncnorm(sigma={sigma})",
                               batch_sampler=batch_sampler)


def exponential_times(lam: float, n: int) -> SubExponentialTimes:
    """``tau_i ~ Exp(lam)`` for all workers: ``tau_i = R = 1/lam`` (§3)."""
    taus = np.full(n, 1.0 / lam)

    def sampler(i: int, rng: np.random.Generator) -> float:
        return rng.exponential(1.0 / lam)

    def jax_sampler(key):
        import jax
        return jax.random.exponential(key, (n,)) / lam

    return SubExponentialTimes(
        taus, sampler, R=1.0 / lam, name=f"exp(lam={lam})",
        batch_sampler=lambda w, rng: rng.exponential(1.0 / lam, size=len(w)),
        jax_sampler=jax_sampler)


def shifted_exponential_times(mus: Sequence[float], lams: Sequence[float]
                              ) -> SubExponentialTimes:
    """``tau_i = mu_i + Exp(lam_i)`` (§D.1): R = max_i 1/lam_i."""
    mus = np.asarray(mus, dtype=float)
    lams = np.asarray(lams, dtype=float)
    taus = mus + 1.0 / lams

    def sampler(i: int, rng: np.random.Generator) -> float:
        return mus[i] + rng.exponential(1.0 / lams[i])

    def jax_sampler(key):
        import jax
        return mus + jax.random.exponential(key, mus.shape) / lams

    return SubExponentialTimes(
        taus, sampler, R=float(np.max(1.0 / lams)), name="shifted-exp",
        batch_sampler=lambda w, rng: mus[w] + rng.exponential(1.0 / lams[w]),
        jax_sampler=jax_sampler)


def gamma_times(means: Sequence[float], var: float) -> SubExponentialTimes:
    """Gamma with per-worker mean ``tau_i`` and common variance (§K.3).

    shape k = tau^2/var, scale theta = var/tau; R = O(max sqrt(k)*theta).
    """
    means = np.asarray(means, dtype=float)
    ks = means ** 2 / var
    thetas = var / means
    R = float(np.max(np.maximum(np.sqrt(ks), 1.0) * thetas))

    def sampler(i: int, rng: np.random.Generator) -> float:
        return rng.gamma(ks[i], thetas[i])

    def jax_sampler(key):
        import jax
        return jax.random.gamma(key, ks) * thetas

    return SubExponentialTimes(
        means, sampler, R=R, name="gamma",
        batch_sampler=lambda w, rng: rng.gamma(ks[w], thetas[w]),
        jax_sampler=jax_sampler)


def uniform_times(means: Sequence[float], half_width: float
                  ) -> SubExponentialTimes:
    """``tau_i ~ Unif(tau_i - w, tau_i + w)`` (§K.3/K.4). Bounded => R=O(w)."""
    means = np.asarray(means, dtype=float)

    def sampler(i: int, rng: np.random.Generator) -> float:
        return rng.uniform(means[i] - half_width, means[i] + half_width)

    def jax_sampler(key):
        import jax
        import jax.numpy as jnp
        u = jax.random.uniform(key, means.shape,
                               minval=-half_width, maxval=half_width)
        # same clamp the engine applies to every NumPy draw via
        # sample_time / sample_times (times are nonnegative a.s.)
        return jnp.maximum(means + u, 0.0)

    return SubExponentialTimes(
        means, sampler, R=float(half_width), name=f"uniform(w={half_width})",
        batch_sampler=lambda w, rng: rng.uniform(means[w] - half_width,
                                                 means[w] + half_width),
        jax_sampler=jax_sampler)


def chi2_times(dofs: Sequence[int]) -> SubExponentialTimes:
    """``tau_i ~ chi^2_{k_i}`` (§D.1): tau_i = k_i, R = O(max sqrt(k_i))."""
    dofs = np.asarray(dofs, dtype=float)

    def sampler(i: int, rng: np.random.Generator) -> float:
        return rng.chisquare(dofs[i])

    return SubExponentialTimes(dofs.copy(), sampler,
                               R=float(2.0 * np.sqrt(np.max(dofs))),
                               name="chi2",
                               batch_sampler=lambda w, rng:
                                   rng.chisquare(dofs[w]))


# ---------------------------------------------------------------------------
# Assumption 5.1 — Universal computation model.
# ---------------------------------------------------------------------------

class UniversalModel:
    """Computation powers ``v_i(t)`` on a uniform grid with linear interp.

    ``N_i(t0, t1) = floor(int_{t0}^{t1} v_i(s) ds)`` — eq. (11). The paper's
    Figures 3/4 define powers exactly this way (grid ``t_k = 0.1 k`` +
    linear interpolation), so a trapezoid cumulative integral on the grid is
    *exact* for these instances.
    """

    def __init__(self, grid: np.ndarray, powers: np.ndarray) -> None:
        # powers: (n, T) nonnegative samples on grid (T,)
        self.grid = np.asarray(grid, dtype=float)
        self.powers = np.maximum(np.asarray(powers, dtype=float), 0.0)
        self.n = self.powers.shape[0]
        dt = np.diff(self.grid)
        mids = 0.5 * (self.powers[:, 1:] + self.powers[:, :-1])
        self.cum = np.concatenate(
            [np.zeros((self.n, 1)), np.cumsum(mids * dt, axis=1)], axis=1)

    def integral(self, i: int, t0: float, t1: float) -> float:
        """``int_{t0}^{t1} v_i`` (exact for piecewise-linear powers)."""
        return self._cum_at(i, t1) - self._cum_at(i, t0)

    def _cum_at(self, i: int, t: float) -> float:
        g = self.grid
        if t <= g[0]:
            return 0.0
        if t >= g[-1]:
            # extrapolate with the final power value (constant tail)
            return float(self.cum[i, -1] + self.powers[i, -1] * (t - g[-1]))
        j = int(np.searchsorted(g, t) - 1)
        dt = t - g[j]
        h = g[j + 1] - g[j]
        v0 = self.powers[i, j]
        v1 = self.powers[i, j + 1]
        vt = v0 + (v1 - v0) * dt / h
        return float(self.cum[i, j] + 0.5 * (v0 + vt) * dt)

    def N(self, i: int, t0: float, t1: float) -> int:
        return int(math.floor(self.integral(i, t0, t1) + 1e-12))

    def time_for_integral(self, i: int, t0: float, target: float) -> float:
        """Smallest ``t >= t0`` with ``int_{t0}^{t} v_i >= target`` (inf if never)."""
        base = self._cum_at(i, t0)
        want = base + target
        if self.cum[i, -1] < want:
            tail_v = self.powers[i, -1]
            if tail_v <= 0:
                return math.inf
            return float(self.grid[-1]
                         + (want - self.cum[i, -1]) / tail_v)
        # binary search on [t0, grid[-1]]
        lo, hi = t0, float(self.grid[-1])
        for _ in range(80):
            mid = 0.5 * (lo + hi)
            if self._cum_at(i, mid) >= want:
                hi = mid
            else:
                lo = mid
        return hi

    def finish_times(self, workers: Sequence[int], t0: float,
                     target: float = 1.0) -> np.ndarray:
        """Batched :meth:`time_for_integral` for the event engine."""
        return np.array([self.time_for_integral(int(i), t0, target)
                         for i in workers])


@dataclasses.dataclass
class PiecewisePower:
    """Analytic power: constant ``v`` until ``t_switch`` then ``v_after``.

    Used for the §6/§I "worker becomes infinitely fast" example
    (v_after = inf encoded as a huge float).
    """

    v: float
    t_switch: float = math.inf
    v_after: float = math.inf

    def integral(self, t0: float, t1: float) -> float:
        if t1 <= self.t_switch:
            return self.v * (t1 - t0)
        pre = self.v * (max(self.t_switch, t0) - t0) if t0 < self.t_switch else 0.0
        post = self.v_after * (t1 - max(self.t_switch, t0))
        return pre + post


def powers_figure3(n: int = 50, seed: int = 0, t_max: float = 400.0
                   ) -> UniversalModel:
    """Figure 3: ``v_i(t_k) = max(sin(a_i t_k + s_i) + eps, 0)``."""
    rng = np.random.default_rng(seed)
    grid = np.arange(0.0, t_max, 0.1)
    a = rng.uniform(0.5, 1.0, size=n)
    s = rng.uniform(0.0, 2 * np.pi, size=n)
    eps = rng.normal(0.0, 0.1, size=(n, len(grid)))
    powers = np.maximum(np.sin(a[:, None] * grid[None, :] + s[:, None]) + eps,
                        0.0)
    return UniversalModel(grid, powers)


def powers_figure4(n: int = 50, seed: int = 0, t_max: float = 400.0
                   ) -> UniversalModel:
    """Figure 4: ``v_i(t_k) = max(s_i + 3 sin(t_k + phi_i) + eps, 0.1)``."""
    rng = np.random.default_rng(seed)
    grid = np.arange(0.0, t_max, 0.1)
    s = rng.uniform(10.5, 11.0, size=n)
    phi = rng.uniform(0.0, 2 * np.pi, size=n)
    eps = rng.normal(0.0, 0.1, size=(n, len(grid)))
    powers = np.maximum(s[:, None] + 3 * np.sin(grid[None, :] + phi[:, None])
                        + eps, 0.1)
    return UniversalModel(grid, powers)


class PartialParticipationModel(UniversalModel):
    """Assumption 5.4 — equal power ``v`` except ≤ p·n stragglers at any time.

    ``straggler_fn(t) -> set of straggler indices`` may be adversarial; by
    default a rotating window (the worst *stationary* adversary for m-sync:
    it keeps rotating which workers are dead so no fixed subset works).
    """

    def __init__(self, n: int, v: float = 1.0, p: float = 0.1,
                 period: float = 1.0, t_max: float = 400.0,
                 straggler_fn: Optional[Callable[[float], set]] = None,
                 dt: float = 0.05) -> None:
        self.v0 = v
        self.p = p
        k = int(math.floor(p * n))
        grid = np.arange(0.0, t_max, dt)
        powers = np.full((n, len(grid)), float(v))
        if straggler_fn is None:
            def straggler_fn(t: float) -> set:
                start = int(t / period) * k % max(n, 1)
                return {(start + j) % n for j in range(k)}
        for ti, t in enumerate(grid):
            for i in straggler_fn(float(t)):
                powers[i, ti] = 0.0
        super().__init__(grid, powers)
