"""Deterministic synthetic data pipeline.

No internet in this container: corpora are generated, not downloaded. Three
sources, all seeded and reproducible:

* :class:`SyntheticLM` — Zipf-distributed token stream with local Markov
  structure (so models can actually reduce loss, unlike iid-uniform).
* :class:`CharCorpus` — a procedurally generated "shakespeare-like" char
  corpus for the NanoGPT experiments (§K.5 analogue).
* :func:`gaussian_mixture` — the CIFAR-10 stand-in for the §K.4 two-layer
  NN experiment: D-dim Gaussian mixture, ``num_classes`` components.

Batches are dicts {tokens, labels, loss_mask} shaped for ``Model.loss``;
``worker_shards`` splits a batch into the per-worker groups the m-sync
engine masks over (global_batch % n_workers == 0 enforced here).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

__all__ = ["SyntheticLM", "CharCorpus", "gaussian_mixture", "worker_shards"]


@dataclasses.dataclass
class SyntheticLM:
    """Markov-Zipf token stream: P(next | cur) concentrated on a few
    successors; unigram marginal ~ Zipf(1.2)."""

    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    branching: int = 8

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        V = self.vocab_size
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self.unigram = (ranks ** -1.2) / np.sum(ranks ** -1.2)
        # each token gets `branching` successors drawn from the unigram
        self.succ = rng.choice(V, size=(V, self.branching), p=self.unigram)
        self.succ_w = rng.dirichlet(np.ones(self.branching), size=V)
        self._step = 0

    def batch(self, step: Optional[int] = None) -> dict:
        step = self._step if step is None else step
        self._step = step + 1
        rng = np.random.default_rng((self.seed, step))
        B, S, V = self.batch_size, self.seq_len, self.vocab_size
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.choice(V, size=B, p=self.unigram)
        for t in range(S):
            u = rng.random(B)
            # mix: 80% markov successor, 20% unigram resample
            choice = (rng.random((B, self.branching))
                      * self.succ_w[toks[:, t]]).argmax(-1)
            markov = self.succ[toks[:, t], choice]
            fresh = rng.choice(V, size=B, p=self.unigram)
            toks[:, t + 1] = np.where(u < 0.8, markov, fresh)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
            "loss_mask": np.ones((B, S), np.float32),
        }

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


@dataclasses.dataclass
class CharCorpus:
    """Procedural character corpus: nested clause structure + a fixed word
    bank, so a small LM has plenty of learnable structure (NanoGPT-style
    char-level training, paper §K.5)."""

    seq_len: int
    batch_size: int
    seed: int = 0
    length: int = 1 << 18

    WORDS = ("the quick brown fox jumps over lazy dog and all that is gold "
             "does not glitter nor all those who wander are lost the old "
             "that is strong does not wither deep roots are not reached by "
             "the frost from the ashes a fire shall be woken").split()

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        parts = []
        n = 0
        while n < self.length:
            sent = " ".join(rng.choice(self.WORDS,
                                       size=rng.integers(4, 12)))
            parts.append(sent + ". ")
            n += len(parts[-1])
        text = "".join(parts)[:self.length]
        self.vocab = sorted(set(text))
        self.vocab_size = len(self.vocab)
        stoi = {c: i for i, c in enumerate(self.vocab)}
        self.data = np.array([stoi[c] for c in text], np.int32)

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        B, S = self.batch_size, self.seq_len
        starts = rng.integers(0, len(self.data) - S - 1, size=B)
        toks = np.stack([self.data[s:s + S + 1] for s in starts])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                "loss_mask": np.ones((B, S), np.float32)}


def gaussian_mixture(num_classes: int = 10, dim: int = 3072,
                     n: int = 50000, seed: int = 0,
                     spread: float = 3.0) -> Tuple[np.ndarray, np.ndarray]:
    """CIFAR-10 stand-in (§K.4): returns (X (n, dim) float32, y (n,))."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, spread, size=(num_classes, dim)) / np.sqrt(dim)
    y = rng.integers(0, num_classes, size=n)
    X = centers[y] + rng.normal(0, 1.0, size=(n, dim)) / np.sqrt(dim)
    return X.astype(np.float32), y.astype(np.int32)


def worker_shards(batch: dict, n_workers: int) -> list:
    """Split a global batch into n per-worker micro-batches (group view)."""
    B = batch["tokens"].shape[0]
    assert B % n_workers == 0, f"batch {B} % workers {n_workers} != 0"
    per = B // n_workers
    return [{k: v[i * per:(i + 1) * per] for k, v in batch.items()}
            for i in range(n_workers)]
