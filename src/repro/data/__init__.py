from .pipeline import CharCorpus, SyntheticLM, gaussian_mixture, worker_shards
