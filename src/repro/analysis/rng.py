"""RNG-discipline pass: RNG001 / RNG002 / RNG003.

Scope (decided by the caller via path patterns, see ``cli.DEFAULT_RNG_GLOBS``):
``core/batch_jax.py``, ``core/time_models.py`` and ``kernels/`` for the
key-plumbing rules; the jax-only modules (``batch_jax`` + ``kernels``,
NOT ``time_models`` whose NumPy layer is the reference implementation)
additionally ban host ``np.random``.

The keyed-draw contract these rules pin down (DESIGN.md §3b): every
``jax.random`` draw consumes a key that reaches it through ``split`` /
``fold_in`` / parameter plumbing. A literal ``PRNGKey(7)`` inside an
engine body silently correlates seeds; the *same* key expression feeding
two draw sites reuses a stream (draws become identical, not
independent); a host ``np.random`` call inside a jax engine both breaks
device residency and escapes the per-seed Philox counter discipline.
"""

from __future__ import annotations

import ast
import itertools
from typing import Dict, Iterator, List, Optional

from .findings import Finding
from .passes import ModuleSource, assigned_names, call_name

__all__ = ["run_rng_pass", "DRAW_FNS"]

_KEY_ROOTS = {"jax.random.PRNGKey", "jax.random.key"}

# jax.random functions that CONSUME a key (first arg / key=). split and
# fold_in are derivations, not draws — deriving twice from one parent is
# the legitimate pattern, so they are excluded on purpose.
DRAW_FNS = frozenset({
    "normal", "uniform", "bernoulli", "exponential", "gamma", "beta",
    "categorical", "choice", "permutation", "randint", "bits", "poisson",
    "truncated_normal", "gumbel", "laplace", "logistic", "cauchy",
    "rademacher", "dirichlet", "multivariate_normal", "t",
})

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def _scope_body(fn: ast.AST) -> Iterator[ast.AST]:
    """Walk a function's body, stopping at nested function boundaries."""
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    stack = list(body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, _FUNC_NODES):
            continue                      # nested scope analyzed separately
        stack.extend(ast.iter_child_nodes(node))


def _assignment_counts(fn: ast.AST) -> Dict[str, int]:
    """How many times each name is (re)bound inside this scope."""
    counts: Dict[str, int] = {}

    def bump(name: str) -> None:
        counts[name] = counts.get(name, 0) + 1

    args = getattr(fn, "args", None)
    if args is not None:
        for a in itertools.chain(args.posonlyargs, args.args,
                                 args.kwonlyargs,
                                 filter(None, [args.vararg, args.kwarg])):
            bump(a.arg)
    for node in _scope_body(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                for name in assigned_names(t):
                    bump(name)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            for name in assigned_names(node.target):
                bump(name)
        elif isinstance(node, (ast.For, ast.AsyncFor)):
            for name in assigned_names(node.target):
                bump(name)
                bump(name)                # loop vars rebind per iteration
        elif isinstance(node, ast.comprehension):
            for name in assigned_names(node.target):
                bump(name)
                bump(name)
        elif isinstance(node, ast.withitem) and node.optional_vars:
            for name in assigned_names(node.optional_vars):
                bump(name)
        elif isinstance(node, ast.NamedExpr):
            for name in assigned_names(node.target):
                bump(name)
    return counts


def _draw_key_arg(node: ast.Call, mod: ModuleSource) -> Optional[ast.AST]:
    """The key expression of a jax.random draw call, else None."""
    name = call_name(node, mod)
    if not name or not name.startswith("jax.random."):
        return None
    if name.rsplit(".", 1)[-1] not in DRAW_FNS:
        return None
    if node.args:
        return node.args[0]
    for kw in node.keywords:
        if kw.arg == "key":
            return kw.value
    return None


def run_rng_pass(mod: ModuleSource, jax_only: bool) -> List[Finding]:
    """RNG001/RNG002 on every function scope; RNG003 iff ``jax_only``."""
    findings: List[Finding] = []

    # RNG003: module-wide, any scope (host RNG is wrong even at import
    # time in a jax-only engine module).
    if jax_only:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                name = call_name(node, mod)
                if name and (name.startswith("numpy.random.")
                             or name.startswith("np.random.")):
                    findings.append(Finding(
                        mod.rel, node.lineno, "RNG003",
                        f"host RNG call {name} in jax-only engine module"))

    for fn in ast.walk(mod.tree):
        if not isinstance(fn, _FUNC_NODES):
            continue
        counts = _assignment_counts(fn)
        # key-expression dump -> first draw site line
        seen_keys: Dict[str, int] = {}
        for node in _scope_body(fn):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node, mod)
            # RNG001: literal-constant root key inside an engine body
            if (name in _KEY_ROOTS and node.args
                    and isinstance(node.args[0], ast.Constant)):
                findings.append(Finding(
                    mod.rel, node.lineno, "RNG001",
                    f"literal {name}({node.args[0].value!r}) inside a "
                    f"function body; derive keys via split/fold_in or "
                    f"accept one as a parameter"))
            # RNG002: identical key expression at two draw sites
            key_expr = _draw_key_arg(node, mod)
            if key_expr is None:
                continue
            names = [n.id for n in ast.walk(key_expr)
                     if isinstance(n, ast.Name)]
            if any(counts.get(n, 1) > 1 for n in names):
                continue            # name rebound between sites: streams
                                    # may differ, syntactic equality lies
            dump = ast.dump(key_expr)
            if dump in seen_keys:
                findings.append(Finding(
                    mod.rel, node.lineno, "RNG002",
                    f"key expression {ast.unparse(key_expr)!r} already "
                    f"feeds the draw at line {seen_keys[dump]}; reusing "
                    f"it makes the two draws identical — split the key"))
            else:
                seen_keys[dump] = node.lineno
    return mod.apply_pragmas(findings)
