"""``python -m repro.analysis`` — run every contract pass over a tree.

Default scan: ``src/`` + ``benchmarks/`` under ``--root`` (the repo
checkout; CI runs from the repo root). Pass explicit files/dirs to
narrow the sweep. ``--format json`` emits a machine-readable findings
list (the CI artifact); exit status is nonzero iff findings remain
after pragma filtering.

Pass scoping by path (mirrors ISSUE 6 / DESIGN "Enforced invariants"):

* RNG discipline — ``core/batch_jax.py``, ``core/time_models.py``,
  ``kernels/*``; the host-RNG ban (RNG003) only on the jax-only modules
  (``batch_jax`` + ``kernels``), since ``time_models``' NumPy layer *is*
  the reference implementation.
* Jit/scan purity — every ``.py`` file scanned; the x64 dtype rule
  (JIT005) only on ``core/batch_jax.py``, the one module with an
  ``x64=True`` engine mode to protect.
* Robustness — swallowed exceptions (ROB001) on engine/launch code
  (``core/``, ``launch/``); non-atomic JSON artifact writes (ROB002) on
  the artifact writers (``exp/``, ``benchmarks/``).
* Registry cross-check — once per invocation against the repo-root
  ``strategies.py`` / ``scenarios.py`` / ``time_models.py`` / DESIGN.md
  quartet (skipped with ``--no-registry`` or when the quartet is absent,
  e.g. scanning a fixture directory).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from .findings import RULES, Finding
from .passes import iter_py_files, load_module
from .purity import run_purity_pass
from .registry import run_registry_pass
from .rng import run_rng_pass
from .robustness import run_robustness_pass

__all__ = ["analyze", "main"]

_RNG_SCOPE = ("core/batch_jax.py", "core/time_models.py", "/kernels/")
_JAX_ONLY = ("core/batch_jax.py", "/kernels/")
_X64_STRICT = ("core/batch_jax.py",)
_ROB_EXC_SCOPE = ("core/", "launch/")        # ROB001: engine/launch code
_ROB_IO_SCOPE = ("exp/", "benchmarks/")      # ROB002: artifact writers


def _in_scope(rel: str, patterns) -> bool:
    rel = "/" + rel.replace("\\", "/")      # so "kernels/x.py" matches
    return any(rel.endswith(p) or p in rel for p in patterns)


def analyze(root: Path, paths: Optional[List[Path]] = None,
            registry: bool = True) -> List[Finding]:
    """Run all passes; returns pragma-filtered findings, sorted."""
    root = Path(root)
    if paths is None:
        paths = [p for p in (root / "src", root / "benchmarks")
                 if p.exists()]
    findings: List[Finding] = []
    for path in iter_py_files([Path(p) for p in paths]):
        try:
            rel = str(path.relative_to(root))
        except ValueError:
            rel = str(path)
        try:
            mod = load_module(path, rel=rel)
        except SyntaxError as exc:
            findings.append(Finding(rel, exc.lineno or 1, "PARSE",
                                    f"syntax error: {exc.msg}"))
            continue
        if _in_scope(rel, _RNG_SCOPE):
            findings.extend(
                run_rng_pass(mod, jax_only=_in_scope(rel, _JAX_ONLY)))
        findings.extend(
            run_purity_pass(mod, x64_strict=_in_scope(rel, _X64_STRICT)))
        rob_exc = _in_scope(rel, _ROB_EXC_SCOPE)
        rob_io = _in_scope(rel, _ROB_IO_SCOPE)
        if rob_exc or rob_io:
            findings.extend(run_robustness_pass(
                mod, exceptions=rob_exc, io=rob_io))
    if registry and (root / "DESIGN.md").exists():
        findings.extend(run_registry_pass(root))
    return sorted(findings)


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-level contract analyzer: RNG-stream discipline, "
                    "jit/scan purity, registry/coverage cross-checks.")
    parser.add_argument("paths", nargs="*", type=Path,
                        help="files or directories to scan "
                             "(default: <root>/src + <root>/benchmarks)")
    parser.add_argument("--root", type=Path, default=Path("."),
                        help="repo root for default paths and the "
                             "registry cross-check (default: cwd)")
    parser.add_argument("--format", choices=("text", "json"),
                        default="text")
    parser.add_argument("--no-registry", action="store_true",
                        help="skip the DESIGN.md registry cross-check")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule table and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule}  {desc}")
        return 0

    findings = analyze(args.root, paths=args.paths or None,
                       registry=not args.no_registry)
    if args.format == "json":
        print(json.dumps({"count": len(findings),
                          "findings": [f.to_dict() for f in findings]},
                         indent=2))
    else:
        for f in findings:
            print(f.format())
        print(f"repcheck: {len(findings)} finding(s)"
              if findings else "repcheck: clean")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
