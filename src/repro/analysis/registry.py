"""Registry/coverage cross-check pass: REG001 – REG007.

Statically (no imports executed) collects:

* ``STRATEGIES`` names — ``@register_strategy("name")`` decorations in
  ``core/strategies.py`` (plus literal ``STRATEGIES["name"] = ...``
  assignments);
* ``SCENARIOS`` names — ``@register_scenario("name")`` in
  ``exp/scenarios.py``, and per-factory the time-model constructors each
  references;
* time-model factory names — top-level functions/classes (and their
  methods) in ``core/time_models.py``;
* the DESIGN.md §3b *coverage matrix* (markdown table whose first header
  cell starts with ``strategy``) and *scenario table* (first header cell
  ``scenario``), both searched inside the §3b section;
* the parity-matrix test's ``COVERAGE`` dict literal in
  ``tests/test_strategy_matrix.py`` (REG006) — the engine-parity
  declaration every registered strategy must carry.

* the DESIGN.md §3b *sharded backend table* (first header cell
  ``sharded kind``) against the ``SHARDED_KINDS`` tuple literal in
  ``launch/sweep.py`` (REG007) — the engine families the
  ``jax_sharded`` backend routes natively must be documented, and the
  doc must not promise kinds the router does not shard;

and reports drift in either direction. Matrix rows may group
strategies with ``/`` (``sync/msync``) and carry parenthesized
qualifiers — ``deadline (serial — by design)`` parses as ``deadline``.
REG006 adds the registry ↔ COVERAGE legs (both directions); together
with REG001/REG002's registry ↔ DESIGN-matrix legs that closes the
triangle, so the code, the parity tests and the docs cannot drift
apart pairwise. Registry findings are
structural, not line-local: they have no pragma escape — fix the
matrix or the registry.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from .findings import Finding
from .passes import load_module

__all__ = ["run_registry_pass", "collect_registered",
           "parse_design_tables", "parse_coverage_table",
           "parse_sharded_table", "collect_sharded_kinds"]

_SECTION_RE = re.compile(r"^##\s+§3b\b", re.MULTILINE)
_NEXT_SECTION_RE = re.compile(r"^##\s+(?!#)", re.MULTILINE)


def collect_registered(path: Path, decorator: str,
                       registry: str) -> Dict[str, int]:
    """``{name: lineno}`` of every registration in a registry module."""
    mod = load_module(path)
    out: Dict[str, int] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            for deco in node.decorator_list:
                if (isinstance(deco, ast.Call)
                        and isinstance(deco.func, ast.Name)
                        and deco.func.id == decorator
                        and deco.args
                        and isinstance(deco.args[0], ast.Constant)):
                    out[deco.args[0].value] = node.lineno
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if (isinstance(t, ast.Subscript)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == registry
                        and isinstance(t.slice, ast.Constant)):
                    out[t.slice.value] = node.lineno
    return out


def _tables_in(text: str, base_line: int) -> List[List[Tuple[int, List[str]]]]:
    """All markdown tables as lists of (lineno, cells) rows."""
    tables, current = [], []
    for lineno, line in enumerate(text.splitlines(), start=base_line):
        stripped = line.strip()
        if stripped.startswith("|"):
            cells = [c.strip() for c in stripped.strip("|").split("|")]
            if all(set(c) <= set("-: ") for c in cells):
                continue                      # separator row
            current.append((lineno, cells))
        elif current:
            tables.append(current)
            current = []
    if current:
        tables.append(current)
    return tables


def _row_strategies(cell: str) -> List[str]:
    """First-column cell -> strategy tokens (strip parens, split '/')."""
    cell = re.sub(r"\(.*?\)", "", cell)
    cell = cell.replace("`", "").replace("*", "")
    return [tok.strip() for tok in cell.split("/") if tok.strip()]


def parse_design_tables(design_path: Path):
    """(matrix: {name: lineno}, scenarios: {name: lineno}) from §3b.

    Missing section/tables come back as ``None`` so the caller can emit
    a structural finding instead of a spray of per-name mismatches.
    """
    text = design_path.read_text()
    m = _SECTION_RE.search(text)
    if not m:
        return None, None
    start = m.end()
    nxt = _NEXT_SECTION_RE.search(text, start)
    section = text[start:nxt.start()] if nxt else text[start:]
    base_line = text[:start].count("\n") + 1
    matrix: Optional[Dict[str, int]] = None
    scen: Optional[Dict[str, int]] = None
    for table in _tables_in(section, base_line):
        header = table[0][1]
        first = header[0].lower()
        if first.startswith("strategy") and matrix is None:
            matrix = {}
            for lineno, cells in table[1:]:
                for tok in _row_strategies(cells[0]):
                    matrix[tok] = lineno
        elif first.startswith("scenario") and scen is None:
            scen = {}
            for lineno, cells in table[1:]:
                for tok in _row_strategies(cells[0]):
                    scen[tok] = lineno
    return matrix, scen


def parse_sharded_table(design_path: Path) -> Optional[Dict[str, int]]:
    """``{kind: lineno}`` from the §3b sharded backend table (first
    header cell starting with ``sharded``); ``None`` when §3b or the
    table is missing so the caller can emit one structural finding."""
    text = design_path.read_text()
    m = _SECTION_RE.search(text)
    if not m:
        return None
    start = m.end()
    nxt = _NEXT_SECTION_RE.search(text, start)
    section = text[start:nxt.start()] if nxt else text[start:]
    base_line = text[:start].count("\n") + 1
    for table in _tables_in(section, base_line):
        if table[0][1][0].lower().startswith("sharded"):
            out: Dict[str, int] = {}
            for lineno, cells in table[1:]:
                for tok in _row_strategies(cells[0]):
                    out[tok] = lineno
            return out
    return None


def collect_sharded_kinds(sweep_path: Path) -> Optional[Dict[str, int]]:
    """``{kind: lineno}`` from the ``SHARDED_KINDS`` tuple/list literal
    of string constants in ``launch/sweep.py`` (static — no import).
    ``None`` when no such literal assignment exists."""
    mod = load_module(sweep_path)
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Assign):
            continue
        if not any(isinstance(t, ast.Name) and t.id == "SHARDED_KINDS"
                   for t in node.targets):
            continue
        if not isinstance(node.value, (ast.Tuple, ast.List)):
            return None
        out: Dict[str, int] = {}
        for elt in node.value.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out[elt.value] = elt.lineno
        return out
    return None


def parse_coverage_table(path: Path) -> Optional[Dict[str, int]]:
    """``{name: lineno}`` from the parity-matrix test's ``COVERAGE``
    dict literal (string keys only). ``None`` when the module defines no
    such literal — the caller emits a structural REG006 instead of
    per-name noise."""
    mod = load_module(path)
    for node in mod.tree.body:
        if not isinstance(node, ast.Assign):
            continue
        if not any(isinstance(t, ast.Name) and t.id == "COVERAGE"
                   for t in node.targets):
            continue
        if not isinstance(node.value, ast.Dict):
            return None
        out: Dict[str, int] = {}
        for key in node.value.keys:
            if isinstance(key, ast.Constant) and isinstance(key.value, str):
                out[key.value] = key.lineno
        return out
    return None


def _time_model_names(path: Path) -> Tuple[Set[str], Dict[str, Set[str]]]:
    """Top-level def/class names + per-class attribute names."""
    mod = load_module(path)
    top: Set[str] = set()
    class_attrs: Dict[str, Set[str]] = {}
    for node in mod.tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            top.add(node.name)
        elif isinstance(node, ast.ClassDef):
            top.add(node.name)
            attrs: Set[str] = set()
            for sub in ast.walk(node):
                if isinstance(sub, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)):
                    attrs.add(sub.name)
                elif isinstance(sub, ast.Assign):
                    for t in sub.targets:
                        if isinstance(t, ast.Name):
                            attrs.add(t.id)
                        elif (isinstance(t, ast.Attribute)
                              and isinstance(t.value, ast.Name)
                              and t.value.id == "self"):
                            attrs.add(t.attr)
                elif isinstance(sub, ast.AnnAssign) and isinstance(
                        sub.target, (ast.Name, ast.Attribute)):
                    if isinstance(sub.target, ast.Name):
                        attrs.add(sub.target.id)
                    elif isinstance(sub.target.value, ast.Name) \
                            and sub.target.value.id == "self":
                        attrs.add(sub.target.attr)
            class_attrs[node.name] = attrs
    return top, class_attrs


def run_registry_pass(root: Path, *,
                      strategies_path: Optional[Path] = None,
                      scenarios_path: Optional[Path] = None,
                      time_models_path: Optional[Path] = None,
                      design_path: Optional[Path] = None,
                      matrix_test_path: Optional[Path] = None,
                      sweep_path: Optional[Path] = None
                      ) -> List[Finding]:
    root = Path(root)
    strategies_path = strategies_path or (
        root / "src/repro/core/strategies.py")
    sweep_path = sweep_path or (root / "src/repro/launch/sweep.py")
    scenarios_path = scenarios_path or (root / "src/repro/exp/scenarios.py")
    time_models_path = time_models_path or (
        root / "src/repro/core/time_models.py")
    design_path = design_path or (root / "DESIGN.md")
    matrix_test_path = matrix_test_path or (
        root / "tests/test_strategy_matrix.py")
    findings: List[Finding] = []

    missing = [p for p in (strategies_path, scenarios_path,
                           time_models_path, design_path)
               if not p.exists()]
    if missing:
        return [Finding(str(p), 1, "REG001",
                        "registry cross-check input missing")
                for p in missing]

    strategies = collect_registered(strategies_path, "register_strategy",
                                    "STRATEGIES")
    scenarios = collect_registered(scenarios_path, "register_scenario",
                                   "SCENARIOS")
    matrix, scen_table = parse_design_tables(design_path)
    rel_design = str(design_path)
    rel_strat = str(strategies_path)
    rel_scen = str(scenarios_path)

    if matrix is None:
        findings.append(Finding(rel_design, 1, "REG002",
                                "DESIGN.md §3b coverage matrix (table "
                                "with 'strategy' header) not found"))
        matrix = {}
    if scen_table is None:
        findings.append(Finding(rel_design, 1, "REG004",
                                "DESIGN.md §3b scenario table (table "
                                "with 'scenario' header) not found"))
        scen_table = {}

    for name, lineno in sorted(strategies.items()):
        if name not in matrix:
            findings.append(Finding(
                rel_strat, lineno, "REG001",
                f"strategy {name!r} registered here but absent from the "
                f"DESIGN.md §3b coverage matrix"))
    for name, lineno in sorted(matrix.items()):
        if name not in strategies:
            findings.append(Finding(
                rel_design, lineno, "REG002",
                f"coverage-matrix row names strategy {name!r} which is "
                f"not registered in STRATEGIES"))
    for name, lineno in sorted(scenarios.items()):
        if name not in scen_table:
            findings.append(Finding(
                rel_scen, lineno, "REG003",
                f"scenario {name!r} registered here but absent from the "
                f"DESIGN.md §3b scenario table"))
    for name, lineno in sorted(scen_table.items()):
        if name not in scenarios:
            findings.append(Finding(
                rel_design, lineno, "REG004",
                f"scenario-table row names scenario {name!r} which is "
                f"not registered in SCENARIOS"))

    # REG006: the parity-matrix COVERAGE table closes the triangle —
    # registry <-> COVERAGE and COVERAGE <-> DESIGN matrix, both ways
    rel_matrix = str(matrix_test_path)
    if not matrix_test_path.exists():
        findings.append(Finding(
            rel_matrix, 1, "REG006",
            "parity-matrix test (COVERAGE engine table) missing — every "
            "registered strategy must declare its engine parity there"))
        coverage: Dict[str, int] = {}
    else:
        parsed = parse_coverage_table(matrix_test_path)
        if parsed is None:
            findings.append(Finding(
                rel_matrix, 1, "REG006",
                "no COVERAGE dict literal of string keys found in the "
                "parity-matrix test"))
            coverage = {}
        else:
            coverage = parsed
    if coverage:
        for name, lineno in sorted(strategies.items()):
            if name not in coverage:
                findings.append(Finding(
                    rel_strat, lineno, "REG006",
                    f"strategy {name!r} registered here but absent from "
                    f"the parity-matrix COVERAGE table"))
        for name, lineno in sorted(coverage.items()):
            if name not in strategies:
                findings.append(Finding(
                    rel_matrix, lineno, "REG006",
                    f"COVERAGE row names strategy {name!r} which is not "
                    f"registered in STRATEGIES"))

    # REG007: SHARDED_KINDS <-> DESIGN §3b sharded backend table, both
    # ways — what the jax_sharded router natively runs is documented,
    # and the doc promises nothing the router would fall back on
    rel_sweep = str(sweep_path)
    if not sweep_path.exists():
        findings.append(Finding(
            rel_sweep, 1, "REG007",
            "launch/sweep.py missing — cannot cross-check SHARDED_KINDS "
            "against the DESIGN.md sharded backend table"))
    else:
        kinds = collect_sharded_kinds(sweep_path)
        sharded_table = parse_sharded_table(design_path)
        if kinds is None:
            findings.append(Finding(
                rel_sweep, 1, "REG007",
                "no SHARDED_KINDS tuple literal of string constants "
                "found in launch/sweep.py"))
        elif sharded_table is None:
            findings.append(Finding(
                rel_design, 1, "REG007",
                "DESIGN.md §3b sharded backend table (table with "
                "'sharded kind' header) not found"))
        else:
            for name, lineno in sorted(kinds.items()):
                if name not in sharded_table:
                    findings.append(Finding(
                        rel_sweep, lineno, "REG007",
                        f"engine kind {name!r} is in SHARDED_KINDS but "
                        f"absent from the DESIGN.md §3b sharded backend "
                        f"table"))
            for name, lineno in sorted(sharded_table.items()):
                if name not in kinds:
                    findings.append(Finding(
                        rel_design, lineno, "REG007",
                        f"sharded-backend-table row names kind {name!r} "
                        f"which is not in SHARDED_KINDS — the jax_sharded "
                        f"router would silently fall back on it"))

    # REG005: every time_models name the scenario factories touch exists
    top, class_attrs = _time_model_names(time_models_path)
    scen_mod = load_module(scenarios_path)
    tm_imports: Dict[str, str] = {}       # local alias -> imported name
    for node in ast.walk(scen_mod.tree):
        if isinstance(node, ast.ImportFrom) and node.module \
                and node.module.endswith("time_models"):
            for alias in node.names:
                tm_imports[alias.asname or alias.name] = alias.name
                if alias.name not in top:
                    findings.append(Finding(
                        rel_scen, node.lineno, "REG005",
                        f"import of {alias.name!r} from time_models, "
                        f"which defines no such factory"))
    for node in ast.walk(scen_mod.tree):
        if isinstance(node, ast.Attribute) \
                and isinstance(node.value, ast.Name) \
                and node.value.id in tm_imports:
            cls = tm_imports[node.value.id]
            attrs = class_attrs.get(cls)
            if attrs is not None and node.attr not in attrs:
                findings.append(Finding(
                    rel_scen, node.lineno, "REG005",
                    f"{cls}.{node.attr} referenced here but "
                    f"{cls} defines no such factory"))
    return findings
