"""Finding record + ``# repcheck: ignore[...]`` pragma suppression.

A :class:`Finding` is one rule violation at one source location. Every
rule has a stable ID (``RNG001``, ``JIT003``, ``REG002``, ...) so a
violation can be allowlisted in place with a same-line pragma::

    x = jnp.ones(S, jnp.float32)  # repcheck: ignore[JIT005]

Multiple IDs may be listed (``ignore[JIT001,JIT003]``); ``ignore[*]``
suppresses every rule on that line. Pragmas are the escape hatch of last
resort — DESIGN.md "Enforced invariants" requires a justification
comment next to each one.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Set

__all__ = ["Finding", "parse_pragmas", "filter_suppressed", "RULES"]

# Rule ID -> one-line description. The CLI prints this table under
# --list-rules and DESIGN.md "Enforced invariants" mirrors it.
RULES: Dict[str, str] = {
    "RNG001": "literal-constant PRNGKey/key() inside an engine function "
              "body (keys must arrive via split/fold_in/parameters)",
    "RNG002": "syntactically-identical key expression feeds two distinct "
              "jax.random draw sites (stream reuse)",
    "RNG003": "np.random call in a jax-only engine module (host RNG "
              "breaks device-resident reproducibility)",
    "JIT001": "host coercion (float()/int()/.item()/np.asarray) on a "
              "traced value inside a jit/scan/while_loop function",
    "JIT002": "Python `if`/`while` branches on a traced parameter inside "
              "a scan/while_loop body (use lax.cond/jnp.where)",
    "JIT003": "print()/time.time()/time.perf_counter() inside a traced "
              "function (side effect fires at trace time only)",
    "JIT004": "attribute mutation (obj.attr = ...) inside a traced "
              "function (silent trace-time side effect)",
    "JIT005": "hard-coded jnp.float32/float64 dtype inside a scanned "
              "engine body (breaks x64 engine-mode parity; derive the "
              "dtype from a carried array)",
    "REG001": "strategy registered in STRATEGIES but missing from the "
              "DESIGN.md §3b coverage matrix",
    "REG002": "DESIGN.md §3b matrix row names a strategy that is not "
              "registered in STRATEGIES",
    "REG003": "scenario registered in SCENARIOS but missing from the "
              "DESIGN.md §3b scenario table",
    "REG004": "DESIGN.md §3b scenario table row names a scenario that "
              "is not registered in SCENARIOS",
    "REG005": "SCENARIOS factory references a time-model factory that "
              "does not exist in repro.core.time_models",
    "REG006": "STRATEGIES entry and the parity-matrix COVERAGE table "
              "(tests/test_strategy_matrix.py) drifted apart — every "
              "registration needs an engine-coverage row and vice versa",
    "REG007": "SHARDED_KINDS (launch/sweep.py) and the DESIGN.md §3b "
              "sharded backend table drifted apart — every natively "
              "sharded engine kind needs a table row and vice versa",
    "ROB001": "bare except / `except Exception: pass` in engine or "
              "launch code silently swallows failures the degradation "
              "ladder should record",
    "ROB002": "non-atomic artifact write: json.dump into "
              "open(path, 'w') (use repro.exp.runner.atomic_write_json "
              "— tmp file + os.replace)",
}

_PRAGMA_RE = re.compile(
    r"#\s*repcheck:\s*ignore\[([A-Za-z0-9_*,\s]+)\]")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at one source location."""
    path: str
    line: int
    rule: str
    message: str
    severity: str = "error"

    def format(self) -> str:
        return (f"{self.path}:{self.line}: {self.rule} "
                f"[{self.severity}] {self.message}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def parse_pragmas(source: str) -> Dict[int, Set[str]]:
    """Map 1-based line number -> set of suppressed rule IDs ('*' = all)."""
    pragmas: Dict[int, Set[str]] = {}
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = _PRAGMA_RE.search(text)
        if m:
            ids = {tok.strip() for tok in m.group(1).split(",")
                   if tok.strip()}
            pragmas.setdefault(lineno, set()).update(ids)
    return pragmas


def filter_suppressed(findings: List[Finding],
                      pragmas: Dict[int, Set[str]]) -> List[Finding]:
    """Drop findings whose line carries a matching (or ``*``) pragma."""
    out = []
    for f in findings:
        ids = pragmas.get(f.line, ())
        if f.rule in ids or "*" in ids:
            continue
        out.append(f)
    return out
