"""Jit/scan purity pass: JIT001 – JIT005.

Discovery: a function is *traced* when it is (a) decorated with
``jax.jit`` (bare or under ``functools.partial``), (b) passed to
``jax.jit`` / ``lax.scan`` / ``lax.while_loop`` / ``lax.fori_loop`` /
``lax.cond`` / ``lax.switch`` / ``jax.vmap`` / ``jax.checkpoint``
(lambdas and within-module names both resolve), or (c) called by name
from another traced function defined in the same module (trace-time
closure). Resolution is deliberately *within-module only*: cross-module
call graphs would need imports executed, and the contract modules keep
their scanned code self-contained.

Inside a traced function the pass tracks the *param-derived* name set —
parameters (minus jit ``static_argnames``/``static_argnums``) plus
anything assigned from them, to a fixpoint — and flags:

* JIT001  host coercions (``float()``/``int()``/``bool()``, ``.item()``,
  ``.tolist()``, any ``numpy.*`` call) applied to a param-derived value;
* JIT002  Python ``if``/``while``/ternary branching on a param-derived
  name — only in loop bodies (scan step, while cond/body, fori body,
  cond/switch branches) where parameters are traced by construction;
  ``x is None`` and ``isinstance`` tests are exempt (static pytree
  structure checks);
* JIT003  ``print`` / ``time.time`` / ``time.perf_counter`` /
  ``time.monotonic`` / ``breakpoint`` anywhere in a traced function;
* JIT004  attribute mutation (``obj.attr = ...``) anywhere in a traced
  function;
* JIT005  (x64-strict modules only) a hard-coded ``jnp.float32`` /
  ``jnp.float64`` inside a traced function — the engine dtype must
  derive from a carried array so ``x64=True`` switches the whole
  program.
"""

from __future__ import annotations

import ast
import dataclasses
import itertools
from typing import Dict, List, Optional, Set, Tuple

from .findings import Finding
from .passes import ModuleSource, assigned_names, call_name, dotted_name

__all__ = ["run_purity_pass", "traced_functions"]

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)

# canonical call target -> positions of the function-valued arguments,
# and whether those functions are loop bodies (params traced for sure)
_TRACED_ARG_SLOTS: Dict[str, Tuple[Tuple[int, ...], bool]] = {
    "jax.jit": ((0,), False),
    "jit": ((0,), False),
    "jax.vmap": ((0,), False),
    "jax.checkpoint": ((0,), False),
    "jax.remat": ((0,), False),
    "jax.lax.scan": ((0,), True),
    "lax.scan": ((0,), True),
    "jax.lax.while_loop": ((0, 1), True),
    "lax.while_loop": ((0, 1), True),
    "jax.lax.fori_loop": ((2,), True),
    "lax.fori_loop": ((2,), True),
    "jax.lax.cond": ((1, 2), True),
    "lax.cond": ((1, 2), True),
    "jax.lax.switch": ((1, 2, 3, 4, 5), True),
    "lax.switch": ((1, 2, 3, 4, 5), True),
}

_SIDE_EFFECT_CALLS = {"print", "breakpoint", "time.time",
                      "time.perf_counter", "time.monotonic",
                      "time.sleep"}

_HOST_COERCIONS = {"float", "int", "bool", "complex"}
_HOST_METHODS = {"item", "tolist"}


@dataclasses.dataclass
class TracedFn:
    node: ast.AST                 # FunctionDef / AsyncFunctionDef / Lambda
    kind: str                     # "jit" | "loop" | "closure"
    static_names: Set[str] = dataclasses.field(default_factory=set)

    @property
    def name(self) -> str:
        return getattr(self.node, "name", "<lambda>")


def _scope_body(fn: ast.AST):
    body = fn.body if isinstance(fn.body, list) else [fn.body]
    stack = list(body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, _FUNC_NODES):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _canonical(mod: ModuleSource, name: Optional[str]) -> Optional[str]:
    if name is None:
        return None
    head, _, tail = name.partition(".")
    head = mod.import_aliases.get(head, mod.from_imports.get(head, head))
    return f"{head}.{tail}" if tail else head


def _static_from_jit_call(call: ast.Call) -> Set[str]:
    """Constant ``static_argnames`` from a jit/partial(jit, ...) call."""
    out: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                out.add(v.value)
            elif isinstance(v, (ast.Tuple, ast.List)):
                out.update(e.value for e in v.elts
                           if isinstance(e, ast.Constant))
    return out


def _unwrap_partial(node: ast.AST, mod: ModuleSource):
    """``partial(jax.jit, ...)`` / ``jax.checkpoint(f)`` -> inner expr."""
    while isinstance(node, ast.Call):
        name = _canonical(mod, dotted_name(node.func))
        if name in ("functools.partial", "partial", "jax.checkpoint",
                    "jax.remat", "jax.jit", "jit") and node.args:
            node = node.args[0]
        else:
            break
    return node


def _name_to_defs(mod: ModuleSource) -> Dict[str, List[ast.AST]]:
    defs: Dict[str, List[ast.AST]] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, []).append(node)
    return defs


def traced_functions(mod: ModuleSource) -> List[TracedFn]:
    """Discover traced functions: decorator/call roots + module closure."""
    defs = _name_to_defs(mod)
    found: Dict[int, TracedFn] = {}       # id(node) -> TracedFn

    def add(expr: ast.AST, kind: str, static: Set[str]) -> None:
        expr = _unwrap_partial(expr, mod)
        if isinstance(expr, ast.Lambda):
            found.setdefault(id(expr), TracedFn(expr, kind, static))
        elif isinstance(expr, ast.Name):
            for d in defs.get(expr.id, []):
                found.setdefault(id(d), TracedFn(d, kind, static))

    # (a) decorators
    for name, nodes in defs.items():
        for node in nodes:
            for deco in node.decorator_list:
                target = deco.args[0] if (isinstance(deco, ast.Call)
                                          and deco.args) else deco
                cname = _canonical(mod, dotted_name(target))
                if cname in ("jax.jit", "jit", "jax.checkpoint",
                             "jax.remat"):
                    static = (_static_from_jit_call(deco)
                              if isinstance(deco, ast.Call) else set())
                    found.setdefault(id(node),
                                     TracedFn(node, "jit", static))

    # (b) call-site roots
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        cname = _canonical(mod, dotted_name(node.func))
        slots = _TRACED_ARG_SLOTS.get(cname or "")
        if not slots:
            continue
        positions, is_loop = slots
        static = _static_from_jit_call(node) if "jit" in (cname or "") \
            else set()
        for pos in positions:
            if pos < len(node.args):
                add(node.args[pos], "loop" if is_loop else "jit", static)

    # (c) within-module trace-time closure, to a fixpoint
    work = list(found.values())
    while work:
        tf = work.pop()
        for node in _scope_body(tf.node):
            if isinstance(node, ast.Call) and isinstance(node.func,
                                                         ast.Name):
                for d in defs.get(node.func.id, []):
                    if id(d) not in found:
                        nf = TracedFn(d, "closure")
                        found[id(d)] = nf
                        work.append(nf)
    return list(found.values())


def _param_derived(tf: TracedFn) -> Set[str]:
    """Names derived from (non-static) parameters, to a fixpoint."""
    args = getattr(tf.node, "args", None)
    derived: Set[str] = set()
    if args is not None:
        for a in itertools.chain(args.posonlyargs, args.args,
                                 args.kwonlyargs,
                                 filter(None, [args.vararg, args.kwarg])):
            if a.arg not in tf.static_names:
                derived.add(a.arg)
    changed = True
    while changed:
        changed = False
        for node in _scope_body(tf.node):
            targets: List[str] = []
            value: Optional[ast.AST] = None
            if isinstance(node, ast.Assign):
                value = node.value
                for t in node.targets:
                    targets.extend(assigned_names(t))
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                value = node.value
                targets.extend(assigned_names(node.target))
            elif isinstance(node, (ast.For, ast.AsyncFor)):
                value = node.iter
                targets.extend(assigned_names(node.target))
            elif isinstance(node, ast.NamedExpr):
                value = node.value
                targets.extend(assigned_names(node.target))
            if value is None or not targets:
                continue
            if any(isinstance(n, ast.Name) and n.id in derived
                   for n in ast.walk(value)):
                new = set(targets) - derived
                if new:
                    derived.update(new)
                    changed = True
    return derived


def _mentions(node: ast.AST, names: Set[str]) -> bool:
    return any(isinstance(n, ast.Name) and n.id in names
               for n in ast.walk(node))


def _is_static_test(test: ast.AST) -> bool:
    """``x is None`` / ``x is not None`` / ``isinstance(...)`` tests are
    pytree-structure checks, static under tracing."""
    if isinstance(test, ast.Compare) and all(
            isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
        consts = [test.left, *test.comparators]
        if any(isinstance(c, ast.Constant) and c.value is None
               for c in consts):
            return True
    if isinstance(test, ast.Call) and isinstance(test.func, ast.Name) \
            and test.func.id in ("isinstance", "callable", "hasattr"):
        return True
    if isinstance(test, ast.BoolOp):
        return all(_is_static_test(v) for v in test.values)
    return False


def run_purity_pass(mod: ModuleSource, x64_strict: bool) -> List[Finding]:
    findings: List[Finding] = []
    for tf in traced_functions(mod):
        derived = _param_derived(tf)
        label = f"traced function {tf.name!r} ({tf.kind})"
        for node in _scope_body(tf.node):
            # JIT003: trace-time-only side effects
            if isinstance(node, ast.Call):
                cname = _canonical(mod, dotted_name(node.func))
                if cname in _SIDE_EFFECT_CALLS:
                    findings.append(Finding(
                        mod.rel, node.lineno, "JIT003",
                        f"{cname}() inside {label} fires at trace time "
                        f"only (and re-fires on every retrace)"))
                # JIT001: host coercions on traced values
                elif cname in _HOST_COERCIONS and node.args \
                        and _mentions(node.args[0], derived):
                    findings.append(Finding(
                        mod.rel, node.lineno, "JIT001",
                        f"{cname}() on param-derived value inside {label} "
                        f"forces a host sync (ConcretizationTypeError "
                        f"under jit)"))
                elif cname and cname.startswith("numpy.") and any(
                        _mentions(a, derived) for a in node.args):
                    findings.append(Finding(
                        mod.rel, node.lineno, "JIT001",
                        f"{cname}() on param-derived value inside {label} "
                        f"pulls the array to host"))
                elif isinstance(node.func, ast.Attribute) \
                        and node.func.attr in _HOST_METHODS \
                        and _mentions(node.func.value, derived):
                    findings.append(Finding(
                        mod.rel, node.lineno, "JIT001",
                        f".{node.func.attr}() on param-derived value "
                        f"inside {label} forces a host sync"))
            # JIT002: Python branching on traced values (loop bodies)
            if tf.kind == "loop" and isinstance(
                    node, (ast.If, ast.While, ast.IfExp)):
                if _mentions(node.test, derived) \
                        and not _is_static_test(node.test):
                    findings.append(Finding(
                        mod.rel, node.lineno, "JIT002",
                        f"Python branch on param-derived test inside "
                        f"{label}; use lax.cond/jnp.where"))
            # JIT004: attribute mutation
            targets: List[ast.AST] = []
            if isinstance(node, ast.Assign):
                targets = list(node.targets)
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            for t in targets:
                if isinstance(t, ast.Attribute):
                    findings.append(Finding(
                        mod.rel, node.lineno, "JIT004",
                        f"attribute mutation "
                        f"{ast.unparse(t)} = ... inside {label} is a "
                        f"trace-time side effect"))
            # JIT005: hard-coded engine dtype (x64-strict modules)
            if x64_strict and isinstance(node, ast.Attribute):
                cname = _canonical(mod, dotted_name(node))
                if cname in ("jax.numpy.float32", "jax.numpy.float64",
                             "jnp.float32", "jnp.float64"):
                    findings.append(Finding(
                        mod.rel, node.lineno, "JIT005",
                        f"hard-coded {cname} inside {label} pins the "
                        f"engine dtype; derive it from a carried "
                        f"array's .dtype so x64=True switches the whole "
                        f"program"))
    return mod.apply_pragmas(findings)
