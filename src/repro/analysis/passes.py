"""Pass framework: parsed-module container + shared AST helpers.

Each pass is a callable ``(ModuleSource) -> List[Finding]`` (or, for
the repo-level registry pass, a callable over the repo root). The
orchestrator in :mod:`repro.analysis.cli` loads every ``.py`` file,
runs the per-module passes whose scope matches, applies the pragma
filter, and merges the results.

AST helpers here are deliberately syntactic: ``dotted_name`` prints an
attribute chain (``jax.random.split``), ``call_name`` resolves a call's
target through common import aliases. No imports are executed — the
analyzer must be runnable on a tree whose dependencies are absent.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, List, Optional, Set

from .findings import Finding, filter_suppressed, parse_pragmas

__all__ = ["ModuleSource", "load_module", "dotted_name", "call_name",
           "assigned_names", "iter_py_files"]


@dataclasses.dataclass
class ModuleSource:
    """A parsed module plus everything passes need about it."""
    path: Path
    rel: str                       # path as reported in findings
    source: str
    tree: ast.Module
    pragmas: Dict[int, Set[str]]
    # alias -> canonical module, from `import numpy as np` etc.
    import_aliases: Dict[str, str] = dataclasses.field(default_factory=dict)
    # name -> canonical dotted origin, from `from jax import lax` etc.
    from_imports: Dict[str, str] = dataclasses.field(default_factory=dict)

    def apply_pragmas(self, findings: List[Finding]) -> List[Finding]:
        return filter_suppressed(findings, self.pragmas)


def load_module(path: Path, rel: Optional[str] = None) -> ModuleSource:
    source = path.read_text()
    tree = ast.parse(source, filename=str(path))
    mod = ModuleSource(path=path, rel=rel or str(path), source=source,
                       tree=tree, pragmas=parse_pragmas(source))
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                mod.import_aliases[alias.asname or alias.name] = alias.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for alias in node.names:
                mod.from_imports[alias.asname or alias.name] = (
                    f"{node.module}.{alias.name}")
    return mod


def dotted_name(node: ast.AST) -> Optional[str]:
    """``jax.random.split`` for an Attribute/Name chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call, mod: ModuleSource) -> Optional[str]:
    """Canonical dotted target of a call, resolved through imports.

    ``np.random.normal`` -> ``numpy.random.normal`` when the module did
    ``import numpy as np``; ``lax.scan`` -> ``jax.lax.scan`` after
    ``from jax import lax``; plain names pass through unchanged.
    """
    name = dotted_name(node.func)
    if name is None:
        return None
    head, _, tail = name.partition(".")
    if head in mod.import_aliases:
        head = mod.import_aliases[head]
    elif head in mod.from_imports:
        head = mod.from_imports[head]
    return f"{head}.{tail}" if tail else head


def assigned_names(target: ast.AST) -> List[str]:
    """Flatten an assignment target into the plain names it binds."""
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in target.elts:
            out.extend(assigned_names(elt))
        return out
    if isinstance(target, ast.Starred):
        return assigned_names(target.value)
    return []


def iter_py_files(paths: List[Path]) -> List[Path]:
    """Expand files/directories into a sorted list of ``.py`` files."""
    out: Set[Path] = set()
    for p in paths:
        if p.is_dir():
            out.update(p.rglob("*.py"))
        elif p.suffix == ".py":
            out.add(p)
    return sorted(out)
