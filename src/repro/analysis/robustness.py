"""Robustness pass: swallowed exceptions + non-atomic artifact writes.

Two rules backing the DESIGN §3c degradation-ladder and crash-safety
contracts:

* **ROB001** — in engine/launch code (``core/``, ``launch/``), a bare
  ``except:`` or an ``except Exception:`` whose body is only ``pass``
  silently swallows failures the ladder is supposed to *record*. The
  fix is to narrow the exception, handle it, or append a downgrade
  record (see ``TraceBatch.routing``); an intentional swallow takes a
  same-line ``# repcheck: ignore[ROB001]``. The ladder's own
  ``except Exception:`` blocks are fine — they retry and record, so
  their bodies are not ``pass``.
* **ROB002** — in artifact-writing code (``exp/``, ``benchmarks/``), a
  ``json.dump(obj, fh)`` into a handle opened with ``open(path, "w")``
  is not crash-safe: a kill mid-write leaves a truncated JSON that
  poisons resume/perf-gate readers. Use
  :func:`repro.exp.runner.atomic_write_json` (tmp + ``os.replace``).
  Functions that call ``os.replace`` themselves are exempt — that IS
  the atomic pattern, so the helper's own body doesn't flag.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional

from .findings import Finding
from .passes import ModuleSource, call_name

__all__ = ["run_robustness_pass"]

_WRITE_MODES = ("w", "wt", "w+", "wb")


def _is_pass_only(body: List[ast.stmt]) -> bool:
    return all(isinstance(s, ast.Pass) for s in body)


def _exception_names(node: Optional[ast.expr]) -> List[str]:
    """Names caught by an except clause (``Exception``, tuples, ...)."""
    if node is None:
        return []
    if isinstance(node, ast.Tuple):
        out: List[str] = []
        for elt in node.elts:
            out.extend(_exception_names(elt))
        return out
    if isinstance(node, ast.Name):
        return [node.id]
    if isinstance(node, ast.Attribute):
        return [node.attr]
    return []


def _check_exceptions(mod: ModuleSource) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            out.append(Finding(
                mod.rel, node.lineno, "ROB001",
                "bare `except:` swallows everything (including "
                "SystemExit/KeyboardInterrupt); catch a specific "
                "exception or `Exception`, and record the failure "
                "instead of hiding it"))
        elif ("Exception" in _exception_names(node.type)
              and _is_pass_only(node.body)):
            out.append(Finding(
                mod.rel, node.lineno, "ROB001",
                "`except Exception: pass` silently swallows engine "
                "failures; handle it, narrow it, or record a "
                "downgrade (TraceBatch.routing) so the degradation "
                "is observable"))
    return out


def _open_write_handles(with_node: ast.With, mod: ModuleSource
                        ) -> List[str]:
    """Names bound to ``open(path, "w"...)`` by this ``with``'s items."""
    names: List[str] = []
    for item in with_node.items:
        call = item.context_expr
        if not (isinstance(call, ast.Call)
                and call_name(call, mod) in ("open", "io.open")):
            continue
        mode = None
        if len(call.args) >= 2:
            mode = call.args[1]
        for kw in call.keywords:
            if kw.arg == "mode":
                mode = kw.value
        if not (isinstance(mode, ast.Constant)
                and isinstance(mode.value, str)
                and mode.value in _WRITE_MODES):
            continue
        if isinstance(item.optional_vars, ast.Name):
            names.append(item.optional_vars.id)
    return names


def _calls_os_replace(scope: ast.AST, mod: ModuleSource) -> bool:
    return any(isinstance(n, ast.Call)
               and call_name(n, mod) == "os.replace"
               for n in ast.walk(scope))


def _check_atomic_writes(mod: ModuleSource) -> List[Finding]:
    # nearest enclosing function decides the os.replace exemption
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(mod.tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node

    def enclosing_scope(node: ast.AST) -> ast.AST:
        cur = parents.get(node)
        while cur is not None and not isinstance(
                cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            cur = parents.get(cur)
        return cur if cur is not None else mod.tree

    out: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.With):
            continue
        handles = _open_write_handles(node, mod)
        if not handles:
            continue
        for inner in ast.walk(node):
            if not (isinstance(inner, ast.Call)
                    and call_name(inner, mod) == "json.dump"
                    and len(inner.args) >= 2
                    and isinstance(inner.args[1], ast.Name)
                    and inner.args[1].id in handles):
                continue
            if _calls_os_replace(enclosing_scope(node), mod):
                continue            # tmp + os.replace: the atomic pattern
            out.append(Finding(
                mod.rel, inner.lineno, "ROB002",
                "json.dump into open(path, 'w') is not crash-safe (a "
                "kill mid-write truncates the artifact); use "
                "repro.exp.runner.atomic_write_json (tmp + os.replace)"))
    return out


def run_robustness_pass(mod: ModuleSource, *, exceptions: bool = True,
                        io: bool = True) -> List[Finding]:
    """ROB001/ROB002 over one module; scope gating (which rule applies
    to which tree region) lives in :mod:`repro.analysis.cli`."""
    findings: List[Finding] = []
    if exceptions:
        findings.extend(_check_exceptions(mod))
    if io:
        findings.extend(_check_atomic_writes(mod))
    return mod.apply_pragmas(findings)
