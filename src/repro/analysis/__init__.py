"""AST-level contract analyzer for the repo's hand-enforced invariants.

Three pass families (see DESIGN.md "Enforced invariants" for the rule
table and rationale):

* :mod:`repro.analysis.rng` — RNG-stream discipline in the engine
  modules (RNG001–RNG003);
* :mod:`repro.analysis.purity` — jit/scan purity of traced functions
  (JIT001–JIT005);
* :mod:`repro.analysis.registry` — ``STRATEGIES`` / ``SCENARIOS`` /
  time-model / DESIGN.md §3b coverage-matrix / parity-matrix COVERAGE
  lockstep (REG001–REG007);
* :mod:`repro.analysis.robustness` — swallowed exceptions and
  non-atomic artifact writes (ROB001–ROB002).

Stdlib-``ast`` only: the analyzer parses, never imports, so it runs on
a tree whose dependencies are absent (and CI runs it before pytest).
Entry points: ``python -m repro.analysis`` or
:func:`repro.analysis.analyze`. Violations are suppressed in place with
``# repcheck: ignore[RULE]`` pragmas.
"""

from .cli import analyze, main
from .findings import RULES, Finding, filter_suppressed, parse_pragmas
from .passes import ModuleSource, load_module
from .purity import run_purity_pass, traced_functions
from .registry import (collect_registered, collect_sharded_kinds,
                       parse_coverage_table, parse_design_tables,
                       parse_sharded_table, run_registry_pass)
from .rng import run_rng_pass
from .robustness import run_robustness_pass

__all__ = [
    "analyze", "main", "Finding", "RULES", "parse_pragmas",
    "filter_suppressed", "ModuleSource", "load_module",
    "run_rng_pass", "run_purity_pass", "traced_functions",
    "run_registry_pass", "collect_registered", "parse_design_tables",
    "parse_coverage_table", "parse_sharded_table",
    "collect_sharded_kinds", "run_robustness_pass",
]
