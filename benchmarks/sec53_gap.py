"""Section 5.3 numerical-gap reproduction (Figures 3 & 4).

Evaluates the non-explicit recursions: m-Sync upper bound t̄_K̄ (eq. 13)
vs the universal lower bound t̲_K̲ (eq. 12, c1=16, c2=1 per footnote 6),
on the paper's two computation-power ensembles.

Paper's measured gaps:
  Fig 3 (chaotic):  ratio <= 1.52 (sigma^2/eps=100, m=15),
                    ratio <= 1.85 (sigma^2/eps=1000, m=14)
  Fig 4 (periodic): ratio <= 1.11 (sigma^2/eps=100, m=49),
                    ratio <= 1.37 (sigma^2/eps=1000, m=50)

Our power ensembles use the paper's generative recipe (their exact seeds
are unknown), so we assert the same <=2x ballpark, and report the measured
ratio next to the paper's.
"""

from __future__ import annotations

from repro.core import (lower_bound_recursion, msync_upper_recursion,
                        powers_figure3, powers_figure4)

CASES = [
    ("fig3", powers_figure3, 100.0, 15, 1.52),
    ("fig3", powers_figure3, 1000.0, 14, 1.85),
    ("fig4", powers_figure4, 100.0, 49, 1.11),
    ("fig4", powers_figure4, 1000.0, 50, 1.37),
]


def run(fast: bool = True):
    rows = []
    L = Delta = 1.0
    eps = 1.0   # L*Delta/eps = 1 as in the paper
    for fig, powers_fn, s2e, m, paper_ratio in CASES:
        sigma2 = s2e * eps
        # enough grid for the recursions to stay on-grid
        model = powers_fn(n=50, seed=0,
                          t_max=(3000.0 if s2e >= 1000 else 600.0))
        lb = lower_bound_recursion(model, L, Delta, eps, sigma2)
        # idle-start evaluation (matches the paper's §5.3 numerics) and the
        # Theorem 5.3 worst-case (stale gradient first => N=2, exactly ~2x)
        ub1 = msync_upper_recursion(model, L, Delta, eps, sigma2, m,
                                    n_grads=1.0)
        ub2 = msync_upper_recursion(model, L, Delta, eps, sigma2, m,
                                    n_grads=2.0)
        rows.append((f"sec53/{fig}/s2e={int(s2e)}/m={m}/gap_ratio",
                     ub1 / lb,
                     f"paper={paper_ratio} worstcase={ub2 / lb:.2f} "
                     f"ub={ub1:.1f}s lb={lb:.1f}s"))
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
