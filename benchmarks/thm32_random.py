"""Theorem 3.2: measured E[T_rand] of the event simulator vs the closed
form (LΔ/ε)(τ_m + R log n) max(1, σ²/(mε)) — per-iteration comparison
across the paper's distributions (§3, §D.1, §K.3), mean ± std across
seeds through the seed-batched engine (one vectorized call per
distribution sweeps the whole m grid)."""

import numpy as np

from repro.exp import make_scenario, run_experiment


def run(fast: bool = True, seeds: int = None):
    n = 32
    K = 100 if fast else 400
    seeds = seeds or (8 if fast else 20)
    cases = {
        "truncnorm": make_scenario("truncnorm", n, sigma=0.5),
        "exponential": make_scenario("exponential", n, lam=1.0),
        "gamma": make_scenario("gamma", n, var=0.25),
        "uniform": make_scenario("uniform", n, half_width=0.5),
    }
    rows = []
    for name, model in cases.items():
        res = run_experiment("msync", model, n=n, K=K, seeds=seeds,
                             grid={"m": [4, 16, n]})
        for r in res.rows:
            m = r["params"]["m"]
            mean_iter = r["total_time_mean"] / K
            std_iter = r["total_time_std"] / K
            taus = np.sort(model.mean_times())
            bound = taus[m - 1] + model.R * np.log(max(n, 2))
            rows.append((f"thm32/{name}/m={m}/mean_iter_s", mean_iter,
                         f"±{std_iter:.4g} over {r['seeds']} seeds "
                         f"bound={bound:.3f} R={model.R:.3f} "
                         f"ok={mean_iter <= bound * 1.05}"))
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
