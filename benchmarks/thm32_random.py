"""Theorem 3.2: measured E[T_rand] of the event simulator vs the closed
form (LΔ/ε)(τ_m + R log n) max(1, σ²/(mε)) — per-iteration comparison
across the paper's distributions (§3, §D.1, §K.3)."""

import numpy as np

from repro.core import (STRATEGIES, exponential_times, gamma_times, simulate,
                        truncated_normal_times, uniform_times)


def run(fast: bool = True):
    n = 32
    K = 100 if fast else 400
    reps = 6 if fast else 20
    mus = np.sqrt(np.arange(1, n + 1))
    cases = {
        "truncnorm": truncated_normal_times(mus, sigma=0.5),
        "exponential": exponential_times(lam=1.0, n=n),
        "gamma": gamma_times(mus, var=0.25),
        "uniform": uniform_times(np.ones(n), half_width=0.5),
    }
    rows = []
    for name, model in cases.items():
        for m in (4, 16, n):
            mean_iter = np.mean([
                simulate(STRATEGIES["msync"](m=m), model, K=K,
                         seed=s).total_time / K
                for s in range(reps)])
            taus = np.sort(model.mean_times())
            bound = taus[m - 1] + model.R * np.log(max(n, 2))
            rows.append((f"thm32/{name}/m={m}/mean_iter_s", mean_iter,
                         f"bound={bound:.3f} R={model.R:.3f} "
                         f"ok={mean_iter <= bound * 1.05}"))
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
