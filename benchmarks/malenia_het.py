"""Section 6 heterogeneous gap: Malenia's (16) vs Synchronous SGD's (1).

The paper: tau_n / mean(tau) = O(1) whenever tau_m = tau_1 m^alpha with
alpha <= 4 — even though workers cannot be ignored in the heterogeneous
setting, full synchronization loses only a constant."""

import numpy as np

from repro.core import FixedTimes, t_malenia, t_sync_full


def run(fast: bool = True):
    rows = []
    L = Delta = 1.0
    eps = 1e-2
    n = 1000
    for alpha in (0.5, 1.0, 2.0, 4.0):
        taus = FixedTimes.power_law(n, alpha).taus
        sigma2 = 100 * n * eps   # noise-dominated: the regime §6 discusses
        tm = t_malenia(taus, L, Delta, eps, sigma2, c=1.0)
        ts = t_sync_full(taus, L, Delta, eps, sigma2, c=1.0)
        rows.append((f"malenia/alpha={alpha}/sync_over_malenia", ts / tm,
                     f"tau_n/mean={taus[-1] / np.mean(taus):.2f} "
                     f"(paper: O(1) = alpha+1 for alpha<=4)"))
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
