"""Pallas top-m partial-sort kernel vs ``lax.top_k`` vs the iterative
tie-class extraction, at the paper-relevant shapes (ISSUE 3 satellite).

The batched simulators need the m-th smallest of ``(S, n)`` candidates
per round. This benchmark times the three lowerings at n ∈ {1e3, 1e5}
(plus the jitted scan-shaped dispatch) and asserts they agree. On this
CPU-only container the Pallas kernel runs in interpret mode
(``repro.kernels.ops.INTERPRET``, i.e. ``REPRO_PALLAS_INTERPRET`` unset
or ``=1``) — interpret timings measure the Python kernel body, NOT TPU
performance; the number that matters on CPU is iterative vs top_k. On a
real TPU set ``REPRO_PALLAS_INTERPRET=0`` to compile the kernel and get
a meaningful Pallas column.

A second sweep covers the big-``m`` regime (``m > 64`` — the
``batch >> 64`` Rennala/Malenia pools, ISSUE 4): the counting-bisection
selection (``mth_smallest_counting``) vs ``lax.top_k``. Its raw-call
timing on CPU is shape-dependent; the point of the counting path is
that it is *elementwise only*, so inside a jitted ``lax.scan`` body it
fuses instead of forcing the slow sort lowering (the simbatch Rennala
parity tests exercise exactly that).
"""

import time

import numpy as np

from repro.kernels import ops
from repro.kernels.order_stats import (mth_smallest_counting,
                                       mth_smallest_iterative,
                                       mth_smallest_pallas)


def _timed(fn, reps: int = 5) -> float:
    fn()                                     # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(fast: bool = True):
    import jax
    import jax.numpy as jnp
    from jax import lax

    rows = []
    S = 32
    # both sizes even in fast mode — n=1e5 is the whole point (top_k's
    # CPU lowering scales badly); fast mode trims the m sweep instead
    sizes = [1_000, 100_000]
    ms = (10,) if fast else (10, 64)
    interpret = ops.INTERPRET

    topk = jax.jit(lambda x, m: -lax.top_k(-x, m)[0][..., m - 1],
                   static_argnames="m")
    iterative = jax.jit(mth_smallest_iterative, static_argnames="m")

    for n in sizes:
        x = jnp.asarray(np.random.default_rng(0).uniform(0.0, 1.0, (S, n)))
        for m in ms:
            ref = np.sort(np.asarray(x), axis=1)[:, m - 1]
            t_iter = _timed(lambda: jax.block_until_ready(iterative(x, m=m)))
            t_topk = _timed(lambda: jax.block_until_ready(topk(x, m=m)))
            t_pal = _timed(lambda: jax.block_until_ready(
                mth_smallest_pallas(x, m, interpret=interpret)), reps=2)
            for name, fn in [("iterative", lambda: iterative(x, m=m)),
                             ("topk", lambda: topk(x, m=m)),
                             ("pallas", lambda: mth_smallest_pallas(
                                 x, m, interpret=interpret))]:
                np.testing.assert_allclose(np.asarray(fn()), ref,
                                           rtol=1e-6, err_msg=name)
            tag = f"order_stats/n={n}/m={m}"
            rows.append((f"{tag}/iterative_s", t_iter,
                         f"S={S} fused extraction"))
            rows.append((f"{tag}/topk_s", t_topk,
                         f"iter/topk={t_iter / t_topk:.2f}"))
            rows.append((f"{tag}/pallas_s", t_pal,
                         "interpret (CPU)" if interpret
                         else "compiled (TPU lane)"))
    # big-m regime: counting bisection vs top_k (fused-path selection)
    counting = jax.jit(mth_smallest_counting, static_argnames="m")
    for n, m in (((10_000, 256),) if fast
                 else ((10_000, 256), (100_000, 1024))):
        x = jnp.asarray(np.random.default_rng(1).uniform(0.0, 1.0, (S, n)))
        ref = np.sort(np.asarray(x), axis=1)[:, m - 1]
        t_cnt = _timed(lambda: jax.block_until_ready(counting(x, m=m)))
        t_topk = _timed(lambda: jax.block_until_ready(topk(x, m=m)))
        np.testing.assert_allclose(np.asarray(counting(x, m=m)), ref,
                                   rtol=1e-6)
        tag = f"order_stats/bigm/n={n}/m={m}"
        rows.append((f"{tag}/counting_s", t_cnt,
                     f"S={S} elementwise bisection (fuses in scans)"))
        rows.append((f"{tag}/topk_s", t_topk,
                     f"counting/topk={t_cnt / t_topk:.2f}"))
    rows.append(("order_stats/interpret", float(interpret),
                 "REPRO_PALLAS_INTERPRET=0 for compiled TPU runs"))
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
