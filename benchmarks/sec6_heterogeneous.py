"""§6 heterogeneous-data experiment: why m-Sync with m < n CANNOT work
when worker i exclusively holds f_i, and Malenia SGD can.

Each worker owns a private coordinate block. m-Sync with m<n keeps
aggregating only the fastest workers' gradients (fixed sqrt-law times =>
the first m finishers are exactly the fastest m), so slow workers' blocks
NEVER receive signal — the error plateaus at the ignored blocks' share.
Malenia (harmonic per-worker batching) drives every block down.

Both methods now run through the one Strategy-API engine: ``MSync`` and
``Malenia`` each take the ``grads_by_worker`` per-worker oracle hook, so
the former hand-rolled m-sync loop is gone and the comparison is
mean ± std across seeds via ``run_experiment``."""

import numpy as np

from repro.core.oracle import heterogeneous_quadratics
from repro.core.time_models import FixedTimes
from repro.exp import run_experiment


def run(fast: bool = True, seeds: int = 8):
    n = 8
    prob, grad_i, x_star = heterogeneous_quadratics(n, d_per=10, seed=0)
    model = FixedTimes.sqrt_law(n)
    K = 400 if fast else 2000
    m = n // 2
    rows = []

    # m-sync m=n/2 with per-worker oracles: workers n/2..n never accepted
    res_m = run_experiment(("msync", {"m": m, "grads_by_worker": grad_i}),
                           model, n=n, K=K, seeds=seeds, problem=prob,
                           gamma=0.3, record_every=100)
    errs = [np.linalg.norm(tr.x_final - x_star) / np.linalg.norm(x_star)
            for tr in res_m.batch.traces[0]]
    err_msync = float(np.mean(errs))
    rows.append(("sec6het/msync_m4of8/rel_err", err_msync,
                 f"±{np.std(errs):.3f} over {len(errs)} seeds; plateaus: "
                 f"ignored blocks never updated"))

    res_mal = run_experiment(("malenia", {"S": 1.0,
                                          "grads_by_worker": grad_i}),
                             model, n=n, K=K, seeds=seeds, problem=prob,
                             gamma=0.3, record_every=100)
    gn_last = np.array([tr.grad_norms[-1]
                        for tr in res_mal.batch.traces[0]])
    gn_first = np.array([tr.grad_norms[0]
                         for tr in res_mal.batch.traces[0]])
    rows.append(("sec6het/malenia/final_gradnorm_sq", float(gn_last.mean()),
                 f"±{gn_last.std():.2e} over {len(gn_last)} seeds; "
                 f"converges (msync rel_err={err_msync:.3f})"))
    rows.append(("sec6het/msync_fails_malenia_works",
                 float(err_msync > 0.5
                       and (gn_last < 1e-2 * gn_first).all()),
                 "1.0 = paper's §6 impossibility confirmed"))
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
