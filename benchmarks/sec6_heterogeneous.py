"""§6 heterogeneous-data experiment: why m-Sync with m < n CANNOT work
when worker i exclusively holds f_i, and Malenia SGD can.

Each worker owns a private coordinate block. m-Sync with m<n keeps
aggregating only the fastest workers' gradients, so slow workers' blocks
NEVER receive signal — the error plateaus at the ignored blocks' share.
Malenia (harmonic per-worker batching) drives every block down."""

import numpy as np

from repro.core import STRATEGIES, FixedTimes, simulate
from repro.core.oracle import heterogeneous_quadratics


def run(fast: bool = True):
    n = 8
    prob, grad_i, x_star = heterogeneous_quadratics(n, d_per=10, seed=0)
    model = FixedTimes.sqrt_law(n)
    rows = []

    # m-sync m=n/2 with per-worker oracles: workers n/2..n ignored.
    # emulate by aggregating grads of the FIRST m workers each round
    # (fixed times => first finishers are exactly the fastest m).
    x = prob.x0.copy()
    rng = np.random.default_rng(0)
    m = n // 2
    for _ in range(400 if fast else 2000):
        g = sum(grad_i(i, x, rng) for i in range(m)) / m
        x = x - 0.3 * g
    err_msync = float(np.linalg.norm(x - x_star) / np.linalg.norm(x_star))
    rows.append(("sec6het/msync_m4of8/rel_err", err_msync,
                 "plateaus: ignored blocks never updated"))

    tr = simulate(STRATEGIES["malenia"](S=1.0, grads_by_worker=grad_i),
                  model, K=400 if fast else 2000, problem=prob, gamma=0.3,
                  seed=0, record_every=100)
    rows.append(("sec6het/malenia/final_gradnorm_sq", tr.grad_norms[-1],
                 f"converges (msync rel_err={err_msync:.3f})"))
    rows.append(("sec6het/msync_fails_malenia_works",
                 float(err_msync > 0.5 and tr.grad_norms[-1]
                       < 1e-2 * tr.grad_norms[0]),
                 "1.0 = paper's §6 impossibility confirmed"))
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
