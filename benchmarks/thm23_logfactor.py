"""Theorem 2.3: T_sync <= O(T_optimal * log(n+1)), tight at tau_i = i.

Table: ratio T_sync/T_optimal (c=1 both) across tau laws and n, against
log(n+1)."""

import math

import numpy as np

from repro.core import FixedTimes, t_optimal, t_sync

LAWS = {
    "sqrt": lambda n: FixedTimes.sqrt_law(n).taus,
    "linear": lambda n: FixedTimes.linear(n).taus,
    "const": lambda n: np.ones(n),
    "pow1.2": lambda n: FixedTimes.power_law(n, 1.2).taus,
    "exp_gap": lambda n: np.concatenate([np.ones(n - 1), [1000.0]]),
}


def run(fast: bool = True):
    rows = []
    L = Delta = 1.0
    eps = 1e-2
    for law, fn in LAWS.items():
        for n in (10, 100, 1000):
            taus = fn(n)
            sigma2 = n * eps          # the interesting regime sigma^2/eps = n
            ts, m_s = t_sync(taus, L, Delta, eps, sigma2, c=1.0)
            to, m_o = t_optimal(taus, L, Delta, eps, sigma2, c=1.0)
            rows.append((f"thm23/{law}/n={n}/ratio", ts / to,
                         f"log(n+1)={math.log(n + 1):.2f} m*={m_s}"))
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
