"""Figure 5 reproduction: quadratic optimization, n=1000 workers,
tau_i = sqrt(i), comparing Synchronous SGD, m-Synchronous SGD (m=10),
Asynchronous SGD and Rennala SGD on simulated wall-clock time.

Paper's claim: Sync SGD is slow (stragglers with large tau_i); m-Sync with
m=10 matches the optimal asynchronous methods despite one gradient per
worker per iteration.
"""

from __future__ import annotations

import numpy as np

from repro.core import STRATEGIES, FixedTimes, quadratic_worst_case, simulate


def run(fast: bool = True):
    n = 200 if fast else 1000
    d = 200 if fast else 1000
    model = FixedTimes.sqrt_law(n)
    prob = quadratic_worst_case(d=d, p=0.1)
    K = 150 if fast else 600

    rows = []
    runs = {
        "sync_sgd": lambda: simulate(
            STRATEGIES["sync"](), model, K=K, problem=prob, gamma=1.0,
            record_every=10),
        "msync_sgd_m10": lambda: simulate(
            STRATEGIES["msync"](m=10), model, K=K, problem=prob, gamma=1.0,
            record_every=10),
        # async tolerates delay ~ n only with a much smaller stepsize
        "async_sgd": lambda: simulate(
            STRATEGIES["async"](delay_adaptive=True), model, K=K * 60,
            problem=prob, gamma=0.02, record_every=1000),
        "rennala_sgd_b10": lambda: simulate(
            STRATEGIES["rennala"](batch=10), model, K=K, problem=prob,
            gamma=1.0, record_every=10),
    }
    results = {}
    for name, fn in runs.items():
        tr = fn()
        results[name] = tr
        # time to reach half the initial gradient norm (robust target)
        g0 = tr.grad_norms[0]
        hit = np.argmax(tr.grad_norms <= 0.25 * g0)
        t_hit = tr.times[hit] if tr.grad_norms[hit] <= 0.25 * g0 \
            else float("inf")
        rows.append((f"fig5/{name}/time_to_quarter_gradnorm", t_hit,
                     f"final_gn={tr.grad_norms[-1]:.3e}"))
    # the paper's ordering: msync ≈ rennala ≈ async << sync
    t = {k: rows[i][1] for i, k in enumerate(runs)}
    ratio = t["sync_sgd"] / max(t["msync_sgd_m10"], 1e-9)
    rows.append(("fig5/sync_over_msync_time_ratio", ratio,
                 "paper: >> 1 (sync pays stragglers)"))
    return rows


def main():
    for name, val, derived in run(fast=True):
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
