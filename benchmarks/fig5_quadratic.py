"""Figure 5 reproduction: quadratic optimization, n workers,
tau_i = sqrt(i), comparing Synchronous SGD, m-Synchronous SGD (m=10),
Asynchronous SGD and Rennala SGD on simulated wall-clock time.

Paper's claim: Sync SGD is slow (stragglers with large tau_i); m-Sync with
m=10 matches the optimal asynchronous methods despite one gradient per
worker per iteration. Each method runs through ``run_experiment`` across
seeds; the reported time-to-target is the cross-seed median (q50) of the
wall-clock to reach a quarter of the initial gradient norm.
"""

from __future__ import annotations

from repro.core import quadratic_worst_case
from repro.exp import run_experiment


def run(fast: bool = True, seeds: int = 8):
    n = 200 if fast else 1000
    d = 200 if fast else 1000
    prob = quadratic_worst_case(d=d, p=0.1)
    K = 150 if fast else 600

    cases = {
        "sync_sgd": (("sync", {}), dict(K=K, gamma=1.0, record_every=10)),
        "msync_sgd_m10": (("msync", {"m": 10}),
                          dict(K=K, gamma=1.0, record_every=10)),
        # async tolerates delay ~ n only with a much smaller stepsize
        "async_sgd": (("async", {"delay_adaptive": True}),
                      dict(K=K * 60, gamma=0.02, record_every=1000)),
        "rennala_sgd_b10": (("rennala", {"batch": 10}),
                            dict(K=K, gamma=1.0, record_every=10)),
    }
    rows = []
    t50 = {}
    for name, (spec, kw) in cases.items():
        res = run_experiment(spec, "fixed_sqrt", n=n, K=kw["K"],
                             seeds=seeds, problem=prob, gamma=kw["gamma"],
                             record_every=kw["record_every"],
                             target_frac=0.25)
        r = res.rows[0]
        t50[name] = r["time_to_target_q50"]
        rows.append((f"fig5/{name}/time_to_quarter_gradnorm",
                     r["time_to_target_q50"],
                     f"q10={r['time_to_target_q10']:.4g} "
                     f"q90={r['time_to_target_q90']:.4g} over "
                     f"{r['seeds']} seeds "
                     f"hit_rate={r['time_to_target_hit_rate']:.2f}"))
    # the paper's ordering: msync ≈ rennala ≈ async << sync
    ratio = t50["sync_sgd"] / max(t50["msync_sgd_m10"], 1e-9)
    rows.append(("fig5/sync_over_msync_time_ratio", ratio,
                 "paper: >> 1 (sync pays stragglers)"))
    return rows


def main():
    for name, val, derived in run(fast=True):
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
