"""Figure 8/9 grid (K.1/K.2): method comparison across computation-time
laws and noise levels, plus robustness to growing n.

Timing-only simulation (gradient math factored out) through the
experiment layer: per-useful-gradient wall time, mean ± std across
seeds, for each method × tau law × n. Fixed-time scenarios are routed
through the seed-batched vectorized engine. The paper's qualitative
claims are checked downstream (tests): m-sync tracks the asynchronous
methods; full sync degrades as the tau law steepens; m-sync is robust
to n.

``run()`` also writes ``BENCH_fig8.json`` (per-case
``s_per_useful_grad_mean``; the fixed-time laws are deterministic end
to end, so these are exact machine-independent simulator outputs):
``benchmarks/perf_gate.py`` compares it against the committed baseline
in ``benchmarks/baselines/`` in CI, gating behavior drift in the
per-figure run_experiment path beyond the simbatch shapes (ISSUE 4)."""

import os

from repro.core import optimal_m
from repro.exp import make_scenario, run_experiment

BENCH_JSON = os.environ.get("REPRO_BENCH_FIG8_JSON", "BENCH_fig8.json")

LAWS = {"sqrt": ("fixed_sqrt", {}),
        "linear": ("fixed_linear", {}),
        "pow1.2": ("fixed_power", {"alpha": 1.2})}


def run(fast: bool = True, seeds: int = 8):
    rows = []
    metrics = {}
    K = 60 if fast else 300
    for law, (scen, scen_kw) in LAWS.items():
        for n in ((100,) if fast else (100, 1000)):
            model = make_scenario(scen, n, **scen_kw)
            sigma2_eps = 100.0   # sigma^2/eps used for m*
            m_star = optimal_m(model.taus, sigma2_eps, 1.0)
            cases = {
                "sync": (("sync", {}), K),
                f"msync_m{m_star}": (("msync", {"m": m_star}), K),
                "async": (("async", {}), K * max(m_star, 1)),
                f"rennala_b{m_star}": (("rennala", {"batch": m_star}), K),
            }
            for name, (spec, K_run) in cases.items():
                res = run_experiment(spec, model, n=n, K=K_run, seeds=seeds)
                r = res.rows[0]
                metrics[f"{law}/n={n}/{name}"] = r["s_per_useful_grad_mean"]
                rows.append(
                    (f"fig8/{law}/n={n}/{name}/s_per_useful_grad",
                     r["s_per_useful_grad_mean"],
                     f"±{r['s_per_useful_grad_std']:.4g} over "
                     f"{r['seeds']} seeds "
                     f"discard={r['discard_fraction_mean']:.2f} "
                     f"backend={r['backend']}"))
    from repro.exp.runner import atomic_write_json
    atomic_write_json(BENCH_JSON, {"meta": {"fast": fast, "seeds": seeds},
                                   "s_per_useful_grad_mean": metrics})
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
