"""Figure 8/9 grid (K.1/K.2): method comparison across computation-time
laws and noise levels, plus robustness to growing n.

Timing-only simulation (gradient math factored out): per-useful-gradient
wall time for each method, across tau in {sqrt(i), i, i^1.2} and n in
{100, 1000}. The paper's qualitative claims checked downstream (tests):
m-sync tracks the asynchronous methods; full sync degrades as the tau law
steepens; m-sync is robust to n."""

import numpy as np

from repro.core import STRATEGIES, FixedTimes, optimal_m, simulate


def run(fast: bool = True):
    rows = []
    K = 60 if fast else 300
    for law, fn in {"sqrt": FixedTimes.sqrt_law,
                    "linear": FixedTimes.linear,
                    "pow1.2": lambda n: FixedTimes.power_law(n, 1.2)}.items():
        for n in ((100,) if fast else (100, 1000)):
            model = fn(n)
            sigma2_eps = 100.0   # sigma^2/eps used for m*
            m_star = optimal_m(model.taus, sigma2_eps, 1.0)
            runs = {
                "sync": simulate("sync", model, K=K),
                f"msync_m{m_star}": simulate(
                    STRATEGIES["msync"](m=m_star), model, K=K),
                "async": simulate("async", model, K=K * max(m_star, 1)),
                f"rennala_b{m_star}": simulate(
                    STRATEGIES["rennala"](batch=m_star), model, K=K),
            }
            for name, tr in runs.items():
                per_grad = tr.total_time / max(tr.gradients_used, 1)
                rows.append(
                    (f"fig8/{law}/n={n}/{name}/s_per_useful_grad",
                     per_grad,
                     f"discard={tr.discard_fraction:.2f}"))
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
