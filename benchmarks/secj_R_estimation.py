"""Section J analogue: estimate the sub-exponential R of real step times.

The paper measured NanoGPT fwd+bwd steps on a V100 and found
R log(n) << mean (R ~ 0.6ms vs mean 72.2ms). We repeat the procedure on
this container's CPU with the paper's exact NanoGPT config (6L, d=384,
block 512, vocab 50304): record step times, estimate the smallest R with
mean exp(|t - mean|/R) = 2, and report R log(n)/mean for n = 1e6."""

import time

import jax
import numpy as np

from repro.core import estimate_R
from repro.configs import get_config, reduced
from repro.data import SyntheticLM
from repro.models import build_model
from repro.optim import sgd
from repro.train import Trainer


def run(fast: bool = True):
    cfg = get_config("nanogpt-paper")
    if fast:  # same family, smaller: keeps the benchmark < 1 min on CPU
        cfg = reduced(cfg, d_model=256, layers_per_stage=3, vocab=2048)
    model = build_model(cfg)
    tr = Trainer(model, sgd(lr=0.1), n_workers=1)
    state = tr.init_state()
    data = SyntheticLM(vocab_size=cfg.vocab_size,
                       seq_len=min(cfg.max_seq_len, 512) if not fast else 128,
                       batch_size=8 if fast else 12, seed=0)
    it = iter(data)
    # warmup (compile) + timed steps, as in §J (10 warmup, 200 steps)
    n_steps = 30 if fast else 200
    for _ in range(3):
        state, *_ = tr.step(state, next(it))
    times = []
    for _ in range(n_steps):
        t0 = time.perf_counter()
        state, *_ = tr.step(state, next(it))
        times.append(time.perf_counter() - t0)
    times = np.array(times)
    R = estimate_R(times)
    mean = float(times.mean())
    rows = [
        ("secj/mean_step_s", mean, f"n_steps={n_steps}"),
        ("secj/R", R, "smallest R with mean exp(|t-mean|/R)=2"),
        ("secj/Rlogn_over_mean_n1e6", R * np.log(1e6) / mean,
         "paper: 8.2/72.2 = 0.11 (V100); << 1 confirms Cor 3.4 regime"),
    ]
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
