"""Sharded-sweep scaling lane: ``backend="jax_sharded"`` vs the
unsharded ``backend="jax"`` sweep as a function of device count.

The device count is forced with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — a flag jax
reads at first import, so each measurement runs in a fresh WORKER
subprocess (``--worker N``; same pattern as
``tests/test_hlo_analysis.py``) and the module's ``run()`` is the
driver that spawns one worker per N in ``DEVICES``, cross-checks them
and writes ``BENCH_sweep.json``.

Each worker runs a paper-scale m-sync ``m``-sweep (the Figure 8 /
Theorem 2.3 shape: one grid point per ``m``, S seeds each) twice, both
COLD:

* unsharded ``backend="jax"`` — the engine vmaps seeds but serializes
  grid points, and the closure-compiled timing program recompiles per
  ``m`` (``m`` is static there): the sweep pays ``len(M_GRID)``
  compiles;
* ``backend="jax_sharded"`` — the :mod:`repro.launch.sweep` backend
  fuses the whole sweep into ONE shape bucket (``m`` is traced
  row-wise), pays one AOT compile, and ``shard_map``s the
  (point × seed) units across the forced devices.

On the single-core CI host the speedup is therefore mostly compile
amortization plus fusion (forced host "devices" share one core); on a
real multi-device host the same lane additionally measures data
parallelism. Both effects are exactly what the backend exists for, and
the floor asserted here (``>= {MIN_SPEEDUP_D4}x`` at 4 devices) holds
on the weakest case.

Workers also verify per-seed BITWISE parity between the two backends
(the sharded sweep's core contract) and report the simulated
``total_time_mean``; the driver asserts the value is identical across
device counts — sharding must not change a single bit of the
simulation — and writes it as a machine-independent drift detector.

``BENCH_sweep.json`` sections (gated by ``benchmarks/perf_gate.py``
against ``benchmarks/baselines/BENCH_sweep.json``):

* ``speedup_vs_unsharded.dN`` — one-sided floors (higher is better);
  the committed baseline is seeded so the -30% floor at d4 lands on
  the acceptance 2.5x.
* ``total_time_mean.*`` — two-sided simulated outputs (exact,
  machine-independent).
"""

from __future__ import annotations

import json
import os
import sys
import time

BENCH_JSON = os.environ.get("REPRO_BENCH_SWEEP_JSON", "BENCH_sweep.json")

#: forced host device counts, one worker subprocess each
DEVICES = (1, 2, 4)
MIN_SPEEDUP_D4 = 2.5

# paper-scale sweep shape: an m-grid wide enough that the unsharded
# backend's per-point closure compiles dominate (Theorem 2.3 m-sweep)
SCENARIO = "exponential"
N = 400
S = 16
K = 120
M_GRID = (2, 4, 6, 10, 16, 24, 40, 64)

_SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _worker(devices: int) -> dict:
    """Measure one forced-device-count point (runs in a subprocess)."""
    import jax
    import numpy as np

    from repro.core import simulate_batch
    from repro.exp import make_scenario

    assert jax.local_device_count() == devices, (
        f"XLA_FLAGS did not take: {jax.local_device_count()} != {devices}")
    model = make_scenario(SCENARIO, N)
    spec = ("msync", {"m": M_GRID[0]})
    grid = {"m": list(M_GRID)}

    t0 = time.perf_counter()
    tb_j = simulate_batch(spec, model, K=K, seeds=S, grid=grid,
                          backend="jax")
    t_unsharded = time.perf_counter() - t0

    t0 = time.perf_counter()
    tb_s = simulate_batch(spec, model, K=K, seeds=S, grid=grid,
                          backend="jax_sharded")
    t_sharded = time.perf_counter() - t0

    bitwise = all(
        a.total_time == b.total_time
        and a.gradients_computed == b.gradients_computed
        and np.array_equal(a.times, b.times)
        for ga, gb in zip(tb_j.traces, tb_s.traces)
        for a, b in zip(ga, gb))

    # warm re-run: the fused program is AOT-cached, so this isolates
    # execute time (reported as context, never gated — machine-bound)
    t0 = time.perf_counter()
    tb_w = simulate_batch(spec, model, K=K, seeds=S, grid=grid,
                          backend="jax_sharded")
    t_sharded_warm = time.perf_counter() - t0
    cold = tb_s.routing[0]["shard"]
    warm = tb_w.routing[0]["shard"]

    return {
        "devices": devices,
        "t_unsharded": t_unsharded,
        "t_sharded": t_sharded,
        "t_sharded_warm": t_sharded_warm,
        "speedup": t_unsharded / t_sharded,
        "bitwise_equal": bool(bitwise),
        "bucket": cold["bucket"],
        "warm_cache_hit": bool(warm["cache_hit"]),
        "compile_s": cold.get("compile_s"),
        "exec_s": cold.get("exec_s"),
        "total_time_mean": float(tb_s.total_time.mean()),
    }


#: per-worker-launch wall-clock ceiling (re-exported for the baseline
#: meta; the shared runner owns the retry policy)
SPAWN_TIMEOUT_S = 600


def _spawn(devices: int) -> dict:
    """Run ``--worker devices`` in a subprocess with the XLA flag set,
    through the shared :func:`benchmarks.subproc.run_json_worker`
    timeout+retry runner (compile-cache warmup makes the second attempt
    much cheaper)."""
    from .subproc import run_json_worker

    env = dict(os.environ)
    flag = f"--xla_force_host_platform_device_count={devices}"
    env["XLA_FLAGS"] = f"{env.get('XLA_FLAGS', '')} {flag}".strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (_SRC, env.get("PYTHONPATH")) if p)
    return run_json_worker(
        [sys.executable, "-m", "benchmarks.sweep_scaling",
         "--worker", str(devices)],
        label=f"sweep_scaling worker d={devices}", env=env,
        timeout_s=SPAWN_TIMEOUT_S,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def run(fast: bool = True):
    # one fixed config: the baseline's meta must match bit for bit
    del fast
    results = {d: _spawn(d) for d in DEVICES}

    for d, r in results.items():
        assert r["bitwise_equal"], (
            f"sharded sweep at {d} devices is NOT bitwise equal to the "
            f"unsharded jax backend — parity contract broken")
    sims = {r["total_time_mean"] for r in results.values()}
    assert len(sims) == 1, (
        f"simulated total_time_mean differs across device counts: "
        f"{sorted(sims)} — sharding changed the simulation")

    speedups = {f"d{d}": r["speedup"] for d, r in results.items()}
    assert speedups[f"d{max(DEVICES)}"] >= MIN_SPEEDUP_D4, (
        f"sharded sweep only {speedups[f'd{max(DEVICES)}']:.2f}x over the "
        f"unsharded jax backend at {max(DEVICES)} forced devices "
        f"(need >= {MIN_SPEEDUP_D4}x)")

    rows = []
    for d, r in results.items():
        rows.append((
            f"sweep_scaling/n={N}/S={S}/G={len(M_GRID)}/d{d}/unsharded_s",
            r["t_unsharded"], f"{len(M_GRID)} per-point compiles (cold)"))
        rows.append((
            f"sweep_scaling/n={N}/S={S}/G={len(M_GRID)}/d{d}/sharded_s",
            r["t_sharded"],
            f"speedup={r['speedup']:.1f}x cold; bucket={r['bucket']} "
            f"compile={r['compile_s']:.2f}s exec={r['exec_s']:.3f}s"))
        rows.append((
            f"sweep_scaling/d{d}/sharded_warm_s", r["t_sharded_warm"],
            f"AOT cache hit={r['warm_cache_hit']}"))
    rows.append((
        f"sweep_scaling/speedup_d{max(DEVICES)}",
        speedups[f"d{max(DEVICES)}"],
        f"acceptance: >= {MIN_SPEEDUP_D4}x, bitwise-identical traces"))

    from repro.exp.runner import atomic_write_json
    atomic_write_json(BENCH_JSON, {
        "meta": {"scenario": SCENARIO, "n": N, "S": S, "K": K,
                 "m_grid": list(M_GRID), "devices": list(DEVICES)},
        "speedup_vs_unsharded": speedups,
        "total_time_mean": {
            "exponential_msync_sweep": results[DEVICES[0]]
            ["total_time_mean"],
        },
    })
    return rows


def main() -> None:
    if len(sys.argv) >= 3 and sys.argv[1] == "--worker":
        print(json.dumps(_worker(int(sys.argv[2]))))
        return
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
