"""Perf regression gate: compare fresh benchmark JSON artifacts against
the committed baselines (ISSUE 3 satellite; generalized to multiple
artifacts for ISSUE 4; per-lane diff + split exit codes for ISSUE 6).

The gate takes ``measured baseline`` path PAIRS — CI runs it over
``BENCH_simbatch.json`` (engine speedups + simulated outputs),
``BENCH_fig8.json`` (the fig8_grid per-figure ``run_experiment``
artifact, so behavior drift beyond the simbatch shapes is caught too)
and, in the sharded lane, ``BENCH_sweep.json`` (the
``backend="jax_sharded"`` scaling-efficiency lane from
``benchmarks/sweep_scaling.py``).

Rules per artifact (tolerance ±30% by default, ``REPRO_PERF_TOL``
overrides):

* ``speedup_vs_serial.*`` / ``speedup_vs_unsharded.*``
  (:data:`ONE_SIDED_SECTIONS`) — one-sided floors: a measured speedup
  may exceed the baseline freely but must not drop below
  ``baseline * (1 - tol)`` (perf regression).
* every other numeric section (``total_time_mean.*``,
  ``s_per_useful_grad_mean.*``, ...) — two-sided: these are *simulated*
  outputs, so drift in either direction is a behavior change, not noise.

Keys present in the baseline but missing from the measurement (or vice
versa — including whole sections) fail loudly — silently dropping a
tracked metric is how perf gates rot, and mismatched ``meta`` entries
(n/S/K/seeds/...) fail as a config mismatch rather than masquerading as
drift.

Exit codes (CI branches on these):

* ``0`` — every lane within bounds;
* ``1`` — numeric failure only: a speedup under its floor or a
  simulated output outside the two-sided band (a *perf/behavior
  regression* — investigate the change);
* ``2`` — structural failure: a baseline file missing/unreadable, a
  ``meta`` config mismatch, or metric keys present on one side only
  (the *gate itself* is broken — regenerate or re-commit
  ``benchmarks/baselines/``). Structural beats numeric when both occur.

On failure every offending lane prints one aligned row — lane name,
measured value, baseline value, and the bound it violated — so the CI
log answers "which lane, by how much" without re-running locally.

Speedup ratios are hardware-sensitive: a baseline recorded on a fast
dev box would set floors a slower CI runner cannot meet even without a
regression. The committed baselines in ``benchmarks/baselines/`` are
therefore seeded *conservatively* — speedup entries are chosen so the
-30% floors land at the acceptance criteria asserted inside
``simbatch_speed.py`` itself (jax 7.15 → floor 5x, counter 5.72 →
floor 4x, async keyed 1.86 → floor 1.3x, arrival-scan chain 4.29 →
floor 3x, routed-vs-alternative 1.43 → floor 1x, sharded-sweep dN
3.571 → floor 2.5x, chain-layout ragged pool 4.286 → floor 3x and
ragged wall 1.5 → floor 1.05x), while simulated-output
entries are exact simulator results (machine-independent, tight drift
detectors — the fig8 grid is deterministic end to end). To tighten the
speedup floors, regenerate the baseline ON THE RUNNER CLASS IT GATES
(``python -m benchmarks.run --only simbatch`` there, then copy
``BENCH_simbatch.json`` over the baseline) — never from a dev box.
Loosen a noisy lane with ``REPRO_PERF_TOL`` rather than deleting
metrics.

    python -m benchmarks.perf_gate \
        BENCH_simbatch.json benchmarks/baselines/BENCH_simbatch.json \
        BENCH_fig8.json benchmarks/baselines/BENCH_fig8.json
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import List, Optional

# sections gated as one-sided floors (higher is better); everything else
# numeric is a simulated output, gated two-sided
ONE_SIDED_SECTIONS = ("speedup_vs_serial", "speedup_vs_unsharded")

EXIT_OK = 0
EXIT_REGRESSION = 1      # numeric: floor/band violated
EXIT_STRUCTURAL = 2      # missing baseline / config or key mismatch


@dataclasses.dataclass(frozen=True)
class Failure:
    """One failed lane: what was measured, what bounded it, which kind.

    ``kind`` is ``"regression"`` (numeric violation — exit 1) or
    ``"structural"`` (missing/mismatched gate inputs — exit 2).
    ``measured``/``baseline`` are ``None`` for one-sided-missing keys.
    """
    lane: str
    measured: Optional[float]
    baseline: Optional[float]
    bound: str
    kind: str

    def row(self) -> str:
        fmt = (lambda v: "—" if v is None
               else (f"{v:.6g}" if isinstance(v, (int, float)) else str(v)))
        return (f"  {self.lane:<42} {fmt(self.measured):>12} "
                f"{fmt(self.baseline):>12}   {self.bound}")


_HEADER = (f"  {'lane':<42} {'measured':>12} {'baseline':>12}   bound")


def compare(measured: dict, baseline: dict, tol: float) -> List[Failure]:
    """Return the failed lanes (empty => gate passes)."""
    failures: List[Failure] = []
    meta_m = measured.get("meta", {})
    meta_b = baseline.get("meta", {})
    for key in sorted(set(meta_m) | set(meta_b)):
        got, want = meta_m.get(key), meta_b.get(key)
        if got != want:
            failures.append(Failure(
                f"meta.{key}", None, None,
                f"config mismatch: measured {got!r} vs baseline {want!r} "
                f"— regenerate the baseline", "structural"))
    if failures:
        return failures

    sections = sorted(k for k in baseline
                      if k != "meta" and isinstance(baseline[k], dict))
    for extra in sorted(k for k in measured
                        if k != "meta" and isinstance(measured[k], dict)
                        and k not in baseline):
        failures.append(Failure(
            extra, None, None,
            "section not in baseline — re-commit benchmarks/baselines/",
            "structural"))

    def keys_match(section):
        a = set(measured.get(section, {}))
        b = set(baseline.get(section, {}))
        for missing in sorted(b - a):
            failures.append(Failure(
                f"{section}.{missing}", None,
                baseline[section][missing],
                "missing from measurement", "structural"))
        for extra in sorted(a - b):
            failures.append(Failure(
                f"{section}.{extra}", measured[section][extra], None,
                "not in baseline — re-commit benchmarks/baselines/",
                "structural"))
        return sorted(a & b)

    for section in sections:
        one_sided = section in ONE_SIDED_SECTIONS
        for key in keys_match(section):
            got = measured[section][key]
            want = baseline[section][key]
            if one_sided:
                floor = want * (1.0 - tol)
                if got < floor:
                    failures.append(Failure(
                        f"{section}.{key}", got, want,
                        f">= {floor:.2f}x (floor = baseline - {tol:.0%})"
                        f" — perf regression", "regression"))
            elif abs(got - want) > tol * abs(want):
                failures.append(Failure(
                    f"{section}.{key}", got, want,
                    f"within ±{tol:.0%} of baseline — simulated-output "
                    f"drift", "regression"))
    return failures


def exit_code(failures: List[Failure]) -> int:
    if any(f.kind == "structural" for f in failures):
        return EXIT_STRUCTURAL
    if failures:
        return EXIT_REGRESSION
    return EXIT_OK


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+",
                    help="measured baseline [measured baseline ...] pairs")
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("REPRO_PERF_TOL", "0.30")))
    args = ap.parse_args(argv)
    if len(args.files) % 2:
        ap.error("need (measured, baseline) path PAIRS")
    rc = EXIT_OK
    for mpath, bpath in zip(args.files[::2], args.files[1::2]):
        try:
            with open(mpath) as fh:
                measured = json.load(fh)
            with open(bpath) as fh:
                baseline = json.load(fh)
        except (OSError, json.JSONDecodeError) as exc:
            print(f"PERF GATE FAIL [{mpath} vs {bpath}]: cannot load "
                  f"gate inputs: {exc}")
            rc = max(rc, EXIT_STRUCTURAL)
            continue
        failures = compare(measured, baseline, args.tol)
        if failures:
            print(f"PERF GATE FAIL [{mpath} vs {bpath}] — "
                  f"{len(failures)} lane(s):")
            print(_HEADER)
            for f in failures:
                print(f.row())
        else:
            n_metrics = sum(len(v) for k, v in baseline.items()
                            if k != "meta" and isinstance(v, dict))
            print(f"perf gate OK [{mpath} vs {bpath}] "
                  f"(tol ±{args.tol:.0%}, {n_metrics} metrics)")
        rc = max(rc, exit_code(failures))
    return rc


if __name__ == "__main__":
    sys.exit(main())
