"""Perf regression gate: compare fresh benchmark JSON artifacts against
the committed baselines (ISSUE 3 satellite; generalized to multiple
artifacts for ISSUE 4).

The gate takes ``measured baseline`` path PAIRS — CI runs it over both
``BENCH_simbatch.json`` (engine speedups + simulated outputs) and
``BENCH_fig8.json`` (the fig8_grid per-figure ``run_experiment``
artifact, so behavior drift beyond the simbatch shapes is caught too).

Rules per artifact (tolerance ±30% by default, ``REPRO_PERF_TOL``
overrides):

* ``speedup_vs_serial.*`` — one-sided floors: a measured speedup may
  exceed the baseline freely but must not drop below
  ``baseline * (1 - tol)`` (perf regression).
* every other numeric section (``total_time_mean.*``,
  ``s_per_useful_grad_mean.*``, ...) — two-sided: these are *simulated*
  outputs, so drift in either direction is a behavior change, not noise.

Keys present in the baseline but missing from the measurement (or vice
versa — including whole sections) fail loudly — silently dropping a
tracked metric is how perf gates rot, and mismatched ``meta`` entries
(n/S/K/seeds/...) fail as a config mismatch rather than masquerading as
drift.

Speedup ratios are hardware-sensitive: a baseline recorded on a fast
dev box would set floors a slower CI runner cannot meet even without a
regression. The committed baselines in ``benchmarks/baselines/`` are
therefore seeded *conservatively* — speedup entries are chosen so the
-30% floors land at the acceptance criteria asserted inside
``simbatch_speed.py`` itself (jax 7.15 → floor 5x, counter 5.72 →
floor 4x, async keyed 1.86 → floor 1.3x, arrival-scan chain 4.29 →
floor 3x, routed-vs-alternative 1.43 → floor 1x), while simulated-output
entries are exact simulator results (machine-independent, tight drift
detectors — the fig8 grid is deterministic end to end). To tighten the
speedup floors, regenerate the baseline ON THE RUNNER CLASS IT GATES
(``python -m benchmarks.run --only simbatch`` there, then copy
``BENCH_simbatch.json`` over the baseline) — never from a dev box.
Loosen a noisy lane with ``REPRO_PERF_TOL`` rather than deleting
metrics.

    python -m benchmarks.perf_gate \
        BENCH_simbatch.json benchmarks/baselines/BENCH_simbatch.json \
        BENCH_fig8.json benchmarks/baselines/BENCH_fig8.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# sections gated as one-sided floors (higher is better); everything else
# numeric is a simulated output, gated two-sided
ONE_SIDED_SECTIONS = ("speedup_vs_serial",)


def compare(measured: dict, baseline: dict, tol: float) -> list:
    """Return a list of failure strings (empty => gate passes)."""
    failures = []
    meta_m = measured.get("meta", {})
    meta_b = baseline.get("meta", {})
    for key in sorted(set(meta_m) | set(meta_b)):
        got, want = meta_m.get(key), meta_b.get(key)
        if got != want:
            failures.append(
                f"meta.{key}: measured {got!r} vs baseline {want!r} — "
                f"benchmark config mismatch, not a perf result; "
                f"regenerate the baseline")
    if failures:
        return failures

    sections = sorted(k for k in baseline
                      if k != "meta" and isinstance(baseline[k], dict))
    for extra in sorted(k for k in measured
                        if k != "meta" and isinstance(measured[k], dict)
                        and k not in baseline):
        failures.append(f"{extra}: section not in baseline — "
                        f"re-commit benchmarks/baselines/")

    def keys_match(section):
        a = set(measured.get(section, {}))
        b = set(baseline.get(section, {}))
        for missing in sorted(b - a):
            failures.append(f"{section}.{missing}: missing from measurement")
        for extra in sorted(a - b):
            failures.append(f"{section}.{extra}: not in baseline — "
                            f"re-commit benchmarks/baselines/")
        return sorted(a & b)

    for section in sections:
        one_sided = section in ONE_SIDED_SECTIONS
        for key in keys_match(section):
            got = measured[section][key]
            want = baseline[section][key]
            if one_sided:
                if got < want * (1.0 - tol):
                    failures.append(
                        f"{section}.{key}: {got:.2f}x < "
                        f"{want:.2f}x * (1 - {tol:.0%}) — perf regression")
            elif abs(got - want) > tol * abs(want):
                failures.append(
                    f"{section}.{key}: {got:.6g} vs baseline "
                    f"{want:.6g} (> ±{tol:.0%}) — simulated-output drift")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+",
                    help="measured baseline [measured baseline ...] pairs")
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("REPRO_PERF_TOL", "0.30")))
    args = ap.parse_args()
    if len(args.files) % 2:
        ap.error("need (measured, baseline) path PAIRS")
    rc = 0
    for mpath, bpath in zip(args.files[::2], args.files[1::2]):
        with open(mpath) as fh:
            measured = json.load(fh)
        with open(bpath) as fh:
            baseline = json.load(fh)
        failures = compare(measured, baseline, args.tol)
        for f in failures:
            print(f"PERF GATE FAIL [{mpath}]: {f}")
        if not failures:
            n_metrics = sum(len(v) for k, v in baseline.items()
                            if k != "meta" and isinstance(v, dict))
            print(f"perf gate OK [{mpath} vs {bpath}] "
                  f"(tol ±{args.tol:.0%}, {n_metrics} metrics)")
        rc |= bool(failures)
    return rc


if __name__ == "__main__":
    sys.exit(main())
