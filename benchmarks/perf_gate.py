"""Perf regression gate: compare a fresh ``BENCH_simbatch.json`` against
the committed baseline (ISSUE 3 satellite).

Rules (tolerance ±30% by default, ``REPRO_PERF_TOL`` overrides):

* ``speedup_vs_serial.*`` — one-sided floors: a measured speedup may
  exceed the baseline freely but must not drop below
  ``baseline * (1 - tol)`` (perf regression).
* ``total_time_mean.*`` — two-sided: these are *simulated* wall-clock
  outputs, so drift in either direction is a behavior change, not noise.

Keys present in the baseline but missing from the measurement (or vice
versa) fail loudly — silently dropping a tracked metric is how perf
gates rot, and mismatched ``meta`` shapes (n/S/K/fast) fail as a config
mismatch rather than masquerading as drift.

Speedup ratios are hardware-sensitive: a baseline recorded on a fast
dev box would set floors a slower CI runner cannot meet even without a
regression. The committed baseline in ``benchmarks/baselines/`` is
therefore seeded *conservatively* — its speedup entries are chosen so
the -30% floors land at the acceptance criteria asserted inside
``simbatch_speed.py`` itself (jax 7.15 → floor 5x, counter 5.72 →
floor 4x), while ``total_time_mean`` entries are exact simulated
outputs (machine-independent, tight drift detectors). To tighten the
speedup floors, regenerate the baseline ON THE RUNNER CLASS IT GATES
(``python -m benchmarks.run --only simbatch`` there, then copy
``BENCH_simbatch.json`` over the baseline) — never from a dev box.
Loosen a noisy lane with ``REPRO_PERF_TOL`` rather than deleting
metrics.

    python -m benchmarks.perf_gate BENCH_simbatch.json \
        benchmarks/baselines/BENCH_simbatch.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def compare(measured: dict, baseline: dict, tol: float) -> list:
    """Return a list of failure strings (empty => gate passes)."""
    failures = []
    for key in ("n", "S", "K", "m", "fast"):
        got = measured.get("meta", {}).get(key)
        want = baseline.get("meta", {}).get(key)
        if got != want:
            failures.append(
                f"meta.{key}: measured {got!r} vs baseline {want!r} — "
                f"benchmark config mismatch, not a perf result; "
                f"regenerate the baseline")
    if failures:
        return failures

    def keys_match(section):
        a = set(measured.get(section, {}))
        b = set(baseline.get(section, {}))
        for missing in sorted(b - a):
            failures.append(f"{section}.{missing}: missing from measurement")
        for extra in sorted(a - b):
            failures.append(f"{section}.{extra}: not in baseline — "
                            f"re-commit benchmarks/baselines/")
        return sorted(a & b)

    for key in keys_match("speedup_vs_serial"):
        got = measured["speedup_vs_serial"][key]
        want = baseline["speedup_vs_serial"][key]
        if got < want * (1.0 - tol):
            failures.append(
                f"speedup_vs_serial.{key}: {got:.2f}x < "
                f"{want:.2f}x * (1 - {tol:.0%}) — perf regression")
    for key in keys_match("total_time_mean"):
        got = measured["total_time_mean"][key]
        want = baseline["total_time_mean"][key]
        if abs(got - want) > tol * abs(want):
            failures.append(
                f"total_time_mean.{key}: {got:.6g} vs baseline "
                f"{want:.6g} (> ±{tol:.0%}) — simulated-output drift")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("measured", help="fresh BENCH_simbatch.json")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("REPRO_PERF_TOL", "0.30")))
    args = ap.parse_args()
    with open(args.measured) as fh:
        measured = json.load(fh)
    with open(args.baseline) as fh:
        baseline = json.load(fh)
    failures = compare(measured, baseline, args.tol)
    for f in failures:
        print(f"PERF GATE FAIL: {f}")
    if not failures:
        print(f"perf gate OK (tol ±{args.tol:.0%}, "
              f"{len(measured.get('speedup_vs_serial', {}))} speedups, "
              f"{len(measured.get('total_time_mean', {}))} totals)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
