"""Acceptance smoke for the batched engine: ``simulate_batch`` must be
≥ 5x faster than a serial per-seed ``simulate()`` loop for timing-only
m-sync at n=1000 × 32 seeds (ISSUE 2), and must agree with the serial
results.

The serial baseline already runs the round-vectorized scalar fast path
(~54x over the event loop), so this measures batching gain on top of it.
The JAX backend (one jitted (seeds, rounds, workers) program) is timed
after one warmup call — JIT compilation is a one-time cost, amortized
across every sweep of the same shape. The NumPy vectorized backend's
ratio is reported as context (exact RNG parity, smaller speedup)."""

import time

import numpy as np

from repro.core import STRATEGIES, simulate, simulate_batch
from repro.exp import make_scenario


def run(fast: bool = True):
    # no seeds override: n=1000 × 32 seeds is the acceptance shape
    n, S = 1000, 32
    K = 120 if fast else 600
    m = 10
    model = make_scenario("fixed_sqrt", n)

    t0 = time.perf_counter()
    serial = [simulate(STRATEGIES["msync"](m=m), model, K=K, seed=s)
              for s in range(S)]
    t_serial = time.perf_counter() - t0

    spec = ("msync", {"m": m})
    simulate_batch(spec, model, K=K, seeds=S, backend="jax")   # JIT warmup
    t_jax = min(_timed(lambda: simulate_batch(spec, model, K=K, seeds=S,
                                              backend="jax"))
                for _ in range(3))
    batch = simulate_batch(spec, model, K=K, seeds=S, backend="jax")
    for s, tr in enumerate(serial):
        bt = batch.traces[0][s]
        assert np.isclose(bt.total_time, tr.total_time, rtol=1e-5), s
        assert bt.gradients_computed == tr.gradients_computed, s
        assert bt.gradients_used == tr.gradients_used, s

    t_vec = min(_timed(lambda: simulate_batch(spec, model, K=K, seeds=S,
                                              backend="vectorized"))
                for _ in range(3))

    speedup = t_serial / t_jax
    rows = [
        (f"simbatch/n={n}/S={S}/serial_s", t_serial, f"K={K} m={m}"),
        (f"simbatch/n={n}/S={S}/jax_batch_s", t_jax,
         f"speedup={speedup:.1f}x (warm)"),
        (f"simbatch/n={n}/S={S}/numpy_batch_s", t_vec,
         f"speedup={t_serial / t_vec:.1f}x (exact RNG parity)"),
        ("simbatch/speedup_vs_serial", speedup,
         "acceptance: >= 5x, results identical"),
    ]
    assert speedup >= 5.0, (
        f"simulate_batch jax backend only {speedup:.1f}x over the serial "
        f"per-seed loop (need >= 5x)")
    return rows


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
