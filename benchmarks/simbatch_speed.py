"""Acceptance smoke + perf baseline for the batched engine.

Three asserted floors at the n=1000 × 32-seed acceptance shape:

* ``backend="jax"`` must be ≥ 5x over a serial per-seed ``simulate()``
  loop for timing-only m-sync under the deterministic ``fixed_sqrt``
  model (ISSUE 2), agreeing with the serial results;
* ``backend="vectorized"`` with ``rng_scheme="counter"`` must be ≥ 4x
  over serial under a *random* model (``exponential`` — ISSUE 3: the
  per-seed stream draws capped the old vectorized backend at ~1.2x); and
* the keyed Async draw path (ISSUE 4: one per-worker keyed draw per
  arrival from the pre-split key grid) must be ≥ 1.3x over the PR 3
  full-row draw pattern at the same shape (reproduced exactly by
  dropping ``jax_sampler_item``, which falls back to row draws) —
  measured ~2.2x here, the ~n× draw-volume cut minus the loop's fixed
  argmin/scatter cost. The serial event loop stays the right engine for
  *small* async sweeps (its per-arrival cost is O(log n), the device
  loop's is O(S·n)); the lane reports that ratio as context rather than
  gating it.

The serial baseline already runs the round-vectorized scalar fast path
(~54x over the event loop), so the m-sync floors measure batching gain
on top of it. The JAX backend is timed after one warmup call — JIT
compilation is a one-time cost, amortized across every sweep of the
same shape. The stream-scheme ratio is reported as context (exact RNG
parity, smaller speedup).

``run()`` also writes ``BENCH_simbatch.json`` (per-backend
``speedup_vs_serial`` plus simulated ``total_time_mean`` per benchmark
model): the perf regression gate (``benchmarks/perf_gate.py``, run by
CI) compares it against the committed baseline in
``benchmarks/baselines/``.
"""

import dataclasses
import json
import os
import time

import numpy as np

from repro.core import STRATEGIES, simulate, simulate_batch
from repro.exp import make_scenario

BENCH_JSON = os.environ.get("REPRO_BENCH_JSON", "BENCH_simbatch.json")


def run(fast: bool = True):
    # no seeds override: n=1000 × 32 seeds is the acceptance shape
    n, S = 1000, 32
    K = 120 if fast else 600
    m = 10
    spec = ("msync", {"m": m})

    # -------------------------- deterministic model: jax >= 5x (ISSUE 2)
    model = make_scenario("fixed_sqrt", n)
    t0 = time.perf_counter()
    serial = [simulate(STRATEGIES["msync"](m=m), model, K=K, seed=s)
              for s in range(S)]
    t_serial = time.perf_counter() - t0

    simulate_batch(spec, model, K=K, seeds=S, backend="jax")   # JIT warmup
    t_jax = min(_timed(lambda: simulate_batch(spec, model, K=K, seeds=S,
                                              backend="jax"))
                for _ in range(3))
    batch = simulate_batch(spec, model, K=K, seeds=S, backend="jax")
    for s, tr in enumerate(serial):
        bt = batch.traces[0][s]
        assert np.isclose(bt.total_time, tr.total_time, rtol=1e-5), s
        assert bt.gradients_computed == tr.gradients_computed, s
        assert bt.gradients_used == tr.gradients_used, s
    fixed_total_mean = float(np.mean([tr.total_time for tr in serial]))

    t_vec = min(_timed(lambda: simulate_batch(spec, model, K=K, seeds=S,
                                              backend="vectorized"))
                for _ in range(3))

    # ------------------------------ random model: counter >= 4x (ISSUE 3)
    rmodel = make_scenario("exponential", n)
    t0 = time.perf_counter()
    rserial = [simulate(STRATEGIES["msync"](m=m), rmodel, K=K, seed=s)
               for s in range(S)]
    t_rserial = time.perf_counter() - t0
    t_counter = min(_timed(lambda: simulate_batch(
        spec, rmodel, K=K, seeds=S, backend="vectorized",
        rng_scheme="counter")) for _ in range(3))
    t_stream = min(_timed(lambda: simulate_batch(
        spec, rmodel, K=K, seeds=S, backend="vectorized",
        rng_scheme="stream")) for _ in range(3))
    cbatch = simulate_batch(spec, rmodel, K=K, seeds=S,
                            backend="vectorized", rng_scheme="counter")
    exp_total_mean = float(cbatch.total_time.mean())
    # distribution sanity: counter means track the per-seed-stream serial
    # runs (same model, same shape)
    rserial_mean = float(np.mean([tr.total_time for tr in rserial]))
    assert np.isclose(exp_total_mean, rserial_mean, rtol=0.15), \
        (exp_total_mean, rserial_mean)

    # ---------------- keyed async draws: >= 1.3x vs PR 3 row draws (ISSUE 4)
    K_async = 2000
    # dropping jax_sampler_item reproduces the PR 3 draw pattern exactly:
    # the engine falls back to one full (S, n) row draw per arrival
    rowdraw_model = dataclasses.replace(rmodel, jax_sampler_item=None)
    simulate_batch("async", rmodel, K=K_async, seeds=S, backend="jax")
    t_akeyed = min(_timed(lambda: simulate_batch(
        "async", rmodel, K=K_async, seeds=S, backend="jax"))
        for _ in range(3))
    simulate_batch("async", rowdraw_model, K=K_async, seeds=S,
                   backend="jax")
    t_arow = min(_timed(lambda: simulate_batch(
        "async", rowdraw_model, K=K_async, seeds=S, backend="jax"))
        for _ in range(3))
    t0 = time.perf_counter()
    aserial = [simulate(STRATEGIES["async"](), rmodel, K=K_async, seed=s)
               for s in range(S)]
    t_aserial = time.perf_counter() - t0
    abatch = simulate_batch("async", rmodel, K=K_async, seeds=S,
                            backend="jax")
    async_total_mean = float(abatch.total_time.mean())
    # distribution sanity vs the serial event engine
    aserial_mean = float(np.mean([tr.total_time for tr in aserial]))
    assert np.isclose(async_total_mean, aserial_mean, rtol=0.15), \
        (async_total_mean, aserial_mean)
    speedup_keyed = t_arow / t_akeyed

    speedup = t_serial / t_jax
    speedup_counter = t_rserial / t_counter
    rows = [
        (f"simbatch/n={n}/S={S}/serial_s", t_serial, f"K={K} m={m}"),
        (f"simbatch/n={n}/S={S}/jax_batch_s", t_jax,
         f"speedup={speedup:.1f}x (warm)"),
        (f"simbatch/n={n}/S={S}/numpy_batch_s", t_vec,
         f"speedup={t_serial / t_vec:.1f}x (fixed model)"),
        ("simbatch/speedup_vs_serial", speedup,
         "acceptance: >= 5x, results identical"),
        (f"simbatch/exp/n={n}/S={S}/serial_s", t_rserial, f"K={K} m={m}"),
        (f"simbatch/exp/n={n}/S={S}/counter_s", t_counter,
         f"speedup={speedup_counter:.1f}x (Philox tensor draws)"),
        (f"simbatch/exp/n={n}/S={S}/stream_s", t_stream,
         f"speedup={t_rserial / t_stream:.1f}x (exact RNG parity)"),
        ("simbatch/counter_speedup_vs_serial", speedup_counter,
         "acceptance: >= 4x on a random model"),
        (f"simbatch/async/n={n}/S={S}/keyed_s", t_akeyed,
         f"K={K_async} one keyed draw per arrival"),
        (f"simbatch/async/n={n}/S={S}/rowdraw_s", t_arow,
         "PR 3 draw pattern: full (S, n) row per arrival"),
        (f"simbatch/async/n={n}/S={S}/serial_s", t_aserial,
         "context: serial event loop (O(log n) per arrival)"),
        ("simbatch/async_keyed_speedup_vs_rowdraw", speedup_keyed,
         "acceptance: >= 1.3x (draw volume cut ~n x)"),
    ]
    assert speedup >= 5.0, (
        f"simulate_batch jax backend only {speedup:.1f}x over the serial "
        f"per-seed loop (need >= 5x)")
    assert speedup_counter >= 4.0, (
        f"vectorized backend with rng_scheme='counter' only "
        f"{speedup_counter:.1f}x over serial on the exponential model "
        f"(need >= 4x)")
    assert speedup_keyed >= 1.3, (
        f"keyed async draws only {speedup_keyed:.2f}x over the PR 3 "
        f"row-draw pattern (need >= 1.3x)")

    with open(BENCH_JSON, "w") as fh:
        json.dump({
            "meta": {"n": n, "S": S, "K": K, "m": m, "fast": fast,
                     "K_async": K_async},
            "speedup_vs_serial": {
                "jax": speedup,
                "vectorized_fixed": t_serial / t_vec,
                "vectorized_counter": speedup_counter,
                "vectorized_stream": t_rserial / t_stream,
                "async_keyed_vs_rowdraw": speedup_keyed,
            },
            "total_time_mean": {
                "fixed_sqrt_msync": fixed_total_mean,
                "exponential_msync": exp_total_mean,
                "exponential_async": async_total_mean,
            },
        }, fh, indent=2)
    return rows


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
