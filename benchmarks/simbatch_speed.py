"""Acceptance smoke + perf baseline for the batched engine.

Two asserted floors at the n=1000 × 32-seed acceptance shape:

* ``backend="jax"`` must be ≥ 5x over a serial per-seed ``simulate()``
  loop for timing-only m-sync under the deterministic ``fixed_sqrt``
  model (ISSUE 2), agreeing with the serial results; and
* ``backend="vectorized"`` with ``rng_scheme="counter"`` must be ≥ 4x
  over serial under a *random* model (``exponential`` — ISSUE 3: the
  per-seed stream draws capped the old vectorized backend at ~1.2x).

The serial baseline already runs the round-vectorized scalar fast path
(~54x over the event loop), so both floors measure batching gain on top
of it. The JAX backend is timed after one warmup call — JIT compilation
is a one-time cost, amortized across every sweep of the same shape. The
stream-scheme ratio is reported as context (exact RNG parity, smaller
speedup).

``run()`` also writes ``BENCH_simbatch.json`` (per-backend
``speedup_vs_serial`` plus simulated ``total_time_mean`` per benchmark
model): the perf regression gate (``benchmarks/perf_gate.py``, run by
CI) compares it against the committed baseline in
``benchmarks/baselines/``.
"""

import json
import os
import time

import numpy as np

from repro.core import STRATEGIES, simulate, simulate_batch
from repro.exp import make_scenario

BENCH_JSON = os.environ.get("REPRO_BENCH_JSON", "BENCH_simbatch.json")


def run(fast: bool = True):
    # no seeds override: n=1000 × 32 seeds is the acceptance shape
    n, S = 1000, 32
    K = 120 if fast else 600
    m = 10
    spec = ("msync", {"m": m})

    # -------------------------- deterministic model: jax >= 5x (ISSUE 2)
    model = make_scenario("fixed_sqrt", n)
    t0 = time.perf_counter()
    serial = [simulate(STRATEGIES["msync"](m=m), model, K=K, seed=s)
              for s in range(S)]
    t_serial = time.perf_counter() - t0

    simulate_batch(spec, model, K=K, seeds=S, backend="jax")   # JIT warmup
    t_jax = min(_timed(lambda: simulate_batch(spec, model, K=K, seeds=S,
                                              backend="jax"))
                for _ in range(3))
    batch = simulate_batch(spec, model, K=K, seeds=S, backend="jax")
    for s, tr in enumerate(serial):
        bt = batch.traces[0][s]
        assert np.isclose(bt.total_time, tr.total_time, rtol=1e-5), s
        assert bt.gradients_computed == tr.gradients_computed, s
        assert bt.gradients_used == tr.gradients_used, s
    fixed_total_mean = float(np.mean([tr.total_time for tr in serial]))

    t_vec = min(_timed(lambda: simulate_batch(spec, model, K=K, seeds=S,
                                              backend="vectorized"))
                for _ in range(3))

    # ------------------------------ random model: counter >= 4x (ISSUE 3)
    rmodel = make_scenario("exponential", n)
    t0 = time.perf_counter()
    rserial = [simulate(STRATEGIES["msync"](m=m), rmodel, K=K, seed=s)
               for s in range(S)]
    t_rserial = time.perf_counter() - t0
    t_counter = min(_timed(lambda: simulate_batch(
        spec, rmodel, K=K, seeds=S, backend="vectorized",
        rng_scheme="counter")) for _ in range(3))
    t_stream = min(_timed(lambda: simulate_batch(
        spec, rmodel, K=K, seeds=S, backend="vectorized",
        rng_scheme="stream")) for _ in range(3))
    cbatch = simulate_batch(spec, rmodel, K=K, seeds=S,
                            backend="vectorized", rng_scheme="counter")
    exp_total_mean = float(cbatch.total_time.mean())
    # distribution sanity: counter means track the per-seed-stream serial
    # runs (same model, same shape)
    rserial_mean = float(np.mean([tr.total_time for tr in rserial]))
    assert np.isclose(exp_total_mean, rserial_mean, rtol=0.15), \
        (exp_total_mean, rserial_mean)

    speedup = t_serial / t_jax
    speedup_counter = t_rserial / t_counter
    rows = [
        (f"simbatch/n={n}/S={S}/serial_s", t_serial, f"K={K} m={m}"),
        (f"simbatch/n={n}/S={S}/jax_batch_s", t_jax,
         f"speedup={speedup:.1f}x (warm)"),
        (f"simbatch/n={n}/S={S}/numpy_batch_s", t_vec,
         f"speedup={t_serial / t_vec:.1f}x (fixed model)"),
        ("simbatch/speedup_vs_serial", speedup,
         "acceptance: >= 5x, results identical"),
        (f"simbatch/exp/n={n}/S={S}/serial_s", t_rserial, f"K={K} m={m}"),
        (f"simbatch/exp/n={n}/S={S}/counter_s", t_counter,
         f"speedup={speedup_counter:.1f}x (Philox tensor draws)"),
        (f"simbatch/exp/n={n}/S={S}/stream_s", t_stream,
         f"speedup={t_rserial / t_stream:.1f}x (exact RNG parity)"),
        ("simbatch/counter_speedup_vs_serial", speedup_counter,
         "acceptance: >= 4x on a random model"),
    ]
    assert speedup >= 5.0, (
        f"simulate_batch jax backend only {speedup:.1f}x over the serial "
        f"per-seed loop (need >= 5x)")
    assert speedup_counter >= 4.0, (
        f"vectorized backend with rng_scheme='counter' only "
        f"{speedup_counter:.1f}x over serial on the exponential model "
        f"(need >= 4x)")

    with open(BENCH_JSON, "w") as fh:
        json.dump({
            "meta": {"n": n, "S": S, "K": K, "m": m, "fast": fast},
            "speedup_vs_serial": {
                "jax": speedup,
                "vectorized_fixed": t_serial / t_vec,
                "vectorized_counter": speedup_counter,
                "vectorized_stream": t_rserial / t_stream,
            },
            "total_time_mean": {
                "fixed_sqrt_msync": fixed_total_mean,
                "exponential_msync": exp_total_mean,
            },
        }, fh, indent=2)
    return rows


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
