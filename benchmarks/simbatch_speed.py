"""Acceptance smoke + perf baseline for the batched engine.

Four asserted floors at the n=1000 × 32-seed acceptance shape:

* ``backend="jax"`` must be ≥ 5x over a serial per-seed ``simulate()``
  loop for timing-only m-sync under the deterministic ``fixed_sqrt``
  model (ISSUE 2), agreeing with the serial results;
* ``backend="vectorized"`` with ``rng_scheme="counter"`` must be ≥ 4x
  over serial under a *random* model (``exponential`` — ISSUE 3: the
  per-seed stream draws capped the old vectorized backend at ~1.2x);
* the keyed Async draw path (ISSUE 4: one per-worker keyed draw per
  arrival from the pre-split key grid) must be ≥ 1.3x over the PR 3
  full-row draw pattern inside the ``lax.while_loop`` reference engine
  (both reached via ``async_engine="while"``); and
* the **renewal-chain arrival-scan** engine (ISSUE 5: pre-draw chains,
  merge the pool once, O(1) per-arrival transitions — timing-only Async
  is sort-and-slice) must be ≥ 3x over that while_loop engine at
  K=2000 arrivals — measured ~20x here, and faster than the serial
  event heap too, which is what lets ``backend="fastest"``'s cost-model
  router send CPU async sweeps of this scale to jax (the while_loop
  lost ~6x to the heap at the same shape).

The serial baseline already runs the round-vectorized scalar fast path
(~54x over the event loop), so the m-sync floors measure batching gain
on top of it. The JAX backends are timed after one warmup call — the
m-sync fixed program and the timing-only arrival-scan programs are
jit-cached across calls, and the remaining closure-compiled programs
amortize across sweeps of the same shape. The stream-scheme ratio is
reported as context (exact RNG parity, smaller speedup).

``run()`` also writes ``BENCH_simbatch.json`` (per-backend
``speedup_vs_serial`` plus simulated ``total_time_mean`` per benchmark
model): the perf regression gate (``benchmarks/perf_gate.py``, run by
CI) compares it against the committed baseline in
``benchmarks/baselines/``.

``--calibrate`` measures THIS machine's engines and inverts the
``backend="fastest"`` cost model
(:data:`repro.core.batch.COST_CONSTANTS`) for its per-machine
constants, writing a JSON artifact that
:func:`repro.core.batch.load_cost_constants` merges over the hard-coded
container defaults (point ``REPRO_COST_CONSTANTS`` at it, or call the
loader). The defaults only need to get routing ORDERINGS right;
calibrating tightens the boundaries on hosts with very different
serial/jit ratios (e.g. a fast dev box vs a throttled CI runner).
"""

import argparse
import dataclasses
import os
import time

import numpy as np

from repro.core import STRATEGIES, make_strategy, simulate, simulate_batch
from repro.core.batch import load_cost_constants
from repro.core.batch_jax import arrival_scan_work, simulate_batch_jax
from repro.exp import make_scenario

BENCH_JSON = os.environ.get("REPRO_BENCH_JSON", "BENCH_simbatch.json")
CALIB_JSON_DEFAULT = "cost_constants.json"


def run(fast: bool = True):
    # no seeds override: n=1000 × 32 seeds is the acceptance shape
    n, S = 1000, 32
    K = 120 if fast else 600
    m = 10
    spec = ("msync", {"m": m})

    # -------------------------- deterministic model: jax >= 5x (ISSUE 2)
    model = make_scenario("fixed_sqrt", n)
    t0 = time.perf_counter()
    serial = [simulate(STRATEGIES["msync"](m=m), model, K=K, seed=s)
              for s in range(S)]
    t_serial = time.perf_counter() - t0

    simulate_batch(spec, model, K=K, seeds=S, backend="jax")   # JIT warmup
    t_jax = min(_timed(lambda: simulate_batch(spec, model, K=K, seeds=S,
                                              backend="jax"))
                for _ in range(3))
    batch = simulate_batch(spec, model, K=K, seeds=S, backend="jax")
    for s, tr in enumerate(serial):
        bt = batch.traces[0][s]
        assert np.isclose(bt.total_time, tr.total_time, rtol=1e-5), s
        assert bt.gradients_computed == tr.gradients_computed, s
        assert bt.gradients_used == tr.gradients_used, s
    fixed_total_mean = float(np.mean([tr.total_time for tr in serial]))

    t_vec = min(_timed(lambda: simulate_batch(spec, model, K=K, seeds=S,
                                              backend="vectorized"))
                for _ in range(3))

    # ------------------------------ random model: counter >= 4x (ISSUE 3)
    rmodel = make_scenario("exponential", n)
    t0 = time.perf_counter()
    rserial = [simulate(STRATEGIES["msync"](m=m), rmodel, K=K, seed=s)
               for s in range(S)]
    t_rserial = time.perf_counter() - t0
    t_counter = min(_timed(lambda: simulate_batch(
        spec, rmodel, K=K, seeds=S, backend="vectorized",
        rng_scheme="counter")) for _ in range(3))
    t_stream = min(_timed(lambda: simulate_batch(
        spec, rmodel, K=K, seeds=S, backend="vectorized",
        rng_scheme="stream")) for _ in range(3))
    cbatch = simulate_batch(spec, rmodel, K=K, seeds=S,
                            backend="vectorized", rng_scheme="counter")
    exp_total_mean = float(cbatch.total_time.mean())
    # distribution sanity: counter means track the per-seed-stream serial
    # runs (same model, same shape)
    rserial_mean = float(np.mean([tr.total_time for tr in rserial]))
    assert np.isclose(exp_total_mean, rserial_mean, rtol=0.15), \
        (exp_total_mean, rserial_mean)

    # ---------------- keyed async draws: >= 1.3x vs PR 3 row draws (ISSUE 4)
    # both variants run the PR 4 while_loop REFERENCE engine
    # (async_engine="while") — the keyed-vs-rowdraw ratio is a property
    # of that loop's draw plumbing, kept gated so the reference stays
    # honest; the routed engine is the ISSUE 5 arrival scan below
    K_async = 2000
    astrat = make_strategy("async")
    seeds_l = list(range(S))

    def while_engine(model):
        return simulate_batch_jax(astrat, model, K_async, seeds=seeds_l,
                                  async_engine="while")

    # dropping jax_sampler_item reproduces the PR 3 draw pattern exactly:
    # the engine falls back to one full (S, n) row draw per arrival
    rowdraw_model = dataclasses.replace(rmodel, jax_sampler_item=None)
    while_engine(rmodel)
    t_akeyed = min(_timed(lambda: while_engine(rmodel)) for _ in range(3))
    while_engine(rowdraw_model)
    t_arow = min(_timed(lambda: while_engine(rowdraw_model))
                 for _ in range(3))
    t0 = time.perf_counter()
    aserial = [simulate(STRATEGIES["async"](), rmodel, K=K_async, seed=s)
               for s in range(S)]
    t_aserial = time.perf_counter() - t0
    speedup_keyed = t_arow / t_akeyed

    # -------- chain-scan arrival engine: >= 3x vs the while_loop (ISSUE 5)
    simulate_batch("async", rmodel, K=K_async, seeds=S,
                   backend="jax")                          # warm the cache
    t_achain = min(_timed(lambda: simulate_batch(
        "async", rmodel, K=K_async, seeds=S, backend="jax"))
        for _ in range(3))
    abatch = simulate_batch("async", rmodel, K=K_async, seeds=S,
                            backend="jax")
    async_total_mean = float(abatch.total_time.mean())
    # distribution sanity vs the serial event engine
    aserial_mean = float(np.mean([tr.total_time for tr in aserial]))
    assert np.isclose(async_total_mean, aserial_mean, rtol=0.15), \
        (async_total_mean, aserial_mean)
    speedup_chain = t_akeyed / t_achain

    # ---- cost-model router: the routed backend must actually be fastest
    fb = simulate_batch("async", rmodel, K=K_async, seeds=S,
                        backend="fastest")
    routed = fb.routing[0]["chosen"]
    assert fb.backend == routed, (fb.backend, fb.routing)
    alt = "serial" if routed == "jax" else "jax"
    t_routed = min(_timed(lambda: simulate_batch(
        "async", rmodel, K=K_async, seeds=S, backend=routed))
        for _ in range(3))
    t_alt = {"serial": t_aserial, "jax": t_achain}[alt]
    routed_vs_alt = t_alt / t_routed

    speedup = t_serial / t_jax
    speedup_counter = t_rserial / t_counter
    rows = [
        (f"simbatch/n={n}/S={S}/serial_s", t_serial, f"K={K} m={m}"),
        (f"simbatch/n={n}/S={S}/jax_batch_s", t_jax,
         f"speedup={speedup:.1f}x (warm)"),
        (f"simbatch/n={n}/S={S}/numpy_batch_s", t_vec,
         f"speedup={t_serial / t_vec:.1f}x (fixed model)"),
        ("simbatch/speedup_vs_serial", speedup,
         "acceptance: >= 5x, results identical"),
        (f"simbatch/exp/n={n}/S={S}/serial_s", t_rserial, f"K={K} m={m}"),
        (f"simbatch/exp/n={n}/S={S}/counter_s", t_counter,
         f"speedup={speedup_counter:.1f}x (Philox tensor draws)"),
        (f"simbatch/exp/n={n}/S={S}/stream_s", t_stream,
         f"speedup={t_rserial / t_stream:.1f}x (exact RNG parity)"),
        ("simbatch/counter_speedup_vs_serial", speedup_counter,
         "acceptance: >= 4x on a random model"),
        (f"simbatch/async/n={n}/S={S}/while_keyed_s", t_akeyed,
         f"K={K_async} while_loop reference, one keyed draw per arrival"),
        (f"simbatch/async/n={n}/S={S}/while_rowdraw_s", t_arow,
         "PR 3 draw pattern: full (S, n) row per arrival"),
        (f"simbatch/async/n={n}/S={S}/chain_scan_s", t_achain,
         f"ISSUE 5 arrival scan: speedup={speedup_chain:.1f}x vs while"),
        (f"simbatch/async/n={n}/S={S}/serial_s", t_aserial,
         f"serial event loop; chain scan is "
         f"{t_aserial / t_achain:.1f}x faster"),
        ("simbatch/async_keyed_speedup_vs_rowdraw", speedup_keyed,
         "acceptance: >= 1.3x (draw volume cut ~n x)"),
        ("simbatch/async_chain_speedup_vs_while", speedup_chain,
         "acceptance: >= 3x (merge once + O(1) transitions)"),
        (f"simbatch/async/routed={routed}", t_routed,
         f"cost-model pick beats {alt} by {routed_vs_alt:.1f}x"),
    ]
    assert speedup >= 5.0, (
        f"simulate_batch jax backend only {speedup:.1f}x over the serial "
        f"per-seed loop (need >= 5x)")
    assert speedup_counter >= 4.0, (
        f"vectorized backend with rng_scheme='counter' only "
        f"{speedup_counter:.1f}x over serial on the exponential model "
        f"(need >= 4x)")
    assert speedup_keyed >= 1.3, (
        f"keyed async draws only {speedup_keyed:.2f}x over the PR 3 "
        f"row-draw pattern (need >= 1.3x)")
    assert speedup_chain >= 3.0, (
        f"arrival-scan async engine only {speedup_chain:.2f}x over the "
        f"PR 4 while_loop reference (need >= 3x)")
    assert routed_vs_alt >= 1.0, (
        f"backend='fastest' routed async to {routed}, but {alt} is "
        f"{1.0 / routed_vs_alt:.2f}x faster — cost model miscalibrated")

    from repro.exp.runner import atomic_write_json
    atomic_write_json(BENCH_JSON, {
            "meta": {"n": n, "S": S, "K": K, "m": m, "fast": fast,
                     "K_async": K_async, "async_engine": "scan",
                     "async_routed": routed},
            "speedup_vs_serial": {
                "jax": speedup,
                "vectorized_fixed": t_serial / t_vec,
                "vectorized_counter": speedup_counter,
                "vectorized_stream": t_rserial / t_stream,
                "async_keyed_vs_rowdraw": speedup_keyed,
                "async_chain_vs_while": speedup_chain,
                "async_chain_vs_serial": t_aserial / t_achain,
                "async_routed_vs_alt": routed_vs_alt,
            },
            "total_time_mean": {
                "fixed_sqrt_msync": fixed_total_mean,
                "exponential_msync": exp_total_mean,
                "exponential_async": async_total_mean,
            },
        })
    return rows


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def calibrate(out: str = CALIB_JSON_DEFAULT):
    """Measure this machine's engines and solve
    :func:`repro.core.batch.estimate_backend_seconds` for its constants.

    Each constant is recovered from the engine whose cost formula it
    dominates, at a shape where that term IS dominant (so the inversion
    is well-conditioned): serial m-sync → ``np_elem``, serial async
    event loop → ``heap_event``, counter-vectorized m-sync →
    ``vec_elem``, warm jit-cached FixedTimes m-sync → ``jax_elem``,
    cold-minus-warm closure-compiled m-sync → ``jit_compile``, warm
    timing-only Async arrival scan → ``pool_elem``, warm Ringmaster
    minus its pool term → ``scan_step``, warm Rennala renewal-round
    scan → ``round_elem`` (prices the rennala/malenia/ringleader
    family). ``accel_speedup`` is left to
    the default — there is nothing to measure on a CPU-only host, and
    :func:`load_cost_constants` fills any key the artifact omits.

    Writes ``{"meta": ..., "constants": ...}`` to ``out`` (the shape
    ``load_cost_constants`` consumes) and round-trips it through the
    loader as a self-check. Returns harness rows.
    """
    n, S, K, m = 400, 8, 100, 8
    K_async = 1500
    work = float(S) * K * n
    rmodel = make_scenario("exponential", n)
    fmodel = make_scenario("fixed_sqrt", n)
    spec = ("msync", {"m": m})

    t_serial = _timed(lambda: [
        simulate(STRATEGIES["msync"](m=m), rmodel, K=K, seed=s)
        for s in range(S)])
    np_elem = t_serial / work

    t_heap = _timed(lambda: [
        simulate(STRATEGIES["async"](), rmodel, K=K_async, seed=s)
        for s in range(S)])
    heap_event = t_heap / (S * K_async)

    t_vec = min(_timed(lambda: simulate_batch(
        spec, rmodel, K=K, seeds=S, backend="vectorized",
        rng_scheme="counter")) for _ in range(3))
    vec_elem = t_vec / work

    # FixedTimes timing program is module-cached: warm time is pure
    # scanned compute, the jax_elem term alone
    simulate_batch(spec, fmodel, K=K, seeds=S, backend="jax")
    t_jax_warm = min(_timed(lambda: simulate_batch(
        spec, fmodel, K=K, seeds=S, backend="jax")) for _ in range(3))
    jax_elem = t_jax_warm / work

    # random-model program is closure-compiled: first call at a fresh
    # shape pays the compile the cost model charges per call
    t_jax_cold = _timed(lambda: simulate_batch(
        spec, rmodel, K=K, seeds=S, backend="jax"))
    t_jax_rwarm = min(_timed(lambda: simulate_batch(
        spec, rmodel, K=K, seeds=S, backend="jax")) for _ in range(3))
    jit_compile = max(t_jax_cold - t_jax_rwarm, 0.05)

    pool, _ = arrival_scan_work(rmodel, n, K_async, ringmaster=False,
                                max_delay=0)
    simulate_batch("async", rmodel, K=K_async, seeds=S, backend="jax")
    t_async = min(_timed(lambda: simulate_batch(
        "async", rmodel, K=K_async, seeds=S, backend="jax"))
        for _ in range(3))
    pool_elem = t_async / (S * pool)

    md = 8
    rspec = ("ringmaster", {"max_delay": md})
    pool_r, window = arrival_scan_work(rmodel, n, K_async, ringmaster=True,
                                       max_delay=md)
    simulate_batch(rspec, rmodel, K=K_async, seeds=S, backend="jax")
    t_ring = min(_timed(lambda: simulate_batch(
        rspec, rmodel, K=K_async, seeds=S, backend="jax"))
        for _ in range(3))
    scan_step = max((t_ring - S * pool_r * pool_elem)
                    / (window * (S / 32.0)), 1e-8)

    # warm rennala renewal-round scan → round_elem: the whole
    # rennala/malenia/ringleader family prices per scanned pool element
    # (elems = S*K*n*batch for rennala), and the warm AOT-cached call is
    # pure compute, so the inversion is direct
    B_cal = 8
    renn = ("rennala", {"batch": B_cal})
    simulate_batch(renn, rmodel, K=K, seeds=S, backend="jax")
    t_renn = min(_timed(lambda: simulate_batch(
        renn, rmodel, K=K, seeds=S, backend="jax")) for _ in range(3))
    round_elem = t_renn / (work * B_cal)

    constants = {
        "np_elem": np_elem, "heap_event": heap_event,
        "vec_elem": vec_elem, "jax_elem": jax_elem,
        "jit_compile": jit_compile, "pool_elem": pool_elem,
        "scan_step": scan_step, "round_elem": round_elem,
    }
    from repro.exp.runner import atomic_write_json
    atomic_write_json(out, {"meta": {"n": n, "S": S, "K": K, "m": m,
                                     "K_async": K_async,
                                     "source": "simbatch_speed --calibrate"},
                            "constants": constants})
    # self-check: the loader must pick up every measured key
    merged = load_cost_constants(out, apply=False)
    for key, val in constants.items():
        assert merged[key] == val, (key, merged[key], val)
    assert merged["accel_speedup"] > 0          # default fills the gap

    return [(f"calibrate/{k}", v, f"written to {out}")
            for k, v in constants.items()]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--calibrate", action="store_true",
                    help="measure per-machine cost-model constants")
    ap.add_argument("--out", default=CALIB_JSON_DEFAULT,
                    help="calibration JSON path (with --calibrate)")
    args = ap.parse_args()
    rows = calibrate(args.out) if args.calibrate else run()
    for name, val, derived in rows:
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
