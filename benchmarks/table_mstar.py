"""Propositions 4.1/4.2: optimal active-worker selection table.

For each tau law and noise level: the exact argmin of g(m), the Prop 4.2
closed-form choice min(ceil(sigma^2/eps), n), and the g-ratio between
them (1.0 = the closed form is exactly optimal)."""

import numpy as np

from repro.core import FixedTimes, g_of_m, optimal_m, power_law_m


def run(fast: bool = True):
    n = 1000
    rows = []
    eps = 1.0
    for law, taus in {
        "sqrt": FixedTimes.sqrt_law(n).taus,
        "linear": FixedTimes.linear(n).taus,
        "pow0.5+delta": FixedTimes.power_law(
            n, 0.5, 1.0, np.random.default_rng(0).uniform(0, 2.0, n)).taus,
        "pow1.2": FixedTimes.power_law(n, 1.2).taus,
        "const": np.ones(n),
    }.items():
        for s2e in (0.5, 10.0, 100.0, 10000.0):
            sigma2 = s2e * eps
            g = g_of_m(np.sort(taus), sigma2, eps)
            m_exact = optimal_m(taus, sigma2, eps)
            m_prop = power_law_m(n, sigma2, eps)
            ratio = g[m_prop - 1] / g[m_exact - 1]
            rows.append((f"mstar/{law}/s2e={s2e}/g_ratio", ratio,
                         f"m_exact={m_exact} m_prop42={m_prop}"))
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
