"""Shared timeout+retry runner for JSON-emitting worker subprocesses.

Two lanes spawn Python workers and parse their last stdout line as JSON:
the ``sweep_scaling`` benchmark (one worker per forced XLA device
count) and the 4-device sharded-sweep test lane. Both used to hand-roll
``subprocess.run`` — and the test lane had NO deadline, so a hung XLA
compile stalled CI forever and a transient compile-cache miss flaked it.

:func:`run_json_worker` is the one shared spelling: a wall-clock
deadline per attempt, ``attempts`` tries (compile-cache warmup makes a
second attempt much cheaper — the dominant flake mode), and a final
:class:`RuntimeError` that carries the tail of the worker's
stdout/stderr as the diagnostic instead of a bare ``TimeoutExpired``.
"""

from __future__ import annotations

import json
import subprocess
from typing import Dict, List, Optional

__all__ = ["run_json_worker", "DEFAULT_TIMEOUT_S", "DEFAULT_ATTEMPTS"]

#: per-attempt wall-clock ceiling; a hung XLA compile would otherwise
#: stall the whole lane forever
DEFAULT_TIMEOUT_S = 600

#: total tries per worker (first failure is usually compile-cache cold)
DEFAULT_ATTEMPTS = 2


def _tail(text: Optional[str], limit: int = 2000) -> str:
    return (text or "")[-limit:]


def run_json_worker(argv: List[str], *, label: str,
                    env: Optional[Dict[str, str]] = None,
                    cwd: Optional[str] = None,
                    timeout_s: float = DEFAULT_TIMEOUT_S,
                    attempts: int = DEFAULT_ATTEMPTS) -> dict:
    """Run ``argv``; parse the LAST stdout line as JSON.

    Retries on timeout, nonzero exit, or unparseable output (each
    attempt gets a fresh ``timeout_s`` deadline). Raises
    ``RuntimeError`` naming ``label`` with the last attempt's
    stdout/stderr tails once ``attempts`` are exhausted.
    """
    last_err = None
    for attempt in range(1, attempts + 1):
        try:
            proc = subprocess.run(argv, capture_output=True, text=True,
                                  env=env, cwd=cwd, timeout=timeout_s)
        except subprocess.TimeoutExpired as exc:
            last_err = (f"timed out after {timeout_s}s "
                        f"(attempt {attempt}):\n{_tail(exc.stdout)}\n"
                        f"{_tail(exc.stderr)}")
            continue
        if proc.returncode != 0:
            last_err = (f"exit {proc.returncode} (attempt {attempt}):\n"
                        f"{_tail(proc.stdout)}\n{_tail(proc.stderr)}")
            continue
        lines = proc.stdout.strip().splitlines()
        try:
            return json.loads(lines[-1])
        except (IndexError, ValueError):
            last_err = (f"no JSON on last stdout line "
                        f"(attempt {attempt}):\n{_tail(proc.stdout)}\n"
                        f"{_tail(proc.stderr)}")
            continue
    raise RuntimeError(
        f"{label} failed {attempts}x; last: {last_err}")
