"""Time-complexity atlas: every contender x every heterogeneity regime.

The head-to-head race the source paper argues about but never runs: the
synchronous family (m-sync at the Prop 4.1 ``m*``, Rennala, Malenia)
against the async rivals (plain Async, Ringmaster, Ringleader — arXiv
2509.22860 — and the Maranjyan optimal-ASGD line, arXiv 2601.02523)
across fixed, bimodal-straggler, heterogeneous-exponential, heavy-tail,
universal and fault-wrapped compute regimes. Every cell reports wall
seconds per USEFUL gradient (total time / gradients the server applied)
— the time-complexity currency of the paper — so the artifact is an
empirical map of the "async may be necessary" boundary.

Per-strategy horizons equalize the useful-gradient budget (one-per-step
methods run ``m* x`` longer), so cells are rate comparisons, not
equal-step comparisons. ``run()`` asserts the two structural facts the
map must show (and CI gates on): at least one regime where a waste-free
async rival beats m-sync, and at least one where m-sync beats an async
rival — the paper's "it depends on the regime" thesis in one JSON.

``run()`` writes ``BENCH_atlas.json`` (atomic write; override the path
via ``REPRO_BENCH_ATLAS_JSON``). Deterministic at fixed ``(n, K,
seeds)``: the smoke scale routes below the jax probe floor, so every
cell runs the seeded NumPy engines.
"""

import os

from repro.core import optimal_m
from repro.exp import make_scenario, run_experiment
from repro.exp.runner import atomic_write_json

BENCH_JSON = os.environ.get("REPRO_BENCH_ATLAS_JSON", "BENCH_atlas.json")

#: regime name -> (scenario, scenario_kwargs) — one column per family
#: the ISSUE names: fixed, bimodal straggler, heterogeneous
#: exponential, heavy tail, universal, fault-wrapped
REGIMES = [
    ("fixed", "fixed_sqrt", {}),
    ("bimodal", "fixed_bimodal", {}),
    ("exp_het", "exp_het", {}),
    # the skewed-rate regime the ragged chain layout exists for: mean
    # rates span n^alpha, so per-worker chain budgets differ by the
    # same factor (benchmarks/chain_layout.py measures the layout win)
    ("powerlaw", "exp_powerlaw", {}),
    # alpha=2.5 keeps the tail genuinely polynomial (R = inf) while the
    # wait-for-everyone strategies (Malenia, Ringleader) stay runnable
    # at smoke scale — alpha=1.5 spikes make single rounds cost
    # thousands of events
    ("heavy_tail", "heavy_tail_spikes", {"alpha": 2.5}),
    # figure-4 grid: rates stay bounded away from zero, so
    # wait-for-everyone rounds terminate; the figure-3 grid stalls
    # workers outright and degenerates those cells
    ("universal", "universal_fig4", {}),
    ("faulty", "crash_restart", {}),
]


def _m_star(scen: str, scen_kw: dict, n: int) -> int:
    """Prop 4.1 ``m*`` from the regime's own mean compute times
    (universal models carry no closed-form means: fall back to the
    paper's canonical sqrt ladder)."""
    model = make_scenario(scen, n, **scen_kw)
    try:
        taus = model.mean_times()
    except AttributeError:
        taus = make_scenario("fixed_sqrt", n).taus
    return max(int(optimal_m(taus, 100.0, 1.0)), 1)


def _strategies(m_star: int):
    """(name, spec, K multiplier): one-useful-gradient-per-step methods
    get ``m*`` times the horizon so every cell spends a comparable
    useful-gradient budget. Malenia reports the same per-gradient RATE
    from a tenth of the horizon — its serial event count per round is
    ``n x`` the straggler wait, so a full-K cell would dominate the
    whole benchmark's wall time."""
    return [
        (f"msync_m{m_star}", ("msync", {"m": m_star}), 1.0),
        (f"rennala_b{m_star}", ("rennala", {"batch": m_star}), 1.0),
        ("malenia", ("malenia", {"S": float(m_star)}), 0.1),
        ("async", ("async", {}), float(m_star)),
        ("ringmaster", ("ringmaster", {"max_delay": 1}), float(m_star)),
        ("ringleader", ("ringleader", {}), 1.0),
        ("optimal_asgd", ("optimal_asgd", {}), float(m_star)),
    ]


def run(fast: bool = True, seeds: int = 6):
    n = 32 if fast else 256
    K = 100 if fast else 500
    rows = []
    metrics = {}
    for regime, scen, scen_kw in REGIMES:
        m_star = _m_star(scen, scen_kw, n)
        cells = {}
        for sname, spec, k_mult in _strategies(m_star):
            K_cell = max(int(round(K * k_mult)), 10)
            res = run_experiment(spec, scen, n, K_cell, seeds=seeds,
                                 scenario_kwargs=scen_kw)
            r = res.rows[0]
            spg = r["s_per_useful_grad_mean"]
            key = sname.split("_m")[0].split("_b")[0] \
                if sname.startswith(("msync", "rennala")) else sname
            cells[key] = spg
            metrics[f"{regime}/{key}"] = spg
            rows.append((
                f"atlas/{regime}/{key}/s_per_useful_grad",
                spg,
                f"±{r['s_per_useful_grad_std']:.4g} over {r['seeds']} "
                f"seeds m*={m_star} "
                f"discard={r['discard_fraction_mean']:.2f} "
                f"backend={r['backend']}"))
        best_async = min(cells["ringleader"], cells["optimal_asgd"],
                         cells["async"])
        rows.append((f"atlas/{regime}/async_over_msync",
                     best_async / cells["msync"],
                     f"best async rival vs m-sync (<1: async wins)"))

    # the two structural facts the atlas exists to show — the paper's
    # "regime-dependent" thesis, now empirical and CI-gated:
    # (1) heterogeneous-exponential regime: a waste-free async rival
    #     (Ringleader / optimal ASGD) beats m-sync on seconds per
    #     useful gradient (observed ~4x at smoke scale)
    assert min(metrics["exp_het/ringleader"],
               metrics["exp_het/optimal_asgd"]) \
        < metrics["exp_het/msync"], (
        "atlas: no async rival beats m-sync in the heterogeneous "
        "exponential regime — the async-necessary half of the map "
        "vanished")
    # (2) deterministic sqrt regime: the discard-heavy rival (Ringmaster
    #     at max_delay=1) pays for its waste and m-sync wins (~4x)
    assert metrics["fixed/msync"] < metrics["fixed/ringmaster"], (
        "atlas: m-sync no longer beats the discard-heavy Ringmaster in "
        "the fixed sqrt regime — the sync-near-optimal half of the map "
        "vanished")
    atlas_meta = {"n": n, "K": K, "seeds": seeds, "fast": fast,
                  "regimes": [r[0] for r in REGIMES]}
    atomic_write_json(BENCH_JSON, {
        "meta": atlas_meta,
        "s_per_useful_grad_mean": metrics,
    })
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
