"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,derived`` CSV. ``--slow`` runs the paper-scale
versions (n=1000 etc.); default is the fast CI-friendly scale.

Modules:
  fig5_quadratic     Figure 5 (quadratic, n workers, tau=sqrt(i))
  fig8_grid          Figures 8/9 grids (K.1/K.2)
  thm23_logfactor    Theorem 2.3 log-factor table
  thm32_random       Theorem 3.2 E[T_rand] vs bound (random models)
  sec53_gap          §5.3 numerical gap ratios (Figures 3/4) vs paper
  sec6_async_needed  §6/I asynchronicity-needed example
  table_mstar        Propositions 4.1/4.2 m* selection table
  malenia_het        §6 heterogeneous (Malenia) constant-gap table
  sec6_heterogeneous §6 worker-exclusive f_i: m-Sync plateaus, Malenia works
  secj_R_estimation  §J sub-exponential R of real step times
  ablation_m_sweep   measured T(m) vs Theorem 2.3 closed form + Prop 4.1 m*
  thm55_participation  Theorem 5.5 window under the rotating adversary

Simulator-backed modules select methods through the composable Strategy
API (``repro.core.strategies``): ``simulate(STRATEGIES[name](...), ...)``.
"""

from __future__ import annotations

import argparse
import sys
import time

from . import (ablation_m_sweep, fig5_quadratic, fig8_grid, malenia_het,
               sec6_async_needed, sec6_heterogeneous, sec53_gap,
               secj_R_estimation, table_mstar, thm23_logfactor,
               thm32_random, thm55_participation)

MODULES = [
    ("fig5_quadratic", fig5_quadratic),
    ("thm23_logfactor", thm23_logfactor),
    ("table_mstar", table_mstar),
    ("sec53_gap", sec53_gap),
    ("thm32_random", thm32_random),
    ("sec6_async_needed", sec6_async_needed),
    ("malenia_het", malenia_het),
    ("fig8_grid", fig8_grid),
    ("secj_R_estimation", secj_R_estimation),
    ("ablation_m_sweep", ablation_m_sweep),
    ("thm55_participation", thm55_participation),
    ("sec6_heterogeneous", sec6_heterogeneous),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slow", action="store_true",
                    help="paper-scale runs (n=1000, long horizons)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,value,derived")
    failures = 0
    for name, mod in MODULES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            rows = mod.run(fast=not args.slow)
            for rname, val, derived in rows:
                print(f"{rname},{val},{derived}", flush=True)
            print(f"_timing/{name},{time.time() - t0:.1f},seconds",
                  flush=True)
        except Exception as e:  # keep the harness going; report at exit
            failures += 1
            print(f"_error/{name},{type(e).__name__},{e}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
