"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,derived`` CSV. ``--slow`` runs the paper-scale
versions (n=1000 etc.); default is the fast CI-friendly scale.
``--seeds N`` overrides the seed-sweep width of the experiment-layer
modules (those whose ``run()`` accepts a ``seeds`` kwarg); ``--json``
additionally writes every row plus per-module timings as a JSON artifact
(uploaded by CI).

Modules:
  fig5_quadratic     Figure 5 (quadratic, n workers, tau=sqrt(i))
  fig8_grid          Figures 8/9 grids (K.1/K.2)
  thm23_logfactor    Theorem 2.3 log-factor table
  thm32_random       Theorem 3.2 E[T_rand] vs bound (random models)
  sec53_gap          §5.3 numerical gap ratios (Figures 3/4) vs paper
  sec6_async_needed  §6/I asynchronicity-needed example
  table_mstar        Propositions 4.1/4.2 m* selection table
  malenia_het        §6 heterogeneous (Malenia) constant-gap table
  sec6_heterogeneous §6 worker-exclusive f_i: m-Sync plateaus, Malenia works
  secj_R_estimation  §J sub-exponential R of real step times
  ablation_m_sweep   measured T(m) vs Theorem 2.3 closed form + Prop 4.1 m*
  thm55_participation  Theorem 5.5 window under the rotating adversary
  simbatch_speed     simulate_batch jax >= 5x / counter >= 4x acceptance
                     smokes; writes the BENCH_simbatch.json perf baseline
  chain_layout       rectangular vs ragged vs windowed renewal pools on
                     the power-law regime (ragged >= 3x fewer elements);
                     merges its lanes into BENCH_simbatch.json
  sweep_scaling      backend="jax_sharded" vs unsharded sweep speedup at
                     forced device counts (subprocess per XLA_FLAGS
                     setting); writes the BENCH_sweep.json perf baseline
  fault_frontier     strategy race across the §3c fault regimes
                     (crash/slowdown/bursts/spikes/mix) vs fault-free;
                     writes BENCH_fault_frontier.json
  atlas              head-to-head time-complexity atlas: sync family vs
                     async rivals (Ringleader, optimal ASGD, ...) across
                     six heterogeneity regimes; writes BENCH_atlas.json
  order_stats_speed  Pallas top-m kernel vs lax.top_k vs iterative
                     extraction at n in {1e3, 1e5}

Simulator-backed modules run through the experiment layer
(``repro.exp.run_experiment``): strategies × scenarios × seed sweeps via
the batched engine, reporting mean ± std across seeds.
"""

from __future__ import annotations

import argparse
import inspect
import sys
import time

from . import (ablation_m_sweep, atlas, chain_layout, fault_frontier,
               fig5_quadratic, fig8_grid, malenia_het, order_stats_speed,
               sec6_async_needed, sec6_heterogeneous, sec53_gap,
               secj_R_estimation, simbatch_speed, sweep_scaling,
               table_mstar, thm23_logfactor, thm32_random,
               thm55_participation)

MODULES = [
    ("fig5_quadratic", fig5_quadratic),
    ("thm23_logfactor", thm23_logfactor),
    ("table_mstar", table_mstar),
    ("sec53_gap", sec53_gap),
    ("thm32_random", thm32_random),
    ("sec6_async_needed", sec6_async_needed),
    ("malenia_het", malenia_het),
    ("fig8_grid", fig8_grid),
    ("secj_R_estimation", secj_R_estimation),
    ("ablation_m_sweep", ablation_m_sweep),
    ("thm55_participation", thm55_participation),
    ("sec6_heterogeneous", sec6_heterogeneous),
    ("fault_frontier", fault_frontier),
    ("atlas", atlas),
    ("simbatch_speed", simbatch_speed),
    ("chain_layout", chain_layout),
    ("order_stats_speed", order_stats_speed),
    ("sweep_scaling", sweep_scaling),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slow", action="store_true",
                    help="paper-scale runs (n=1000, long horizons)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--seeds", type=int, default=None,
                    help="seed-sweep width for experiment-layer modules")
    ap.add_argument("--json", default=None,
                    help="also write rows + timings to this JSON file")
    args = ap.parse_args()

    print("name,value,derived")
    failures = 0
    all_rows = []
    timings = {}
    for name, mod in MODULES:
        if args.only and args.only not in name:
            continue
        kwargs = {"fast": not args.slow}
        if args.seeds is not None \
                and "seeds" in inspect.signature(mod.run).parameters:
            kwargs["seeds"] = args.seeds
        t0 = time.time()
        try:
            rows = mod.run(**kwargs)
            for rname, val, derived in rows:
                print(f"{rname},{val},{derived}", flush=True)
                all_rows.append({"name": rname, "value": val,
                                 "derived": derived})
            timings[name] = time.time() - t0
            print(f"_timing/{name},{timings[name]:.1f},seconds",
                  flush=True)
        except Exception as e:  # keep the harness going; report at exit
            failures += 1
            print(f"_error/{name},{type(e).__name__},{e}", flush=True)
            all_rows.append({"name": f"_error/{name}",
                             "value": type(e).__name__, "derived": str(e)})
    if args.json:
        from repro.exp.runner import atomic_write_json, sanitize_json
        atomic_write_json(args.json, sanitize_json(
            {"meta": {"slow": args.slow, "seeds": args.seeds,
                      "only": args.only, "failures": failures},
             "timings_s": timings,
             "rows": all_rows}), default=str)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
