"""Ablation: measured m-Sync wall-clock vs the Theorem 2.3 prediction.

For tau_i = sqrt(i), sweep m and compare the SIMULATED time of K(m)
iterations (event simulator, exact accounting) against the closed form
K(m) * tau_m = 16 max(LΔ/ε, σ²LΔ/(mε²)) * tau_m, and check the measured
minimizer sits at the Prop 4.1 m*."""

import numpy as np

from repro.core import STRATEGIES, FixedTimes, optimal_m, simulate
from repro.core.complexity import iteration_complexity


def run(fast: bool = True):
    n = 64
    model = FixedTimes.sqrt_law(n)
    L = Delta = 1.0
    eps, sigma2 = 0.05, 2.0              # sigma^2/eps = 40
    m_star = optimal_m(model.taus, sigma2, eps)
    rows = []
    measured = {}
    for m in sorted({1, 2, 4, 8, 16, 32, 64, m_star}):
        K = iteration_complexity(L, Delta, eps, sigma2, m)
        K_sim = min(K, 80)               # time is additive in K
        t = simulate(STRATEGIES["msync"](m=m), model, K=K_sim).total_time
        total = t / K_sim * K
        theory = K * float(np.sort(model.taus)[m - 1])
        measured[m] = total
        rows.append((f"msweep/m={m}/sim_seconds", total,
                     f"theory={theory:.0f} K={K}"))
    best = min(measured, key=measured.get)
    rows.append(("msweep/measured_argmin_m", best,
                 f"prop41_mstar={m_star} "
                 f"ratio={measured[best] / measured[m_star]:.3f}"))
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
