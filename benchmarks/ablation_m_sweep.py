"""Ablation: measured m-Sync wall-clock vs the Theorem 2.3 prediction.

For tau_i = sqrt(i), sweep m and compare the SIMULATED time of K(m)
iterations (event simulator, exact accounting) against the closed form
K(m) * tau_m = 16 max(LΔ/ε, σ²LΔ/(mε²)) * tau_m, and check the measured
minimizer sits at the Prop 4.1 m*. The whole m grid runs as one
``run_experiment`` sweep at a fixed K_sim = 80 rounds (time is additive
in K, so each m's total is extrapolated to its own K(m) budget)."""

import numpy as np

from repro.core import optimal_m
from repro.core.complexity import iteration_complexity
from repro.exp import make_scenario, run_experiment


def run(fast: bool = True, seeds: int = 8):
    n = 64
    model = make_scenario("fixed_sqrt", n)
    L = Delta = 1.0
    eps, sigma2 = 0.05, 2.0              # sigma^2/eps = 40
    m_star = optimal_m(model.taus, sigma2, eps)
    ms = sorted({1, 2, 4, 8, 16, 32, 64, m_star})
    Ks = {m: iteration_complexity(L, Delta, eps, sigma2, m) for m in ms}
    # time is additive in K: simulate K_sim = 80 rounds (< K(m) for every
    # m here) in one vectorized m-grid sweep and extrapolate to K(m)
    res = run_experiment("msync", model, n=n, K=80, seeds=seeds,
                         grid={"m": ms})
    rows = []
    measured = {}
    for r in res.rows:
        m = r["params"]["m"]
        K, K_sim = Ks[m], 80
        total = r["total_time_mean"] / K_sim * K
        theory = K * float(np.sort(model.taus)[m - 1])
        measured[m] = total
        rows.append((f"msweep/m={m}/sim_seconds", total,
                     f"±{r['total_time_std'] / K_sim * K:.4g} over "
                     f"{r['seeds']} seeds theory={theory:.0f} K={K}"))
    best = min(measured, key=measured.get)
    rows.append(("msweep/measured_argmin_m", best,
                 f"prop41_mstar={m_star} "
                 f"ratio={measured[best] / measured[m_star]:.3f}"))
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
