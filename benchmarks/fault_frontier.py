"""Fault frontier: strategy race across the DESIGN §3c fault regimes.

Races the paper's contenders — m-sync at the Prop 4.1 ``m*``, Rennala
at ``batch=m*`` and plain Async — on Exp(1) workers under each fault
regime (crash/restart, transient slowdown episodes, correlated bursts,
heavy-tail spikes and the stacked ``faulty_mix``), against the
fault-free exponential baseline. Reported per (regime, strategy):
per-useful-gradient wall time, its degradation ratio over the
fault-free run of the same strategy, and the discard fraction.

This is the robustness claim behind the fault subsystem: the paper's
near-optimality argument for synchronous methods is about *renewal*
computation times, and every §3c fault transformation preserves the
renewal structure — so the m-sync vs async ranking should degrade
gracefully, not invert, as faults are layered on. The run asserts only
sanity (faulted regimes are never *faster* in mean than the baseline
beyond noise); the ranking itself is data for the JSON artifact.

``run()`` writes ``BENCH_fault_frontier.json`` (atomic write, like
every benchmark artifact) with the per-cell means for offline
comparison.
"""

import os

from repro.core import optimal_m
from repro.exp import make_scenario, run_experiment
from repro.exp.runner import atomic_write_json

BENCH_JSON = os.environ.get("REPRO_BENCH_FAULT_JSON",
                            "BENCH_fault_frontier.json")

#: regime name -> (scenario, scenario_kwargs); "none" is the fault-free
#: baseline every ratio is computed against
REGIMES = [
    ("none", "exponential", {}),
    ("crash", "crash_restart", {}),
    ("slowdown", "transient_slowdown", {}),
    ("bursts", "correlated_bursts", {}),
    ("spikes", "heavy_tail_spikes", {}),
    ("mix", "faulty_mix", {}),
]


def _strategies(n: int):
    base = make_scenario("exponential", n)
    m_star = optimal_m(base.taus, 100.0, 1.0)
    m_star = max(int(m_star), 1)
    return [
        (f"msync_m{m_star}", ("msync", {"m": m_star}), 1),
        (f"rennala_b{m_star}", ("rennala", {"batch": m_star}), 1),
        ("async", ("async", {}), max(m_star, 1)),
    ]


def run(fast: bool = True, seeds: int = 8):
    n = 32 if fast else 256
    K = 120 if fast else 600
    rows = []
    metrics = {}
    baseline = {}
    for regime, scen, scen_kw in REGIMES:
        for sname, spec, k_mult in _strategies(n):
            res = run_experiment(spec, scen, n, K * k_mult, seeds=seeds,
                                 scenario_kwargs=scen_kw)
            r = res.rows[0]
            spg = r["s_per_useful_grad_mean"]
            metrics[f"{regime}/{sname}"] = spg
            if regime == "none":
                baseline[sname] = spg
                ratio = 1.0
            else:
                ratio = spg / baseline[sname]
            rows.append((
                f"fault_frontier/{regime}/{sname}/s_per_useful_grad",
                spg,
                f"±{r['s_per_useful_grad_std']:.4g} over {r['seeds']} "
                f"seeds x{ratio:.2f} vs fault-free "
                f"discard={r['discard_fraction_mean']:.2f} "
                f"backend={r['backend']}"))
            # sanity: adding faults never speeds a strategy up in mean
            # (generous slack: seeds are few at CI scale)
            assert ratio > 0.8, (
                f"{regime}/{sname}: faulted run {ratio:.2f}x the "
                f"fault-free per-gradient time — fault layer is "
                f"removing work?")
    atomic_write_json(BENCH_JSON, {
        "meta": {"n": n, "K": K, "seeds": seeds, "fast": fast,
                 "regimes": [r[0] for r in REGIMES]},
        "s_per_useful_grad_mean": metrics,
    })
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
