"""Section 6/Appendix I: the example where asynchronicity IS needed.

All workers have power v; after t̄ = 1/v the first worker becomes
(nearly) infinitely fast. m-Sync(m=n) keeps paying 1/v per round while the
asynchronous lower bound collapses to O(1/v) TOTAL. We evaluate both
recursions and report the growing gap as sigma^2/eps scales."""

import numpy as np

from repro.core import UniversalModel, lower_bound_recursion
from repro.core.complexity import msync_upper_recursion


def _model(n=10, v=1.0, fast_power=1e6, t_max=4000.0):
    grid = np.arange(0.0, t_max, 0.05)
    powers = np.full((n, len(grid)), v)
    powers[0, grid > 1.0 / v] = fast_power
    return UniversalModel(grid, powers)


def run(fast: bool = True):
    rows = []
    L = Delta = eps = 1.0
    for s2e in (100.0, 1000.0):
        model = _model()
        ub = msync_upper_recursion(model, L, Delta, eps, s2e * eps,
                                   m=model.n, n_grads=1.0)
        lb = lower_bound_recursion(model, L, Delta, eps, s2e * eps)
        rows.append((f"sec6/s2e={int(s2e)}/msync_over_lower", ub / lb,
                     f"ub={ub:.2f}s lb={lb:.2f}s (async adapts, sync "
                     "cannot; gap grows with sigma^2/eps)"))
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
