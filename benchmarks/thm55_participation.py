"""Theorem 5.5 ablation: m-Sync under the rotating partial-participation
adversary (Assumption 5.4). For p < 0.4, any m in [n/5, (1-2p)n] gives
O(1/v) per iteration; m above the window stalls.

Previously evaluated only through the eq. (13) worst-case recursion; now
the event simulator MEASURES the per-iteration time of m-sync under the
rotating-adversary universal model across the m grid (run_experiment,
mean ± std across seeds — the model is deterministic so std certifies
determinism at 0), with the recursion bound kept in the derived column."""

from repro.core.complexity import msync_upper_recursion
from repro.exp import make_scenario, run_experiment


def run(fast: bool = True, seeds: int = 8):
    n, v, p = 20, 1.0, 0.2
    # slow rotation = harsher adversary: a straggler stays dead for 40 s,
    # so waiting for ALL workers (m > (1-2p)n) pays the revival latency
    # while any m in the Theorem 5.5 window keeps the 4/v bound.
    model = make_scenario("partial_participation", n, v=v, p=p,
                          period=40.0, t_max=4000.0)
    K = 16  # LΔ/ε = 1, σ² = 0
    res = run_experiment("msync", model, n=n, K=K, seeds=seeds,
                         grid={"m": [4, 8, 12, 16, 18, 20]})
    rows = []
    for r in res.rows:
        m = r["params"]["m"]
        per_iter = r["total_time_mean"] / K
        bound = msync_upper_recursion(model, 1, 1, 1.0, 0.0, m) / K
        in_window = n // 5 <= m <= int((1 - 2 * p) * n)
        rows.append((f"thm55/p={p}/m={m}/per_iter_s", per_iter,
                     f"±{r['total_time_std'] / K:.3g} over {r['seeds']} "
                     f"seeds window={in_window} "
                     f"recursion_bound={bound:.2f} thm_bound=4.0"))
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
