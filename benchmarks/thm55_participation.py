"""Theorem 5.5 ablation: m-Sync under the rotating partial-participation
adversary (Assumption 5.4). For p < 0.4, any m in [n/5, (1-2p)n] gives
O(1/v) per iteration; m above the window stalls."""

from repro.core import PartialParticipationModel
from repro.core.complexity import msync_upper_recursion


def run(fast: bool = True):
    n, v, p = 20, 1.0, 0.2
    # slow rotation = harsher adversary: a straggler stays dead for 40 s,
    # so waiting for ALL workers (m > (1-2p)n) pays the revival latency
    # while any m in the Theorem 5.5 window keeps the 4/v bound.
    model = PartialParticipationModel(n=n, v=v, p=p, period=40.0,
                                      t_max=4000.0)
    K = 16  # LΔ/ε = 1, σ² = 0
    rows = []
    for m in (4, 8, 12, 16, 18, 20):
        t = msync_upper_recursion(model, 1, 1, 1.0, 0.0, m)
        per_iter = t / K
        in_window = n // 5 <= m <= int((1 - 2 * p) * n)
        rows.append((f"thm55/p={p}/m={m}/per_iter_s", per_iter,
                     f"window={in_window} bound=4.0"))
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
