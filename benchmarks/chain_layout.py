"""Chain-layout lane: rectangular vs ragged vs windowed renewal pools.

The regime this lane exists for is the power-law speed ladder
(``exp_powerlaw``: worker ``i`` is Exp with mean ``i^alpha``): mean
rates span ``n^alpha``, so the rectangular layout — every worker sized
to the FASTEST worker's expected share of the arrival window — pays
``n * max(L_i)`` pool elements where the ragged layout
(:func:`repro.core.batch_jax._chain_plan_ragged`) pays ``sum(L_i) =
O(arrivals)``. The lane measures three things on that grid:

* **rect vs ragged pool** — deterministic element counts from the two
  planners at the acceptance shape, gated one-sided at >= 3x (the ISSUE
  acceptance criterion; observed ~15x at n=256, alpha=1.2) plus the
  warm wall-clock ratio of the two engine modes as a conservative
  floor;
* **windowed vs cold-restart draws** — a deliberately starved uniform
  chain budget forces the engine through its carried-state window
  retries; the windowed engine draws only extensions
  (``sum(drawn_slots)``) where a cold restart would re-draw the whole
  grown pool every retry (``sum(cumulative totals)``). Both counts are
  deterministic at fixed seeds, gated two-sided.

Results MERGE into ``BENCH_simbatch.json`` (the lane runs after
``simbatch_speed`` in CI): ratio lanes join the one-sided
``speedup_vs_serial`` section, the deterministic element counts form
the two-sided ``chain_layout`` section, and the lane's shape constants
join ``meta``. The committed baseline in ``benchmarks/baselines/``
gates all of it via ``benchmarks/perf_gate.py``.
"""

import json
import os
import time

import numpy as np

from repro.core import make_strategy
from repro.exp import make_scenario
from repro.exp.runner import atomic_write_json

BENCH_JSON = os.environ.get("REPRO_BENCH_JSON", "BENCH_simbatch.json")


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(fast: bool = True):
    import repro.core.batch_jax as bj

    n, alpha = (256, 1.2) if fast else (1024, 1.2)
    K = 800 if fast else 3000
    S = 8
    seeds = list(range(S))
    model = make_scenario("exp_powerlaw", n, alpha=alpha)
    strat = make_strategy("async")

    # ---------------- deterministic planner accounting (exact, gated)
    L_rect = bj._chain_plan(model, n, K)
    rect_elems = L_rect * n
    ragged_elems = int(bj._chain_plan_ragged(model, n, K).sum())
    pool_ratio = rect_elems / ragged_elems

    # ---------------------------- warm wall-clock: rect vs ragged mode
    def engine(layout):
        return bj.simulate_batch_jax(strat, model, K, seeds=seeds,
                                     async_layout=layout)

    engine("rect"), engine("ragged")                    # jit warmup
    t_rect = min(_timed(lambda: engine("rect")) for _ in range(3))
    t_ragged = min(_timed(lambda: engine("ragged")) for _ in range(3))
    wall_ratio = t_rect / t_ragged

    # -------------- windowed carried-state retries vs a cold restart
    # starve a smaller shape so the engine must window (uniform budgets
    # double per retry); the windowed engine draws only the extension
    # each time — a cold restart would redraw the whole grown pool
    nw, Kw, chain0 = 64, 400, 24
    wmodel = make_scenario("exp_powerlaw", nw, alpha=alpha)
    meta = {}
    bj._chain_scan_run(wmodel, None, False, Kw + 1, False, nw, S, Kw,
                       0.0, seeds, chain_len=chain0, meta=meta)
    drawn = meta["drawn_slots"]                  # per-window extensions
    windowed_elems = int(sum(drawn))
    cold_restart_elems = int(sum(np.cumsum(drawn)))
    windows = meta["windows"]

    rows = [
        (f"chain_layout/n={n}/alpha={alpha}/rect_pool_elems", rect_elems,
         f"L={L_rect} per worker x n={n} (K={K} arrivals)"),
        (f"chain_layout/n={n}/alpha={alpha}/ragged_pool_elems",
         ragged_elems, f"sum of per-worker budgets, O(K)"),
        ("chain_layout/ragged_vs_rect_pool", pool_ratio,
         "acceptance: >= 3x fewer pool elements on the power-law grid"),
        (f"chain_layout/n={n}/alpha={alpha}/rect_wall_s", t_rect,
         f"S={S} warm"),
        (f"chain_layout/n={n}/alpha={alpha}/ragged_wall_s", t_ragged,
         f"speedup={wall_ratio:.1f}x (warm)"),
        (f"chain_layout/windowed/n={nw}/drawn_elems", windowed_elems,
         f"{windows} windows, extensions only: {drawn}"),
        (f"chain_layout/windowed/n={nw}/cold_restart_elems",
         cold_restart_elems,
         f"what redrawing the grown pool each retry would cost "
         f"({cold_restart_elems / max(windowed_elems, 1):.2f}x)"),
    ]
    assert pool_ratio >= 3.0, (
        f"ragged layout only {pool_ratio:.1f}x over the rectangular "
        f"pool on the power-law regime (need >= 3x)")
    assert windows >= 2, (
        f"chain_len={chain0} no longer starves the windowed engine at "
        f"n={nw}, K={Kw} — the retry lane measured nothing")
    assert cold_restart_elems > windowed_elems, \
        "windowed engine drew as much as a cold restart would"

    # merge into the simbatch artifact (CI runs this lane right after
    # simbatch_speed; standalone runs create the file with just ours)
    art = {}
    if os.path.exists(BENCH_JSON):
        with open(BENCH_JSON) as fh:
            art = json.load(fh)
    art.setdefault("meta", {}).update(
        chain_n=n, chain_alpha=alpha, chain_K=K, chain_windowed_n=nw)
    art.setdefault("speedup_vs_serial", {}).update(
        chain_ragged_vs_rect_pool=pool_ratio,
        chain_ragged_vs_rect_wall=wall_ratio)
    art["chain_layout"] = {
        "rect_pool_elems": float(rect_elems),
        "ragged_pool_elems": float(ragged_elems),
        "windowed_drawn_elems": float(windowed_elems),
        "windowed_cold_restart_elems": float(cold_restart_elems),
        "windowed_windows": float(windows),
    }
    atomic_write_json(BENCH_JSON, art)
    return rows


def main():
    for name, val, derived in run():
        print(f"{name},{val},{derived}")


if __name__ == "__main__":
    main()
