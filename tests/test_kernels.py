"""Pallas kernel tests: shape/dtype sweeps + hypothesis properties against
the pure-jnp oracles in kernels/ref.py (interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.moe_gmm import moe_gmm_pallas
from repro.kernels.ref import attention_ref, moe_gmm_ref, rwkv_scan_ref
from repro.kernels.rwkv_scan import rwkv_scan_pallas


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- flash
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (1, 24, 2, 2, 16),     # MHA tiny
    (2, 40, 4, 2, 16),     # GQA, padded seq
    (1, 64, 4, 1, 32),     # MQA, exact blocks
    (1, 17, 3, 3, 8),      # odd everything
])
def test_flash_attention_sweep(shape, dtype):
    B, S, H, KV, dh = shape
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KV, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KV, dh)), dtype)
    out = flash_attention_pallas(q, k, v, causal=True, block_q=16,
                                 block_k=16)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [1, 4, 16])
def test_flash_attention_sliding_window(window):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 33, 2, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 33, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 33, 2, 16)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=16, block_k=16)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@given(S=st.integers(2, 48), H=st.sampled_from([1, 2, 4]),
       kv_div=st.sampled_from([1, 2]), causal=st.booleans(),
       seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_flash_attention_property(S, H, kv_div, causal, seed):
    KV = max(1, H // kv_div)
    if H % KV:
        KV = H
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, S, H, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, S, KV, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, S, KV, 8)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=16,
                                 block_k=16)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_flash_softmax_rows_sum_to_one_property():
    # with v = all-ones, attention output must be exactly ones
    S = 32
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(1, S, 2, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, S, 2, 16)), jnp.float32)
    v = jnp.ones((1, S, 2, 16), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, block_q=16,
                                 block_k=16)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- rwkv
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (1, 16, 1, 8, 8),
    (2, 50, 3, 8, 8),      # padded T
    (1, 64, 2, 16, 16),    # exact chunks
])
def test_rwkv_kernel_sweep(shape, dtype):
    B, T, H, K, V = shape
    rng = np.random.default_rng(0)
    r = jnp.asarray(rng.normal(size=(B, T, H, K)), dtype)
    k = jnp.asarray(rng.normal(size=(B, T, H, K)), dtype)
    v = jnp.asarray(rng.normal(size=(B, T, H, V)), dtype)
    w = jnp.asarray(rng.uniform(0.6, 0.999, (B, T, H, K)), dtype)
    u = jnp.asarray(0.1 * rng.normal(size=(H, K)), jnp.float32)
    y, s = rwkv_scan_pallas(r, k, v, w, u, chunk=16)
    yr, sr = rwkv_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr, np.float32),
                               **_tol(dtype))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr, np.float32),
                               **_tol(dtype))


@given(T=st.integers(2, 40), chunk=st.sampled_from([8, 16]),
       seed=st.integers(0, 30))
@settings(max_examples=15, deadline=None)
def test_rwkv_kernel_property(T, chunk, seed):
    rng = np.random.default_rng(seed)
    B, H, K, V = 1, 2, 8, 8
    r = jnp.asarray(rng.normal(size=(B, T, H, K)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, K)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, V)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.6, 0.999, (B, T, H, K)), jnp.float32)
    u = jnp.asarray(0.1 * rng.normal(size=(H, K)), jnp.float32)
    y, s = rwkv_scan_pallas(r, k, v, w, u, chunk=chunk)
    yr, sr = rwkv_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------- gmm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (1, 16, 16, 16),
    (4, 20, 40, 24),       # padding on every dim
    (2, 32, 64, 32),       # exact blocks
    (8, 8, 8, 8),          # tiny
])
def test_moe_gmm_sweep(shape, dtype):
    E, C, din, dout = shape
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(E, C, din)), dtype)
    w = jnp.asarray(rng.normal(size=(E, din, dout)), dtype)
    out = moe_gmm_pallas(x, w, block_m=16, block_n=16, block_k=16)
    ref = moe_gmm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 2e-4,
                               atol=2e-2 if dtype == jnp.bfloat16 else 2e-4)


@given(E=st.integers(1, 4), C=st.integers(1, 24), din=st.integers(1, 32),
       dout=st.integers(1, 24), seed=st.integers(0, 20))
@settings(max_examples=15, deadline=None)
def test_moe_gmm_property(E, C, din, dout, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(E, C, din)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(E, din, dout)), jnp.float32)
    out = moe_gmm_pallas(x, w, block_m=8, block_n=8, block_k=8)
    ref = moe_gmm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------ model integration
def test_model_attention_pallas_path_matches_ref():
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("nanogpt-paper"), d_model=64,
                  layers_per_stage=2, vocab=128)
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 24), 0, 128)
    ref_logits, _ = m.apply(params, toks, impl="ref")
    pl_logits, _ = m.apply(params, toks, impl="pallas")
    np.testing.assert_allclose(np.asarray(pl_logits),
                               np.asarray(ref_logits), rtol=2e-3, atol=2e-3)


def test_model_rwkv_pallas_path_matches_ref():
    from repro.configs import get_config, reduced
    from repro.models import build_model
    cfg = reduced(get_config("rwkv6-3b"), d_model=64, layers_per_stage=2,
                  vocab=128)
    m = build_model(cfg)
    params = m.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 24), 0, 128)
    ref_logits, _ = m.apply(params, toks, impl="ref")
    pl_logits, _ = m.apply(params, toks, impl="pallas")
    np.testing.assert_allclose(np.asarray(pl_logits),
                               np.asarray(ref_logits), rtol=2e-3, atol=2e-3)
