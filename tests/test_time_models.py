"""Tests for the computation-time models (Assumptions 2.2/3.1/5.1/5.4)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (FixedTimes, PartialParticipationModel,
                        UniversalModel, chi2_times, exponential_times,
                        gamma_times, powers_figure3, powers_figure4,
                        shifted_exponential_times, truncated_normal_times,
                        uniform_times)


def test_fixed_times_sorted_factories():
    m = FixedTimes.sqrt_law(10)
    assert np.all(np.diff(m.taus) > 0)
    assert m.sample_time(3, np.random.default_rng(0)) == pytest.approx(2.0)


def test_subexp_samplers_match_reported_means():
    rng = np.random.default_rng(0)
    models = [
        exponential_times(0.5, 8),
        truncated_normal_times(np.linspace(1, 5, 8), 0.5),
        gamma_times(np.linspace(1, 5, 8), var=0.25),
        uniform_times(np.linspace(2, 6, 8), 1.0),
        chi2_times([4, 9, 16, 25]),
        shifted_exponential_times(np.ones(4), np.ones(4) * 2.0),
    ]
    for model in models:
        for i in range(model.n):
            s = np.mean([model.sample_time(i, rng) for _ in range(4000)])
            assert s == pytest.approx(model.mean_times()[i], rel=0.1), model.name


def test_all_samples_nonnegative():
    rng = np.random.default_rng(1)
    model = truncated_normal_times(np.full(4, 0.1), 2.0)  # heavy truncation
    samples = [model.sample_time(i, rng) for i in range(4) for _ in range(500)]
    assert min(samples) >= 0.0


def test_truncated_normal_mean_exceeds_mu_under_truncation():
    model = truncated_normal_times([0.5], sigma=1.0)
    assert model.mean_times()[0] > 0.5


def test_universal_constant_power_N():
    grid = np.arange(0.0, 100.0, 0.5)
    powers = np.full((2, len(grid)), 2.0)  # 2 grads/sec
    m = UniversalModel(grid, powers)
    assert m.N(0, 0.0, 1.0) == 2
    assert m.N(0, 0.0, 0.49) == 0
    assert m.time_for_integral(0, 0.0, 1.0) == pytest.approx(0.5, abs=1e-6)
    # extrapolation past grid end uses final power
    assert m.N(0, 0.0, 200.0) == 400


def test_universal_zero_power_never_finishes():
    grid = np.arange(0.0, 10.0, 0.5)
    powers = np.zeros((1, len(grid)))
    m = UniversalModel(grid, powers)
    assert m.time_for_integral(0, 0.0, 1.0) == np.inf


@given(st.floats(0.1, 5.0), st.floats(0.0, 20.0), st.floats(0.1, 10.0))
@settings(max_examples=50, deadline=None)
def test_universal_integral_additivity(v, t0, dt):
    grid = np.arange(0.0, 50.0, 0.25)
    m = UniversalModel(grid, np.full((1, len(grid)), v))
    mid = t0 + dt / 2
    total = m.integral(0, t0, t0 + dt)
    assert total == pytest.approx(
        m.integral(0, t0, mid) + m.integral(0, mid, t0 + dt), rel=1e-6,
        abs=1e-9)
    assert total == pytest.approx(v * dt, rel=1e-6, abs=1e-9)


def test_figure3_powers_shape_and_bounds():
    m = powers_figure3(n=50, seed=0, t_max=50.0)
    assert m.n == 50
    assert np.all(m.powers >= 0)
    assert np.max(m.powers) <= 1.0 + 1.0  # sin + noise margin


def test_figure4_powers_floor():
    m = powers_figure4(n=50, seed=0, t_max=50.0)
    assert np.all(m.powers >= 0.1 - 1e-12)


def test_partial_participation_bound():
    n, p = 20, 0.25
    m = PartialParticipationModel(n=n, v=1.0, p=p, t_max=60.0)
    # at every grid instant at most floor(p*n) powers are zero
    zeros_per_t = np.sum(m.powers == 0.0, axis=0)
    assert np.max(zeros_per_t) <= int(p * n)
    # and all nonzero powers equal v
    nz = m.powers[m.powers > 0]
    assert np.allclose(nz, 1.0)
