"""Tests for the computation-time models (Assumptions 2.2/3.1/5.1/5.4)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (FixedTimes, PartialParticipationModel,
                        UniversalModel, chi2_times, exponential_times,
                        gamma_times, powers_figure3, powers_figure4,
                        shifted_exponential_times, truncated_normal_times,
                        uniform_times)
from repro.core.time_models import philox_rngs


def _all_subexp_factories(n=8):
    """Every SubExponentialTimes factory, with whether its batch_sampler
    is documented stream-equal to sequential scalar draws (truncnorm's
    vectorized rejection resamples in a different order)."""
    taus = np.linspace(1.0, 4.0, n)
    return [
        (exponential_times(0.8, n), True),
        (shifted_exponential_times(taus, np.full(n, 2.0)), True),
        (gamma_times(taus, var=0.25), True),
        (uniform_times(taus, 0.5), True),
        (chi2_times(1 + np.arange(n) % 5), True),
        (truncated_normal_times(taus, 0.5), False),
    ]


def test_fixed_times_sorted_factories():
    m = FixedTimes.sqrt_law(10)
    assert np.all(np.diff(m.taus) > 0)
    assert m.sample_time(3, np.random.default_rng(0)) == pytest.approx(2.0)


def test_subexp_samplers_match_reported_means():
    rng = np.random.default_rng(0)
    models = [
        exponential_times(0.5, 8),
        truncated_normal_times(np.linspace(1, 5, 8), 0.5),
        gamma_times(np.linspace(1, 5, 8), var=0.25),
        uniform_times(np.linspace(2, 6, 8), 1.0),
        chi2_times([4, 9, 16, 25]),
        shifted_exponential_times(np.ones(4), np.ones(4) * 2.0),
    ]
    for model in models:
        for i in range(model.n):
            s = np.mean([model.sample_time(i, rng) for _ in range(4000)])
            assert s == pytest.approx(model.mean_times()[i], rel=0.1), model.name


def test_batch_and_jax_sampler_parity_sweep():
    """ISSUE 3 satellite: for EVERY SubExponentialTimes factory, the
    batch_sampler and jax_sampler agree with the scalar sampler —
    distribution-equal via moment checks everywhere, stream-equal where
    documented (all but truncnorm's rejection resampling)."""
    import jax

    for model, stream_equal in _all_subexp_factories():
        n = model.n
        assert model.batch_sampler is not None, model.name
        assert model.jax_sampler is not None, model.name
        # batch_sampler moments
        rng = np.random.default_rng(0)
        draws = np.stack([model.sample_times(np.arange(n), rng)
                          for _ in range(3000)])
        np.testing.assert_allclose(draws.mean(axis=0), model.mean_times(),
                                   rtol=0.1, err_msg=model.name)
        # jax_sampler moments (mean AND variance against NumPy draws)
        keys = jax.random.split(jax.random.PRNGKey(0), 3000)
        jdraws = np.asarray(jax.vmap(model.jax_sampler)(keys))
        np.testing.assert_allclose(jdraws.mean(axis=0),
                                   model.mean_times(), rtol=0.1,
                                   err_msg=model.name)
        np.testing.assert_allclose(jdraws.var(axis=0), draws.var(axis=0),
                                   rtol=0.25, atol=1e-3,
                                   err_msg=model.name)
        assert np.all(jdraws >= 0.0), model.name
        # stream equality: one batched call == sequential scalar draws
        if stream_equal:
            a = model.sample_times(np.arange(n), np.random.default_rng(5))
            r = np.random.default_rng(5)
            b = np.array([model.sample_time(i, r) for i in range(n)])
            np.testing.assert_array_equal(a, b, err_msg=model.name)


def test_sample_times_tensor_contract():
    """Stream rows replay successive sample_times calls; counter rows are
    per-seed reproducible pure functions of the seed value."""
    model = gamma_times(np.linspace(1.0, 3.0, 6), var=0.25)
    w = np.arange(6)
    # stream: row r == r-th successive sample_times call on default_rng(s)
    got = model.sample_times_tensor(w, 3, [0, 9], rng_scheme="stream")
    for row, s in zip(got, (0, 9)):
        rng = np.random.default_rng(s)
        for r in range(3):
            np.testing.assert_array_equal(row[r],
                                          model.sample_times(w, rng))
    # counter: deterministic per seed value, regardless of sweep
    a = model.sample_times_tensor(w, 4, [3], rng_scheme="counter")
    b = model.sample_times_tensor(w, 4, [0, 3], rng_scheme="counter")
    np.testing.assert_array_equal(a[0], b[1])
    # stateful generators continue the stream across chunked calls
    rngs = philox_rngs([3])
    c1 = model.sample_times_tensor(w, 2, rngs, rng_scheme="counter")
    c2 = model.sample_times_tensor(w, 2, rngs, rng_scheme="counter")
    np.testing.assert_array_equal(np.concatenate([c1, c2], axis=1), b[1:])
    # moments survive the tiled bulk draw
    big = model.sample_times_tensor(w, 2000, [0], rng_scheme="counter")
    np.testing.assert_allclose(big[0].mean(axis=0), model.mean_times(),
                               rtol=0.1)
    with pytest.raises(ValueError):
        model.sample_times_tensor(w, 2, [0], rng_scheme="philox")
    # FixedTimes: pure broadcast, no RNG
    fixed = FixedTimes(np.array([2.0, 1.0]))
    np.testing.assert_array_equal(
        fixed.sample_times_tensor([1, 0], 2, [0, 1]),
        np.full((2, 2, 2), [1.0, 2.0]))


def test_all_samples_nonnegative():
    rng = np.random.default_rng(1)
    model = truncated_normal_times(np.full(4, 0.1), 2.0)  # heavy truncation
    samples = [model.sample_time(i, rng) for i in range(4) for _ in range(500)]
    assert min(samples) >= 0.0


def test_truncated_normal_mean_exceeds_mu_under_truncation():
    model = truncated_normal_times([0.5], sigma=1.0)
    assert model.mean_times()[0] > 0.5


def test_universal_constant_power_N():
    grid = np.arange(0.0, 100.0, 0.5)
    powers = np.full((2, len(grid)), 2.0)  # 2 grads/sec
    m = UniversalModel(grid, powers)
    assert m.N(0, 0.0, 1.0) == 2
    assert m.N(0, 0.0, 0.49) == 0
    assert m.time_for_integral(0, 0.0, 1.0) == pytest.approx(0.5, abs=1e-6)
    # extrapolation past grid end uses final power
    assert m.N(0, 0.0, 200.0) == 400


def test_universal_zero_power_never_finishes():
    grid = np.arange(0.0, 10.0, 0.5)
    powers = np.zeros((1, len(grid)))
    m = UniversalModel(grid, powers)
    assert m.time_for_integral(0, 0.0, 1.0) == np.inf


@given(st.floats(0.1, 5.0), st.floats(0.0, 20.0), st.floats(0.1, 10.0))
@settings(max_examples=50, deadline=None)
def test_universal_integral_additivity(v, t0, dt):
    grid = np.arange(0.0, 50.0, 0.25)
    m = UniversalModel(grid, np.full((1, len(grid)), v))
    mid = t0 + dt / 2
    total = m.integral(0, t0, t0 + dt)
    assert total == pytest.approx(
        m.integral(0, t0, mid) + m.integral(0, mid, t0 + dt), rel=1e-6,
        abs=1e-9)
    assert total == pytest.approx(v * dt, rel=1e-6, abs=1e-9)


def test_finish_times_vectorized_matches_scalar_inversion():
    """ISSUE 3 satellite: the batched searchsorted/quadratic inversion
    must match the scalar 80-iteration bisection to 1e-9 on the
    Figure 3/4 grids, including the constant-tail extrapolation."""
    for model in (powers_figure3(n=12, seed=0, t_max=80.0),
                  powers_figure4(n=12, seed=1, t_max=80.0)):
        w = np.arange(model.n)
        for t0 in (0.0, 2.31, 17.9, 79.0):
            got = model.finish_times(w, t0, 1.0)
            want = np.array([model.time_for_integral(i, t0, 1.0)
                             for i in range(model.n)])
            np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)
        # per-worker t0 arrays (the fast-path restart shape)
        t0s = np.linspace(0.0, 60.0, model.n)
        got = model.finish_times(w, t0s, 1.0)
        want = np.array([model.time_for_integral(i, float(t0s[i]), 1.0)
                         for i in range(model.n)])
        np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)
        # constant-tail extrapolation: targets far past the grid end
        got = model.finish_times(w, 79.9, 50.0)
        want = np.array([model.time_for_integral(i, 79.9, 50.0)
                         for i in range(model.n)])
        assert np.all(got > 80.0)
        np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_finish_times_zero_power_and_inf_branches():
    grid = np.arange(0.0, 10.0, 0.5)
    powers = np.zeros((3, len(grid)))
    powers[1] = 1.0
    powers[2, :10] = 2.0          # power dies mid-grid: zero tail
    m = UniversalModel(grid, powers)
    got = m.finish_times([0, 1, 2], 0.0, 1.0)
    assert np.isinf(got[0])                       # v = 0 forever
    assert got[1] == pytest.approx(1.0, abs=1e-9)
    assert got[2] == pytest.approx(0.5, abs=1e-9)
    # target unreachable before the zero tail => inf
    assert np.isinf(m.finish_times([2], 0.0, 100.0)[0])
    # inf start times stay inf (never-finishing restarts propagate)
    np.testing.assert_array_equal(m.finish_times([1, 1], [np.inf, 0.0]),
                                  [np.inf, 1.0])
    # partial participation grids go through the same vectorized path
    pp = PartialParticipationModel(n=10, v=1.0, p=0.2, period=2.0,
                                   t_max=40.0)
    w = np.arange(10)
    got = pp.finish_times(w, 3.3, 1.0)
    want = np.array([pp.time_for_integral(i, 3.3, 1.0) for i in range(10)])
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_finish_times_jax_matches_scalar_to_1e9():
    """ISSUE 4 acceptance: the jit-compatible finish_times_jax must
    match the scalar/NumPy inversion to 1e-9 on the Fig 3/4 grids,
    including the constant-tail extrapolation branch (t past the grid)
    — under x64, since the engines' float32 default cannot express that
    tolerance."""
    import jax
    from jax.experimental import enable_x64

    for mk in (powers_figure3, powers_figure4):
        model = mk(n=16, seed=0, t_max=60.0)
        w = np.arange(16)
        for t0 in (0.0, 7.3, np.linspace(0.0, 80.0, 16)):  # 80 > grid end
            ref = model.finish_times(w, t0)
            with enable_x64():
                got = np.asarray(model.finish_times_jax(
                    np.broadcast_to(np.asarray(t0, dtype=np.float64),
                                    (16,))))
            np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-9)


def test_finish_times_jax_tail_inf_and_worker_branches():
    """v = 0 tail => inf, t0 = inf => inf, and the explicit ``workers``
    indexing the arrival-indexed engine uses — all against the NumPy
    path."""
    from jax.experimental import enable_x64

    grid = np.arange(0.0, 10.0, 0.1)
    powers = np.ones((2, len(grid)))
    powers[1, 50:] = 0.0                 # power dies at t = 5
    m = UniversalModel(grid, powers)
    with enable_x64():
        got = np.asarray(m.finish_times_jax(np.array([9.9, 9.0]),
                                            target=5.0))
        ref = m.finish_times([0, 1], np.array([9.9, 9.0]), target=5.0)
        assert np.isinf(got[1]) and np.isinf(ref[1])
        np.testing.assert_allclose(got[0], ref[0], rtol=1e-9)
        gi = np.asarray(m.finish_times_jax(np.array([np.inf, 1.0]),
                                           workers=np.array([0, 1])))
        assert np.isinf(gi[0])
        np.testing.assert_allclose(gi[1], m.finish_times([1], 1.0)[0],
                                   rtol=1e-9)
    # batched (seeds, workers) shape — the engine's actual call form
    m3 = powers_figure3(n=6, seed=1, t_max=40.0)
    t0 = np.random.default_rng(0).uniform(0.0, 30.0, (3, 6))
    got = np.asarray(m3.finish_times_jax(t0.astype(np.float32)))
    ref = np.stack([m3.finish_times(np.arange(6), row) for row in t0])
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-3)


def test_jax_sampler_item_matches_marginals():
    """ISSUE 4 tentpole: every factory's single-draw jax_sampler_item
    (the keyed Async path) draws from the same per-worker marginal as
    the scalar sampler — mean check per worker, nonnegative always."""
    import jax

    for model, _ in _all_subexp_factories():
        assert model.jax_sampler_item is not None, model.name
        keys = jax.random.split(jax.random.PRNGKey(0), 4000)
        for i in (0, model.n - 1):
            d = np.asarray(jax.vmap(
                lambda k: model.jax_sampler_item(k, i))(keys))
            assert (d >= 0).all(), model.name
            assert np.mean(d) == pytest.approx(model.mean_times()[i],
                                               rel=0.1), model.name


def test_jax_worker_key_grid_contract():
    """Grid rows are pure functions of the seed VALUE: independent of
    the sweep composition and of call order (the per-worker keyed-draw
    contract in DESIGN.md §3b)."""
    from repro.core.time_models import jax_worker_key_grid

    a = np.asarray(jax_worker_key_grid([0, 3], 5))
    b = np.asarray(jax_worker_key_grid([5, 3, 9], 5))
    assert a.shape == (2, 5, 2)
    np.testing.assert_array_equal(a[1], b[1])     # seed 3 row identical
    np.testing.assert_array_equal(
        a, np.asarray(jax_worker_key_grid([0, 3], 5)))
    # distinct workers get distinct stream roots
    assert len({tuple(k) for k in a[0]}) == 5


def test_figure3_powers_shape_and_bounds():
    m = powers_figure3(n=50, seed=0, t_max=50.0)
    assert m.n == 50
    assert np.all(m.powers >= 0)
    assert np.max(m.powers) <= 1.0 + 1.0  # sin + noise margin


def test_figure4_powers_floor():
    m = powers_figure4(n=50, seed=0, t_max=50.0)
    assert np.all(m.powers >= 0.1 - 1e-12)


def test_partial_participation_bound():
    n, p = 20, 0.25
    m = PartialParticipationModel(n=n, v=1.0, p=p, t_max=60.0)
    # at every grid instant at most floor(p*n) powers are zero
    zeros_per_t = np.sum(m.powers == 0.0, axis=0)
    assert np.max(zeros_per_t) <= int(p * n)
    # and all nonzero powers equal v
    nz = m.powers[m.powers > 0]
    assert np.allclose(nz, 1.0)
