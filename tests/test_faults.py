"""Tests for the fault-injection subsystem (repro.core.faults, DESIGN
§3c): identity wrappers must be bitwise no-ops on every engine, fault
scenarios must run on serial AND jax with serial↔jax distribution
parity, the mean/R transformations must match their closed forms, and
the §3c scenarios must be registered."""

import math

import numpy as np
import pytest

from repro.core import (FixedTimes, exponential_times, simulate_batch)
from repro.core.faults import (CorrelatedBursts, CrashRestart, FaultyTimes,
                               HeavyTailSpike, IdentityFault,
                               TransientSlowdown, with_faults)
from repro.core.time_models import philox_rngs
from repro.exp import SCENARIOS, make_scenario

STRATS = [("msync", {"m": 3}), ("rennala", {"batch": 3}), ("async", {})]


# --------------------------------------------------- identity = bitwise no-op
def test_identity_wrapper_shares_base_samplers_by_identity():
    model = exponential_times(1.0, 6)
    for wrapped in (with_faults(model), with_faults(model, IdentityFault())):
        assert isinstance(wrapped, FaultyTimes)
        # object identity => shared jit program caches, bitwise no-op
        assert wrapped.jax_sampler is model.jax_sampler
        assert wrapped.jax_sampler_item is model.jax_sampler_item
        np.testing.assert_array_equal(wrapped.taus, model.taus)
        assert wrapped.R == model.R
        assert wrapped.name == model.name


@pytest.mark.parametrize("spec", STRATS)
@pytest.mark.parametrize("backend", ["serial", "jax"])
def test_identity_wrapper_bitwise_noop(spec, backend):
    """ISSUE 8 acceptance: wrapping with only identity faults is a
    bitwise no-op on the fault-free engines, serial and jax, for each
    strategy family."""
    model = exponential_times(1.0, 6)
    wrapped = with_faults(model, IdentityFault())
    kw = dict(K=40, seeds=6, backend=backend)
    a = simulate_batch(spec, model, **kw)
    b = simulate_batch(spec, wrapped, **kw)
    for ta, tb in zip(a.traces[0], b.traces[0]):
        assert ta.total_time == tb.total_time
        assert ta.gradients_computed == tb.gradients_computed


@pytest.mark.parametrize("rng_scheme", ["counter", "stream"])
def test_identity_wrapper_bitwise_noop_vectorized(rng_scheme):
    model = exponential_times(1.0, 6)
    wrapped = with_faults(model)
    kw = dict(K=40, seeds=5, backend="vectorized", rng_scheme=rng_scheme)
    a = simulate_batch(("msync", {"m": 3}), model, **kw)
    b = simulate_batch(("msync", {"m": 3}), wrapped, **kw)
    for ta, tb in zip(a.traces[0], b.traces[0]):
        assert ta.total_time == tb.total_time


# ------------------------------------------ fault scenarios: engines + parity
@pytest.mark.parametrize("scenario", ["crash_restart", "correlated_bursts"])
@pytest.mark.parametrize("spec", STRATS)
def test_fault_scenarios_serial_jax_parity(scenario, spec):
    """Crash/restart and correlated-burst regimes run under m-sync,
    Rennala and Async on both engines; the engines draw from different
    RNG schemes (distribution-equal), so parity is on the cross-seed
    mean total time."""
    model = make_scenario(scenario, 8)
    a = simulate_batch(spec, model, K=60, seeds=12, backend="serial")
    b = simulate_batch(spec, model, K=60, seeds=12, backend="jax")
    ma = a.total_time.mean()
    mb = b.total_time.mean()
    assert ma > 0 and mb > 0
    assert 0.75 < ma / mb < 1.33, (scenario, spec, ma, mb)


@pytest.mark.parametrize(
    "scenario", ["crash_restart", "crash_fixed", "transient_slowdown",
                 "correlated_bursts", "heavy_tail_spikes", "faulty_mix"])
def test_fault_scenarios_registered_and_slower_in_mean(scenario):
    assert scenario in SCENARIOS
    model = make_scenario(scenario, 6)
    assert isinstance(model, FaultyTimes)
    # every fault adds time in expectation: transformed taus dominate
    # the base means elementwise
    base_taus = np.asarray(model.base.taus, dtype=float)
    assert np.all(np.asarray(model.taus) >= base_taus - 1e-12)


def test_faulted_convenience_method():
    model = exponential_times(1.0, 4)
    wrapped = model.faulted(CrashRestart(p=0.1, mean_downtime=1.0))
    assert isinstance(wrapped, FaultyTimes)
    assert wrapped.base is model


# ------------------------------------------------------- mean / R closed forms
def test_transform_means_and_R_closed_forms():
    taus = np.array([1.0, 2.0, 4.0])
    cr = CrashRestart(p=0.2, mean_downtime=3.0)
    np.testing.assert_allclose(cr.transform_means(taus),
                               taus * 1.1 + 0.2 * 3.0)
    assert cr.transform_R(5.0, taus) == 2 * 5.0 + 3.0

    ts = TransientSlowdown(rate=0.5, mean_episode=2.0, factor=3.0)
    np.testing.assert_allclose(ts.transform_means(taus),
                               taus * (1 + 0.5 * 2.0 * 2.0))

    cb = CorrelatedBursts(p_episode=0.25, frac=0.5, mean_extra=8.0)
    np.testing.assert_allclose(cb.transform_means(taus),
                               taus + 0.25 * 0.5 * 8.0)

    ht = HeavyTailSpike(p=0.1, alpha=1.5, scale=5.0)
    np.testing.assert_allclose(ht.transform_means(taus),
                               taus + 0.1 * 5.0 / 0.5)
    assert ht.transform_R(5.0, taus) == math.inf


def test_crash_restart_empirical_mean_matches_transform():
    """The NumPy draw path realizes the advertised mean map."""
    n, rounds = 3, 4000
    model = with_faults(exponential_times(1.0, n),
                        CrashRestart(p=0.3, mean_downtime=2.0))
    rng = np.random.default_rng(0)
    draws = model.sample_times_tensor(np.arange(n), rounds, [rng],
                                      "stream")
    emp = np.asarray(draws).reshape(rounds, n).mean(axis=0)
    np.testing.assert_allclose(emp, model.taus, rtol=0.1)


def test_heavy_tail_spike_empirical_mean():
    n, rounds = 2, 6000
    model = with_faults(exponential_times(1.0, n),
                        HeavyTailSpike(p=0.2, alpha=2.0, scale=3.0))
    rng = np.random.default_rng(1)
    draws = model.sample_times_tensor(np.arange(n), rounds, [rng],
                                      "stream")
    emp = np.asarray(draws).reshape(rounds, n).mean(axis=0)
    np.testing.assert_allclose(emp, model.taus, rtol=0.15)


# ------------------------------------------------- sweep independence (§3b)
def test_faulted_draws_sweep_independent_counter_and_jax():
    """Per-seed results must not depend on which other seeds are in the
    sweep — the contract the checkpoint/resume layer builds on."""
    model = make_scenario("crash_restart", 6)
    spec = ("msync", {"m": 2})
    for backend, scheme in (("vectorized", "counter"), ("jax", "counter")):
        solo = simulate_batch(spec, model, K=30, seeds=[3],
                              backend=backend, rng_scheme=scheme)
        pair = simulate_batch(spec, model, K=30, seeds=[3, 9],
                              backend=backend, rng_scheme=scheme)
        assert solo.traces[0][0].total_time == pair.traces[0][0].total_time


def test_fault_noise_streams_disjoint_from_base():
    """Wrapping must not perturb the base draw itself: the faulted draw
    is always >= the base portion it embeds... checked distributionally:
    the wrapped per-seed Philox draws differ from base only by added
    fault noise (wrapped >= u * base with u in [0,1] for crashes, so the
    *minimum* over many rounds stays nonnegative and the base stream,
    redrawn unwrapped, is unchanged)."""
    n = 4
    base = exponential_times(1.0, n)
    wrapped = with_faults(base, CorrelatedBursts(p_episode=0.3, frac=0.5,
                                                 mean_extra=2.0))
    # same Philox seed stream, base model: identical whether or not the
    # wrapped model was sampled first (no shared mutable RNG state)
    r1 = philox_rngs([5])[0]
    a = base.sample_times_tensor(np.arange(n), 50, [r1], "counter")
    r2 = philox_rngs([5])[0]
    _ = wrapped.sample_times_tensor(np.arange(n), 50,
                                    philox_rngs([5]), "counter")
    b = base.sample_times_tensor(np.arange(n), 50, [r2], "counter")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # bursts only ever ADD time
    w = np.asarray(wrapped.sample_times_tensor(
        np.arange(n), 200, philox_rngs([5]), "counter")).reshape(200, n)
    base_again = np.asarray(base.sample_times_tensor(
        np.arange(n), 200, philox_rngs([5]), "counter")).reshape(200, n)
    assert np.all(w >= base_again - 1e-12)


# ---------------------------------------------------------------- validation
def test_fault_validation():
    with pytest.raises(ValueError):
        CrashRestart(p=1.5, mean_downtime=1.0)
    with pytest.raises(ValueError):
        HeavyTailSpike(p=0.1, alpha=1.0, scale=1.0)   # needs alpha > 1
    with pytest.raises(ValueError):
        TransientSlowdown(rate=-1.0, mean_episode=1.0, factor=2.0)
    with pytest.raises(ValueError):
        CorrelatedBursts(p_episode=0.1, frac=2.0, mean_extra=1.0)
    with pytest.raises(TypeError):
        with_faults(object(), IdentityFault())
    with pytest.raises(TypeError):
        FaultyTimes(exponential_times(1.0, 3), ["not a fault"])


def test_crash_fixed_turns_deterministic_model_stochastic():
    model = make_scenario("crash_fixed", 5)
    rng = np.random.default_rng(0)
    draws = np.asarray(model.sample_times_tensor(
        np.arange(5), 200, [rng], "stream")).reshape(200, 5)
    assert draws.std(axis=0).max() > 0         # crashes add randomness
    base = FixedTimes.sqrt_law(5, 1.0)
    assert np.all(draws >= 0)
    assert np.all(draws.min(axis=0) <= np.asarray(base.taus) + 1e-12)
