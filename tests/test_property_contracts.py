"""Property tests for the two engine-load-bearing RNG/sort contracts.

Real ``hypothesis`` in CI; the deterministic conftest stand-in in the
container (same ``@given``/``strategies`` subset either way):

* :func:`repro.core.time_models.jax_chain_draws` — **prefix stability**:
  row ``(s, j)`` is a pure function of ``(seed key, slot j)`` via
  ``fold_in``, so growing ``L`` appends rows and never reshuffles
  existing ones. The arrival-scan and ringleader engines' chain-doubling
  retries rely on this to keep already-completed work bitwise identical
  across retries.
* :func:`repro.kernels.order_stats.smallest_k` — **tie contract**: the
  ``k`` smallest per row in ascending order with ties broken by flat
  index (stable), bitwise equal between the host (NumPy stable argsort)
  and device (``jnp.argsort(stable=True)``) paths. The async pool merge
  orders simultaneous arrivals by (worker, arrival index) through
  exactly this property.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.time_models import (exponential_times, jax_chain_draws,
                                    jax_chain_draws_ragged, ragged_layout,
                                    shifted_exponential_times)
from repro.kernels.order_stats import smallest_k


def _chain_keys(seeds):
    return jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])


# ------------------------------------------------------- jax_chain_draws
@settings(max_examples=15, deadline=None)
@given(st.integers(1, 6), st.integers(1, 12), st.integers(1, 12),
       st.lists(st.integers(0, 2 ** 20), min_size=1, max_size=4))
def test_chain_draws_prefix_stable(n, L1, extra, seeds):
    """Growing L only appends rows: the shorter chain is a bitwise
    prefix of the longer one, per seed and per worker."""
    sampler = exponential_times(1.0, n).jax_sampler
    keys = _chain_keys(seeds)
    short = np.asarray(jax_chain_draws(keys, L1, sampler))
    long = np.asarray(jax_chain_draws(keys, L1 + extra, sampler))
    assert short.shape == (len(seeds), L1, n)
    np.testing.assert_array_equal(short, long[:, :L1])


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 6), st.integers(1, 10),
       st.lists(st.integers(0, 2 ** 20), min_size=2, max_size=4),
       st.integers(0, 3))
def test_chain_draws_sweep_independent(n, L, seeds, pick):
    """Row (s, j) depends only on (seed key, j): a seed's chain in a
    multi-seed sweep equals its singleton-sweep chain bitwise, and
    equals the per-slot fold_in spelling of the contract."""
    pick = pick % len(seeds)
    sampler = exponential_times(1.0, n).jax_sampler
    batch = np.asarray(jax_chain_draws(_chain_keys(seeds), L, sampler))
    solo = np.asarray(jax_chain_draws(_chain_keys([seeds[pick]]), L,
                                      sampler))
    np.testing.assert_array_equal(batch[pick], solo[0])
    key = jax.random.PRNGKey(int(seeds[pick]))
    for j in (0, L - 1):
        row = np.asarray(sampler(jax.random.fold_in(key, j)))
        np.testing.assert_array_equal(batch[pick, j], row)


# ------------------------------------------------- ragged chain layout
@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(1, 8), min_size=2, max_size=5),
       st.lists(st.integers(0, 5), min_size=2, max_size=5),
       st.lists(st.integers(0, 2 ** 20), min_size=1, max_size=3))
def test_ragged_per_worker_prefix_stable(buds, extras, seeds):
    """Growing any worker's budget appends that worker's slots and never
    re-keys existing ones, and a window extension (``starts=buds``)
    draws exactly the appended tail — per seed, per worker, bitwise."""
    n = min(len(buds), len(extras))
    b = np.asarray(buds[:n], dtype=np.int64)
    e = np.asarray(extras[:n], dtype=np.int64)
    sampler = exponential_times(1.0, n).jax_sampler
    keys = _chain_keys(seeds)
    short = np.asarray(jax_chain_draws_ragged(keys, b, sampler))
    long = np.asarray(jax_chain_draws_ragged(keys, b + e, sampler))
    ext = np.asarray(jax_chain_draws_ragged(keys, e, sampler, starts=b))
    off_s, _, _, _ = ragged_layout(b)
    off_l, _, _, _ = ragged_layout(b + e)
    off_e, _, _, _ = ragged_layout(e)
    for i in range(n):
        np.testing.assert_array_equal(
            short[:, off_s[i]:off_s[i] + b[i]],
            long[:, off_l[i]:off_l[i] + b[i]])
        np.testing.assert_array_equal(
            ext[:, off_e[i]:off_e[i] + e[i]],
            long[:, off_l[i] + b[i]:off_l[i] + b[i] + e[i]])


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 6), st.integers(1, 10),
       st.lists(st.integers(0, 2 ** 20), min_size=1, max_size=3))
def test_ragged_uniform_budgets_match_rectangular_bitwise(n, L, seeds):
    """With uniform budgets the ragged flat buffer is the rectangular
    ``(S, L, n)`` chain transposed to worker-major and flattened —
    bitwise, per the documented contract."""
    sampler = exponential_times(1.0, n).jax_sampler
    keys = _chain_keys(seeds)
    rect = np.asarray(jax_chain_draws(keys, L, sampler))       # (S, L, n)
    flat = np.asarray(jax_chain_draws_ragged(
        keys, np.full(n, L, dtype=np.int64), sampler))
    np.testing.assert_array_equal(
        flat, rect.transpose(0, 2, 1).reshape(len(seeds), n * L))


@settings(max_examples=3, deadline=None)
@given(st.lists(st.integers(0, 2 ** 16), min_size=1, max_size=2),
       st.booleans())
def test_engine_rect_ragged_parity_uniform_rates(seeds, ringmaster):
    """The arrival-scan engine's result is layout-independent: at
    uniform rates the rectangular and ragged layouts produce identical
    traces (bitwise under x64)."""
    from repro.core.batch_jax import simulate_batch_jax
    from repro.core.strategies import STRATEGIES
    n, K = 5, 16
    model = exponential_times(1.0, n)
    strat = (STRATEGIES["ringmaster"](max_delay=2) if ringmaster
             else STRATEGIES["async"]())
    runs = [simulate_batch_jax(strat, model, K, seeds=list(seeds),
                               async_layout=lay, x64=True)
            for lay in ("ragged", "rect")]
    for a, b in zip(*runs):
        assert a.total_time == b.total_time
        assert a.gradients_computed == b.gradients_computed
        np.testing.assert_array_equal(a.times, b.times)


@settings(max_examples=3, deadline=None)
@given(st.lists(st.integers(0, 2 ** 16), min_size=1, max_size=2),
       st.booleans())
def test_windowed_resume_parity_bitwise(seeds, ringmaster):
    """A starved chain budget forces windowed carried-state retries; the
    result must equal the single-window (generous-budget) run bitwise
    under x64 — the retry only draws and scans the extension."""
    from repro.core.batch_jax import simulate_batch_jax
    from repro.core.strategies import STRATEGIES
    n, K = 5, 20
    means = np.arange(1, n + 1, dtype=float) ** 1.5  # skewed rates
    model = shifted_exponential_times(np.zeros(n), 1.0 / means)
    strat = (STRATEGIES["ringmaster"](max_delay=2) if ringmaster
             else STRATEGIES["async"]())
    starved = simulate_batch_jax(strat, model, K, seeds=list(seeds),
                                 async_chain=4, x64=True)
    cold = simulate_batch_jax(strat, model, K, seeds=list(seeds),
                              async_chain=512, x64=True)
    for a, b in zip(starved, cold):
        assert a.total_time == b.total_time
        assert a.gradients_computed == b.gradients_computed
        np.testing.assert_array_equal(a.times, b.times)


def test_windowed_retry_reuses_carried_state():
    """Draw/scan accounting for the forced-exhaustion retry: the
    windowed engine scans strictly increasing, non-overlapping arrival
    ranges (the certified prefix is never re-scanned) and each window
    only appends drawn slots."""
    import repro.core.batch_jax as bj
    # single seed: the recorded (p0, p1) ranges are exact per-seed scan
    # positions (multi-seed runs record the bounding box across seeds)
    n, S, K = 6, 1, 24
    means = np.arange(1, n + 1, dtype=float) ** 1.5
    model = shifted_exponential_times(np.zeros(n), 1.0 / means)
    meta = {}
    bj._chain_scan_run(model, None, False, K + 1, False, n, S, K, 0.0,
                       [0], chain_len=4, meta=meta)
    assert meta["windows"] >= 2, "chain_len=4 must force a retry"
    ranges = meta["scan_ranges"]
    assert ranges, "windowed engine must record its scan ranges"
    for (p0, p1) in ranges:
        assert p0 < p1
    for (_, p1), (q0, _) in zip(ranges, ranges[1:]):
        assert q0 >= p1, ("window re-scanned part of the certified "
                          f"prefix: {ranges}")
    drawn = meta["drawn_slots"]                  # per-window extension draws
    assert len(drawn) == meta["windows"]
    assert all(d > 0 for d in drawn), \
        "every window must draw a nonempty extension, never redraw"


# ------------------------------------------------------------ smallest_k
def _tie_heavy_rows(flat, rows):
    """Reshape a drawn flat list into a (rows, cols) float array; the
    tiny sampled_from support set forces heavy ties."""
    cols = len(flat) // rows
    return np.asarray(flat[:rows * cols], dtype=np.float64).reshape(
        rows, cols)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.sampled_from([0.0, 0.5, 1.0, 1.0, 2.0]),
                min_size=4, max_size=24),
       st.integers(1, 4), st.integers(1, 24))
def test_smallest_k_tie_contract(flat, rows, k):
    """values ascending, indices = NumPy stable argsort prefix (ties by
    flat index), and values == x[indices] — on tie-heavy rows."""
    rows = max(1, min(rows, len(flat) // 2))
    x = _tie_heavy_rows(flat, rows)
    k = max(1, min(k, x.shape[1]))
    vals, idx = smallest_k(jnp.asarray(x), k)
    vals, idx = np.asarray(vals), np.asarray(idx)
    ref_idx = np.argsort(x, axis=-1, kind="stable")[:, :k]
    np.testing.assert_array_equal(idx, ref_idx)
    np.testing.assert_array_equal(vals,
                                  np.take_along_axis(x, ref_idx, axis=-1))
    assert (np.diff(vals, axis=-1) >= 0).all()


@settings(max_examples=15, deadline=None)
@given(st.lists(st.floats(0.0, 4.0), min_size=4, max_size=24),
       st.integers(1, 24), st.booleans())
def test_smallest_k_host_device_agree(flat, k, host_first):
    """The host (NumPy) and device (jnp stable argsort) paths are
    bitwise interchangeable — same values AND same tie-broken indices."""
    x = _tie_heavy_rows(flat, 2)
    k = max(1, min(k, x.shape[1]))
    xj = jnp.asarray(x)
    order = [True, False] if host_first else [False, True]
    (v1, i1), (v2, i2) = (smallest_k(xj, k, prefer_host=h) for h in order)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
