"""Property tests for the two engine-load-bearing RNG/sort contracts.

Real ``hypothesis`` in CI; the deterministic conftest stand-in in the
container (same ``@given``/``strategies`` subset either way):

* :func:`repro.core.time_models.jax_chain_draws` — **prefix stability**:
  row ``(s, j)`` is a pure function of ``(seed key, slot j)`` via
  ``fold_in``, so growing ``L`` appends rows and never reshuffles
  existing ones. The arrival-scan and ringleader engines' chain-doubling
  retries rely on this to keep already-completed work bitwise identical
  across retries.
* :func:`repro.kernels.order_stats.smallest_k` — **tie contract**: the
  ``k`` smallest per row in ascending order with ties broken by flat
  index (stable), bitwise equal between the host (NumPy stable argsort)
  and device (``jnp.argsort(stable=True)``) paths. The async pool merge
  orders simultaneous arrivals by (worker, arrival index) through
  exactly this property.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.time_models import exponential_times, jax_chain_draws
from repro.kernels.order_stats import smallest_k


def _chain_keys(seeds):
    return jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])


# ------------------------------------------------------- jax_chain_draws
@settings(max_examples=15, deadline=None)
@given(st.integers(1, 6), st.integers(1, 12), st.integers(1, 12),
       st.lists(st.integers(0, 2 ** 20), min_size=1, max_size=4))
def test_chain_draws_prefix_stable(n, L1, extra, seeds):
    """Growing L only appends rows: the shorter chain is a bitwise
    prefix of the longer one, per seed and per worker."""
    sampler = exponential_times(1.0, n).jax_sampler
    keys = _chain_keys(seeds)
    short = np.asarray(jax_chain_draws(keys, L1, sampler))
    long = np.asarray(jax_chain_draws(keys, L1 + extra, sampler))
    assert short.shape == (len(seeds), L1, n)
    np.testing.assert_array_equal(short, long[:, :L1])


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 6), st.integers(1, 10),
       st.lists(st.integers(0, 2 ** 20), min_size=2, max_size=4),
       st.integers(0, 3))
def test_chain_draws_sweep_independent(n, L, seeds, pick):
    """Row (s, j) depends only on (seed key, j): a seed's chain in a
    multi-seed sweep equals its singleton-sweep chain bitwise, and
    equals the per-slot fold_in spelling of the contract."""
    pick = pick % len(seeds)
    sampler = exponential_times(1.0, n).jax_sampler
    batch = np.asarray(jax_chain_draws(_chain_keys(seeds), L, sampler))
    solo = np.asarray(jax_chain_draws(_chain_keys([seeds[pick]]), L,
                                      sampler))
    np.testing.assert_array_equal(batch[pick], solo[0])
    key = jax.random.PRNGKey(int(seeds[pick]))
    for j in (0, L - 1):
        row = np.asarray(sampler(jax.random.fold_in(key, j)))
        np.testing.assert_array_equal(batch[pick, j], row)


# ------------------------------------------------------------ smallest_k
def _tie_heavy_rows(flat, rows):
    """Reshape a drawn flat list into a (rows, cols) float array; the
    tiny sampled_from support set forces heavy ties."""
    cols = len(flat) // rows
    return np.asarray(flat[:rows * cols], dtype=np.float64).reshape(
        rows, cols)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.sampled_from([0.0, 0.5, 1.0, 1.0, 2.0]),
                min_size=4, max_size=24),
       st.integers(1, 4), st.integers(1, 24))
def test_smallest_k_tie_contract(flat, rows, k):
    """values ascending, indices = NumPy stable argsort prefix (ties by
    flat index), and values == x[indices] — on tie-heavy rows."""
    rows = max(1, min(rows, len(flat) // 2))
    x = _tie_heavy_rows(flat, rows)
    k = max(1, min(k, x.shape[1]))
    vals, idx = smallest_k(jnp.asarray(x), k)
    vals, idx = np.asarray(vals), np.asarray(idx)
    ref_idx = np.argsort(x, axis=-1, kind="stable")[:, :k]
    np.testing.assert_array_equal(idx, ref_idx)
    np.testing.assert_array_equal(vals,
                                  np.take_along_axis(x, ref_idx, axis=-1))
    assert (np.diff(vals, axis=-1) >= 0).all()


@settings(max_examples=15, deadline=None)
@given(st.lists(st.floats(0.0, 4.0), min_size=4, max_size=24),
       st.integers(1, 24), st.booleans())
def test_smallest_k_host_device_agree(flat, k, host_first):
    """The host (NumPy) and device (jnp stable argsort) paths are
    bitwise interchangeable — same values AND same tie-broken indices."""
    x = _tie_heavy_rows(flat, 2)
    k = max(1, min(k, x.shape[1]))
    xj = jnp.asarray(x)
    order = [True, False] if host_first else [False, True]
    (v1, i1), (v2, i2) = (smallest_k(xj, k, prefer_host=h) for h in order)
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
