"""ISSUE 6: tests for the ``repro.analysis`` contract analyzer.

Three layers: (1) minimal good/bad fixture snippets per rule — every
rule ID must fire on its bad snippet and stay silent on the good twin;
(2) registry cross-check drift on a miniature strategies/scenarios/
time_models/DESIGN quartet AND on mutated copies of the real repo files
(the acceptance criterion: deleting a §3b matrix row or a STRATEGIES
registration must fail the check); (3) the live repo is finding-free
under the shipped pragma set, which is also what the CI repcheck lane
asserts. The perf-gate failure modes (per-lane diff rows, exit-code
split) ride along at the bottom.
"""

from __future__ import annotations

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (RULES, analyze, load_module, main,
                            parse_design_tables, parse_pragmas,
                            run_purity_pass, run_registry_pass,
                            run_rng_pass)

ROOT = Path(__file__).resolve().parents[1]


def _mod(tmp_path, src, name="snippet.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(src))
    return load_module(p, rel=name)


def _rules(findings):
    return sorted(f.rule for f in findings)


# ------------------------------------------------------------ RNG rules
def test_rng001_literal_prngkey_in_body(tmp_path):
    bad = _mod(tmp_path, """
        import jax

        def engine(n):
            key = jax.random.PRNGKey(0)
            return jax.random.normal(key, (n,))
        """)
    assert _rules(run_rng_pass(bad, jax_only=False)) == ["RNG001"]


def test_rng001_good_twins(tmp_path):
    good = _mod(tmp_path, """
        import jax

        SEED_KEY = jax.random.PRNGKey(0)        # module level: allowed

        def engine(key, s, n):
            k1 = jax.random.fold_in(key, 3)     # derivation: allowed
            root = jax.random.PRNGKey(int(s))   # non-constant: allowed
            return jax.random.normal(k1, (n,)) + jax.random.uniform(
                root, (n,))
        """)
    assert run_rng_pass(good, jax_only=False) == []


def test_rng002_duplicate_key_expression(tmp_path):
    bad = _mod(tmp_path, """
        import jax

        def engine(key, n):
            a = jax.random.normal(key, (n,))
            b = jax.random.uniform(key, (n,))
            return a + b
        """)
    findings = run_rng_pass(bad, jax_only=False)
    assert _rules(findings) == ["RNG002"]
    assert "already feeds the draw" in findings[0].message


def test_rng002_subscript_key_reuse_and_split_ok(tmp_path):
    bad = _mod(tmp_path, """
        import jax

        def engine(key, n):
            sub = jax.random.split(key, 2)
            a = jax.random.normal(sub[0], (n,))
            b = jax.random.uniform(sub[0], (n,))
            return a + b
        """)
    assert _rules(run_rng_pass(bad, jax_only=False)) == ["RNG002"]
    good = _mod(tmp_path, """
        import jax

        def engine(key, n):
            sub = jax.random.split(key, 2)
            return (jax.random.normal(sub[0], (n,))
                    + jax.random.uniform(sub[1], (n,)))
        """, name="good.py")
    assert run_rng_pass(good, jax_only=False) == []


def test_rng002_reassigned_key_not_flagged(tmp_path):
    # the carry idiom: key is split and rebound between the two draws,
    # so the syntactically-equal expressions name different streams
    good = _mod(tmp_path, """
        import jax

        def engine(key, n):
            a = jax.random.normal(key, (n,))
            key, _ = jax.random.split(key)
            b = jax.random.normal(key, (n,))
            return a + b
        """)
    assert run_rng_pass(good, jax_only=False) == []


def test_rng003_host_rng_in_jax_only_module(tmp_path):
    src = """
        import numpy as np

        def engine(n):
            return np.random.default_rng(0).normal(size=n)
        """
    bad = _mod(tmp_path, src)
    assert _rules(run_rng_pass(bad, jax_only=True)) == ["RNG003"]
    # the same code in a NumPy-layer module (time_models) is legitimate
    assert run_rng_pass(_mod(tmp_path, src, name="tm.py"),
                        jax_only=False) == []


# ------------------------------------------------------------ JIT rules
def test_jit001_host_coercion_in_jitted_fn(tmp_path):
    bad = _mod(tmp_path, """
        import jax

        @jax.jit
        def f(x):
            return float(x) + 1.0
        """)
    assert _rules(run_purity_pass(bad, x64_strict=False)) == ["JIT001"]


def test_jit001_item_and_np_asarray(tmp_path):
    bad = _mod(tmp_path, """
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            y = x * 2
            return np.asarray(y), y.item()
        """)
    assert _rules(run_purity_pass(bad, x64_strict=False)) \
        == ["JIT001", "JIT001"]


def test_jit001_static_coercions_allowed(tmp_path):
    good = _mod(tmp_path, """
        import functools
        import jax
        import numpy as np

        @functools.partial(jax.jit, static_argnames=("n",))
        def f(x, n):
            pad = int(n) + 1          # static arg: host int is fine
            c = np.arange(4)          # closure constant: fine
            return x * pad + c.sum()
        """)
    assert run_purity_pass(good, x64_strict=False) == []


def test_jit002_python_branch_in_loop_body(tmp_path):
    bad = _mod(tmp_path, """
        from jax import lax

        def outer(c0):
            def body(c):
                if c > 0:
                    return c - 1
                return c
            return lax.while_loop(lambda c: c < 10, body, c0)
        """)
    assert _rules(run_purity_pass(bad, x64_strict=False)) == ["JIT002"]


def test_jit002_static_tests_allowed(tmp_path):
    good = _mod(tmp_path, """
        from jax import lax

        def outer(c0, flag=None):
            def body(c):
                if flag is None:          # pytree-structure test: static
                    return c - 1
                return c - 2
            return lax.while_loop(lambda c: c < 10, body, c0)
        """)
    assert run_purity_pass(good, x64_strict=False) == []


def test_jit003_print_in_scan_step(tmp_path):
    bad = _mod(tmp_path, """
        from jax import lax

        def outer(xs, c0):
            def step(c, x):
                print(c)
                return c + x, c
            return lax.scan(step, c0, xs)
        """)
    assert _rules(run_purity_pass(bad, x64_strict=False)) == ["JIT003"]


def test_jit003_time_in_traced_closure(tmp_path):
    # helper called from a jitted fn is traced too (within-module
    # closure resolution): its time.time() fires at trace time only
    bad = _mod(tmp_path, """
        import time
        import jax

        def stamp(x):
            t0 = time.time()
            return x + t0

        @jax.jit
        def f(x):
            return stamp(x)
        """)
    assert _rules(run_purity_pass(bad, x64_strict=False)) == ["JIT003"]


def test_jit004_attribute_mutation(tmp_path):
    bad = _mod(tmp_path, """
        import jax

        @jax.jit
        def f(x, box):
            box.cache = x
            return x
        """)
    assert _rules(run_purity_pass(bad, x64_strict=False)) == ["JIT004"]


def test_jit005_hardcoded_dtype_x64_strict_only(tmp_path):
    src = """
        import jax.numpy as jnp
        from jax import lax

        def outer(c0):
            def body(c):
                return c * jnp.ones(3, jnp.float32)
            return lax.while_loop(lambda c: c[0] < 9, body, c0)
        """
    bad = _mod(tmp_path, src)
    assert _rules(run_purity_pass(bad, x64_strict=True)) == ["JIT005"]
    # modules without an x64 engine mode are out of scope for JIT005
    assert run_purity_pass(bad, x64_strict=False) == []


def test_untraced_host_code_not_flagged(tmp_path):
    good = _mod(tmp_path, """
        import time

        def dispatcher(x):
            t0 = time.time()              # host code: fine
            print("running", float(x))    # host code: fine
            return x
        """)
    assert run_purity_pass(good, x64_strict=True) == []


# -------------------------------------------------------------- pragmas
def test_pragma_suppresses_named_rule(tmp_path):
    mod = _mod(tmp_path, """
        import jax

        def engine(n):
            key = jax.random.PRNGKey(0)  # repcheck: ignore[RNG001]
            return jax.random.normal(key, (n,))
        """)
    assert run_rng_pass(mod, jax_only=False) == []


def test_pragma_other_rule_does_not_suppress(tmp_path):
    mod = _mod(tmp_path, """
        import jax

        def engine(n):
            key = jax.random.PRNGKey(0)  # repcheck: ignore[JIT001]
            return jax.random.normal(key, (n,))
        """)
    assert _rules(run_rng_pass(mod, jax_only=False)) == ["RNG001"]


def test_pragma_parsing_star_and_lists():
    pragmas = parse_pragmas(
        "a = 1  # repcheck: ignore[RNG001, JIT003]\n"
        "b = 2\n"
        "c = 3  # repcheck: ignore[*]\n")
    assert pragmas == {1: {"RNG001", "JIT003"}, 3: {"*"}}


# ----------------------------------------------------- registry (fixtures)
_MINI_STRATEGIES = """
STRATEGIES = {}


def register_strategy(name):
    def deco(f):
        STRATEGIES[name] = f
        return f
    return deco


@register_strategy("msync")
def make_msync():
    pass


@register_strategy("malenia")
def make_malenia():
    pass
"""

_MINI_SCENARIOS = """
from repro.core.time_models import FixedTimes, exponential_times

SCENARIOS = {}


def register_scenario(name):
    def deco(f):
        SCENARIOS[name] = f
        return f
    return deco


@register_scenario("fixed_sqrt")
def fixed_sqrt(n):
    return FixedTimes.sqrt_law(n)


@register_scenario("exponential")
def exponential(n):
    return exponential_times(1.0, n)
"""

_MINI_TIME_MODELS = """
class FixedTimes:
    @staticmethod
    def sqrt_law(n):
        return n


def exponential_times(lam, n):
    return n
"""

_MINI_MATRIX = """
COVERAGE = {
    "msync": ("serial",),
    "malenia": ("serial", "jax"),
}
"""

_MINI_SWEEP = """
SHARDED_KINDS = ("msync", "malenia")
"""

_MINI_DESIGN = """# design

## §3b Engine coverage

| strategy \\ model | Fixed |
|------------------|-------|
| msync            | serial |
| malenia          | serial, jax |

| scenario    | family |
|-------------|--------|
| fixed_sqrt  | Fixed  |
| exponential | SubExp |

| sharded kind | engine program |
|--------------|----------------|
| `msync`      | round scan     |
| `malenia`    | renewal rounds |

## §4 Other section

| strategy \\ model | ignored |
|------------------|---------|
| bogus            | table outside §3b |
"""


@pytest.fixture
def mini_repo(tmp_path):
    paths = {
        "strategies": tmp_path / "strategies.py",
        "scenarios": tmp_path / "scenarios.py",
        "time_models": tmp_path / "time_models.py",
        "design": tmp_path / "DESIGN.md",
        "matrix": tmp_path / "test_strategy_matrix.py",
        "sweep": tmp_path / "sweep.py",
    }
    paths["strategies"].write_text(_MINI_STRATEGIES)
    paths["scenarios"].write_text(_MINI_SCENARIOS)
    paths["time_models"].write_text(_MINI_TIME_MODELS)
    paths["design"].write_text(_MINI_DESIGN)
    paths["matrix"].write_text(_MINI_MATRIX)
    paths["sweep"].write_text(_MINI_SWEEP)
    return paths


def _run_mini(paths):
    return run_registry_pass(
        paths["design"].parent,
        strategies_path=paths["strategies"],
        scenarios_path=paths["scenarios"],
        time_models_path=paths["time_models"],
        design_path=paths["design"],
        matrix_test_path=paths["matrix"],
        sweep_path=paths["sweep"])


def test_registry_mini_repo_clean(mini_repo):
    assert _run_mini(mini_repo) == []


def test_reg001_strategy_missing_from_matrix(mini_repo):
    design = mini_repo["design"].read_text()
    mini_repo["design"].write_text(
        "\n".join(l for l in design.splitlines()
                  if not l.startswith("| malenia")))
    findings = _run_mini(mini_repo)
    assert _rules(findings) == ["REG001"]
    assert "malenia" in findings[0].message


def test_reg002_matrix_row_without_registration(mini_repo):
    strat = mini_repo["strategies"].read_text()
    mini_repo["strategies"].write_text(
        strat.replace('@register_strategy("malenia")\n', ""))
    findings = _run_mini(mini_repo)
    # the dropped registration orphans BOTH tables that still name it:
    # the DESIGN matrix row (REG002) and the parity COVERAGE row (REG006)
    assert _rules(findings) == ["REG002", "REG006"]
    assert all("malenia" in f.message for f in findings)


def test_reg003_scenario_missing_from_table(mini_repo):
    design = mini_repo["design"].read_text()
    mini_repo["design"].write_text(
        "\n".join(l for l in design.splitlines()
                  if not l.startswith("| exponential")))
    findings = _run_mini(mini_repo)
    assert _rules(findings) == ["REG003"]


def test_reg004_table_row_without_registration(mini_repo):
    design = mini_repo["design"].read_text()
    mini_repo["design"].write_text(design.replace(
        "| exponential | SubExp |",
        "| exponential | SubExp |\n| ghost_scenario | SubExp |"))
    findings = _run_mini(mini_repo)
    assert _rules(findings) == ["REG004"]
    assert "ghost_scenario" in findings[0].message


def test_reg005_nonexistent_factory(mini_repo):
    scen = mini_repo["scenarios"].read_text()
    mini_repo["scenarios"].write_text(scen.replace(
        "FixedTimes.sqrt_law(n)", "FixedTimes.cube_law(n)"))
    findings = _run_mini(mini_repo)
    assert _rules(findings) == ["REG005"]
    assert "cube_law" in findings[0].message


def test_reg005_import_of_missing_name(mini_repo):
    scen = mini_repo["scenarios"].read_text()
    mini_repo["scenarios"].write_text(scen.replace(
        "FixedTimes, exponential_times",
        "FixedTimes, exponential_times, gamma_times"))
    findings = _run_mini(mini_repo)
    assert _rules(findings) == ["REG005"]
    assert "gamma_times" in findings[0].message


def test_reg006_registration_without_coverage_row(mini_repo):
    """ISSUE 9: a STRATEGIES entry with no parity-matrix COVERAGE row is
    REG006 drift (pointing at the registration line)."""
    matrix = mini_repo["matrix"].read_text()
    mini_repo["matrix"].write_text(
        matrix.replace('    "malenia": ("serial", "jax"),\n', ""))
    findings = _run_mini(mini_repo)
    assert _rules(findings) == ["REG006"]
    assert "malenia" in findings[0].message
    assert findings[0].path == str(mini_repo["strategies"])


def test_reg006_coverage_row_without_registration(mini_repo):
    matrix = mini_repo["matrix"].read_text()
    mini_repo["matrix"].write_text(matrix.replace(
        '"malenia": ("serial", "jax"),',
        '"malenia": ("serial", "jax"),\n    "ghost": ("serial",),'))
    findings = _run_mini(mini_repo)
    assert _rules(findings) == ["REG006"]
    assert "ghost" in findings[0].message
    assert findings[0].path == str(mini_repo["matrix"])


def test_reg006_missing_matrix_test_is_structural(mini_repo):
    mini_repo["matrix"].unlink()
    findings = _run_mini(mini_repo)
    assert _rules(findings) == ["REG006"]
    assert "missing" in findings[0].message


def test_reg006_no_coverage_literal_is_structural(mini_repo):
    mini_repo["matrix"].write_text("COVERAGE = build_coverage()\n")
    findings = _run_mini(mini_repo)
    assert _rules(findings) == ["REG006"]
    assert "dict literal" in findings[0].message


def test_reg007_kind_missing_from_sharded_table(mini_repo):
    """ISSUE 10: a SHARDED_KINDS entry the DESIGN §3b sharded backend
    table does not document is REG007 drift (pointing at the literal)."""
    mini_repo["sweep"].write_text(
        'SHARDED_KINDS = ("msync", "malenia", "ghost_kind")\n')
    findings = _run_mini(mini_repo)
    assert _rules(findings) == ["REG007"]
    assert "ghost_kind" in findings[0].message
    assert findings[0].path == str(mini_repo["sweep"])


def test_reg007_table_row_without_sharded_kind(mini_repo):
    design = mini_repo["design"].read_text()
    mini_repo["design"].write_text(design.replace(
        "| `malenia`    | renewal rounds |",
        "| `malenia`    | renewal rounds |\n| `phantom` | nothing |"))
    findings = _run_mini(mini_repo)
    assert _rules(findings) == ["REG007"]
    assert "phantom" in findings[0].message
    assert "fall back" in findings[0].message
    assert findings[0].path == str(mini_repo["design"])


def test_reg007_missing_sweep_is_structural(mini_repo):
    mini_repo["sweep"].unlink()
    findings = _run_mini(mini_repo)
    assert _rules(findings) == ["REG007"]
    assert "missing" in findings[0].message


def test_reg007_no_kinds_literal_is_structural(mini_repo):
    mini_repo["sweep"].write_text("SHARDED_KINDS = make_kinds()\n")
    findings = _run_mini(mini_repo)
    assert _rules(findings) == ["REG007"]
    assert "literal" in findings[0].message


def test_reg007_no_sharded_table_is_structural(mini_repo):
    design = mini_repo["design"].read_text()
    mini_repo["design"].write_text(design.replace(
        "| sharded kind | engine program |\n"
        "|--------------|----------------|\n"
        "| `msync`      | round scan     |\n"
        "| `malenia`    | renewal rounds |\n", ""))
    findings = _run_mini(mini_repo)
    assert _rules(findings) == ["REG007"]
    assert "sharded" in findings[0].message.lower()


def test_missing_matrix_table_is_structural_finding(mini_repo):
    mini_repo["design"].write_text("# design\n\n## §3b Engines\n\nprose\n")
    rules = _rules(_run_mini(mini_repo))
    assert "REG002" in rules and "REG004" in rules    # tables missing
    assert "REG001" in rules and "REG003" in rules    # all regs unmatched


# ------------------------------------------------- registry (live repo)
def test_live_registry_crosscheck_clean():
    """The plain-pytest spelling of the CI repcheck registry lane:
    STRATEGIES / SCENARIOS / time_models / DESIGN §3b are in lockstep."""
    assert run_registry_pass(ROOT) == []


def test_live_design_tables_cover_all_registrations():
    matrix, scen = parse_design_tables(ROOT / "DESIGN.md")
    assert matrix is not None and scen is not None
    assert set(matrix) == {"sync", "msync", "auto_m", "async", "rennala",
                           "malenia", "ringmaster", "ringleader",
                           "optimal_asgd", "deadline", "dropout"}
    # 16 base regimes (incl. the PR 10 power-law pair) + 6 §3c faults
    assert len(scen) == 22


def test_live_coverage_table_matches_design_matrix():
    """The parity COVERAGE table and the DESIGN §3b matrix name exactly
    the same strategies (the REG006 + REG001/REG002 triangle, spelled
    out directly)."""
    from repro.analysis import parse_coverage_table
    matrix, _ = parse_design_tables(ROOT / "DESIGN.md")
    coverage = parse_coverage_table(ROOT / "tests/test_strategy_matrix.py")
    assert coverage is not None
    assert set(coverage) == set(matrix)


def test_live_sharded_table_matches_sharded_kinds():
    """ISSUE 10: the DESIGN §3b sharded-kind table, the parsed
    SHARDED_KINDS literal, and the imported tuple agree exactly (the
    REG007 lockstep, spelled out directly)."""
    from repro.analysis import collect_sharded_kinds, parse_sharded_table
    from repro.launch.sweep import SHARDED_KINDS
    table = parse_sharded_table(ROOT / "DESIGN.md")
    kinds = collect_sharded_kinds(ROOT / "src/repro/launch/sweep.py")
    assert table is not None and kinds is not None
    assert set(table) == set(kinds) == set(SHARDED_KINDS)


def test_deleting_live_coverage_row_fails_crosscheck(tmp_path):
    """ISSUE 9 acceptance: dropping a COVERAGE row from the live parity
    test breaks the REG006 cross-check."""
    src = (ROOT / "tests/test_strategy_matrix.py").read_text()
    mutated = tmp_path / "test_strategy_matrix.py"
    mutated.write_text(src.replace(
        '    "ringleader": ("serial", "jax"),\n', ""))
    findings = run_registry_pass(ROOT, matrix_test_path=mutated)
    assert any(f.rule == "REG006" and "ringleader" in f.message
               for f in findings)


def test_deleting_live_matrix_row_fails_crosscheck(tmp_path):
    """Acceptance: deleting any §3b matrix row breaks the cross-check."""
    design = (ROOT / "DESIGN.md").read_text()
    mutated = tmp_path / "DESIGN.md"
    mutated.write_text("\n".join(
        l for l in design.splitlines() if not l.startswith("| rennala")))
    findings = run_registry_pass(ROOT, design_path=mutated)
    assert any(f.rule == "REG001" and "rennala" in f.message
               for f in findings)


def test_deleting_live_strategy_registration_fails_crosscheck(tmp_path):
    """Acceptance: dropping a STRATEGIES entry breaks the cross-check."""
    strat = (ROOT / "src/repro/core/strategies.py").read_text()
    mutated = tmp_path / "strategies.py"
    mutated.write_text(
        strat.replace('@register_strategy("ringmaster")\n', ""))
    findings = run_registry_pass(ROOT, strategies_path=mutated)
    assert any(f.rule == "REG002" and "ringmaster" in f.message
               for f in findings)


# ------------------------------------------------------ live repo + CLI
def test_live_repo_is_finding_free():
    """ISSUE 6 acceptance: the analyzer exits clean on the whole tree
    under the shipped pragma set (the CI repcheck lane's assertion)."""
    assert analyze(ROOT) == []


def test_cli_json_on_bad_tree(tmp_path, capsys):
    engine_dir = tmp_path / "kernels"
    engine_dir.mkdir()
    (engine_dir / "bad.py").write_text(textwrap.dedent("""
        import numpy as np

        def engine(n):
            return np.random.normal(size=n)
        """))
    rc = main(["--root", str(tmp_path), "--format", "json",
               str(engine_dir)])
    out = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert out["count"] == 1
    assert out["findings"][0]["rule"] == "RNG003"
    assert out["findings"][0]["line"] == 5


def test_cli_text_clean_dir(tmp_path, capsys):
    (tmp_path / "ok.py").write_text("x = 1\n")
    rc = main(["--root", str(tmp_path), str(tmp_path)])
    assert rc == 0
    assert "clean" in capsys.readouterr().out


def test_cli_list_rules_covers_all_ids(capsys):
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in RULES:
        assert rule in out


def test_cli_subprocess_end_to_end():
    """The exact CI repcheck invocation exits 0 on the real tree."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--format", "json"],
        cwd=ROOT, capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert json.loads(proc.stdout)["count"] == 0


# ------------------------------------------------------------ perf gate
def _gate_doc(**sections):
    doc = {"meta": {"n": 4, "K": 10}}
    doc.update(sections)
    return doc


def test_perf_gate_exit_code_split():
    from benchmarks import perf_gate
    base = _gate_doc(speedup_vs_serial={"jax": 5.0},
                     total_time_mean={"async": 1.0})
    ok = perf_gate.compare(base, base, tol=0.3)
    assert ok == [] and perf_gate.exit_code(ok) == perf_gate.EXIT_OK

    slow = _gate_doc(speedup_vs_serial={"jax": 2.0},
                     total_time_mean={"async": 1.0})
    reg = perf_gate.compare(slow, base, tol=0.3)
    assert [f.kind for f in reg] == ["regression"]
    assert perf_gate.exit_code(reg) == perf_gate.EXIT_REGRESSION

    missing = _gate_doc(speedup_vs_serial={"jax": 5.0})
    struct = perf_gate.compare(missing, base, tol=0.3)
    assert any(f.kind == "structural" for f in struct)
    assert perf_gate.exit_code(struct) == perf_gate.EXIT_STRUCTURAL


def test_perf_gate_meta_mismatch_is_structural():
    from benchmarks import perf_gate
    a = _gate_doc(total_time_mean={"async": 1.0})
    b = _gate_doc(total_time_mean={"async": 1.0})
    b["meta"]["n"] = 8
    failures = perf_gate.compare(a, b, tol=0.3)
    assert [f.kind for f in failures] == ["structural"]
    assert "config mismatch" in failures[0].bound


def test_perf_gate_failure_row_is_readable():
    from benchmarks import perf_gate
    base = _gate_doc(speedup_vs_serial={"jax_vs_serial": 5.0})
    slow = _gate_doc(speedup_vs_serial={"jax_vs_serial": 2.0})
    (failure,) = perf_gate.compare(slow, base, tol=0.3)
    row = failure.row()
    assert "speedup_vs_serial.jax_vs_serial" in row
    assert "2" in row and "5" in row and "floor" in failure.bound


def test_perf_gate_cli_exit_codes(tmp_path, capsys):
    from benchmarks import perf_gate
    base = tmp_path / "base.json"
    meas = tmp_path / "meas.json"
    base.write_text(json.dumps(
        _gate_doc(speedup_vs_serial={"jax": 5.0})))
    meas.write_text(json.dumps(
        _gate_doc(speedup_vs_serial={"jax": 2.0})))
    assert perf_gate.main([str(meas), str(base)]) \
        == perf_gate.EXIT_REGRESSION
    out = capsys.readouterr().out
    assert "lane" in out and "measured" in out and "baseline" in out
    assert perf_gate.main([str(meas), str(tmp_path / "absent.json")]) \
        == perf_gate.EXIT_STRUCTURAL
    assert perf_gate.main([str(base), str(base)]) == perf_gate.EXIT_OK
