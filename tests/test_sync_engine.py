"""Tests for the mesh-level m-sync engine (core/sync_engine)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FixedTimes, SimulatedStraggler, SyncMode, SyncPolicy,
                        first_m_mask, participation_example_weights,
                        uniform_times)


def test_first_m_mask():
    times = np.array([5.0, 1.0, 3.0, 2.0])
    mask = first_m_mask(times, 2)
    np.testing.assert_array_equal(mask, [False, True, False, True])
    assert first_m_mask(times, 4).all()


def test_first_m_mask_ties_stable():
    mask = first_m_mask(np.array([1.0, 1.0, 1.0]), 2)
    np.testing.assert_array_equal(mask, [True, True, False])


def test_participation_weights_mean_preserving():
    # weighted mean over the batch == mean over participating groups
    mask = jnp.asarray([True, False, True, False])
    w = participation_example_weights(mask, 4, 16)
    assert w.shape == (16,)
    assert float(w.sum()) == pytest.approx(16.0)  # mean-preserving
    # nonparticipants weighted 0, participants n/m = 2
    np.testing.assert_allclose(np.asarray(w[:4]), 2.0)
    np.testing.assert_allclose(np.asarray(w[4:8]), 0.0)


def test_straggler_m_sync_duration_is_mth_order_stat():
    model = FixedTimes(np.array([1.0, 2.0, 3.0, 100.0]))
    st = SimulatedStraggler(model, SyncPolicy(SyncMode.M_SYNC, m=3))
    mask, m, dur = st.step()
    assert m == 3
    assert dur == pytest.approx(3.0)
    np.testing.assert_array_equal(mask, [True, True, True, False])


def test_straggler_full_waits_for_max():
    model = FixedTimes(np.array([1.0, 50.0]))
    st = SimulatedStraggler(model, SyncPolicy(SyncMode.FULL))
    _, m, dur = st.step()
    assert (m, dur) == (2, pytest.approx(50.0))


def test_deadline_mask_and_fallback():
    model = FixedTimes(np.array([0.5, 0.9, 30.0]))
    st = SimulatedStraggler(model, SyncPolicy(SyncMode.DEADLINE,
                                              deadline=1.0))
    mask, m, dur = st.step()
    assert m == 2 and dur <= 1.0
    # deadline so tight nobody finishes: falls back to the fastest worker
    st2 = SimulatedStraggler(model, SyncPolicy(SyncMode.DEADLINE,
                                               deadline=0.1))
    mask2, m2, _ = st2.step()
    assert m2 == 1 and mask2[0]


def test_auto_m_warmup_uses_all_workers():
    model = uniform_times(np.ones(4), 0.1)
    st = SimulatedStraggler(model, SyncPolicy(SyncMode.AUTO_M))
    _, m, _ = st.step()  # estimator has no sigma yet -> full participation
    assert m == 4


def test_wallclock_accumulates():
    model = FixedTimes(np.array([1.0, 2.0]))
    st = SimulatedStraggler(model, SyncPolicy(SyncMode.FULL))
    for _ in range(5):
        st.step()
    assert st.wallclock == pytest.approx(10.0)


def test_masked_group_mean_shard_map():
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.core import masked_group_mean
    mesh = Mesh(np.array(jax.devices()[:4]), ("dp",))
    grads = jnp.arange(4.0)          # per-group scalar "gradient"
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0])

    def f(g, mk):
        return masked_group_mean(g, mk, "dp")

    out = shard_map(f, mesh=mesh, in_specs=(P("dp"), P("dp")),
                    out_specs=P("dp"))(grads, mask)
    # every group holds the m-sync estimator: (0 + 2)/2 = 1
    np.testing.assert_allclose(np.asarray(out), 1.0)
