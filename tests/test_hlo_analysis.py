"""Unit tests for the loop-aware HLO cost/collective parser, validated
against programs with analytically known costs on a multi-device CPU mesh.

These tests need >1 host device; they run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps its single-device view (smoke tests rely on it).
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.hlo_analysis import (Roofline, _shape_bytes,
                                       collective_bytes, hlo_cost,
                                       roofline_terms, CollectiveStats)


def _run_sub(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


SCAN_PROG = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.hlo_analysis import hlo_cost, collective_bytes
    N, L = 128, 7
    from repro.launch.mesh import make_mesh_auto
    mesh = make_mesh_auto((2, 4), ("data", "model"))
    shard = NamedSharding(mesh, P(None, "model"))
    def f(x, ws):
        def body(x, w):
            y = x @ w
            return jax.lax.with_sharding_constraint(y, shard), None
        x, _ = jax.lax.scan(body, x, ws)
        return x.sum()
    xs = jax.ShapeDtypeStruct((N, N), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, N, N), jnp.float32)
    with mesh:
        c = jax.jit(f, in_shardings=(shard,
            NamedSharding(mesh, P(None, "model", None)))).lower(xs, ws) \\
            .compile()
    txt = c.as_text()
    cost = hlo_cost(txt)
    coll = collective_bytes(txt)
    raw = c.cost_analysis()
    if isinstance(raw, (list, tuple)):   # jax < 0.5 returns one per device
        raw = raw[0]
    print(json.dumps({"flops": cost["flops"], "bytes": cost["bytes"],
                      "raw_flops": float(raw["flops"]),
                      "ar": coll.by_kind["all-reduce"],
                      "count": coll.count}))
""")


@pytest.fixture(scope="module")
def scan_result():
    return _run_sub(SCAN_PROG)


def test_loop_trip_count_scales_flops(scan_result):
    N, L = 128, 7
    # contraction dim sharded over model=4: per-device k = N/4
    expect = 2 * (N * N) * (N // 4) * L
    assert scan_result["flops"] == pytest.approx(expect, rel=0.05)
    # XLA's own analysis counts the body once — ours must be ~L larger
    assert scan_result["flops"] > 3 * scan_result["raw_flops"]


def test_loop_collectives_scaled(scan_result):
    N, L = 128, 7
    # one all-reduce of the full (N, N) f32 result per iteration (+ scalar)
    assert scan_result["ar"] == pytest.approx(N * N * 4 * L, rel=0.01)
    assert scan_result["count"] >= L


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert _shape_bytes("bf16[10]") == 20
    assert _shape_bytes("(f32[2,2], s32[3])") == 16 + 12
    assert _shape_bytes("pred[]") == 1


def test_collective_parser_on_synthetic_hlo():
    hlo = textwrap.dedent("""
    HloModule m
    ENTRY %main (a: f32[64,64]) -> f32[64,64] {
      %a = f32[64,64]{1,0} parameter(0)
      %ar = f32[64,64]{1,0} all-reduce(%a), replica_groups={}
      ROOT %out = f32[64,64]{1,0} copy(%ar)
    }
    """)
    st = collective_bytes(hlo)
    assert st.by_kind["all-reduce"] == 64 * 64 * 4
    assert st.count == 1


def test_roofline_dominant_term():
    r = Roofline(compute_s=1.0, memory_s=2.0, collective_s=0.5,
                 flops=1, bytes_hbm=1, bytes_coll=1, model_flops=0.5)
    assert r.dominant == "memory"
    assert r.useful_flops_ratio == 0.5


def test_roofline_terms_units():
    cost = {"flops": 197e12, "bytes accessed": 819e9}
    coll = CollectiveStats(50e9, {}, 1)
    r = roofline_terms(cost, coll, n_chips=1, model_flops=197e12)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.collective_s == pytest.approx(1.0)
