"""Per-architecture smoke tests: REDUCED same-family variants (<=4 layers,
d_model<=512, <=4 experts) run one forward + one train step on CPU and
assert output shapes + no NaNs. The FULL configs are exercised only via the
dry-run (ShapeDtypeStructs, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config, reduced
from repro.models import build_model

ALL_ARCHS = [a for a in ARCH_IDS]


def _batch_for(cfg, B=2, S=16, key=0):
    ks = jax.random.split(jax.random.key(key), 4)
    toks = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.encoder is not None:
        batch["frames"] = 0.1 * jax.random.normal(
            ks[1], (B, cfg.encoder.frontend_len, cfg.d_model))
    if cfg.vision_tokens:
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            ks[2], (B, cfg.vision_tokens, cfg.d_model))
    return batch


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = reduced(get_config(arch), d_model=64, layers_per_stage=2,
                          vocab=256)
            m = build_model(cfg)
            params = m.init_params(jax.random.key(0))
            cache[arch] = (cfg, m, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(models, arch):
    cfg, m, params = models(arch)
    batch = _batch_for(cfg)
    logits, aux = m.apply(params, batch["tokens"],
                          extra_embeds=batch.get("patch_embeds"),
                          frames=batch.get("frames"))
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_reduces_loss_and_finite(models, arch):
    cfg, m, params = models(arch)
    batch = _batch_for(cfg)

    @jax.jit
    def step(params):
        (l, metrics), g = jax.value_and_grad(m.loss, has_aux=True)(
            params, batch)
        new = jax.tree.map(lambda p, gg: p - 0.05 * gg.astype(p.dtype),
                           params, g)
        return l, new

    l0, params1 = step(params)
    assert np.isfinite(float(l0))
    # one more step on the same batch must not blow up and should not
    # increase the loss dramatically (sanity, not convergence)
    l1, _ = step(params1)
    assert np.isfinite(float(l1))
    assert float(l1) < float(l0) + 1.0


@pytest.mark.parametrize("arch", ["llama3.2-3b", "granite-34b", "rwkv6-3b",
                                  "jamba-v0.1-52b", "deepseek-moe-16b",
                                  "kimi-k2-1t-a32b", "nanogpt-paper"])
def test_decode_matches_full_forward(models, arch):
    cfg, m, params = models(arch)
    B, S = 2, 10
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    full, _ = m.apply(params, toks)
    cache = m.init_cache(B, max_len=16)
    outs = []
    for t in range(S):
        lg, cache = m.decode_step(params, toks[:, t:t + 1], cache)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_static_cache_decode_matches(models):
    cfg, m, params = models("granite-8b")
    B, S = 2, 9
    toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    full, _ = m.apply(params, toks)
    cache = m.init_cache(B, max_len=16)
    for t in range(S - 1):
        _, cache = m.decode_step(params, toks[:, t:t + 1], cache)
    lg, _ = m.decode_step(params, toks[:, S - 1:S], cache,
                          static_cache=True)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_whisper_decode_with_cross_attention(models):
    cfg, m, params = models("whisper-base")
    B, S = 2, 8
    batch = _batch_for(cfg, B=B, S=S)
    full, _ = m.apply(params, batch["tokens"], frames=batch["frames"])
    memory = m._encode(params, batch["frames"],
                       __import__("repro.sharding", fromlist=["specs"])
                       .specs.ShardCtx.null())
    cache = m.init_cache(B, max_len=16)
    outs = []
    for t in range(S):
        lg, cache = m.decode_step(params, batch["tokens"][:, t:t + 1],
                                  cache, memory=memory)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_vlm_prefix_changes_logits(models):
    cfg, m, params = models("phi-3-vision-4.2b")
    batch = _batch_for(cfg)
    lg1, _ = m.apply(params, batch["tokens"],
                     extra_embeds=batch["patch_embeds"])
    lg2, _ = m.apply(params, batch["tokens"],
                     extra_embeds=batch["patch_embeds"] * 0.0)
    assert lg1.shape == lg2.shape  # prefix stripped from outputs
    assert float(jnp.max(jnp.abs(lg1 - lg2))) > 1e-4  # but attends to it


def test_reduced_configs_within_limits():
    for arch in ALL_ARCHS:
        cfg = reduced(get_config(arch), d_model=64, layers_per_stage=2,
                      vocab=256)
        assert cfg.d_model <= 512
        assert cfg.num_layers <= 8
        if cfg.moe is not None:
            assert cfg.moe.num_experts <= 4


def test_full_configs_match_assignment_card():
    card = {
        "whisper-base": (12, 512, 8, 8, 2048, 51865),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "mistral-nemo-12b": (40, 5120, 32, 8, 14336, 131072),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
    }
    for arch, (L, d, H, kv, ff, V) in card.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.attn.num_heads == H, arch
        assert cfg.attn.num_kv_heads == kv, arch
        assert cfg.vocab_size == V, arch
        if cfg.moe is not None and arch != "whisper-base":
            # card's d_ff is the routed-expert FFN width for pure-MoE archs
            if arch in ("kimi-k2-1t-a32b", "deepseek-moe-16b"):
                assert cfg.moe.d_expert == ff, arch
            else:
                assert cfg.d_ff == ff, arch
        else:
            assert cfg.d_ff == ff, arch
    # MoE cards
    km = get_config("kimi-k2-1t-a32b").moe
    assert (km.num_experts, km.experts_per_token) == (384, 8)
    dm = get_config("deepseek-moe-16b").moe
    assert (dm.num_experts, dm.experts_per_token) == (64, 6)
    assert dm.num_shared_experts == 2
    jm = get_config("jamba-v0.1-52b").moe
    assert (jm.num_experts, jm.experts_per_token) == (16, 2)
    # param totals: kimi ~1T, active ~32B
    kc = get_config("kimi-k2-1t-a32b")
    assert 0.9e12 < kc.param_count() < 1.2e12
    assert 25e9 < kc.active_param_count() < 40e9
