"""Tests for the experiment layer (repro.exp): the SCENARIOS registry,
run_experiment summaries/JSON, and the MSync per-worker oracle hook the
§6 heterogeneous benchmark rides on."""

import json

import numpy as np
import pytest

from repro.core import FixedTimes, simulate
from repro.core.oracle import heterogeneous_quadratics
from repro.core.strategies import MSync
from repro.core.time_models import SubExponentialTimes, UniversalModel
from repro.exp import SCENARIOS, csv_rows, make_scenario, run_experiment


EXPECTED = {"fixed_sqrt", "fixed_linear", "fixed_power", "truncnorm",
            "exponential", "shifted_exp", "gamma", "uniform", "chi2",
            "universal_fig3", "universal_fig4", "partial_participation"}


def test_scenarios_registry_covers_paper_regimes():
    assert set(SCENARIOS) >= EXPECTED
    for name in EXPECTED:
        n = 6
        model = make_scenario(name, n)
        assert model.n == n, name
        assert isinstance(model, (FixedTimes, SubExponentialTimes,
                                  UniversalModel)), name
    with pytest.raises(KeyError):
        make_scenario("nope", 4)


def test_scenario_kwargs_forwarded():
    model = make_scenario("fixed_power", 5, alpha=2.0)
    np.testing.assert_allclose(model.taus, np.arange(1, 6, dtype=float) ** 2)


def test_run_experiment_summary_and_json(tmp_path):
    path = tmp_path / "exp.json"
    res = run_experiment("msync", "fixed_sqrt", n=16, K=12, seeds=4,
                         grid={"m": [2, 16]}, json_path=str(path),
                         name="unit")
    assert [r["params"] for r in res.rows] == [{"m": 2}, {"m": 16}]
    for r in res.rows:
        assert r["seeds"] == 4
        assert r["scenario"] == "fixed_sqrt"
        assert np.isfinite(r["total_time_mean"])
    # m=16 (full sync) is slower per round than m=2
    assert res.rows[1]["total_time_mean"] > res.rows[0]["total_time_mean"]
    data = json.loads(path.read_text())
    assert data["name"] == "unit"
    assert data["meta"]["backend"] == "vectorized"
    assert len(data["rows"]) == 2

    rows = csv_rows(res, "unit", "total_time_mean")
    assert rows[0][0] == "unit/m=2"
    assert "over 4 seeds" in rows[0][2]


def test_run_experiment_accepts_model_instance():
    model = FixedTimes(np.array([1.0, 3.0]))
    res = run_experiment("sync", model, n=2, K=4, seeds=2)
    assert res.rows[0]["total_time_mean"] == pytest.approx(12.0)
    with pytest.raises(ValueError):
        run_experiment("sync", model, n=3, K=4, seeds=2)


def test_msync_grads_by_worker_hook():
    """Satellite: MSync takes the per-worker oracle hook Malenia has; with
    worker-exclusive blocks and fixed sqrt-law times, blocks owned by the
    n-m slow workers receive NO update, exactly the §6 argument."""
    n, d_per = 6, 4
    prob, grad_i, x_star = heterogeneous_quadratics(n, d_per=d_per, seed=0)
    model = FixedTimes.sqrt_law(n)
    m = 3
    tr = simulate(MSync(m=m, grads_by_worker=grad_i), model, K=60,
                  problem=prob, gamma=0.3, seed=0, record_every=10)
    assert tr.x_final is not None
    slow = tr.x_final[m * d_per:]
    fast = tr.x_final[:m * d_per]
    np.testing.assert_array_equal(slow, np.zeros_like(slow))
    assert np.linalg.norm(fast - x_star[:m * d_per]) \
        < 0.5 * np.linalg.norm(x_star[:m * d_per])


def test_sec6_benchmark_rows_still_certify_the_claim():
    from benchmarks.sec6_heterogeneous import run
    rows = dict((r[0], r[1]) for r in run(fast=True, seeds=2))
    assert rows["sec6het/msync_m4of8/rel_err"] > 0.5
    assert rows["sec6het/msync_fails_malenia_works"] == 1.0
