"""Tests for the composable Strategy API (repro.core.strategies): legacy
parity, registry round-trips, the vectorized fast path, the new deadline /
dropout strategies, and the strategy-driven mesh participation."""

import warnings

import numpy as np
import pytest

from repro.core import (STRATEGIES, Dropout, FixedTimes, MSync,
                        SimulatedStraggler, exponential_times, make_strategy,
                        quadratic_worst_case, simulate, uniform_times)
from repro.core.algorithms import (run_async_sgd, run_m_sync_sgd,
                                   run_malenia_sgd, run_rennala_sgd,
                                   run_ringmaster_asgd, run_sync_sgd)


def _assert_traces_identical(a, b):
    np.testing.assert_array_equal(a.times, b.times)
    np.testing.assert_array_equal(a.values, b.values)
    np.testing.assert_array_equal(a.grad_norms, b.grad_norms)
    assert a.total_time == b.total_time
    assert a.iterations == b.iterations
    assert a.gradients_used == b.gradients_used
    assert a.gradients_computed == b.gradients_computed
    assert a.discard_fraction == b.discard_fraction


@pytest.fixture
def prob():
    return quadratic_worst_case(d=40, p=0.5)


# ---------------------------------------------------------------- parity
# Two layers of parity:
# 1. each legacy run_* shim must produce a seeded trace bitwise-identical
#    to the same strategy run through simulate() directly (routing);
# 2. the trace must match GOLDEN values captured by executing the
#    pre-refactor per-method event loops (git 208eda2,
#    src/repro/core/algorithms.py) on the same seeds — this pins behavior
#    to the REMOVED implementation, which the shim-vs-simulate comparison
#    alone cannot do (both sides share the new engine).
# Golden floats are exact except where the engine's gamma*(mult)
# associativity differs from the legacy gamma/(...) by a few ulps.

_GOLDEN = {
    # total_time, iterations, used, computed, sum(times), sum(values),
    # grad_norms[-1]
    "msync": (240.0, 120, 240, 290, 3000.0,
              14.220883731893153, 2.649644685689712e-4),
    "sync_uniform": (72.99527930728364, 60, 360, 360, 2226.734633511154,
                     67.03647806048981, 8.650569666167693e-3),
    "async_tol": (470.0, 801, 801, 801, 9628.0,
                  24.53928370849114, 9.538576727998534e-4),
    "rennala_exp": (36.27058435285476, 50, 200, 349, 959.038663433911,
                    46.936874623568976, 5.227684367408059e-3),
    "malenia": (356.0, 25, 435, 483, 4565.0,
                44.280622540763765, 2.9687374556976717e-2),
    "ringmaster": (100.0, 200, 200, 201, 1050.0,
                   14.098384511555627, 4.0897176741069125e-4),
}


def _assert_golden(tr, key):
    tt, it, used, comp, tsum, vsum, gn = _GOLDEN[key]
    assert tr.total_time == tt
    assert tr.iterations == it
    assert tr.gradients_used == used
    assert tr.gradients_computed == comp
    assert float(tr.times.sum()) == pytest.approx(tsum, rel=1e-12)
    assert float(tr.values.sum()) == pytest.approx(vsum, rel=1e-9)
    assert float(tr.grad_norms[-1]) == pytest.approx(gn, rel=1e-9)


def test_parity_msync(prob):
    model = FixedTimes(np.array([1.0, 2.0, 5.0, 100.0]))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = run_m_sync_sgd(model, K=120, m=2, problem=prob, gamma=0.4,
                                seed=7, record_every=5)
    new = simulate(STRATEGIES["msync"](m=2), model, K=120, problem=prob,
                   gamma=0.4, seed=7, record_every=5)
    _assert_traces_identical(legacy, new)
    _assert_golden(new, "msync")


def test_parity_sync(prob):
    model = uniform_times(np.ones(6), 0.3)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = run_sync_sgd(model, K=60, problem=prob, gamma=0.2, seed=1)
    new = simulate(STRATEGIES["sync"](), model, K=60, problem=prob,
                   gamma=0.2, seed=1)
    _assert_traces_identical(legacy, new)
    _assert_golden(new, "sync_uniform")


def test_parity_async_with_tol(prob):
    # covers the tolerance-exit cadence too (legacy checked the
    # pre-increment iteration counter: tol_offset = 1)
    model = FixedTimes(np.array([1.0, 2.0, 5.0, 100.0]))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = run_async_sgd(model, K=3000, problem=prob, gamma=0.05,
                               delay_adaptive=True, seed=2,
                               record_every=20, tol_grad_sq=1e-3)
    new = simulate(STRATEGIES["async"](delay_adaptive=True), model, K=3000,
                   problem=prob, gamma=0.05, seed=2, record_every=20,
                   tol_grad_sq=1e-3)
    _assert_traces_identical(legacy, new)
    _assert_golden(new, "async_tol")


def test_parity_rennala(prob):
    model = exponential_times(lam=2.0, n=5)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = run_rennala_sgd(model, K=50, batch=4, problem=prob,
                                 gamma=0.3, seed=3)
    new = simulate(STRATEGIES["rennala"](batch=4), model, K=50,
                   problem=prob, gamma=0.3, seed=3)
    _assert_traces_identical(legacy, new)
    _assert_golden(new, "rennala_exp")


def test_parity_malenia(prob):
    model = FixedTimes(np.array([1.0, 4.0, 9.0]))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = run_malenia_sgd(model, K=25, S=2.0, problem=prob,
                                 gamma=0.3, seed=4)
    new = simulate(STRATEGIES["malenia"](S=2.0), model, K=25, problem=prob,
                   gamma=0.3, seed=4)
    _assert_traces_identical(legacy, new)
    _assert_golden(new, "malenia")


def test_parity_ringmaster(prob):
    model = FixedTimes(np.array([1.0, 1.0, 60.0]))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = run_ringmaster_asgd(model, K=200, max_delay=4,
                                     problem=prob, gamma=0.2, seed=5,
                                     record_every=10)
    new = simulate(STRATEGIES["ringmaster"](max_delay=4), model, K=200,
                   problem=prob, gamma=0.2, seed=5, record_every=10)
    _assert_traces_identical(legacy, new)
    _assert_golden(new, "ringmaster")


# ---------------------------------------------------------------- registry
def test_registry_round_trip_every_name():
    model = FixedTimes(np.ones(4))
    assert set(STRATEGIES) >= {"sync", "msync", "auto_m", "async",
                               "rennala", "malenia", "ringmaster",
                               "deadline", "dropout"}
    for name in STRATEGIES:
        strat = STRATEGIES[name]()         # default-constructible
        tr = simulate(strat, model, K=3)
        assert tr.iterations == 3, name
        assert tr.total_time > 0, name


def test_make_strategy_and_string_dispatch():
    model = FixedTimes(np.array([1.0, 2.0]))
    a = simulate(make_strategy("msync", m=1), model, K=5)
    b = simulate("sync", model, K=5)
    assert a.total_time == pytest.approx(5 * 1.0)
    assert b.total_time == pytest.approx(5 * 2.0)
    with pytest.raises(KeyError):
        make_strategy("nope")


# ------------------------------------------------------- vectorized engine
def test_fast_path_matches_generic_loop_bitwise():
    # Dropout(p=0) has identical semantics to its inner m-sync but is
    # routed through the generic event loop, so it cross-checks the
    # round-vectorized timing fast path exactly (deterministic model).
    for taus, m in [(np.array([1.0, 2.0, 5.0, 100.0]), 2),
                    (np.ones(5), 2), (np.ones(5), 5),
                    (np.array([1.0, 1.0, 2.0, 2.0, 3.0, 6.0]), 3)]:
        model = FixedTimes(taus)
        fast = simulate(MSync(m=m), model, K=37)
        slow = simulate(Dropout(MSync(m=m), p=0.0), model, K=37)
        assert fast.total_time == slow.total_time
        assert fast.gradients_used == slow.gradients_used
        assert fast.gradients_computed == slow.gradients_computed


def test_sample_times_matches_scalar_stream():
    # default batched sampling must consume the RNG exactly like the
    # scalar path (vectorized overrides only change the draw order)
    model = uniform_times(np.arange(1.0, 5.0), 0.25)
    a = model.sample_times(np.arange(4), np.random.default_rng(0))
    r = np.random.default_rng(0)
    b = np.array([model.sample_time(i, r) for i in range(4)])
    np.testing.assert_allclose(a, b)
    fixed = FixedTimes(np.array([3.0, 1.0, 2.0]))
    np.testing.assert_array_equal(
        fixed.sample_times([2, 0], np.random.default_rng(1)), [2.0, 3.0])


# ------------------------------------------------------------- new methods
def test_deadline_steps_at_deadline_with_arrivals():
    model = FixedTimes(np.array([0.5, 0.9, 30.0]))
    tr = simulate(STRATEGIES["deadline"](deadline=1.0), model, K=4)
    # each round: workers 0,1 make the deadline, the server fires at 1.0s
    assert tr.total_time == pytest.approx(4 * 1.0)
    assert tr.gradients_used == 8


def test_deadline_steps_early_when_everyone_finishes():
    model = FixedTimes(np.array([1.0, 2.0, 3.0]))
    tr = simulate(STRATEGIES["deadline"](deadline=100.0), model, K=5)
    assert tr.total_time == pytest.approx(5 * 3.0)   # never waits to 100
    assert tr.gradients_used == 15


def test_deadline_never_stalls_without_arrivals():
    model = FixedTimes(np.array([0.5, 0.7, 30.0]))
    tr = simulate(STRATEGIES["deadline"](deadline=0.1), model, K=3)
    # nobody makes the 0.1s deadline: step on the first arrival instead
    assert tr.total_time == pytest.approx(3 * 0.5)
    assert tr.gradients_used == 3


def test_deadline_converges(prob):
    model = uniform_times(np.ones(6), 0.4)
    tr = simulate(STRATEGIES["deadline"](deadline=1.1), model, K=1500,
                  problem=prob, gamma=0.4, seed=0, record_every=100)
    assert tr.grad_norms[-1] < tr.grad_norms[0] * 1e-2


def test_dropout_rotating_adversary_discards():
    # 25% of workers dead at any instant, rotating each second: the
    # wrapper must suppress some arrivals that plain m-sync would accept
    model = FixedTimes(np.ones(8) * 0.9)
    plain = simulate(MSync(m=4), model, K=20)
    noisy = simulate(Dropout(MSync(m=4), p=0.25, period=1.0), model, K=20)
    assert noisy.gradients_computed > plain.gradients_computed
    assert noisy.total_time >= plain.total_time
    assert noisy.gradients_used == plain.gradients_used == 20 * 4


def test_strategy_param_validation():
    with pytest.raises(ValueError):
        Dropout(MSync(m=1), p=1.0)      # would never finish an iteration
    with pytest.raises(ValueError):
        Dropout(MSync(m=1), period=0.0)
    with pytest.raises(ValueError):
        STRATEGIES["deadline"](deadline=0.0)
    with pytest.raises(ValueError):
        simulate(MSync(m=0), FixedTimes(np.ones(3)), K=2)


def test_dropout_composes_with_async(prob):
    model = FixedTimes(np.ones(4))
    tr = simulate(Dropout(STRATEGIES["async"](), p=0.3, period=2.0), model,
                  K=600, problem=prob, gamma=0.2, seed=1, record_every=50)
    assert tr.discard_fraction > 0          # adversary suppressed some
    assert tr.grad_norms[-1] < tr.grad_norms[0] * 1e-1


# ---------------------------------------------------------------- mesh path
def test_strategy_drives_mesh_masks():
    model = FixedTimes(np.array([1.0, 2.0, 3.0, 100.0]))
    st = SimulatedStraggler(model, STRATEGIES["msync"](m=3))
    mask, m, dur = st.step()
    assert m == 3 and dur == pytest.approx(3.0)
    np.testing.assert_array_equal(mask, [True, True, True, False])


def test_deadline_strategy_on_mesh():
    model = FixedTimes(np.array([0.5, 0.9, 30.0]))
    st = SimulatedStraggler(model, STRATEGIES["deadline"](deadline=1.0))
    mask, m, dur = st.step()
    assert m == 2 and dur <= 1.0
    assert mask[0] and mask[1] and not mask[2]


def test_async_strategy_rejected_on_mesh():
    model = FixedTimes(np.ones(4))
    with pytest.raises(ValueError):
        SimulatedStraggler(model, STRATEGIES["async"]())


def test_legacy_syncpolicy_still_resolves():
    from repro.core import SyncMode, SyncPolicy
    strat = SyncPolicy(SyncMode.M_SYNC, m=2).to_strategy()
    assert isinstance(strat, MSync)
    model = FixedTimes(np.array([1.0, 5.0, 9.0]))
    st = SimulatedStraggler(model, SyncPolicy(SyncMode.M_SYNC, m=2))
    _, m, dur = st.step()
    assert (m, dur) == (2, pytest.approx(5.0))
