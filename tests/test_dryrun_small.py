"""Integration test of the dry-run path on a small (2x4) CPU mesh.

Runs in a subprocess (device-count flag must precede jax init). Exercises
build_lowerable end-to-end for a reduced-size mesh: the same code path the
production 16x16 / 2x16x16 dry-run uses, minus 40 minutes of compiles.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(arch: str, shape: str) -> dict:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax
        import numpy as np
        import repro.launch.dryrun as dr
        from repro.launch.hlo_analysis import collective_bytes, hlo_cost

        from repro.launch.mesh import make_mesh_auto
        mesh = make_mesh_auto((2, 4), ("data", "model"))
        fn, args, shards, meta = dr.build_lowerable("{arch}", "{shape}",
                                                    mesh)
        with mesh:
            lowered = jax.jit(fn, in_shardings=shards).lower(*args)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            txt = compiled.as_text()
        cost = hlo_cost(txt)
        coll = collective_bytes(txt)
        # jaxlib < 0.5 has no peak_memory_in_bytes; sum the components
        peak = getattr(mem, "peak_memory_in_bytes", 0) or (
            mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes)
        print(json.dumps({{
            "peak": peak,
            "flops": cost["flops"],
            "coll": coll.total_bytes,
            "model_flops": meta.get("model_flops", 0.0),
            "attn_mode": meta["attn_mode"],
        }}))
    """)
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=ROOT, timeout=560)
    assert out.returncode == 0, (out.stderr or out.stdout)[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# NOTE: full-size configs on an 8-device mesh: choose the cheap ones.
def test_dryrun_whisper_train_small_mesh():
    r = _run("whisper-base", "train_4k")
    assert r["peak"] > 0
    assert r["flops"] > 0
    assert r["coll"] > 0          # gradient all-reduce must exist
    # parsed flops must cover the model-math flops (remat adds more)
    assert r["flops"] * 8 >= 0.5 * r["model_flops"]


def test_dryrun_whisper_decode_small_mesh():
    r = _run("whisper-base", "decode_32k")
    assert r["peak"] > 0
    # on this 2x4 mesh whisper's kv=8 divides model=4 => HEADS is correct
    # (the production 16-way model axis selects KVSEQ instead)
    from repro.sharding.specs import attn_mode_for
    assert r["attn_mode"] == attn_mode_for(8, 8, 4, "decode", 128)


def test_dryrun_skip_rule():
    import repro.launch.dryrun as dr
    allowed = dr.LONG_OK | set(dr.LONG_SWA)
    assert "rwkv6-3b" in allowed and "jamba-v0.1-52b" in allowed
    assert "granite-34b" not in allowed
