"""Validate the paper's theorems numerically.

These are the EXPERIMENTS.md §Paper-validation checks: Theorem 2.3's
log-factor near-optimality, its tightness at tau_i = i, Theorem 3.2's
expectation bound, Corollary 3.4 regimes, the §5.1 reduction of the
universal recursions to the fixed model, and Theorem 5.5 partial
participation."""

import math

import numpy as np
import pytest

from repro.core import (FixedTimes, PartialParticipationModel, UniversalModel,
                        exponential_times, iteration_complexity, log_factor,
                        lower_bound_recursion, msync_upper_recursion,
                        run_m_sync_sgd, t_malenia, t_optimal, t_rand_upper,
                        t_sync, t_sync_full, truncated_normal_times)

L, DELTA, EPS = 1.0, 1.0, 1e-2


def test_theorem_2_3_log_factor_sqrt_law():
    # tau_i = sqrt(i): T_sync <= C * T_opt * log(n+1) with C modest.
    for n in (10, 100, 1000):
        taus = FixedTimes.sqrt_law(n).taus
        for sigma2 in (1e-2, 1.0, 100.0):
            ts, _ = t_sync(taus, L, DELTA, EPS, sigma2, c=1.0)
            to, _ = t_optimal(taus, L, DELTA, EPS, sigma2, c=1.0)
            assert ts <= to * log_factor(n) * 4.0
            assert ts >= to * 0.99  # sync can never beat the optimum


def test_theorem_2_3_log_factor_tight_at_linear():
    # tau_i = i is the paper's tightness example: the ratio actually grows
    # like log(n) (and never exceeds it modulo constants).
    ratios = []
    for n in (10, 100, 1000, 10000):
        taus = FixedTimes.linear(n).taus
        sigma2 = n * EPS  # sigma^2/eps = n — the interesting regime
        ts, _ = t_sync(taus, L, DELTA, EPS, sigma2, c=1.0)
        to, _ = t_optimal(taus, L, DELTA, EPS, sigma2, c=1.0)
        ratios.append(ts / to)
    assert ratios[-1] > ratios[0] * 1.5          # grows
    for n, r in zip((10, 100, 1000, 10000), ratios):
        assert r <= 2.0 * log_factor(n)          # but only logarithmically


def test_sync_full_never_beats_optimal():
    rng = np.random.default_rng(0)
    for _ in range(20):
        n = int(rng.integers(2, 200))
        taus = np.sort(rng.uniform(0.1, 50.0, n))
        sigma2 = float(rng.uniform(0.001, 10.0))
        tf = t_sync_full(taus, L, DELTA, EPS, sigma2, c=1.0)
        to, _ = t_optimal(taus, L, DELTA, EPS, sigma2, c=1.0)
        assert tf >= to * 0.999


def test_iteration_complexity_matches_eq3():
    assert iteration_complexity(1, 1, 1e-2, 1.0, 10) \
        == math.ceil(16 * max(100.0, 1.0 * 1 / (10 * 1e-4)))


def test_theorem_3_2_expectation_bound():
    # E[T_rand] <= (16 LΔ/ε)(τ_m + R log n) max(1, σ²/(mε)): check the
    # simulator's measured expectation against the closed form.
    n, m = 16, 8
    model = truncated_normal_times(np.sqrt(np.arange(1, n + 1)), sigma=0.5)
    sigma2 = 1.0
    K = iteration_complexity(L, DELTA, EPS, sigma2, m)
    K_sim = 200  # simulate a prefix; time is additive in K (eq. 6)
    times = [run_m_sync_sgd(model, K=K_sim, m=m, seed=s).total_time
             for s in range(10)]
    mean_per_iter = np.mean(times) / K_sim
    bound_per_iter = (t_rand_upper(model.mean_times(), model.R, L, DELTA,
                                   EPS, sigma2, m, c=16.0) / (16 * K)) * 16
    # bound is per-iteration (τ_m + R log n); measured must respect it
    assert mean_per_iter <= bound_per_iter * 1.05


def test_corollary_3_4_exponential_regime():
    # Exp(lam): tau_i = R = 1/lam; Sync SGD (m = n) nearly optimal.
    n = 64
    model = exponential_times(lam=2.0, n=n)
    taus = model.mean_times()
    sigma2 = n * EPS * 10  # sigma^2/eps >> n
    up = t_rand_upper(taus, model.R, L, DELTA, EPS, sigma2, m=n, c=1.0)
    to, _ = t_optimal(taus, L, DELTA, EPS, sigma2, c=1.0)
    assert up <= to * log_factor(n) * 8.0


def test_universal_recursions_reduce_to_fixed_model():
    # §5.1: constant powers v_i = 1/tau_i make (13) give 2k/v_m steps.
    n = 8
    taus = np.arange(1.0, n + 1.0)
    grid = np.arange(0.0, 2000.0, 1.0)
    powers = np.repeat((1.0 / taus)[:, None], len(grid), axis=1)
    model = UniversalModel(grid, powers)
    sigma2 = 0.0  # K = 16 LΔ/ε
    m = 3
    ub = msync_upper_recursion(model, L, DELTA, 1.0, sigma2, m)
    K = 16
    assert ub == pytest.approx(2 * K * taus[m - 1], rel=0.01)


def test_theorem_5_5_partial_participation_linear_time():
    # p < 0.4 stragglers, equal power v: m-sync with m = (1-2p)n completes
    # K iterations in O(K/v) — i.e. bounded per-iteration time <= 4/v.
    n, p, v = 20, 0.2, 1.0
    model = PartialParticipationModel(n=n, v=v, p=p, period=0.7, t_max=900.0)
    m = int((1 - 2 * p) * n)
    ub = msync_upper_recursion(model, L, DELTA, 1.0, 0.0, m)  # K = 16
    assert ub <= 16 * 4.0 / v + 1e-6


def test_malenia_gap_constant_for_powerlaw():
    # §6: for tau_m = tau_1 m^alpha, alpha <= 4, tau_n / mean(tau) = O(1).
    for alpha in (0.5, 1.0, 2.0, 4.0):
        taus = FixedTimes.power_law(1000, alpha).taus
        gap = taus[-1] / np.mean(taus)
        assert gap <= alpha + 1 + 1e-9  # mean of m^alpha ~ n^alpha/(alpha+1)


def test_lower_bound_recursion_monotone():
    grid = np.arange(0.0, 500.0, 0.5)
    powers = np.ones((4, len(grid)))
    model = UniversalModel(grid, powers)
    lb1 = lower_bound_recursion(model, L, DELTA, 1.0, 4.0, c1=4, c2=1)
    lb2 = lower_bound_recursion(model, L, DELTA, 1.0, 16.0, c1=4, c2=1)
    assert lb2 > lb1  # more noise -> larger batches -> more time
