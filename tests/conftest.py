"""Shared test config: a deterministic stand-in for ``hypothesis``.

Five test modules use a small subset of the hypothesis API (``@given`` /
``@settings`` with ``integers`` / ``floats`` / ``booleans`` /
``sampled_from`` / ``lists``), but the container bakes no ``hypothesis``
wheel and nothing may be pip-installed. When the real package is present
it is used untouched; otherwise this conftest registers a minimal
replacement that runs each property test on ``max_examples`` examples
drawn from a per-test fixed seed. Coverage is thinner than real
hypothesis (no shrinking, no adversarial edge-case heuristics), but the
properties are exercised across their whole domain and failures are
reproducible.
"""

from __future__ import annotations

import sys
import types
import zlib

try:
    import hypothesis  # noqa: F401  (real package wins)
except ImportError:
    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def floats(min_value, max_value):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    def lists(elements, min_size=0, max_size=10):
        return _Strategy(lambda rng: [
            elements.draw(rng)
            for _ in range(int(rng.integers(min_size, max_size + 1)))])

    def given(*gargs, **gkwargs):
        def deco(fn):
            def wrapper():
                n_ex = getattr(wrapper, "_max_examples", 20)
                seed = zlib.crc32(fn.__name__.encode())
                rng = np.random.default_rng(seed)
                for _ in range(n_ex):
                    drawn = [s.draw(rng) for s in gargs]
                    kw = {k: s.draw(rng) for k, s in gkwargs.items()}
                    fn(*drawn, **kw)
            # no functools.wraps: __wrapped__ would leak the property's
            # signature and make pytest look for same-named fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__dict__.update(fn.__dict__)
            return wrapper
        return deco

    def settings(max_examples=20, deadline=None, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    for _f in (integers, floats, booleans, sampled_from, lists):
        setattr(_st, _f.__name__, _f)
    _hyp.given = given
    _hyp.settings = settings
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
