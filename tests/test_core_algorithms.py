"""Behavioural tests of the event-driven algorithm simulators (Alg 1/2/3,
Rennala, Malenia) against the paper's exact wall-clock accounting."""

import numpy as np
import pytest

from repro.core import (FixedTimes, Problem, exponential_times,
                        quadratic_worst_case, run_async_sgd, run_m_sync_sgd,
                        run_malenia_sgd, run_rennala_sgd, run_sync_sgd,
                        uniform_times)


def test_sync_sgd_fixed_times_waits_for_slowest():
    # Assumption 2.2: each iteration takes exactly tau_n (eq. (1) accounting).
    model = FixedTimes(np.array([1.0, 2.0, 5.0]))
    tr = run_sync_sgd(model, K=10)
    assert tr.total_time == pytest.approx(10 * 5.0)
    assert tr.iterations == 10
    assert tr.gradients_used == 30  # all three aggregated each iteration


def test_m_sync_fixed_times_waits_for_mth():
    # Theorem 2.3 accounting: duration per iteration is tau_m.
    model = FixedTimes(np.array([1.0, 2.0, 5.0, 100.0]))
    tr = run_m_sync_sgd(model, K=20, m=2)
    assert tr.total_time == pytest.approx(20 * 2.0)
    # the slow workers' stale gradients are computed but discarded
    assert tr.gradients_used == 40
    assert tr.discard_fraction > 0


def test_m_sync_m1_is_fastest_worker():
    model = FixedTimes(np.array([0.5, 3.0, 3.0]))
    tr = run_m_sync_sgd(model, K=8, m=1)
    assert tr.total_time == pytest.approx(8 * 0.5)


def test_async_sgd_every_arrival_updates():
    model = FixedTimes(np.array([1.0, 1.5]))
    tr = run_async_sgd(model, K=10)
    assert tr.iterations == 10
    assert tr.gradients_used == 10
    # arrivals interleave: worker0 at 1,2,3..., worker1 at 1.5,3,...
    assert tr.total_time <= 10 * 1.0  # much faster than sync on 2 workers


def test_rennala_batch_timing_homogeneous():
    # n equal workers, batch=n. Under the paper's "cannot stop
    # computations" remark (§3), in-flight gradients go stale after each
    # update, so steady state costs ~2 gradient-times per iteration — the
    # same "N_i = 2" accounting as recursion (13).
    model = FixedTimes(np.ones(4))
    tr = run_rennala_sgd(model, K=5, batch=4)
    assert 5.0 <= tr.total_time <= 2 * 5.0


def test_rennala_harmonic_speedup():
    # tau = [1, 10]: Rennala with batch 10 gets ~10 gradients per ~10s from
    # the fast worker + 1 from the slow: faster than waiting 10s per *one*.
    model = FixedTimes(np.array([1.0, 10.0]))
    tr = run_rennala_sgd(model, K=3, batch=10)
    sync = run_sync_sgd(model, K=3)
    # sync: 3 iters * 10s = 30s for 3 updates of batch 2;
    # rennala: ~3 * ~9.5s for 3 updates of batch 10 — more grads per second.
    grads_per_sec_rennala = tr.gradients_used / tr.total_time
    grads_per_sec_sync = sync.gradients_used / sync.total_time
    assert grads_per_sec_rennala > 2 * grads_per_sec_sync


def test_malenia_requires_all_workers():
    model = FixedTimes(np.array([1.0, 4.0]))
    tr = run_malenia_sgd(model, K=2, S=1.0)
    # needs B_i >= 1 for every worker => at least tau_n per iteration
    assert tr.total_time >= 2 * 4.0 - 1e-9


def test_msync_converges_on_quadratic():
    prob = quadratic_worst_case(d=50, p=0.5)
    model = FixedTimes(FixedTimes.sqrt_law(8).taus)
    tr = run_m_sync_sgd(model, K=3000, m=4, problem=prob, gamma=0.5,
                        seed=1, record_every=100)
    assert tr.grad_norms[-1] < tr.grad_norms[0] * 1e-2
    assert np.all(np.isfinite(tr.values))


def test_async_converges_on_quadratic():
    prob = quadratic_worst_case(d=50, p=0.5)
    model = FixedTimes(np.ones(4))
    tr = run_async_sgd(model, K=4000, problem=prob, gamma=0.25,
                       delay_adaptive=True, seed=2, record_every=200)
    assert tr.grad_norms[-1] < tr.grad_norms[0] * 1e-2


def test_rennala_converges_on_quadratic():
    prob = quadratic_worst_case(d=50, p=0.5)
    model = FixedTimes(np.ones(4))
    tr = run_rennala_sgd(model, K=1500, batch=8, problem=prob, gamma=0.5,
                         seed=3, record_every=100)
    assert tr.grad_norms[-1] < tr.grad_norms[0] * 1e-2


def test_random_times_mean_wallclock_close_to_tau():
    # Exp(1) times, m=1 of 4. Busy workers must finish stale computations
    # before starting fresh ones (§3 Remark), so the per-iteration time sits
    # between the fresh-start best case E[min of 4 Exp] = 1/4 and the
    # Theorem 3.2 bound E[max_{i<=m} tau] = E[tau] = 1.
    model = exponential_times(lam=1.0, n=4)
    ts = [run_m_sync_sgd(model, K=50, m=1, seed=s).total_time / 50
          for s in range(20)]
    assert 0.25 <= np.mean(ts) <= 1.0


def test_uniform_noise_wallclock():
    model = uniform_times(np.ones(8), half_width=0.5)
    tr = run_sync_sgd(model, K=100, seed=0)
    # E[max of 8 Unif(0.5,1.5)] = 0.5 + 8/9
    assert tr.total_time / 100 == pytest.approx(0.5 + 8 / 9, rel=0.1)


def test_discarded_gradients_accounted():
    model = FixedTimes(np.array([1.0, 1.0, 7.0]))
    tr = run_m_sync_sgd(model, K=30, m=2)
    assert tr.gradients_computed > tr.gradients_used


def test_ringmaster_discards_overly_stale():
    from repro.core import run_ringmaster_asgd
    # one worker 100x slower: its gradients carry huge delays and must be
    # discarded rather than applied
    model = FixedTimes(np.array([1.0, 1.0, 100.0]))
    tr = run_ringmaster_asgd(model, K=300, max_delay=5)
    assert tr.gradients_computed > tr.gradients_used  # stale ones dropped
    assert tr.iterations == 300


def test_ringmaster_converges_where_naive_async_diverges():
    from repro.core import run_ringmaster_asgd
    prob = quadratic_worst_case(d=50, p=0.5)
    model = FixedTimes(np.concatenate([np.ones(4), [200.0]]))
    # naive async with the same (large) constant stepsize goes unstable on
    # a 200-step-delayed gradient; ringmaster caps staleness
    ring = run_ringmaster_asgd(model, K=3000, max_delay=8, problem=prob,
                               gamma=0.4, seed=0, record_every=200)
    assert np.isfinite(ring.grad_norms[-1])
    assert ring.grad_norms[-1] < ring.grad_norms[0] * 1e-2
