"""Property/unit tests for the SSM substrate (chunked scans vs oracle)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.ssm import chunked_scan, reference_scan


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


@pytest.mark.parametrize("chunk", [4, 16, 64])
@pytest.mark.parametrize("with_u", [False, True])
def test_chunked_matches_reference(chunk, with_u):
    rng = np.random.default_rng(0)
    B, T, H, K, V = 2, 37, 3, 8, 5
    q, k = _rand(rng, B, T, H, K), _rand(rng, B, T, H, K)
    v = _rand(rng, B, T, H, V)
    w = jnp.asarray(rng.uniform(0.5, 0.999, (B, T, H, K)), jnp.float32)
    u = 0.1 * _rand(rng, H, K) if with_u else None
    s0 = _rand(rng, B, H, K, V)
    yr, sr = reference_scan(q, k, v, w, u=u, state0=s0)
    yc, sc = chunked_scan(q, k, v, w, u=u, state0=s0, chunk=chunk)
    np.testing.assert_allclose(yr, yc, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(sr, sc, rtol=3e-4, atol=3e-4)


@given(T=st.integers(1, 40), chunk=st.sampled_from([3, 8, 32]),
       seed=st.integers(0, 100), with_u=st.booleans())
@settings(max_examples=25, deadline=None)
def test_chunked_matches_reference_property(T, chunk, seed, with_u):
    rng = np.random.default_rng(seed)
    B, H, K, V = 1, 2, 4, 4
    q, k = _rand(rng, B, T, H, K), _rand(rng, B, T, H, K)
    v = _rand(rng, B, T, H, V)
    w = jnp.asarray(rng.uniform(0.6, 0.999, (B, T, H, K)), jnp.float32)
    u = 0.1 * _rand(rng, H, K) if with_u else None
    yr, sr = reference_scan(q, k, v, w, u=u)
    yc, sc = chunked_scan(q, k, v, w, u=u, chunk=chunk)
    np.testing.assert_allclose(yr, yc, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(sr, sc, rtol=5e-4, atol=5e-4)


def test_state_carries_across_calls():
    # running two halves with carried state == one full run
    rng = np.random.default_rng(1)
    B, T, H, K, V = 1, 24, 2, 4, 4
    q, k = _rand(rng, B, T, H, K), _rand(rng, B, T, H, K)
    v = _rand(rng, B, T, H, V)
    w = jnp.asarray(rng.uniform(0.7, 0.99, (B, T, H, K)), jnp.float32)
    y_full, s_full = chunked_scan(q, k, v, w, chunk=8)
    y1, s1 = chunked_scan(q[:, :12], k[:, :12], v[:, :12], w[:, :12], chunk=8)
    y2, s2 = chunked_scan(q[:, 12:], k[:, 12:], v[:, 12:], w[:, 12:],
                          state0=s1, chunk=8)
    np.testing.assert_allclose(y_full, jnp.concatenate([y1, y2], 1),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s_full, s2, rtol=1e-4, atol=1e-4)


def test_decay_one_is_cumulative_sum():
    # w == 1: state is a plain running sum of k⊗v; y_t = q_t · Σ_{j<=t} kv_j
    rng = np.random.default_rng(2)
    B, T, H, K, V = 1, 10, 1, 3, 3
    q, k = _rand(rng, B, T, H, K), _rand(rng, B, T, H, K)
    v = _rand(rng, B, T, H, V)
    w = jnp.ones((B, T, H, K), jnp.float32)
    y, s = chunked_scan(q, k, v, w, chunk=4)
    kv = np.einsum("bthk,bthv->bthkv", np.asarray(k), np.asarray(v))
    cum = np.cumsum(kv, axis=1)
    y_ref = np.einsum("bthk,bthkv->bthv", np.asarray(q), cum)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(s, cum[:, -1], rtol=1e-4, atol=1e-4)


def test_strong_decay_forgets_history():
    rng = np.random.default_rng(3)
    B, T, H, K, V = 1, 16, 1, 4, 4
    q, k = _rand(rng, B, T, H, K), _rand(rng, B, T, H, K)
    v = _rand(rng, B, T, H, V)
    w = jnp.full((B, T, H, K), 1e-3, jnp.float32)
    _, s = chunked_scan(q, k, v, w, chunk=8)
    # state ≈ last kv only
    last = np.einsum("bhk,bhv->bhkv", np.asarray(k[:, -1]),
                     np.asarray(v[:, -1]))
    np.testing.assert_allclose(s, last, rtol=1e-2, atol=1e-2)
