"""The paper-claims gate: benchmark modules' key numbers asserted in CI.

These duplicate benchmarks/ in assertion form so `pytest` alone certifies
the faithful reproduction (EXPERIMENTS.md §Paper-validation)."""

import numpy as np
import pytest

from repro.core import (FixedTimes, lower_bound_recursion,
                        msync_upper_recursion, powers_figure3,
                        powers_figure4, t_malenia, t_sync_full)


@pytest.mark.parametrize("powers_fn,s2e,m,paper,t_max", [
    (powers_figure3, 100.0, 15, 1.52, 600.0),
    (powers_figure4, 100.0, 49, 1.11, 600.0),
])
def test_sec53_gap_matches_paper(powers_fn, s2e, m, paper, t_max):
    """§5.3: our measured t̄/t̲ must land within 20% of the paper's ratio
    (independent random seeds for the power ensembles)."""
    model = powers_fn(n=50, seed=0, t_max=t_max)
    ub = msync_upper_recursion(model, 1, 1, 1.0, s2e, m, n_grads=1.0)
    lb = lower_bound_recursion(model, 1, 1, 1.0, s2e)
    ratio = ub / lb
    assert ratio == pytest.approx(paper, rel=0.2)
    # and the worst-case (Theorem 5.3, N=2) recursion is ~2x that
    ub2 = msync_upper_recursion(model, 1, 1, 1.0, s2e, m, n_grads=2.0)
    assert 1.6 <= ub2 / ub <= 2.4


def test_sec6_async_needed_gap_grows():
    """§6/I: worker 1 becomes infinitely fast; the lower bound collapses
    to O(1/v) while m-sync(m=n) keeps paying ~1/v per iteration."""
    from repro.core import UniversalModel
    grid = np.arange(0.0, 4000.0, 0.05)
    powers = np.ones((10, len(grid)))
    powers[0, grid > 1.0] = 1e6
    model = UniversalModel(grid, powers)
    gaps = []
    for s2e in (100.0, 1000.0):
        ub = msync_upper_recursion(model, 1, 1, 1.0, s2e, m=10, n_grads=1.0)
        lb = lower_bound_recursion(model, 1, 1, 1.0, s2e)
        gaps.append(ub / lb)
    assert gaps[0] > 50
    assert gaps[1] > 5 * gaps[0]  # gap grows ~linearly in sigma^2/eps


def test_malenia_gap_alpha_plus_one():
    """§6: sync/malenia ratio ≈ alpha + 1 for tau = tau1 * m^alpha."""
    n, eps = 1000, 1e-2
    for alpha, expect in [(1.0, 2.0), (4.0, 5.0)]:
        taus = FixedTimes.power_law(n, alpha).taus
        sigma2 = 100 * n * eps
        ratio = t_sync_full(taus, 1, 1, eps, sigma2, c=1.0) \
            / t_malenia(taus, 1, 1, eps, sigma2, c=1.0)
        assert ratio == pytest.approx(expect, rel=0.1)


def test_fig5_ordering_msync_matches_optimal_methods():
    """Figure 5 (reduced scale): m-sync ≈ Rennala ≪ Sync on time/grad."""
    from repro.core import (quadratic_worst_case, run_m_sync_sgd,
                            run_rennala_sgd, run_sync_sgd)
    model = FixedTimes.sqrt_law(100)
    prob = quadratic_worst_case(d=100, p=0.2)
    K = 120
    sync = run_sync_sgd(model, K=K, problem=prob, gamma=1.0,
                        record_every=30)
    msync = run_m_sync_sgd(model, K=K, m=10, problem=prob, gamma=1.0,
                           record_every=30)
    renn = run_rennala_sgd(model, K=K, batch=10, problem=prob, gamma=1.0,
                           record_every=30)
    # all converge comparably per ITERATION...
    assert msync.grad_norms[-1] < 10 * sync.grad_norms[-1] + 1e-6
    # ...but sync pays tau_n = 10 per iteration vs tau_10 ~ 3.2
    assert sync.total_time > 2.5 * msync.total_time
    # m-sync within 2x of Rennala wall-clock (same batch budget)
    assert msync.total_time < 2.0 * renn.total_time


def test_sec6_heterogeneous_msync_fails_malenia_works():
    """§6: with worker-exclusive f_i, m-Sync(m<n) plateaus (ignored blocks
    never update) while Malenia SGD converges."""
    from benchmarks.sec6_heterogeneous import run
    rows = dict((r[0], r[1]) for r in run(fast=True))
    assert rows["sec6het/msync_m4of8/rel_err"] > 0.5
    assert rows["sec6het/msync_fails_malenia_works"] == 1.0
