"""Tests for §4 (optimal m selection) and §J (R estimation)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (FixedTimes, estimate_R, g_of_m, h_of_m, optimal_m,
                        power_law_m)
from repro.core.selection import OnlineTauEstimator, fit_power_law


def test_prop_4_1_small_noise_gives_m1():
    taus = np.arange(1.0, 101.0)
    assert optimal_m(taus, sigma2=0.5, eps=1.0) == 1


def test_prop_4_1_cap():
    # minimizer must satisfy m <= min(ceil(sigma^2/eps), n)
    taus = np.ones(100)  # equal times: larger m always at least as good
    m = optimal_m(taus, sigma2=1.0, eps=0.05)  # sigma^2/eps = 20
    assert m == 20


def test_prop_4_1_sandwich():
    # sigma^2 h(m) / eps <= g(m) <= 2 sigma^2 h(m) / eps on the capped range
    taus = np.sort(np.random.default_rng(0).uniform(1, 10, 50))
    sigma2, eps = 2.0, 0.1
    cap = min(int(np.ceil(sigma2 / eps)), 50)
    g = g_of_m(taus, sigma2, eps)[:cap]
    h = h_of_m(taus)[:cap]
    assert np.all(g >= sigma2 * h / eps - 1e-12)
    assert np.all(g <= 2 * sigma2 * h / eps + 1e-12)


@given(alpha=st.floats(0.0, 1.0), n=st.integers(2, 300),
       ratio=st.floats(1.0, 1e4))
@settings(max_examples=60, deadline=None)
def test_prop_4_2_powerlaw_choice_optimal(alpha, n, ratio):
    # For tau_m = tau_1 m^alpha (delta = 0), m = min(ceil(sigma2/eps), n)
    # minimizes g  (h is non-increasing).
    taus = FixedTimes.power_law(n, alpha).taus
    eps = 1.0
    sigma2 = ratio
    m_choice = power_law_m(n, sigma2, eps)
    g = g_of_m(taus, sigma2, eps)
    # Prop 4.1's sandwich is tight only up to a factor 2 (the ceil in the
    # cap), so "optimal" in Prop 4.2 means within 2x of the true minimum.
    assert g[m_choice - 1] <= 2.0 * np.min(g) * (1 + 1e-9) + 1e-12


def test_prop_4_2_with_offsets():
    # tau_m = tau_1 m^alpha + delta_m: choice optimal once m >= (δ/τ1)^(1/α)
    n, alpha, tau1, delta = 1000, 0.5, 1.0, 3.0
    rng = np.random.default_rng(1)
    deltas = rng.uniform(0, delta, n)
    taus = FixedTimes.power_law(n, alpha, tau1, deltas).taus
    sigma2, eps = 500.0, 1.0  # cap = 500 >= (3/1)^2 = 9
    m_choice = power_law_m(n, sigma2, eps)
    g = g_of_m(taus, sigma2, eps)
    assert g[m_choice - 1] <= 2.5 * np.min(g)


def test_estimate_R_exponential():
    # Exp(1): theory says R = Θ(1); estimator should land near 1.
    rng = np.random.default_rng(0)
    times = rng.exponential(1.0, 20000)
    R = estimate_R(times)
    assert 0.5 < R < 2.5


def test_estimate_R_constant_times_is_zero():
    assert estimate_R(np.full(100, 3.3)) == 0.0


def test_estimate_R_scales_with_noise():
    rng = np.random.default_rng(0)
    r_small = estimate_R(rng.normal(10, 0.1, 5000))
    r_big = estimate_R(rng.normal(10, 1.0, 5000))
    assert r_big > 5 * r_small


def test_estimate_R_definition_holds():
    rng = np.random.default_rng(3)
    times = rng.gamma(4.0, 0.5, 4000)
    R = estimate_R(times)
    val = np.mean(np.exp(np.abs(times - times.mean()) / R))
    assert val == pytest.approx(2.0, rel=1e-3)


def test_fit_power_law_recovers_alpha():
    taus = FixedTimes.power_law(500, 0.7, tau1=2.0).taus
    tau1, alpha = fit_power_law(taus)
    assert alpha == pytest.approx(0.7, abs=0.01)
    assert tau1 == pytest.approx(2.0, rel=0.05)


def test_online_estimator_converges_to_taus():
    rng = np.random.default_rng(0)
    true_taus = np.array([1.0, 2.0, 4.0, 8.0])
    est = OnlineTauEstimator(4, beta=0.8, eps_target=0.1)
    for _ in range(300):
        est.update_times(true_taus + rng.normal(0, 0.05, 4))
    assert np.allclose(est.tau_hat, true_taus, rtol=0.1)
    est.update_sigma2(4.0)  # sigma^2/eps = 40: g = [40, 40, 53.3, 80]
    m = est.suggest_m(eps=0.1)
    g = g_of_m(true_taus, 4.0, 0.1)
    assert g[m - 1] <= np.min(g) * 1.05  # noisy τ̂ may pick either of the tie


@given(st.lists(st.floats(0.1, 100.0), min_size=2, max_size=50),
       st.floats(0.01, 100.0), st.floats(0.01, 10.0))
@settings(max_examples=80, deadline=None)
def test_optimal_m_is_argmin_property(taus, sigma2, eps):
    taus = np.sort(np.asarray(taus))
    m = optimal_m(taus, sigma2, eps)
    g = g_of_m(taus, sigma2, eps)
    assert g[m - 1] <= np.min(g) + 1e-9
