"""Sharded-vs-unsharded numerical parity for the model forward/loss.

Subprocess with 8 host devices: builds a (2, 4) mesh, runs the reduced
model's loss with full sharding constraints (incl. shard_map MoE) and
checks it matches the single-device result — proving the distribution
layer changes math by ~float-noise only.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.models import build_model
    from repro.sharding.specs import ShardCtx

    results = {}
    for arch, heads_mode in [("llama3.2-3b", "qseq"),
                             ("deepseek-moe-16b", "heads"),
                             ("jamba-v0.1-52b", "heads"),
                             ("rwkv6-3b", "qseq")]:
        cfg = reduced(get_config(arch), d_model=64, layers_per_stage=2,
                      vocab=128)
        m = build_model(cfg)
        params = m.init_params(jax.random.key(0))
        B, S = 4, 16
        toks = jax.random.randint(jax.random.key(1), (B, S), 0, 128)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
        base, _ = m.loss(params, batch)

        from repro.launch.mesh import make_mesh_auto
        mesh = make_mesh_auto((2, 4), ("data", "model"))
        ctx = ShardCtx(mesh=mesh, dp_axes=("data",), model_axis="model",
                       attn_mode=heads_mode)
        with mesh:
            sharded, _ = jax.jit(lambda p, b: m.loss(p, b, ctx))(params,
                                                                 batch)
        results[arch] = [float(base), float(sharded)]
    print(json.dumps(results))
""")


@pytest.fixture(scope="module")
def parity():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                         text=True, env=env, cwd=ROOT, timeout=560)
    assert out.returncode == 0, (out.stderr or out.stdout)[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("arch", ["llama3.2-3b", "deepseek-moe-16b",
                                  "jamba-v0.1-52b", "rwkv6-3b"])
def test_sharded_loss_matches_unsharded(parity, arch):
    base, sharded = parity[arch]
    assert abs(base - sharded) < 5e-3 * max(abs(base), 1.0), \
        f"{arch}: {base} vs {sharded}"
