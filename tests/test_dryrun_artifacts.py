"""Gate on the generated dry-run artifacts (experiments/dryrun/*.json):
all 80 (arch x shape x mesh) combos must be ok or documented-skip, and
every ok record must carry complete roofline data. Skips cleanly if the
sweep hasn't been run in this checkout."""

import glob
import json
import os

import pytest

DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun")

ARCHS = 10
SHAPES = 4
MESHES = 2


@pytest.fixture(scope="module")
def records():
    files = glob.glob(os.path.join(DIR, "*.json"))
    if len(files) < ARCHS * SHAPES * MESHES:
        pytest.skip("dry-run sweep artifacts not present "
                    f"({len(files)} files); run repro.launch.dryrun --all")
    recs = []
    for f in files:
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def test_all_combos_present_no_errors(records):
    assert len(records) == ARCHS * SHAPES * MESHES
    by_status = {}
    for r in records:
        by_status.setdefault(r["status"], []).append(r)
    assert not by_status.get("error"), [
        (r["arch"], r["shape"], r["mesh"]) for r in by_status["error"]]
    assert len(by_status["ok"]) == 66
    assert len(by_status["skipped"]) == 14


def test_skips_are_documented_long500k_only(records):
    for r in records:
        if r["status"] == "skipped":
            assert r["shape"] == "long_500k"
            assert "sub-quadratic" in r["reason"]


def test_ok_records_have_roofline(records):
    for r in records:
        if r["status"] != "ok":
            continue
        ro = r["roofline"]
        assert ro["compute_s"] > 0
        assert ro["memory_s"] > 0
        assert ro["dominant"] in ("compute", "memory", "collective")
        assert 0 <= ro["useful_flops_ratio"] <= 1.05, (r["arch"], r["shape"])
        assert r["memory"]["peak_bytes"] > 0
        # train shapes must have gradient collectives
        if r["kind"] == "train":
            assert r["collectives"]["total_bytes"] > 0


def test_hbm_fits_except_documented_kimi(records):
    over = [(r["arch"], r["shape"], r["mesh"],
             round(r["memory"]["peak_bytes"] / 2 ** 30, 1))
            for r in records if r["status"] == "ok"
            and r["memory"]["peak_bytes"] > 16 * 2 ** 30]
    # the only documented over-HBM combos are kimi-k2 (1T params:
    # single-pod train is physically impossible; multi-pod is 6% over;
    # decode_32k single-pod marginal) — EXPERIMENTS.md §Roofline
    assert all(a == "kimi-k2-1t-a32b" for a, *_ in over), over
