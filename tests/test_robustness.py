"""ISSUE 8: robustness layer tests — the graceful-degradation ladder
(forced jax failure completes on a host engine with a routing record),
crash-safe checkpoint/resume (kill after k of N points, resume, final
JSON byte-identical), atomic artifact writes, the cost-constants
warning, the per-bucket sharded-sweep retry, and the ROB001/ROB002
analyzer rules (good/bad fixture twins + the live tree staying clean).
"""

from __future__ import annotations

import json
import textwrap
import warnings
from pathlib import Path

import numpy as np
import pytest

import repro.core.batch_jax as bj
from repro.core import exponential_times, simulate_batch
from repro.core.batch import ENGINE_LADDER, load_cost_constants
from repro.core.strategies import Trace
from repro.exp import run_experiment
from repro.exp.runner import atomic_write_json

ROOT = Path(__file__).resolve().parents[1]


# ------------------------------------------------------- degradation ladder
def test_ladder_order_and_exposure():
    assert ENGINE_LADDER == ("jax_sharded", "jax", "vectorized", "serial")


def test_forced_jax_failure_falls_back_with_routing_record(monkeypatch):
    """ISSUE 8 acceptance: a forced jax engine failure completes via the
    downgrade ladder and the downgrade is recorded in routing."""
    calls = {"n": 0}

    def boom(*args, **kwargs):
        calls["n"] += 1
        raise RuntimeError("injected engine failure")

    monkeypatch.setattr(bj, "simulate_batch_jax", boom)
    model = exponential_times(1.0, 6)
    tb = simulate_batch(("msync", {"m": 2}), model, K=20, seeds=4,
                        backend="jax")
    assert calls["n"] == 2                      # retry-once before downgrade
    assert tb.backend == "vectorized"           # next eligible rung
    downs = tb.routing[0]["downgrades"]
    assert downs == [{"from": "jax", "to": "vectorized",
                      "error": "RuntimeError",
                      "reason": "injected engine failure",
                      "retried": True}]
    assert np.all(tb.total_time > 0)


def test_forced_jax_failure_reaches_serial_for_noneligible(monkeypatch):
    """Rennala has no vectorized fast path, so the ladder lands on
    serial."""
    monkeypatch.setattr(bj, "simulate_batch_jax",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("injected")))
    model = exponential_times(1.0, 6)
    tb = simulate_batch(("rennala", {"batch": 2}), model, K=15, seeds=3,
                        backend="jax")
    assert tb.backend == "serial"
    assert tb.routing[0]["downgrades"][0]["to"] == "serial"


def test_ladder_preserves_contract_errors(monkeypatch):
    """Validation failures (unsupported combos on a forced jax backend)
    must still raise — the ladder only absorbs execution failures."""
    model = exponential_times(1.0, 4)
    with pytest.raises(NotImplementedError):
        simulate_batch(("deadline", {"deadline": 1.0}), model, K=10,
                       seeds=2, backend="jax")


def test_exhausted_ladder_reraises(monkeypatch):
    """When every rung fails the last exception propagates (after the
    downgrade records were written along the way)."""
    import repro.core.strategies as strategies_mod

    monkeypatch.setattr(bj, "simulate_batch_jax",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("jax down")))
    monkeypatch.setattr(strategies_mod, "simulate",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("serial down")))
    import repro.core.batch as batch_mod
    monkeypatch.setattr(batch_mod, "simulate",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("serial down")))
    model = exponential_times(1.0, 4)
    with pytest.raises(RuntimeError, match="serial down"):
        simulate_batch(("rennala", {"batch": 2}), model, K=10, seeds=2,
                       backend="jax")


# --------------------------------------------------- per-bucket sweep retry
def test_sharded_bucket_failure_falls_back_per_point(monkeypatch):
    from repro.core.strategies import MSync
    from repro.launch.sweep import SweepPoint, run_sharded_sweep

    monkeypatch.setattr(bj, "sharded_msync_run",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("shard program died")))
    model = exponential_times(1.0, 6)
    points = [SweepPoint(index=0, strategy=MSync(m=2), K=12),
              SweepPoint(index=1, strategy=MSync(m=4), K=12)]
    out = run_sharded_sweep(points, model, None, seeds=[0, 1])
    for idx in (0, 1):
        traces, rec = out[idx]
        assert len(traces) == 2 and traces[0].total_time > 0
        assert rec["fallback"] is True
        assert rec["downgrades"][0]["from"] == "jax_sharded:bucket"
        assert rec["downgrades"][0]["error"] == "RuntimeError"


# ------------------------------------------------------- checkpoint / resume
def _run_kwargs(tmp_path, **extra):
    kw = dict(seeds=4, grid={"m": [2, 4, 8]}, backend="vectorized",
              target_frac=0.5)
    kw.update(extra)
    return kw


def test_kill_and_resume_byte_identical_json(tmp_path, monkeypatch):
    """ISSUE 8 acceptance: run killed after k of N grid points, resumed
    with resume=True, final JSON byte-identical to the uninterrupted
    run's."""
    import repro.exp.runner as runner

    a = tmp_path / "a.json"
    run_experiment("msync", "crash_restart", 8, 40, json_path=str(a),
                   checkpoint_dir=str(tmp_path / "ck_a"),
                   **_run_kwargs(tmp_path))

    # plain uncheckpointed run must agree too (vectorized traces are
    # float64 end-to-end, so serialization is lossless)
    p = tmp_path / "p.json"
    run_experiment("msync", "crash_restart", 8, 40, json_path=str(p),
                   **_run_kwargs(tmp_path))

    ck_b = tmp_path / "ck_b"
    orig = runner.simulate_batch
    calls = {"n": 0}

    def kill_on_third(*args, **kwargs):
        calls["n"] += 1
        if calls["n"] >= 3:
            raise KeyboardInterrupt("simulated kill")
        return orig(*args, **kwargs)

    monkeypatch.setattr(runner, "simulate_batch", kill_on_third)
    with pytest.raises(KeyboardInterrupt):
        run_experiment("msync", "crash_restart", 8, 40,
                       checkpoint_dir=str(ck_b), **_run_kwargs(tmp_path))
    monkeypatch.setattr(runner, "simulate_batch", orig)

    done = sorted(f.name for f in ck_b.glob("point-*.json"))
    assert done == ["point-00000.json", "point-00001.json"]

    b = tmp_path / "b.json"
    run_experiment("msync", "crash_restart", 8, 40, json_path=str(b),
                   checkpoint_dir=str(ck_b), resume=True,
                   **_run_kwargs(tmp_path))
    assert a.read_bytes() == b.read_bytes()
    assert a.read_bytes() == p.read_bytes()


def test_resume_skips_completed_points(tmp_path, monkeypatch):
    import repro.exp.runner as runner

    ck = tmp_path / "ck"
    run_experiment("msync", "crash_restart", 8, 40,
                   checkpoint_dir=str(ck), **_run_kwargs(tmp_path))
    monkeypatch.setattr(runner, "simulate_batch",
                        lambda *a, **k: (_ for _ in ()).throw(
                            AssertionError("must not recompute")))
    res = run_experiment("msync", "crash_restart", 8, 40,
                         checkpoint_dir=str(ck), resume=True,
                         **_run_kwargs(tmp_path))
    assert len(res.rows) == 3


def test_resume_refuses_mismatched_manifest(tmp_path):
    ck = tmp_path / "ck"
    run_experiment("msync", "crash_restart", 8, 40,
                   checkpoint_dir=str(ck), **_run_kwargs(tmp_path))
    with pytest.raises(ValueError, match="manifest mismatch"):
        run_experiment("msync", "crash_restart", 8, 50,
                       checkpoint_dir=str(ck), resume=True,
                       **_run_kwargs(tmp_path))


def test_trace_dict_round_trip():
    tr = Trace(times=np.array([0.5, 1.5]), values=np.array([3.0, np.nan]),
               grad_norms=np.array([9.0, 1.0]), iterations=2,
               total_time=1.5, gradients_used=4, gradients_computed=5,
               x_final=np.array([0.1, -0.2]))
    rt = Trace.from_dict(json.loads(json.dumps(tr.as_dict())))
    np.testing.assert_array_equal(rt.times, tr.times)
    np.testing.assert_array_equal(rt.grad_norms, tr.grad_norms)
    assert np.isnan(rt.values[1]) and rt.values[0] == 3.0
    assert rt.total_time == tr.total_time
    np.testing.assert_array_equal(rt.x_final, tr.x_final)
    assert rt.discard_fraction == tr.discard_fraction


# ------------------------------------------------------------- atomic writes
def test_atomic_write_json_no_tmp_left(tmp_path):
    out = tmp_path / "artifact.json"
    atomic_write_json(str(out), {"a": [1.25, "x"]})
    assert json.loads(out.read_text()) == {"a": [1.25, "x"]}
    assert list(tmp_path.glob("*.tmp")) == []
    # overwrite keeps the old file intact until the rename
    atomic_write_json(str(out), {"b": 2})
    assert json.loads(out.read_text()) == {"b": 2}


def test_atomic_write_failure_preserves_previous_artifact(tmp_path):
    out = tmp_path / "artifact.json"
    atomic_write_json(str(out), {"good": True})
    with pytest.raises(TypeError):
        atomic_write_json(str(out), {"bad": object()})   # not serializable
    assert json.loads(out.read_text()) == {"good": True}


# ------------------------------------------------- cost-constants warning
def test_load_cost_constants_warns_once_on_bad_file(tmp_path):
    bad = tmp_path / "calib.json"
    bad.write_text("{not json")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        merged = load_cost_constants(str(bad), apply=False)
    msgs = [w for w in rec if issubclass(w.category, UserWarning)]
    assert len(msgs) == 1
    assert str(bad) in str(msgs[0].message)
    assert "JSONDecodeError" in str(msgs[0].message) \
        or "ValueError" in str(msgs[0].message)
    assert merged["np_elem"] > 0                 # defaults still served

    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        load_cost_constants(str(tmp_path / "absent.json"), apply=False)
    assert any("absent.json" in str(w.message) for w in rec)


def test_load_cost_constants_warning_is_once_per_path(tmp_path):
    bad = tmp_path / "stale.json"
    bad.write_text("{not json")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        load_cost_constants(str(bad), apply=False)
        load_cost_constants(str(bad), apply=False)    # memoized: silent
        load_cost_constants(str(bad), apply=False)
    msgs = [w for w in rec if issubclass(w.category, UserWarning)]
    assert len(msgs) == 1, "same stale path must warn exactly once"
    # a DIFFERENT unreadable path still gets its own warning
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        load_cost_constants(str(tmp_path / "other.json"), apply=False)
    assert any("other.json" in str(w.message) for w in rec)


def test_load_cost_constants_rejects_non_object_json(tmp_path):
    arr = tmp_path / "array.json"
    arr.write_text("[1.0, 2.0, 3.0]")            # valid JSON, wrong shape
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        merged = load_cost_constants(str(arr), apply=False)
    msgs = [w for w in rec if issubclass(w.category, UserWarning)]
    assert len(msgs) == 1
    assert "ValueError" in str(msgs[0].message)
    assert str(arr) in str(msgs[0].message)
    assert merged["np_elem"] > 0                 # defaults still served


# ------------------------------------------------------ ROB001/ROB002 rules
from repro.analysis import analyze, load_module  # noqa: E402
from repro.analysis.robustness import run_robustness_pass  # noqa: E402


def _mod(tmp_path, src, name="snippet.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(src))
    return load_module(p, rel=name)


def _rules(findings):
    return sorted(f.rule for f in findings)


def test_rob001_flags_bare_and_swallowed_excepts(tmp_path):
    mod = _mod(tmp_path, """
        def a():
            try:
                risky()
            except:
                pass

        def b():
            try:
                risky()
            except Exception:
                pass
    """)
    assert _rules(run_robustness_pass(mod)) == ["ROB001", "ROB001"]


def test_rob001_good_twins_stay_silent(tmp_path):
    mod = _mod(tmp_path, """
        def ladder(run, record):
            try:
                return run()
            except Exception as exc:       # handled: recorded, rethrown
                record.append(type(exc).__name__)
                raise

        def narrow():
            try:
                risky()
            except ValueError:
                pass

        def pragma_ok():
            try:
                risky()
            except Exception:  # repcheck: ignore[ROB001]
                pass
    """)
    assert _rules(run_robustness_pass(mod)) == []


def test_rob002_flags_nonatomic_json_dump(tmp_path):
    mod = _mod(tmp_path, """
        import json

        def write(path, obj):
            with open(path, "w") as fh:
                json.dump(obj, fh, indent=2)
    """)
    assert _rules(run_robustness_pass(mod)) == ["ROB002"]


def test_rob002_atomic_pattern_and_reads_stay_silent(tmp_path):
    mod = _mod(tmp_path, """
        import json
        import os

        def atomic(path, obj):
            tmp = path + ".tmp"
            with open(tmp, "w") as fh:
                json.dump(obj, fh)
            os.replace(tmp, path)

        def read(path):
            with open(path) as fh:
                return json.load(fh)

        def text_write(path, s):
            with open(path, "w") as fh:
                fh.write(s)
    """)
    assert _rules(run_robustness_pass(mod)) == []


def test_rob_scope_gating(tmp_path):
    src = """
        import json

        def f(path, obj):
            try:
                g()
            except Exception:
                pass
            with open(path, "w") as fh:
                json.dump(obj, fh)
    """
    mod = _mod(tmp_path, src)
    assert _rules(run_robustness_pass(mod, exceptions=True, io=False)) \
        == ["ROB001"]
    assert _rules(run_robustness_pass(mod, exceptions=False, io=True)) \
        == ["ROB002"]


def test_live_tree_is_rob_clean():
    """The shipped tree carries no ROB findings (CI repcheck lane)."""
    findings = analyze(ROOT, registry=False)
    assert [f for f in findings if f.rule.startswith("ROB")] == []
